//! Cross-round straggler carry-over: deadline rounds with carry on vs
//! off.
//!
//! Runs the same compressed FedAvg workload twice over a straggler-heavy
//! fleet under a calibrated deadline — once discarding every late upload
//! (the classic semi-synchronous rule) and once carrying them into the
//! next round with staleness-discounted weights
//! (`CarryPolicy::CarryDiscounted`, see `coordinator/session.rs`).  Per
//! round it prints who folded (fresh + carried) and what left for the
//! future; the summary compares rounds-to-target-loss and total folded
//! updates.
//!
//! Works out of the box without PJRT artifacts: it falls back to the
//! engine-free fake-train mode on the synthetic manifest, where
//! carry-over counts, participation and timing are real but loss is not
//! measured.  CI runs it in that mode on every PR.
//!
//! ```bash
//! cargo run --release --example carryover \
//!     [-- --clients 256 --rounds 8 --frac 0.2 --slowdown 8 \
//!         --lambda 0.5 --max-age 2 --target-loss 1.0]
//! ```

use hcfl::compression::Scheme;
use hcfl::coordinator::clock::{calibrated_deadline, RoundPolicy};
use hcfl::network::DevicePreset;
use hcfl::prelude::*;
use hcfl::util::cli::Args;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let clients = args.usize_or("clients", 256)?;
    let rounds = args.usize_or("rounds", 8)?;
    let frac = args.f64_or("frac", 0.2)?;
    let slowdown = args.f64_or("slowdown", 8.0)?;
    let lambda = args.f64_or("lambda", 0.5)?;
    let max_age = args.usize_or("max-age", 2)?;
    let target_loss = args.f64_or("target-loss", 1.0)?;
    let client_threads = args.usize_or("client-threads", 4)?;
    let ratio = args.usize_or("ratio", 32)?;

    let artifacts = args.str_or("artifacts", "artifacts");
    let have_engine = hcfl::runtime::pjrt_enabled()
        && std::path::Path::new(artifacts).join("manifest.json").is_file();
    let engine = if have_engine {
        Engine::from_artifacts(artifacts, 4)?
    } else {
        println!("(no PJRT artifacts: running the pipeline in fake-train mode)");
        Engine::with_manifest(Manifest::synthetic(), 4)?
    };
    let scheme = if have_engine {
        Scheme::Hcfl { ratio }
    } else {
        Scheme::TopK { keep: 0.2 }
    };

    let base_cfg = {
        let mut cfg = ExperimentConfig::mnist(scheme, rounds);
        cfg.n_clients = clients;
        cfg.data.n_clients = clients;
        cfg.participation = 0.25;
        cfg.local_epochs = 1;
        cfg.client_threads = client_threads;
        cfg.data.lazy_shards = clients > 512;
        cfg.scenario.devices = DevicePreset::Stragglers { frac, slowdown };
        if !have_engine {
            cfg.model = "fake".into();
            cfg.fake_train = true;
            cfg.batch = 16;
            cfg.data.per_client = 64;
            cfg.data.test_n = 64;
            cfg.data.server_n = 16;
        }
        cfg
    };

    println!(
        "{} with K={clients} (m={}), {:.0}% of devices {slowdown}x stragglers",
        scheme.label(),
        base_cfg.m(),
        frac * 100.0
    );

    // One synchronous probe round fixes the deadline's absolute time
    // scale (modelled compute depends on the host's measured speed).
    let mut probe_sim = Simulation::new(&engine, base_cfg.clone())?;
    let probe = probe_sim.run_round(1)?;
    let t_max = calibrated_deadline(&base_cfg.link, &probe, 3.0);
    println!(
        "fleet: {}/{clients} stragglers; synchronous makespan {:.2}s -> deadline {:.2}s\n",
        probe_sim.fleet().n_slow(),
        probe.makespan_s,
        t_max
    );

    let arms = [
        ("carry off", CarryPolicy::Discard),
        (
            "carry on",
            CarryPolicy::CarryDiscounted {
                lambda,
                max_age_rounds: max_age,
            },
        ),
    ];
    for (name, carry) in arms {
        let mut cfg = base_cfg.clone();
        cfg.scenario.policy = RoundPolicy::Deadline { t_max_s: t_max };
        cfg.scenario.carry = carry;
        println!("== {name}: {} ==", cfg.scenario.label());
        let mut sim = Simulation::new(&engine, cfg)?;
        let mut records = Vec::with_capacity(rounds);
        for t in 1..=rounds {
            let rec = sim.run_round(t)?;
            println!(
                "  round {t}: loss {:.4}  acc {:.3}  folded {}+{} of {}  cut {}  \
                 carried out {}",
                rec.loss,
                rec.accuracy,
                rec.completed,
                rec.carried_in,
                rec.selected,
                rec.stragglers,
                rec.carried_out,
            );
            records.push(rec);
        }
        let report = hcfl::metrics::RunReport {
            scheme: sim.compressor().name(),
            model: sim.cfg.model.clone(),
            rounds: records,
        };
        let to_target = report
            .rounds
            .iter()
            .find(|r| r.loss > 0.0 && r.loss <= target_loss)
            .map(|r| r.round);
        let reached = if !have_engine {
            "n/a (fake-train mode measures traffic, not learning)".to_string()
        } else {
            match to_target {
                Some(t) => format!("{t}"),
                None => format!("not reached in {rounds} rounds"),
            }
        };
        println!(
            "  => rounds to loss <= {target_loss}: {reached}; \
             folded {} fresh + {} carried of {} cut ({} expired, {} still in \
             flight); modelled run time {:.2}s\n",
            report.rounds.iter().map(|r| r.completed).sum::<usize>(),
            report.total_carried_in(),
            report.total_stragglers(),
            report.total_carried_expired(),
            sim.carry_pending(),
            report.total_makespan(),
        );
    }
    Ok(())
}
