//! Dataset-segmentation ablation on the 5-CNN (paper §III-C3, §VI-A).
//!
//! The paper splits the 5-CNN's dense parameters 8-ways so each HCFL
//! compressor sees a lower-entropy distribution.  This driver runs the
//! same HCFL ratio with dense_parts in {1, 8} and reports reconstruction
//! error and accuracy, demonstrating why the segmentation exists.
//!
//! ```bash
//! cargo run --release --example emnist_segmentation [-- --rounds 4]
//! ```

use hcfl::compression::Scheme;
use hcfl::prelude::*;
use hcfl::util::cli::Args;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let rounds = args.usize_or("rounds", 4)?;
    let ratio = args.usize_or("ratio", 8)?;
    let workers = args.usize_or("workers", 6)?;
    let engine = Engine::from_artifacts(args.str_or("artifacts", "artifacts"), workers)?;

    println!("5-CNN / EMNIST segmentation ablation at HCFL 1:{ratio}");
    for parts in [1usize, 8] {
        let mut cfg = ExperimentConfig::emnist(Scheme::Hcfl { ratio }, rounds);
        cfg.dense_parts = parts;
        cfg.local_epochs = args.usize_or("epochs", 1)?;
        cfg.engine_workers = workers;
        let mut sim = Simulation::new(&engine, cfg)?;
        sim.verbose = true;
        let report = sim.run()?;
        println!(
            "dense_parts={parts}: recon MSE {:.4e}, final acc {:.4}, upload {:.2} MB",
            report.mean_recon_mse(),
            report.final_accuracy(),
            report.total_up_bytes() as f64 / 1e6
        );
    }
    Ok(())
}
