//! Serving quickstart: a round server and a swarm client on localhost.
//!
//! Spins up the wire transport end to end for a small fleet — the
//! server binds an ephemeral port and owns the `FlSession`; the swarm
//! dials in with a handful of worker connections and replays the
//! device fleet (seeded fake training + codec encode per assignment) —
//! then re-runs the identical config through the in-process
//! `Simulation` and checks the two paths agree bit for bit.  This is
//! the `examples/`-sized version of the K=10k acceptance test in
//! `tests/transport_loopback.rs`; the standalone binaries (`hcfl-server`
//! / `hcfl-swarm`) run the same protocol across real machines.
//!
//! Engine-free (synthetic manifest, fake training), so it works with no
//! PJRT artifacts; CI smoke-runs it on every PR.
//!
//! ```bash
//! cargo run --release --example loopback_round \
//!     [-- --clients 64 --rounds 3 --workers 4 --keep 0.2 --seed 42]
//! ```
//!
//! Expected output (exact byte/round numbers vary with the flags, the
//! bit-identical verdict must not):
//!
//! ```text
//! serving 3 rounds to 4 swarm connections over 127.0.0.1:<port>
//! round   1: 64/64 aggregated, 0 dropped, up 23.0 KB
//! round   2: 64/64 aggregated, 0 dropped, up 23.0 KB
//! round   3: 64/64 aggregated, 0 dropped, up 23.0 KB
//! swarm sent 192 updates, 1016.1 KB on the wire
//! tcp and in-process paths: bit-identical (d=802)
//! ```

use hcfl::compression::Scheme;
use hcfl::prelude::*;
use hcfl::transport::{demo_config, run_loopback};
use hcfl::util::cli::Args;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let clients = args.usize_or("clients", 64)?;
    let rounds = args.usize_or("rounds", 3)?;
    let workers = args.usize_or("workers", 4)?;
    let keep = args.f64_or("keep", 0.2)?;
    let seed = args.u64_or("seed", 42)?;
    let time_scale = args.f64_or("time-scale", 0.0)?;

    let cfg = demo_config(Scheme::TopK { keep }, clients, rounds, seed);
    let manifest = Manifest::synthetic();

    println!("serving {rounds} rounds to {workers} swarm connections over 127.0.0.1:<port>");
    let run = run_loopback(&manifest, &cfg, workers, time_scale)?;
    for rec in &run.records {
        println!(
            "round {:>3}: {}/{} aggregated, {} dropped, up {:.1} KB",
            rec.round,
            rec.completed,
            rec.selected,
            rec.dropped,
            rec.up_bytes as f64 / 1e3,
        );
    }
    println!(
        "swarm sent {} updates, {:.1} KB on the wire",
        run.swarm.updates_sent,
        run.swarm.bytes_sent as f64 / 1e3,
    );

    // The whole point of the transport: same bits as the simulator.
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers)?;
    let mut sim = Simulation::new(&engine, cfg.clone())?;
    for t in 1..=cfg.rounds {
        sim.run_round(t)?;
    }
    if sim.global() == run.global.as_slice() {
        println!(
            "tcp and in-process paths: bit-identical (d={})",
            run.global.len()
        );
        Ok(())
    } else {
        Err(HcflError::Config(
            "tcp and in-process paths diverged".into(),
        ))
    }
}
