//! End-to-end driver (the EXPERIMENTS.md headline run).
//!
//! Full system on a real small workload: 100 simulated IoT clients with
//! 600-sample synthetic-MNIST shards train LeNet-5 under FedAvg, once
//! uncompressed and once with HCFL 1:16 (paper Algorithm 1 end to end:
//! pre-model phase, AE training, per-round encode/decode, FIFO running
//! aggregation).  Prints both loss curves and the communication ledger.
//!
//! ```bash
//! cargo run --release --example mnist_e2e [-- --rounds 15 --workers 6]
//! ```

use hcfl::compression::Scheme;
use hcfl::prelude::*;
use hcfl::util::cli::Args;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let rounds = args.usize_or("rounds", 12)?;
    let workers = args.usize_or("workers", 6)?;
    let ratio = args.usize_or("ratio", 16)?;
    let engine = Engine::from_artifacts(args.str_or("artifacts", "artifacts"), workers)?;

    let mut reports = Vec::new();
    for scheme in [Scheme::Fedavg, Scheme::Hcfl { ratio }] {
        let mut cfg = ExperimentConfig::mnist(scheme, rounds);
        cfg.local_epochs = args.usize_or("epochs", 2)?;
        cfg.engine_workers = workers;
        eprintln!("=== {} ===", scheme.label());
        let mut sim = Simulation::new(&engine, cfg)?;
        sim.verbose = true;
        let report = sim.run()?;
        std::fs::create_dir_all("results")?;
        let path = format!(
            "results/mnist_e2e_{}.csv",
            report.scheme.to_lowercase().replace([' ', ':'], "_")
        );
        report.write_csv(&path)?;
        reports.push(report);
    }

    let (base, hcfl) = (&reports[0], &reports[1]);
    println!("\n== end-to-end summary (LeNet-5, {} clients, {} rounds) ==", 100, rounds);
    println!("loss curve (round: FedAvg / HCFL):");
    for (a, b) in base.rounds.iter().zip(&hcfl.rounds) {
        println!(
            "  {:>3}: {:.4} / {:.4}   acc {:.4} / {:.4}",
            a.round, a.loss, b.loss, a.accuracy, b.accuracy
        );
    }
    println!(
        "\ncommunication: FedAvg {:.2} MB vs HCFL {:.2} MB (x{:.2} reduction)",
        base.total_up_bytes() as f64 / 1e6,
        hcfl.total_up_bytes() as f64 / 1e6,
        base.total_up_bytes() as f64 / hcfl.total_up_bytes() as f64
    );
    println!(
        "accuracy delta at final round: {:+.4} (paper claims <3% loss at high ratios)",
        hcfl.final_accuracy() - base.final_accuracy()
    );
    println!(
        "mean HCFL reconstruction error: {:.3e}",
        hcfl.mean_recon_mse()
    );
    Ok(())
}
