//! Non-IID client shards: label skew through the full round pipeline.
//!
//! First shows the data-level effect — per-shard label entropy under
//! IID, Dirichlet(alpha) and McMahan label-shard partitions — then runs
//! the same FedAvg workload per partition × aggregator so the
//! survivor-bias / weighting interaction is visible end to end.
//!
//! Works out of the box without PJRT artifacts: it falls back to the
//! engine-free fake-train mode on the synthetic manifest (traffic,
//! participation and timing are real; accuracy is only meaningful with
//! the real engine).  CI runs it in that mode on every PR.
//!
//! ```bash
//! cargo run --release --example noniid \
//!     [-- --clients 24 --rounds 3 --alpha 0.3 --shards-per-client 2]
//! ```

use hcfl::compression::Scheme;
use hcfl::data::{label_entropy, synthetic, DataSpec, Partition};
use hcfl::prelude::*;
use hcfl::util::cli::Args;
use hcfl::util::stats;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let clients = args.usize_or("clients", 24)?;
    let rounds = args.usize_or("rounds", 3)?;
    let alpha = args.f64_or("alpha", 0.3)?;
    let spc = args.usize_or("shards-per-client", 2)?;
    let client_threads = args.usize_or("client-threads", 4)?;

    let partitions = [
        ("iid", Partition::Iid),
        ("dirichlet", Partition::Dirichlet { alpha }),
        (
            "label-shards",
            Partition::LabelShards {
                shards_per_client: spc,
            },
        ),
    ];

    // ---- data level: per-shard label entropy ---------------------------
    println!("per-shard label entropy (nats; ln(10) ≈ 2.303 = balanced), K={clients}:");
    for (name, partition) in &partitions {
        let mut spec = DataSpec::mnist(clients);
        spec.per_client = 120;
        spec.partition = partition.clone();
        let data = synthetic(&spec, 7);
        let ents: Vec<f64> = (0..clients)
            .map(|k| label_entropy(&data.shard(k).y, spec.classes))
            .collect();
        println!(
            "  {name:<13} mean {:.3}  min {:.3}  max {:.3}",
            stats::mean(&ents),
            ents.iter().cloned().fold(f64::INFINITY, f64::min),
            ents.iter().cloned().fold(0.0f64, f64::max),
        );
    }

    // ---- system level: partitions through the round pipeline -----------
    let artifacts = args.str_or("artifacts", "artifacts");
    let have_engine = hcfl::runtime::pjrt_enabled()
        && std::path::Path::new(artifacts).join("manifest.json").is_file();
    let engine = if have_engine {
        Engine::from_artifacts(artifacts, 4)?
    } else {
        println!("\n(no PJRT artifacts: running the pipeline in fake-train mode)");
        Engine::with_manifest(Manifest::synthetic(), 4)?
    };

    println!("\nFedAvg, C=0.25, {rounds} rounds, partition × aggregator:");
    for (name, partition) in &partitions {
        for agg in [AggregatorKind::UniformMean, AggregatorKind::SampleWeighted] {
            let mut cfg = ExperimentConfig::mnist(Scheme::Fedavg, rounds);
            cfg.n_clients = clients;
            cfg.data.n_clients = clients;
            cfg.participation = 0.25;
            cfg.local_epochs = 1;
            cfg.client_threads = client_threads;
            cfg.data.partition = partition.clone();
            // unequal shard sizes, so SampleWeighted differs from the
            // uniform mean (with equal n_k they are identical)
            cfg.data.size_skew = 0.3;
            cfg.scenario.aggregator = agg.clone();
            if !have_engine {
                cfg.model = "fake".into();
                cfg.fake_train = true;
                cfg.batch = 16;
                cfg.data.per_client = 64;
                cfg.data.test_n = 64;
                cfg.data.server_n = 16;
            }
            let mut sim = Simulation::new(&engine, cfg)?;
            let report = sim.run()?;
            println!(
                "  {name:<13} {:<16} final acc {:.4}  aggregated {:.0}%  up {:.1} KB",
                agg.label(),
                report.final_accuracy(),
                report.mean_participation() * 100.0,
                report.total_up_bytes() as f64 / 1e3,
            );
        }
    }
    Ok(())
}
