//! Quickstart: the smallest end-to-end HCFL run.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Builds the engine from the AOT artifacts, trains the HCFL autoencoders
//! on the server's pre-model snapshots, then runs a few FedAvg rounds
//! with compressed uplinks/downlinks and prints the learning curve.

use hcfl::prelude::*;

fn main() -> hcfl::error::Result<()> {
    let engine = Engine::from_artifacts("artifacts", 2)?;
    let cfg = ExperimentConfig::quickstart();
    println!(
        "quickstart: {} on {}, {} clients, {} rounds",
        cfg.scheme.label(),
        cfg.model,
        cfg.n_clients,
        cfg.rounds
    );
    let mut sim = Simulation::new(&engine, cfg)?;
    sim.verbose = true;
    let report = sim.run()?;
    println!(
        "done: final accuracy {:.4}, mean reconstruction error {:.3e}, uploaded {:.2} MB",
        report.final_accuracy(),
        report.mean_recon_mse(),
        report.total_up_bytes() as f64 / 1e6
    );
    Ok(())
}
