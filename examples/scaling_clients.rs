//! Client-scaling sweep (paper Fig. 10 / Theorem 1 in action).
//!
//! Runs HCFL-compressed FedAvg with a growing client count and shows that
//! more clients average away the compressor's reconstruction noise: the
//! accuracy curve converges faster and its tail variance shrinks.
//!
//! ```bash
//! cargo run --release --example scaling_clients [-- --clients 5,20,50]
//! ```

use hcfl::compression::Scheme;
use hcfl::prelude::*;
use hcfl::util::cli::Args;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let ks = args.usize_list_or("clients", &[5, 20, 50])?;
    let rounds = args.usize_or("rounds", 6)?;
    let ratio = args.usize_or("ratio", 16)?;
    let workers = args.usize_or("workers", 6)?;
    let engine = Engine::from_artifacts(args.str_or("artifacts", "artifacts"), workers)?;

    println!("client scaling at HCFL 1:{ratio} ({rounds} rounds, full participation)");
    for &k in &ks {
        let mut cfg = ExperimentConfig::mnist(Scheme::Hcfl { ratio }, rounds);
        cfg.n_clients = k;
        cfg.data.n_clients = k;
        cfg.participation = 1.0;
        cfg.local_epochs = 1;
        cfg.engine_workers = workers;
        let mut sim = Simulation::new(&engine, cfg)?;
        let report = sim.run()?;
        let accs: Vec<String> = report
            .rounds
            .iter()
            .map(|r| format!("{:.3}", r.accuracy))
            .collect();
        println!(
            "K={k:>3}: acc per round [{}], tail stddev {:.4}",
            accs.join(", "),
            report.accuracy_stddev_tail(3)
        );
    }
    Ok(())
}
