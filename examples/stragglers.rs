//! Stragglers, dropouts, and semi-synchronous rounds.
//!
//! Runs the same HCFL-compressed FedAvg workload over a heterogeneous
//! IoT fleet (a fraction of devices 8x slower in compute and uplink)
//! under the three round policies — plus the deadline policy with
//! cross-round carry-over — and prints, per round, who made it into the
//! aggregate: the synchronous round waits out every straggler (huge
//! modelled makespan), the deadline and fastest-m rounds cut them and
//! keep the makespan near the fast cohort's arrival, and the carry arm
//! folds the cut uploads into the next round with staleness-discounted
//! weights instead of wasting them (see `examples/carryover.rs` for the
//! dedicated study).
//!
//! ```bash
//! cargo run --release --example stragglers \
//!     [-- --frac 0.3 --slowdown 8 --clients 10 --rounds 4 --scheme hcfl]
//! ```

use hcfl::compression::Scheme;
use hcfl::coordinator::clock::{calibrated_deadline, RoundPolicy};
use hcfl::network::DevicePreset;
use hcfl::prelude::*;
use hcfl::util::cli::Args;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let frac = args.f64_or("frac", 0.3)?;
    let slowdown = args.f64_or("slowdown", 8.0)?;
    let clients = args.usize_or("clients", 10)?;
    let rounds = args.usize_or("rounds", 4)?;
    let ratio = args.usize_or("ratio", 32)?;
    let workers = args.usize_or("workers", 4)?;
    let scheme = match args.str_or("scheme", "hcfl") {
        "fedavg" => Scheme::Fedavg,
        _ => Scheme::Hcfl { ratio },
    };
    let engine = Engine::from_artifacts(args.str_or("artifacts", "artifacts"), workers)?;

    let base_cfg = {
        let mut cfg = ExperimentConfig::mnist(scheme, rounds);
        cfg.n_clients = clients;
        cfg.data.n_clients = clients;
        cfg.participation = 1.0;
        cfg.local_epochs = 1;
        cfg.engine_workers = workers;
        cfg.scenario.devices = DevicePreset::Stragglers { frac, slowdown };
        cfg
    };

    println!(
        "{} with {clients} clients, {:.0}% of them {slowdown}x stragglers",
        scheme.label(),
        frac * 100.0
    );

    // Calibration: one synchronous round measures the reference device's
    // compute and air time (the deadline needs an absolute time scale,
    // and modelled compute depends on this host's measured speed).  The
    // deadline is broadcast + 3x the reference compute+uplink, which
    // keeps every reference device and cuts anything slowed >3x —
    // independent of how many stragglers the sampled fleet contains.
    let mut probe_sim = Simulation::new(&engine, base_cfg.clone())?;
    let n_slow = probe_sim.fleet().n_slow();
    let probe = probe_sim.run_round(1)?;
    let t_max = calibrated_deadline(&base_cfg.link, &probe, 3.0);
    println!(
        "fleet: {n_slow}/{clients} stragglers; synchronous makespan {:.2}s -> deadline {:.2}s\n",
        probe.makespan_s, t_max
    );

    let fast = clients - n_slow;
    let policies = [
        ("synchronous", RoundPolicy::Synchronous, CarryPolicy::Discard),
        (
            "deadline",
            RoundPolicy::Deadline { t_max_s: t_max },
            CarryPolicy::Discard,
        ),
        (
            "fastest-m",
            RoundPolicy::FastestM { m: fast.max(1) },
            CarryPolicy::Discard,
        ),
        (
            "deadline + carry",
            RoundPolicy::Deadline { t_max_s: t_max },
            CarryPolicy::CarryDiscounted {
                lambda: 0.5,
                max_age_rounds: 2,
            },
        ),
    ];

    for (name, policy, carry) in policies {
        let mut cfg = base_cfg.clone();
        cfg.scenario.policy = policy;
        cfg.scenario.carry = carry;
        println!("== {name}: {} ==", cfg.scenario.label());
        let mut sim = Simulation::new(&engine, cfg)?;
        let mut report_rounds = Vec::with_capacity(rounds);
        for t in 1..=rounds {
            let rec = sim.run_round(t)?;
            println!(
                "  round {t}: acc {:.3}  aggregated {}+{} of {}  cut {} stragglers  \
                 makespan {:>7.2}s  up {:.0} KB",
                rec.accuracy,
                rec.completed,
                rec.carried_in,
                rec.selected,
                rec.stragglers,
                rec.makespan_s,
                rec.up_bytes as f64 / 1e3,
            );
            report_rounds.push(rec);
        }
        let total_makespan: f64 = report_rounds.iter().map(|r| r.makespan_s).sum();
        let total_cut: usize = report_rounds.iter().map(|r| r.stragglers).sum();
        let total_carried: usize = report_rounds.iter().map(|r| r.carried_in).sum();
        println!(
            "  => final acc {:.3}, modelled run time {:.2}s, {total_cut} straggler uploads cut, \
             {total_carried} carried into later rounds\n",
            report_rounds.last().map(|r| r.accuracy).unwrap_or(0.0),
            total_makespan
        );
    }
    Ok(())
}
