//! Theorem 1 sanity check against the live pipeline.
//!
//! Trains K client models, pushes each through the HCFL codec, and
//! compares the measured aggregated-deviation probability with the
//! `2/(Kα)²·L(w)` bound of eq. (10) — including the paper's worked
//! example (K=10000, α=0.01, L=2.5 → 0.0005).
//!
//! ```bash
//! cargo run --release --example theory_check
//! ```

use hcfl::compression::Scheme;
use hcfl::coordinator::session::build_compressor;
use hcfl::data::synthetic;
use hcfl::fl::LocalTrainer;
use hcfl::model::init_flat;
use hcfl::prelude::*;
use hcfl::theory::{empirical_deviation_prob, paper_example, theorem1_bound};
use hcfl::util::cli::Args;
use hcfl::util::rng::Rng;

fn main() -> hcfl::error::Result<()> {
    let args = Args::from_env();
    let k_max = args.usize_or("clients", 12)?;
    let alpha = args.f64_or("alpha", 0.002)?;
    let engine = Engine::from_artifacts(args.str_or("artifacts", "artifacts"), 4)?;

    let mut cfg = ExperimentConfig::mnist(Scheme::Hcfl { ratio: 16 }, 1);
    cfg.n_clients = k_max;
    cfg.data.n_clients = k_max;
    let data = synthetic(&cfg.data, cfg.seed);
    let trainer = LocalTrainer::new(&engine, &cfg.model)?;
    let mut rng = Rng::new(cfg.seed);
    let global = init_flat(&trainer.model.layers, &mut rng);
    let compressor = build_compressor(&engine, &cfg, &data, &global)?;

    let mut clean = Vec::new();
    let mut noisy = Vec::new();
    let mut l_w = 0.0;
    for k in 0..k_max {
        let out = trainer.train(&global, &data.shard(k), 1, 64, 0.05, &mut rng, k % 4)?;
        // Mirror the run pipeline: delta-encode against the broadcast.
        let delta: Vec<f32> = out.params.iter().zip(&global).map(|(w, g)| w - g).collect();
        let upd = compressor.compress(&delta, k % 4)?;
        let mut rec = compressor.decompress(upd, trainer.model.d, k % 4)?;
        for (v, g) in rec.iter_mut().zip(&global) {
            *v += g;
        }
        l_w += out
            .params
            .iter()
            .zip(&rec)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / trainer.model.d as f64;
        clean.push(out.params);
        noisy.push(rec);
    }
    l_w /= k_max as f64;

    println!("measured L(w) = {l_w:.4e}, α = {alpha}");
    for k in [2, k_max / 2, k_max] {
        let bound = theorem1_bound(l_w, k, alpha);
        let meas = empirical_deviation_prob(&clean[..k], &noisy[..k], alpha);
        let ok = meas <= bound + 1e-9;
        println!(
            "K={k:>3}: bound {bound:.4e}  measured {meas:.4e}  {}",
            if ok { "OK (within bound)" } else { "VIOLATION" }
        );
    }
    println!("paper worked example bound: {:.4e} (expect 5.0e-4)", paper_example());
    Ok(())
}
