use hcfl::prelude::*;
use hcfl::util::rng::Rng;
use std::time::Instant;

fn main() {
    let eng = Engine::from_artifacts("artifacts", 1).unwrap();
    let mani = eng.manifest().clone();
    let m = mani.model("lenet").unwrap().clone();
    let mut rng = Rng::new(0);
    let params: Vec<f32> = (0..m.d).map(|_| rng.normal() * 0.05).collect();

    // train_step b64
    let x: Vec<f32> = (0..64*784).map(|_| rng.uniform(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..64).map(|_| rng.below(10) as i32).collect();
    let t0 = Instant::now();
    let _ = eng.call("lenet_train_step_b64", vec![
        TensorValue::vec_f32(params.clone()),
        TensorValue::f32(x.clone(), vec![64, 784]).unwrap(),
        TensorValue::i32(y.clone(), vec![64]).unwrap(),
        TensorValue::scalar_f32(0.05),
    ]).unwrap();
    println!("train_step_b64 first (compile+run): {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..3 {
        let _ = eng.call("lenet_train_step_b64", vec![
            TensorValue::vec_f32(params.clone()),
            TensorValue::f32(x.clone(), vec![64, 784]).unwrap(),
            TensorValue::i32(y.clone(), vec![64]).unwrap(),
            TensorValue::scalar_f32(0.05),
        ]).unwrap();
    }
    println!("train_step_b64 warm x3: {:?}", t0.elapsed());

    // ae train c1024 r8
    let ae = mani.autoencoder(1024, 8).unwrap().clone();
    let aep: Vec<f32> = (0..ae.d).map(|_| rng.normal() * 0.05).collect();
    let batch: Vec<f32> = (0..64*1024).map(|_| rng.normal() * 0.1).collect();
    let t0 = Instant::now();
    let _ = eng.call(&ae.train, vec![
        TensorValue::vec_f32(aep.clone()),
        TensorValue::f32(batch.clone(), vec![64, 1024]).unwrap(),
        TensorValue::scalar_f32(0.05),
    ]).unwrap();
    println!("ae_train first: {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..3 {
        let _ = eng.call(&ae.train, vec![
            TensorValue::vec_f32(aep.clone()),
            TensorValue::f32(batch.clone(), vec![64, 1024]).unwrap(),
            TensorValue::scalar_f32(0.05),
        ]).unwrap();
    }
    println!("ae_train warm x3: {:?}", t0.elapsed());

    // encode
    let w: Vec<f32> = (0..1024).map(|_| rng.normal()).collect();
    let t0 = Instant::now();
    let _ = eng.call(&ae.encode, vec![TensorValue::vec_f32(aep.clone()), TensorValue::vec_f32(w.clone())]).unwrap();
    println!("encode first: {:?}", t0.elapsed());
    let t0 = Instant::now();
    for _ in 0..10 {
        let _ = eng.call(&ae.encode, vec![TensorValue::vec_f32(aep.clone()), TensorValue::vec_f32(w.clone())]).unwrap();
    }
    println!("encode warm x10: {:?}", t0.elapsed());

    // epoch exec
    let xs: Vec<f32> = (0..9*64*784).map(|_| rng.uniform(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..9*64).map(|_| rng.below(10) as i32).collect();
    let t0 = Instant::now();
    let _ = eng.call("lenet_train_epoch_b64_n9", vec![
        TensorValue::vec_f32(params.clone()),
        TensorValue::f32(xs.clone(), vec![9, 64, 784]).unwrap(),
        TensorValue::i32(ys.clone(), vec![9, 64]).unwrap(),
        TensorValue::scalar_f32(0.05),
    ]).unwrap();
    println!("train_epoch first: {:?}", t0.elapsed());
    let t0 = Instant::now();
    let _ = eng.call("lenet_train_epoch_b64_n9", vec![
        TensorValue::vec_f32(params),
        TensorValue::f32(xs, vec![9, 64, 784]).unwrap(),
        TensorValue::i32(ys, vec![9, 64]).unwrap(),
        TensorValue::scalar_f32(0.05),
    ]).unwrap();
    println!("train_epoch warm x1: {:?}", t0.elapsed());
}
