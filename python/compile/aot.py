"""AOT compiler: lower every Layer-2 graph to HLO text + manifest.json.

Interchange format is HLO *text*, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from the ``python/`` directory)::

    python -m compile.aot --out ../artifacts

Artifacts:
    artifacts/<name>.hlo.txt   one per executable (see ``build_artifact_specs``)
    artifacts/manifest.json    input/output specs, layer tables, AE layouts
"""

import argparse
import json
import os
from dataclasses import dataclass, field
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train
from .models import autoencoder, five_cnn, lenet

# Compression configuration: one chunk size per weight segment (DESIGN.md §6),
# paper ratios 1:4 .. 1:32 (§VI-B).
CHUNKS = {"conv": 256, "dense": 1024}
RATIOS = [4, 8, 16, 32]
AE_TRAIN_BATCH = 64
EVAL_BATCH = 512
# Batched codec dispatch sizes (chunks per engine call).  The Rust codec
# greedily tiles a segment range with the largest size that fits and
# falls back to the per-chunk executable for the remainder; this ladder
# covers LeNet's ranges (11 conv / 41 dense chunks) in <= 3 calls each.
CODEC_BATCHES = [2, 8, 32]

# Per-model epoch geometry: shard_size / batch batches per local epoch.
MODELS = {
    "lenet": {
        "module": lenet,
        "train_batches": [10, 64, 600],  # 10/600 feed the Fig.12 B-sweep
        "epoch_batch": 64,
        "epoch_n_batches": 9,  # 600-sample MNIST shard
    },
    "fivecnn": {
        "module": five_cnn,
        "train_batches": [64],
        "epoch_batch": 64,
        "epoch_n_batches": 17,  # 1128-sample EMNIST shard
    },
}


def _spec(dtype: str, shape: Sequence[int]) -> dict:
    return {"dtype": dtype, "shape": list(shape)}


def _sds(dtype, shape):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


@dataclass
class Artifact:
    name: str
    fn: Callable
    inputs: List[dict]  # [{"dtype": "f32", "shape": [...]}]
    outputs: List[dict] = field(default_factory=list)  # filled by eval_shape

    def arg_structs(self):
        return [_sds(_DTYPES[i["dtype"]], i["shape"]) for i in self.inputs]


def _tuplize(fn: Callable) -> Callable:
    """Ensure the lowered function returns a flat tuple."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifact_specs() -> List[Artifact]:
    arts: List[Artifact] = []

    # ---- predictor models -------------------------------------------------
    for mname, cfg in MODELS.items():
        mod = cfg["module"]
        d = mod.layout().total
        for b in cfg["train_batches"]:
            arts.append(
                Artifact(
                    name=f"{mname}_train_step_b{b}",
                    fn=_tuplize(train.make_train_step(mod)),
                    inputs=[
                        _spec("f32", [d]),
                        _spec("f32", [b, mod.INPUT_DIM]),
                        _spec("i32", [b]),
                        _spec("f32", []),
                    ],
                )
            )
        eb, nb = cfg["epoch_batch"], cfg["epoch_n_batches"]
        arts.append(
            Artifact(
                name=f"{mname}_train_epoch_b{eb}_n{nb}",
                fn=_tuplize(train.make_train_epoch(mod, nb)),
                inputs=[
                    _spec("f32", [d]),
                    _spec("f32", [nb, eb, mod.INPUT_DIM]),
                    _spec("i32", [nb, eb]),
                    _spec("f32", []),
                ],
            )
        )
        arts.append(
            Artifact(
                name=f"{mname}_eval_b{EVAL_BATCH}",
                fn=_tuplize(train.make_eval(mod)),
                inputs=[
                    _spec("f32", [d]),
                    _spec("f32", [EVAL_BATCH, mod.INPUT_DIM]),
                    _spec("i32", [EVAL_BATCH]),
                ],
            )
        )

    # ---- HCFL autoencoders -------------------------------------------------
    for chunk in sorted(set(CHUNKS.values())):
        for ratio in RATIOS:
            dae = autoencoder.layout(chunk, ratio).total
            code = chunk // ratio
            key = f"ae_c{chunk}_r{ratio}"
            arts.append(
                Artifact(
                    name=f"{key}_encode",
                    fn=_tuplize(train.make_ae_encode(chunk, ratio)),
                    inputs=[_spec("f32", [dae]), _spec("f32", [chunk])],
                )
            )
            arts.append(
                Artifact(
                    name=f"{key}_decode",
                    fn=_tuplize(train.make_ae_decode(chunk, ratio)),
                    inputs=[
                        _spec("f32", [dae]),
                        _spec("f32", [code]),
                        _spec("f32", []),  # lo
                        _spec("f32", []),  # hi
                        _spec("f32", []),  # mu
                        _spec("f32", []),  # sd
                    ],
                )
            )
            arts.append(
                Artifact(
                    name=f"{key}_train_b{AE_TRAIN_BATCH}",
                    fn=_tuplize(train.make_ae_train(chunk, ratio)),
                    inputs=[
                        _spec("f32", [dae]),
                        _spec("f32", [AE_TRAIN_BATCH, chunk]),
                        _spec("f32", []),
                    ],
                )
            )
            for n in CODEC_BATCHES:
                arts.append(
                    Artifact(
                        name=f"{key}_encode_n{n}",
                        fn=_tuplize(train.make_ae_encode_batch(chunk, ratio)),
                        inputs=[_spec("f32", [dae]), _spec("f32", [n, chunk])],
                    )
                )
                arts.append(
                    Artifact(
                        name=f"{key}_decode_n{n}",
                        fn=_tuplize(train.make_ae_decode_batch(chunk, ratio)),
                        inputs=[
                            _spec("f32", [dae]),
                            _spec("f32", [n, code]),
                            _spec("f32", [n]),  # lo
                            _spec("f32", [n]),  # hi
                            _spec("f32", [n]),  # mu
                            _spec("f32", [n]),  # sd
                        ],
                    )
                )

    # ---- T-FedAvg ternary quantizer ----------------------------------------
    for chunk in sorted(set(CHUNKS.values())):
        arts.append(
            Artifact(
                name=f"ternary_c{chunk}",
                fn=_tuplize(train.make_ternary(chunk)),
                inputs=[_spec("f32", [chunk])],
            )
        )
        for n in CODEC_BATCHES:
            arts.append(
                Artifact(
                    name=f"ternary_c{chunk}_n{n}",
                    fn=_tuplize(train.make_ternary_batch(chunk)),
                    inputs=[_spec("f32", [n, chunk])],
                )
            )

    return arts


_DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def _fill_outputs(art: Artifact) -> None:
    outs = jax.eval_shape(art.fn, *art.arg_structs())
    art.outputs = [
        _spec(_DTYPE_NAMES[o.dtype], o.shape) for o in outs
    ]


def build_manifest(arts: List[Artifact]) -> dict:
    manifest = {
        "version": 1,
        "chunks": CHUNKS,
        "ratios": RATIOS,
        "executables": {
            a.name: {
                "file": f"{a.name}.hlo.txt",
                "inputs": a.inputs,
                "outputs": a.outputs,
            }
            for a in arts
        },
        "models": {},
        "autoencoders": {},
        "ternary": {
            f"c{chunk}": f"ternary_c{chunk}" for chunk in sorted(set(CHUNKS.values()))
        },
        "ternary_batch": {
            f"c{chunk}": {str(n): f"ternary_c{chunk}_n{n}" for n in CODEC_BATCHES}
            for chunk in sorted(set(CHUNKS.values()))
        },
    }
    for mname, cfg in MODELS.items():
        mod = cfg["module"]
        layout = mod.layout()
        eb, nb = cfg["epoch_batch"], cfg["epoch_n_batches"]
        manifest["models"][mname] = {
            "d": layout.total,
            "classes": mod.CLASSES,
            "input_dim": mod.INPUT_DIM,
            "layers": layout.manifest(),
            "train_step": {
                str(b): f"{mname}_train_step_b{b}" for b in cfg["train_batches"]
            },
            "train_epoch": {
                "batch": eb,
                "n_batches": nb,
                "name": f"{mname}_train_epoch_b{eb}_n{nb}",
            },
            "eval": {"batch": EVAL_BATCH, "name": f"{mname}_eval_b{EVAL_BATCH}"},
        }
    for chunk in sorted(set(CHUNKS.values())):
        for ratio in RATIOS:
            key = f"c{chunk}_r{ratio}"
            lay = autoencoder.layout(chunk, ratio)
            manifest["autoencoders"][key] = {
                "chunk": chunk,
                "ratio": ratio,
                "code": chunk // ratio,
                "d": lay.total,
                "enc_dims": autoencoder.enc_dims(chunk, ratio),
                "layers": lay.manifest(),
                "encode": f"ae_{key}_encode",
                "decode": f"ae_{key}_decode",
                "encode_batch": {
                    str(n): f"ae_{key}_encode_n{n}" for n in CODEC_BATCHES
                },
                "decode_batch": {
                    str(n): f"ae_{key}_decode_n{n}" for n in CODEC_BATCHES
                },
                "train": {
                    "batch": AE_TRAIN_BATCH,
                    "name": f"ae_{key}_train_b{AE_TRAIN_BATCH}",
                },
            }
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--only", default=None, help="lower a single artifact by name (debug)"
    )
    parser.add_argument(
        "--force", action="store_true", help="re-lower even if the .hlo.txt exists"
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts = build_artifact_specs()
    if args.only:
        arts = [a for a in arts if a.name == args.only]
        if not arts:
            raise SystemExit(f"unknown artifact {args.only!r}")

    for art in arts:
        _fill_outputs(art)
        path = os.path.join(args.out, f"{art.name}.hlo.txt")
        if os.path.exists(path) and not args.force:
            print(f"[aot] keep   {art.name}")
            continue
        lowered = jax.jit(art.fn).lower(*art.arg_structs())
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] wrote  {art.name}  ({len(text) / 1024:.0f} KiB)")

    manifest = build_manifest(arts if not args.only else build_artifact_specs())
    if not args.only:
        with open(os.path.join(args.out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"[aot] wrote  manifest.json ({len(manifest['executables'])} executables)")


if __name__ == "__main__":
    main()
