"""Layer-1 Pallas kernels for HCFL.

Every kernel here runs with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode traces the kernel body to
plain HLO ops so the Rust runtime executes it natively.  Block shapes are
nevertheless chosen for the TPU memory model (multiples of the (8, 128)
register tile, operands staged through VMEM via ``BlockSpec``) so the same
kernels are MXU/VPU-shaped if compiled for a real TPU.

Kernels:
    matmul     -- tiled GEMM with a VMEM f32 accumulator (custom_vjp).
    fc_block   -- fused ``tanh(x @ w + b)`` (the HCFL FC layer, custom_vjp).
    ternary    -- TWN thresholding for the T-FedAvg baseline.
    scale      -- per-chunk affine [-1, 1] scaling and its inverse.
"""

from .matmul import matmul  # noqa: F401
from .fc_block import fc_block, tanh_bwd  # noqa: F401
from .ternary import ternary_quantize  # noqa: F401
from .scale import chunk_scale, chunk_unscale  # noqa: F401
