"""Fused HCFL FC block: ``tanh(x @ w + b)`` in one Pallas kernel.

This is the building block of the HCFL compressor/extractor (paper Fig. 5:
dense -> activation per layer).  Fusing bias-add and tanh into the GEMM
epilogue saves two HBM round-trips per layer on a real TPU; on the CPU
interpret path it lowers to the equivalent fused HLO.

The paper additionally batch-normalizes the FC input.  At inference the
compressor sees a *single* weight chunk, where batch statistics are
degenerate, so the re-centering/re-scaling role of BN is played by the
per-chunk affine [-1,1] scaling (``kernels.scale``) that feeds the
autoencoder -- see DESIGN.md §4/§5.

``fc_block`` has a custom VJP: the backward pass first applies the
``tanh_bwd`` elementwise kernel (gz = g * (1 - y^2)), then two Pallas
GEMMs for dx and dw; db is a row-sum reduction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .matmul import (
    CPU_BK,
    CPU_BM,
    CPU_BN,
    _matmul_pallas,
    _pick_block,
    _pick_lane_block,
    _round_up,
    _SUBLANE,
)


def _fc_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        # Fused epilogue: bias add + tanh, written once to the output tile.
        o_ref[...] = jnp.tanh(acc_ref[...] + b_ref[...].astype(jnp.float32)).astype(
            o_ref.dtype
        )


def _fc_pallas(x, w, b, *, bm: int = CPU_BM, bn: int = CPU_BN, bk: int = CPU_BK):
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"fc_block shape mismatch: {x.shape} @ {w.shape} + {b.shape}")

    bm = _pick_block(m, _SUBLANE, bm)
    bn = _pick_lane_block(n, bn)
    bk = _pick_lane_block(k, bk)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_fc_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp, bp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def _tanh_bwd_kernel(g_ref, y_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    o_ref[...] = (g * (1.0 - y * y)).astype(o_ref.dtype)


def tanh_bwd(g, y, *, bm: int = CPU_BM, bn: int = CPU_BN):
    """Elementwise VPU kernel: ``g * (1 - y**2)`` (tanh input-gradient)."""
    if g.shape != y.shape or g.ndim != 2:
        raise ValueError(f"tanh_bwd expects equal 2-D shapes, got {g.shape}, {y.shape}")
    m, n = g.shape
    bm = _pick_block(m, _SUBLANE, bm)
    bn = _pick_lane_block(n, bn)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    gp = jnp.pad(g, ((0, mp - m), (0, np_ - n))) if (mp, np_) != (m, n) else g
    yp = jnp.pad(y, ((0, mp - m), (0, np_ - n))) if (mp, np_) != (m, n) else y

    out = pl.pallas_call(
        _tanh_bwd_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), g.dtype),
        interpret=True,
    )(gp, yp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def fc_block(x, w, b):
    """Differentiable fused FC layer: ``tanh(x @ w + b)``."""
    return _fc_pallas(x, w, b)


def _fc_fwd(x, w, b):
    y = _fc_pallas(x, w, b)
    return y, (x, w, y)


def _fc_bwd(res, g):
    x, w, y = res
    gz = tanh_bwd(g, y)
    dx = _matmul_pallas(gz, w.T)
    dw = _matmul_pallas(x.T, gz)
    db = jnp.sum(gz, axis=0)
    return dx, dw, db


fc_block.defvjp(_fc_fwd, _fc_bwd)
