"""Tiled Pallas GEMM -- the single FLOP sink of the whole stack.

Conv layers are im2col'd in Layer 2 so that every dense/conv FLOP lands
here.  The kernel follows the canonical MXU pattern: a 3-D grid over
(M-tiles, N-tiles, K-tiles), operands staged block-by-block through VMEM,
and a VMEM f32 accumulator that is zeroed on the first K step and flushed
to the output block on the last.

``matmul`` carries a custom VJP whose backward pass is two more Pallas
GEMMs (dx = g @ w^T, dw = x^T @ g) so that ``jax.grad`` through any model
built on this kernel stays inside Pallas.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# TPU register tile is (8, 128) for f32; blocks are multiples of it.
_LANE = 128
_SUBLANE = 8

# Default block caps.
#
# TPU profile: (128, 256, 512) keeps every operand tile + the f32
# accumulator well inside a core's ~16 MiB VMEM:
#   x[128,512] + w[512,256] + acc/out[128,256] = 0.6 MiB with room for
#   double-buffering — the shapes the EXPERIMENTS.md §Perf estimate uses.
TPU_BM, TPU_BN, TPU_BK = 128, 256, 512
# CPU-interpret profile (what the shipped artifacts are lowered with):
# interpret mode serializes the grid into an XLA while-loop, so the cap is
# raised until loop overhead is amortized (measured sweep in
# EXPERIMENTS.md §Perf; 128-cap blocks ran the LeNet step 9x slower).
CPU_BM, CPU_BN, CPU_BK = 4096, 1024, 4096


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, unit: int, cap: int) -> int:
    """Largest multiple of ``unit`` that divides the padded dim, <= cap."""
    padded = _round_up(dim, unit)
    return min(padded, cap)


def _pick_lane_block(dim: int, cap: int) -> int:
    """Lane-dimension block size.

    Dims >= 128 use full 128-lane tiles (the MXU shape).  Smaller dims pad
    only to the 8-sublane granularity: on the CPU-interpret correctness
    path a forced 128-lane pad would waste up to ~100x FLOPs on tiny conv
    layers (e.g. LeNet conv1: K=25, N=6); a real-TPU build would instead
    re-layout those layers (see DESIGN.md §5).
    """
    unit = _LANE if dim >= _LANE else _SUBLANE
    return min(_round_up(dim, unit), cap)


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_pallas(x, y, *, bm: int = CPU_BM, bn: int = CPU_BN, bk: int = CPU_BK):
    """Raw (non-differentiable) tiled GEMM: ``x [M,K] @ y [K,N] -> [M,N]``.

    Inputs of any shape are zero-padded up to block multiples; the result
    is sliced back.  Zero padding is exact for matmul.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")

    bm = _pick_block(m, _SUBLANE, bm)
    bn = _pick_lane_block(n, bn)
    bk = _pick_lane_block(k, bk)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else y
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,  # CPU-PJRT target; see module docstring
    )(xp, yp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x, y):
    """Differentiable tiled Pallas GEMM."""
    return _matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return _matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = _matmul_pallas(g, y.T)
    dy = _matmul_pallas(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)
