"""Pure-jnp oracles for every Pallas kernel -- the correctness ground truth.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose between each kernel and its oracle here, for values
and (where the kernel is differentiable) gradients.
"""

import jax.numpy as jnp


def matmul(x, y):
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32)
    ).astype(x.dtype)


def fc_block(x, w, b):
    z = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(
        jnp.float32
    )
    return jnp.tanh(z).astype(x.dtype)


def tanh_bwd(g, y):
    gf = g.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    return (gf * (1.0 - yf * yf)).astype(g.dtype)


def ternary_quantize(w):
    aw = jnp.abs(w).astype(jnp.float32)
    delta = 0.7 * jnp.mean(aw)
    mask = aw > delta
    cnt = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    alpha = jnp.sum(aw * mask.astype(jnp.float32)) / cnt
    q = (jnp.sign(w.astype(jnp.float32)) * mask.astype(jnp.float32)).astype(w.dtype)
    return q, alpha


_EPS = 1e-8


def chunk_scale(w):
    lo = jnp.min(w).astype(jnp.float32)
    hi = jnp.max(w).astype(jnp.float32)
    span = jnp.maximum(hi - lo, _EPS)
    s = (2.0 * (w.astype(jnp.float32) - lo) / span - 1.0).astype(w.dtype)
    return s, lo, hi


def chunk_unscale(s, lo, hi):
    span = jnp.maximum(hi - lo, _EPS)
    return ((s.astype(jnp.float32) + 1.0) * 0.5 * span + lo).astype(s.dtype)
