"""Per-chunk affine [-1, 1] scaling kernels (HCFL pre-processing).

The HCFL FC layers end in tanh, so the autoencoder operates on values in
[-1, 1] (paper §III-C2).  Raw weight chunks are mapped into that range by
a per-chunk min/max affine transform; (lo, hi) travel with the code as two
f32 of side information and the inverse transform is applied after the
decoder.  This per-chunk re-centering/re-scaling also stands in for the
paper's FC-input batch-norm at inference time (DESIGN.md §5).

Both directions are 1-D elementwise Pallas kernels; the min/max reduction
is jnp.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _round_up

_BLOCK = 1024
_EPS = 1e-8


def _scale_kernel(w_ref, lo_ref, hi_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    lo = lo_ref[0]
    hi = hi_ref[0]
    span = jnp.maximum(hi - lo, _EPS)
    o_ref[...] = (2.0 * (w - lo) / span - 1.0).astype(o_ref.dtype)


def _unscale_kernel(s_ref, lo_ref, hi_ref, o_ref):
    s = s_ref[...].astype(jnp.float32)
    lo = lo_ref[0]
    hi = hi_ref[0]
    span = jnp.maximum(hi - lo, _EPS)
    o_ref[...] = ((s + 1.0) * 0.5 * span + lo).astype(o_ref.dtype)


def _apply(kernel, x, lo, hi):
    n = x.shape[0]
    np_ = _round_up(n, _BLOCK)
    xp = jnp.pad(x, (0, np_ - n)) if np_ != n else x
    out = pl.pallas_call(
        kernel,
        grid=(np_ // _BLOCK,),
        in_specs=[
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), x.dtype),
        interpret=True,
    )(xp, lo.reshape(1), hi.reshape(1))
    return out[:n] if np_ != n else out


def chunk_scale(w):
    """Map a 1-D chunk into [-1, 1]; returns (scaled, lo, hi)."""
    if w.ndim != 1:
        raise ValueError(f"chunk_scale expects a 1-D chunk, got {w.shape}")
    lo = jnp.min(w).astype(jnp.float32)
    hi = jnp.max(w).astype(jnp.float32)
    return _apply(_scale_kernel, w, lo, hi), lo, hi


def chunk_unscale(s, lo, hi):
    """Inverse of :func:`chunk_scale` given the (lo, hi) side info."""
    if s.ndim != 1:
        raise ValueError(f"chunk_unscale expects a 1-D chunk, got {s.shape}")
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    return _apply(_unscale_kernel, s, lo, hi)
