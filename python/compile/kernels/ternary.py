"""Ternary weight quantization kernel -- the T-FedAvg baseline (paper [22]).

TWN-style quantization of a flat weight chunk ``w``:

    delta = 0.7 * mean(|w|)
    q_i   = sign(w_i) * 1[|w_i| > delta]          (values in {-1, 0, +1})
    alpha = mean(|w_i| : |w_i| > delta)           (per-chunk scale)

The reductions (delta, alpha) are cheap global reductions done in jnp; the
elementwise thresholding -- the bandwidth-bound part -- is a VPU-shaped
Pallas kernel gridded in 1-D lane blocks.

Wire format accounting (2 bits/weight + one f32 scale per chunk) lives in
the Rust ``compression::ternary`` module.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _round_up

_BLOCK = 1024


def _tq_kernel(w_ref, d_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    d = d_ref[0]
    o_ref[...] = (jnp.sign(w) * (jnp.abs(w) > d).astype(jnp.float32)).astype(
        o_ref.dtype
    )


def ternary_quantize(w):
    """Quantize a 1-D chunk to (q in {-1,0,1}, alpha scale scalar)."""
    if w.ndim != 1:
        raise ValueError(f"ternary_quantize expects a 1-D chunk, got {w.shape}")
    n = w.shape[0]
    aw = jnp.abs(w).astype(jnp.float32)
    delta = 0.7 * jnp.mean(aw)
    mask = aw > delta
    # alpha = mean of |w| above threshold; guard the all-below-threshold case.
    cnt = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    alpha = jnp.sum(aw * mask.astype(jnp.float32)) / cnt

    np_ = _round_up(n, _BLOCK)
    wp = jnp.pad(w, (0, np_ - n)) if np_ != n else w
    q = pl.pallas_call(
        _tq_kernel,
        grid=(np_ // _BLOCK,),
        in_specs=[
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), w.dtype),
        interpret=True,
    )(wp, delta.reshape(1))
    if np_ != n:
        q = q[:n]
    return q, alpha
