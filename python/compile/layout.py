"""Flat-parameter calling convention shared by all Layer-2 graphs.

Every model executable takes / returns its parameter set as a single
``f32[D]`` vector (DESIGN.md §6): the Rust coordinator stays shape-agnostic
and only needs the layer table from the manifest for initialization and
segmentation.  This module owns that layer table.
"""

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LayerSpec:
    """One named parameter tensor inside the flat vector."""

    name: str
    shape: Tuple[int, ...]
    segment: str  # "conv" | "dense" -- HCFL trains one compressor per segment

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


class Layout:
    """Ordered layer table with offsets into the flat f32 vector."""

    def __init__(self, specs: Sequence[LayerSpec]):
        self.specs: List[LayerSpec] = list(specs)
        self.offsets: List[int] = []
        off = 0
        for s in self.specs:
            self.offsets.append(off)
            off += s.size
        self.total = off

    def unflatten(self, flat) -> Dict[str, jnp.ndarray]:
        """Slice the flat vector into named, shaped tensors (inside jit)."""
        out = {}
        for spec, off in zip(self.specs, self.offsets):
            out[spec.name] = flat[off : off + spec.size].reshape(spec.shape)
        return out

    def flatten(self, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return jnp.concatenate(
            [params[s.name].reshape(-1) for s in self.specs], axis=0
        )

    def manifest(self) -> List[dict]:
        """Layer table as JSON-able dicts for artifacts/manifest.json."""
        return [
            {
                "name": s.name,
                "shape": list(s.shape),
                "offset": off,
                "size": s.size,
                "segment": s.segment,
            }
            for s, off in zip(self.specs, self.offsets)
        ]

    def init_flat(self, key) -> jnp.ndarray:
        """Fan-in uniform init (matches rust/src/model/init.rs)."""
        import jax

        chunks = []
        for s in self.specs:
            key, sub = jax.random.split(key)
            if len(s.shape) > 1:
                fan_in = int(np.prod(s.shape[:-1]))
                limit = float(np.sqrt(6.0 / fan_in))
                chunks.append(
                    jax.random.uniform(
                        sub, (s.size,), jnp.float32, -limit, limit
                    )
                )
            else:
                chunks.append(jnp.zeros((s.size,), jnp.float32))
        return jnp.concatenate(chunks, axis=0)


import jax  # noqa: E402  (used in init_flat; kept after class for clarity)
