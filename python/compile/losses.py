"""Loss functions for the predictor models and the HCFL joint objective.

The HCFL objective implements paper eq. (8): ``L = λ·H − (1−λ)·I`` where H
is the reconstruction term (the paper's eq. (7) shows the cross-entropy of
a Gaussian output is Θ(MSE), so we use MSE directly) and I is a
mutual-information surrogate.  The paper never specifies an MI estimator;
we use the code-variance surrogate ``mean(log(1 + var(code)))`` --
maximizing the per-dimension variance of a bounded (tanh) code maximizes
the Gaussian-channel capacity of the bottleneck, the same information-
bottleneck argument as the paper's refs [30, 31].  See DESIGN.md §4.
"""

import jax
import jax.numpy as jnp


def softmax_cross_entropy(logits, labels, n_classes: int):
    """Mean CE over the batch; labels are int32 class indices."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, n_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def accuracy_count(logits, labels):
    """Number of correct predictions in the batch (as f32)."""
    pred = jnp.argmax(logits, axis=-1).astype(labels.dtype)
    return jnp.sum((pred == labels).astype(jnp.float32))


def mse(a, b):
    return jnp.mean((a - b) ** 2)


def mi_surrogate(code):
    """Variance surrogate for I(W, C); code is [B, M]."""
    var = jnp.var(code, axis=0)
    return jnp.mean(jnp.log1p(var))


def hcfl_loss(x, x_hat, code, lam: float = 0.9):
    """Paper eq. (8): λ·MSE − (1−λ)·I_sur (minimized)."""
    return lam * mse(x_hat, x) - (1.0 - lam) * mi_surrogate(code)
