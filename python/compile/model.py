"""Layer-2 entry point (kept at the canonical path).

The actual graphs live in :mod:`compile.models` (lenet / five_cnn /
autoencoder) and :mod:`compile.train` (train/eval/encode/decode builders);
this module re-exports them so the documented layout
(``python/compile/model.py``) resolves.
"""

from .models import autoencoder, five_cnn, lenet  # noqa: F401
from .train import (  # noqa: F401
    make_ae_decode,
    make_ae_encode,
    make_ae_train,
    make_eval,
    make_ternary,
    make_train_epoch,
    make_train_step,
)
