"""Layer-2 model graphs: the two FL predictors and the HCFL autoencoder.

All dense/conv FLOPs route through the Layer-1 Pallas kernels
(``kernels.matmul`` / ``kernels.fc_block``); convolutions are im2col'd
here so the GEMM kernel is the single FLOP sink.
"""

from . import lenet, five_cnn, autoencoder  # noqa: F401
