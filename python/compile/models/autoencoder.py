"""HCFL compressor/extractor: per-(chunk, ratio) under-complete autoencoder.

Architecture per the paper (§III-C2): stacks of FC blocks (dense + tanh,
the fused Layer-1 ``fc_block`` kernel), *deeper for higher compression
ratios* -- each stage halves the width until the bottleneck ``chunk/ratio``
is reached, and the extractor mirrors the compressor.

The autoencoder operates on weight chunks pre-scaled into [-1, 1]
(``kernels.scale``); the tanh output range therefore covers the full data
range.  Encoder output (the code) is also tanh-bounded, which keeps the
wire representation quantization-friendly.
"""

from typing import List

from ..layout import LayerSpec, Layout
from ..kernels import fc_block


def enc_dims(chunk: int, ratio: int) -> List[int]:
    """Widths of the compressor, input first: halve until chunk/ratio."""
    code = chunk // ratio
    dims = [chunk]
    while dims[-1] > code:
        dims.append(max(dims[-1] // 2, code))
    return dims


def dec_dims(chunk: int, ratio: int) -> List[int]:
    return list(reversed(enc_dims(chunk, ratio)))


def _fc_specs(prefix: str, dims: List[int]) -> List[LayerSpec]:
    specs = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs.append(LayerSpec(f"{prefix}{i}_w", (a, b), "dense"))
        specs.append(LayerSpec(f"{prefix}{i}_b", (b,), "dense"))
    return specs


def layout(chunk: int, ratio: int) -> Layout:
    """Joint layer table: encoder layers first, then decoder layers."""
    return Layout(
        _fc_specs("enc", enc_dims(chunk, ratio))
        + _fc_specs("dec", dec_dims(chunk, ratio))
    )


def _stack(p, prefix: str, n_layers: int, x):
    h = x
    for i in range(n_layers):
        h = fc_block(h, p[f"{prefix}{i}_w"], p[f"{prefix}{i}_b"])
    return h


def encode(p, chunk: int, ratio: int, x):
    """x [B, chunk] in [-1,1] -> code [B, chunk/ratio]."""
    return _stack(p, "enc", len(enc_dims(chunk, ratio)) - 1, x)


def decode(p, chunk: int, ratio: int, code):
    """code [B, chunk/ratio] -> x_hat [B, chunk] in [-1,1]."""
    return _stack(p, "dec", len(dec_dims(chunk, ratio)) - 1, code)
