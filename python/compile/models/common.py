"""Shared conv/pool building blocks for the FL predictor models.

Convolutions are expressed as im2col + Pallas GEMM so that every FLOP of
every model lands in the Layer-1 ``matmul`` kernel (MXU-shaped); pooling
and activations are cheap elementwise/reduce ops XLA fuses on its own.
"""

import jax.numpy as jnp

from ..kernels import matmul


def im2col(x, kh: int, kw: int):
    """Extract VALID kh x kw patches.

    x: [B, H, W, C] -> [B, H-kh+1, W-kw+1, kh*kw*C], with the feature axis
    ordered (di, dj, c) to match ``conv_weight_matrix``.
    """
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = jnp.stack(
        [x[:, i : i + oh, j : j + ow, :] for i in range(kh) for j in range(kw)],
        axis=3,
    )  # [B, OH, OW, kh*kw, C]
    return cols.reshape(b, oh, ow, kh * kw * c)


def conv2d(x, w):
    """VALID conv via im2col + Pallas GEMM.

    x: [B, H, W, C], w: [kh, kw, C, OC] -> [B, OH, OW, OC].
    """
    kh, kw, c, oc = w.shape
    b = x.shape[0]
    cols = im2col(x, kh, kw)
    oh, ow = cols.shape[1], cols.shape[2]
    flat = cols.reshape(b * oh * ow, kh * kw * c)
    out = matmul(flat, w.reshape(kh * kw * c, oc))
    return out.reshape(b, oh, ow, oc)


def conv2d_same(x, w):
    """SAME-padded conv (odd kernels) via pad + :func:`conv2d`."""
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    return conv2d(xp, w)


def maxpool2(x):
    """2x2 max pooling, stride 2 (even spatial dims required)."""
    b, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"maxpool2 needs even dims, got {x.shape}"
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def relu(x):
    return jnp.maximum(x, 0.0)


def dense(x, w, b):
    """Plain dense layer through the Pallas GEMM (activation added by caller)."""
    return matmul(x, w) + b
