"""The paper's "5-CNN" predictor for the (synthetic) EMNIST-47 workload.

Five 3x3 conv layers (16, 32, 32, 64, 64 channels; max-pools after #2 and
#4, SAME padding on #5 to keep the 4x4 spatial grid) followed by two FC
layers (1024 -> 256 -> 47).  ReLU after every pool / conv per the paper's
description; the paper's dropout is omitted because the AOT executables
must be deterministic -- the regularizing role is played by the small
per-client shards (DESIGN.md §4).

~330k parameters: the "complex model" whose dense segment the paper splits
8-ways before compression (§VI-A Dataset segmentation).
"""

from ..layout import LayerSpec, Layout
from .common import conv2d, conv2d_same, dense, maxpool2, relu

INPUT_DIM = 784
CLASSES = 47

_SPECS = [
    LayerSpec("conv1_w", (3, 3, 1, 16), "conv"),
    LayerSpec("conv1_b", (16,), "conv"),
    LayerSpec("conv2_w", (3, 3, 16, 32), "conv"),
    LayerSpec("conv2_b", (32,), "conv"),
    LayerSpec("conv3_w", (3, 3, 32, 32), "conv"),
    LayerSpec("conv3_b", (32,), "conv"),
    LayerSpec("conv4_w", (3, 3, 32, 64), "conv"),
    LayerSpec("conv4_b", (64,), "conv"),
    LayerSpec("conv5_w", (3, 3, 64, 64), "conv"),
    LayerSpec("conv5_b", (64,), "conv"),
    LayerSpec("fc1_w", (1024, 256), "dense"),
    LayerSpec("fc1_b", (256,), "dense"),
    LayerSpec("fc2_w", (256, 47), "dense"),
    LayerSpec("fc2_b", (47,), "dense"),
]


def layout() -> Layout:
    return Layout(_SPECS)


def apply(p, x):
    """Forward pass: x [B, 784] -> logits [B, 47]."""
    b = x.shape[0]
    h = x.reshape(b, 28, 28, 1)
    h = relu(conv2d(h, p["conv1_w"]) + p["conv1_b"])  # 26
    h = relu(conv2d(h, p["conv2_w"]) + p["conv2_b"])  # 24
    h = maxpool2(h)  # 12
    h = relu(conv2d(h, p["conv3_w"]) + p["conv3_b"])  # 10
    h = relu(conv2d(h, p["conv4_w"]) + p["conv4_b"])  # 8
    h = maxpool2(h)  # 4
    h = relu(conv2d_same(h, p["conv5_w"]) + p["conv5_b"])  # 4 (SAME)
    h = h.reshape(b, 1024)
    h = relu(dense(h, p["fc1_w"], p["fc1_b"]))
    return dense(h, p["fc2_w"], p["fc2_b"])
