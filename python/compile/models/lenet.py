"""LeNet-5 predictor for the (synthetic) MNIST workload (paper §VI-A).

conv 5x5x6 -> pool -> conv 5x5x16 -> pool -> fc 120 -> fc 84 -> fc 10,
ReLU activations, softmax classification head.  On 28x28 inputs the
flatten size is 4*4*16 = 256 (the classic 32x32 LeNet has 400); the layer
table in the manifest is the source of truth for the Rust side.

44,426 parameters total.
"""

from ..layout import LayerSpec, Layout
from .common import conv2d, dense, maxpool2, relu

INPUT_DIM = 784
CLASSES = 10

_SPECS = [
    LayerSpec("conv1_w", (5, 5, 1, 6), "conv"),
    LayerSpec("conv1_b", (6,), "conv"),
    LayerSpec("conv2_w", (5, 5, 6, 16), "conv"),
    LayerSpec("conv2_b", (16,), "conv"),
    LayerSpec("fc1_w", (256, 120), "dense"),
    LayerSpec("fc1_b", (120,), "dense"),
    LayerSpec("fc2_w", (120, 84), "dense"),
    LayerSpec("fc2_b", (84,), "dense"),
    LayerSpec("fc3_w", (84, 10), "dense"),
    LayerSpec("fc3_b", (10,), "dense"),
]


def layout() -> Layout:
    return Layout(_SPECS)


def apply(p, x):
    """Forward pass: x [B, 784] -> logits [B, 10]."""
    b = x.shape[0]
    h = x.reshape(b, 28, 28, 1)
    h = relu(conv2d(h, p["conv1_w"]) + p["conv1_b"])  # [B, 24, 24, 6]
    h = maxpool2(h)  # [B, 12, 12, 6]
    h = relu(conv2d(h, p["conv2_w"]) + p["conv2_b"])  # [B, 8, 8, 16]
    h = maxpool2(h)  # [B, 4, 4, 16]
    h = h.reshape(b, 256)
    h = relu(dense(h, p["fc1_w"], p["fc1_b"]))
    h = relu(dense(h, p["fc2_w"], p["fc2_b"]))
    return dense(h, p["fc3_w"], p["fc3_b"])
