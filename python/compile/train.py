"""Graph builders for every AOT-lowered executable.

All builders return pure functions over the flat-parameter convention
(DESIGN.md §6): parameters in and out as one ``f32[D]`` vector, so the
Rust runtime needs no pytree knowledge.  ``aot.py`` jit-lowers each of
these at fixed shapes and dumps HLO text.
"""

from typing import Callable

import jax
import jax.numpy as jnp

from . import losses
from .kernels import chunk_scale, chunk_unscale, ternary_quantize
from .models import autoencoder


# --------------------------------------------------------------------------
# Predictor models (LeNet-5 / 5-CNN)
# --------------------------------------------------------------------------


def make_train_step(model) -> Callable:
    """SGD step: (flat[D], x[B,784], y[B] i32, lr[]) -> (flat', loss)."""
    layout = model.layout()

    def loss_fn(flat, x, y):
        params = layout.unflatten(flat)
        logits = model.apply(params, x)
        return losses.softmax_cross_entropy(logits, y, model.CLASSES)

    def step(flat, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
        return (flat - lr * grads, loss)

    return step


def make_train_epoch(model, n_batches: int) -> Callable:
    """One local epoch scanned inside the graph: 1 dispatch instead of Nb.

    (flat[D], xs[Nb,B,784], ys[Nb,B] i32, lr[]) -> (flat', mean_loss).
    """
    step = make_train_step(model)

    def epoch(flat, xs, ys, lr):
        def body(carry, batch):
            x, y = batch
            new_flat, loss = step(carry, x, y, lr)
            return new_flat, loss

        flat, batch_losses = jax.lax.scan(body, flat, (xs, ys))
        return (flat, jnp.mean(batch_losses))

    del n_batches  # baked into the traced xs/ys shapes
    return epoch


def make_eval(model) -> Callable:
    """(flat[D], x[B,784], y[B] i32) -> (correct_count, mean_loss)."""
    layout = model.layout()

    def evaluate(flat, x, y):
        params = layout.unflatten(flat)
        logits = model.apply(params, x)
        loss = losses.softmax_cross_entropy(logits, y, model.CLASSES)
        return (losses.accuracy_count(logits, y), loss)

    return evaluate


# --------------------------------------------------------------------------
# HCFL autoencoder
# --------------------------------------------------------------------------


def _rows_to_unit(w):
    """Row-wise affine map of [B, chunk] into [-1,1] (training-path scaling;
    the inference path does the same per-chunk via the Pallas scale kernel)."""
    lo = jnp.min(w, axis=1, keepdims=True)
    hi = jnp.max(w, axis=1, keepdims=True)
    span = jnp.maximum(hi - lo, 1e-8)
    return 2.0 * (w - lo) / span - 1.0


def make_ae_train(chunk: int, ratio: int, lam: float = 0.9) -> Callable:
    """HCFL training step on a batch of raw weight chunks.

    (flat_ae[Dae], w[B,chunk], lr[]) -> (flat_ae', loss) with the joint
    objective of paper eq. (8).
    """
    layout = autoencoder.layout(chunk, ratio)

    def loss_fn(flat, w):
        p = layout.unflatten(flat)
        x = _rows_to_unit(w)
        code = autoencoder.encode(p, chunk, ratio, x)
        x_hat = autoencoder.decode(p, chunk, ratio, code)
        return losses.hcfl_loss(x, x_hat, code, lam)

    def step(flat, w, lr):
        loss, grads = jax.value_and_grad(loss_fn)(flat, w)
        return (flat - lr * grads, loss)

    return step


def make_ae_encode(chunk: int, ratio: int) -> Callable:
    """Client-side compressor.

    (flat_ae[Dae], w[chunk]) -> (code, lo, hi, mu, sd): the code plus four
    f32 of side info — the affine scaling pair (lo, hi) and the scaled
    chunk's first two moments (mu, sd).  The moments let the extractor
    renormalize its output to the true chunk statistics: an
    under-complete AE systematically shrinks its output toward the chunk
    mean, and without the correction the reconstructed *energy* vanishes
    (the aligned component would be scaled by rho < 1 every round).  All
    side info is counted in the wire size by the Rust compression module.
    """
    layout = autoencoder.layout(chunk, ratio)

    def encode(flat, w):
        p = layout.unflatten(flat)
        scaled, lo, hi = chunk_scale(w)
        mu = jnp.mean(scaled)
        sd = jnp.std(scaled)
        code = autoencoder.encode(p, chunk, ratio, scaled.reshape(1, chunk))
        return (code.reshape(chunk // ratio), lo, hi, mu, sd)

    return encode


def make_ae_encode_batch(chunk: int, ratio: int) -> Callable:
    """Batched compressor: (flat_ae[Dae], w[N, chunk]) ->
    (codes[N, code], lo[N], hi[N], mu[N], sd[N]).

    ``vmap`` of :func:`make_ae_encode` over the chunk axis, so every row
    runs the identical per-chunk math — the Rust codec dispatches whole
    segment ranges through these instead of one engine call per chunk.
    """
    return jax.vmap(make_ae_encode(chunk, ratio), in_axes=(None, 0))


def make_ae_decode(chunk: int, ratio: int) -> Callable:
    """Server-side extractor: (flat_ae, code, lo, hi, mu, sd) -> w_hat.

    The raw decoder output is renormalized to the transmitted (mu, sd)
    before the inverse affine scaling — see :func:`make_ae_encode`.
    """
    layout = autoencoder.layout(chunk, ratio)

    def decode(flat, code, lo, hi, mu, sd):
        p = layout.unflatten(flat)
        x_hat = autoencoder.decode(
            p, chunk, ratio, code.reshape(1, chunk // ratio)
        ).reshape(chunk)
        # Moment-match the reconstruction to the original chunk.
        x_mu = jnp.mean(x_hat)
        x_sd = jnp.maximum(jnp.std(x_hat), 1e-8)
        x_hat = (x_hat - x_mu) / x_sd * sd + mu
        return chunk_unscale(x_hat, lo, hi)

    return decode


def make_ae_decode_batch(chunk: int, ratio: int) -> Callable:
    """Batched extractor: (flat_ae, codes[N, code], lo[N], hi[N], mu[N],
    sd[N]) -> w_hat[N, chunk] (``vmap`` of :func:`make_ae_decode`)."""
    return jax.vmap(make_ae_decode(chunk, ratio), in_axes=(None, 0, 0, 0, 0, 0))


# --------------------------------------------------------------------------
# T-FedAvg baseline
# --------------------------------------------------------------------------


def make_ternary(chunk: int) -> Callable:
    """(w[chunk]) -> (q[chunk] in {-1,0,1}, alpha[]) -- TWN quantization."""

    def quantize(w):
        return ternary_quantize(w)

    del chunk
    return quantize


def make_ternary_batch(chunk: int) -> Callable:
    """(w[N, chunk]) -> (q[N, chunk], alpha[N]): row-wise TWN quantization
    (``vmap`` of :func:`make_ternary`)."""
    return jax.vmap(make_ternary(chunk), in_axes=(0,))
