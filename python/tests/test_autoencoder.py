"""HCFL autoencoder graph tests: architecture, training signal, round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train
from compile.models import autoencoder

jax.config.update("jax_platform_name", "cpu")


class TestArchitecture:
    @pytest.mark.parametrize("chunk", [256, 1024])
    @pytest.mark.parametrize("ratio", [4, 8, 16, 32])
    def test_enc_dims(self, chunk, ratio):
        dims = autoencoder.enc_dims(chunk, ratio)
        assert dims[0] == chunk
        assert dims[-1] == chunk // ratio
        # strictly narrowing (under-complete)
        assert all(a > b for a, b in zip(dims[:-1], dims[1:]))
        # paper §III-C2: higher compression ratio => deeper network
        if ratio > 4:
            assert len(dims) > len(autoencoder.enc_dims(chunk, 4))

    def test_decoder_mirrors_encoder(self):
        assert autoencoder.dec_dims(1024, 8) == list(
            reversed(autoencoder.enc_dims(1024, 8))
        )

    def test_layout_total_matches_dims(self):
        chunk, ratio = 256, 4
        enc = autoencoder.enc_dims(chunk, ratio)
        dec = autoencoder.dec_dims(chunk, ratio)
        want = sum(a * b + b for a, b in zip(enc[:-1], enc[1:])) + sum(
            a * b + b for a, b in zip(dec[:-1], dec[1:])
        )
        assert autoencoder.layout(chunk, ratio).total == want


def _chunk_data(key, n, chunk):
    """Synthetic 'weight chunks': smooth low-rank structure + noise, like
    real model weights (correlated, centered)."""
    k1, k2 = jax.random.split(key)
    basis = jax.random.normal(k1, (8, chunk)) * 0.1
    coef = jax.random.normal(k2, (n, 8))
    return coef @ basis + jax.random.normal(key, (n, chunk)) * 0.01


class TestTraining:
    @pytest.mark.parametrize("chunk,ratio", [(256, 4), (256, 32)])
    def test_loss_decreases(self, chunk, ratio):
        step = train.make_ae_train(chunk, ratio)
        lay = autoencoder.layout(chunk, ratio)
        flat = lay.init_flat(jax.random.PRNGKey(0))
        w = _chunk_data(jax.random.PRNGKey(1), 64, chunk)
        losses = []
        for _ in range(15):
            flat, loss = step(flat, w, jnp.float32(0.05))
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_encode_decode_shapes(self):
        chunk, ratio = 256, 8
        lay = autoencoder.layout(chunk, ratio)
        flat = lay.init_flat(jax.random.PRNGKey(0))
        enc = train.make_ae_encode(chunk, ratio)
        dec = train.make_ae_decode(chunk, ratio)
        w = jax.random.normal(jax.random.PRNGKey(1), (chunk,)) * 0.1
        code, lo, hi, mu, sd = enc(flat, w)
        assert code.shape == (chunk // ratio,)
        assert float(sd) > 0.0
        w_hat = dec(flat, code, lo, hi, mu, sd)
        assert w_hat.shape == (chunk,)
        assert bool(jnp.all(jnp.isfinite(w_hat)))

    def test_decode_preserves_chunk_moments(self):
        # The variance-preserving extractor must reproduce the scaled
        # chunk's first two moments regardless of AE quality.
        chunk, ratio = 256, 8
        lay = autoencoder.layout(chunk, ratio)
        flat = lay.init_flat(jax.random.PRNGKey(0))
        enc = train.make_ae_encode(chunk, ratio)
        dec = train.make_ae_decode(chunk, ratio)
        w = jax.random.normal(jax.random.PRNGKey(2), (chunk,)) * 0.05
        code, lo, hi, mu, sd = enc(flat, w)
        w_hat = dec(flat, code, lo, hi, mu, sd)
        # map back into scaled space and compare moments
        span = float(hi - lo)
        s_hat = 2.0 * (w_hat - lo) / span - 1.0
        np.testing.assert_allclose(float(jnp.mean(s_hat)), float(mu), atol=1e-4)
        np.testing.assert_allclose(float(jnp.std(s_hat)), float(sd), rtol=1e-3)

    def test_trained_ae_reconstructs_better_than_init(self):
        chunk, ratio = 256, 4
        lay = autoencoder.layout(chunk, ratio)
        step = train.make_ae_train(chunk, ratio)
        enc = train.make_ae_encode(chunk, ratio)
        dec = train.make_ae_decode(chunk, ratio)

        flat0 = lay.init_flat(jax.random.PRNGKey(0))
        data = _chunk_data(jax.random.PRNGKey(1), 64, chunk)
        flat = flat0
        for _ in range(60):
            flat, _ = step(flat, data, jnp.float32(0.05))

        def recon_mse(f, w):
            code, lo, hi, mu, sd = enc(f, w)
            return float(jnp.mean((dec(f, code, lo, hi, mu, sd) - w) ** 2))

        w_test = data[0]
        assert recon_mse(flat, w_test) < recon_mse(flat0, w_test)
