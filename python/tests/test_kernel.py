"""Kernel vs pure-jnp oracle: the core Layer-1 correctness signal.

Hypothesis sweeps shapes/dtypes; every differentiable kernel is checked
for values AND gradients against ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from compile.kernels import (
    chunk_scale,
    chunk_unscale,
    fc_block,
    matmul,
    tanh_bwd,
    ternary_quantize,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=200)
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


class TestMatmul:
    @settings(max_examples=8, deadline=None, suppress_health_check=list(HealthCheck))
    @given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
    def test_values(self, m, k, n, dtype, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = _rand(k1, (m, k), dtype)
        y = _rand(k2, (k, n), dtype)
        np.testing.assert_allclose(
            np.asarray(matmul(x, y), np.float32),
            np.asarray(ref.matmul(x, y), np.float32),
            **_tol(dtype),
        )

    @settings(max_examples=4, deadline=None, suppress_health_check=list(HealthCheck))
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_grads(self, m, k, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = _rand(k1, (m, k), jnp.float32, 0.3)
        y = _rand(k2, (k, n), jnp.float32, 0.3)
        f_ker = lambda a, b: jnp.sum(matmul(a, b) ** 2)
        f_ref = lambda a, b: jnp.sum(ref.matmul(a, b) ** 2)
        for g_ker, g_ref in zip(
            jax.grad(f_ker, (0, 1))(x, y), jax.grad(f_ref, (0, 1))(x, y)
        ):
            np.testing.assert_allclose(g_ker, g_ref, rtol=1e-4, atol=1e-4)

    def test_shape_mismatch_raises(self):
        x = jnp.zeros((4, 5))
        y = jnp.zeros((6, 7))
        with pytest.raises(ValueError):
            matmul(x, y)

    def test_exact_block_multiple(self):
        # No-padding fast path: dims already multiples of (8, 128).
        x = _rand(jax.random.PRNGKey(0), (16, 256), jnp.float32)
        y = _rand(jax.random.PRNGKey(1), (256, 128), jnp.float32)
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul(x, y), rtol=1e-5, atol=1e-5
        )


class TestFcBlock:
    @settings(max_examples=6, deadline=None, suppress_health_check=list(HealthCheck))
    @given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
    def test_values(self, m, k, n, dtype, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(k1, (m, k), dtype, 0.5)
        w = _rand(k2, (k, n), dtype, 0.2)
        b = _rand(k3, (n,), dtype, 0.2)
        np.testing.assert_allclose(
            np.asarray(fc_block(x, w, b), np.float32),
            np.asarray(ref.fc_block(x, w, b), np.float32),
            **_tol(dtype),
        )

    @settings(max_examples=4, deadline=None, suppress_health_check=list(HealthCheck))
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_grads(self, m, k, n, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(k1, (m, k), jnp.float32, 0.5)
        w = _rand(k2, (k, n), jnp.float32, 0.2)
        b = _rand(k3, (n,), jnp.float32, 0.2)
        f_ker = lambda *a: jnp.sum(jnp.sin(fc_block(*a)))
        f_ref = lambda *a: jnp.sum(jnp.sin(ref.fc_block(*a)))
        for g_ker, g_ref in zip(
            jax.grad(f_ker, (0, 1, 2))(x, w, b),
            jax.grad(f_ref, (0, 1, 2))(x, w, b),
        ):
            np.testing.assert_allclose(g_ker, g_ref, rtol=1e-4, atol=1e-4)

    def test_output_bounded(self):
        x = _rand(jax.random.PRNGKey(0), (8, 64), jnp.float32, 10.0)
        w = _rand(jax.random.PRNGKey(1), (64, 32), jnp.float32, 10.0)
        b = jnp.zeros((32,))
        y = fc_block(x, w, b)
        assert float(jnp.max(jnp.abs(y))) <= 1.0 + 1e-6


class TestTanhBwd:
    @settings(max_examples=5, deadline=None, suppress_health_check=list(HealthCheck))
    @given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_values(self, m, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        g = _rand(k1, (m, n), jnp.float32)
        y = jnp.tanh(_rand(k2, (m, n), jnp.float32))
        np.testing.assert_allclose(
            tanh_bwd(g, y), ref.tanh_bwd(g, y), rtol=1e-6, atol=1e-6
        )


class TestTernary:
    @settings(max_examples=8, deadline=None, suppress_health_check=list(HealthCheck))
    @given(n=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, seed):
        w = _rand(jax.random.PRNGKey(seed), (n,), jnp.float32)
        q, alpha = ternary_quantize(w)
        qr, ar = ref.ternary_quantize(w)
        np.testing.assert_allclose(q, qr)
        np.testing.assert_allclose(alpha, ar, rtol=1e-6)

    @settings(max_examples=5, deadline=None, suppress_health_check=list(HealthCheck))
    @given(n=st.integers(1, 2000), seed=st.integers(0, 2**31 - 1))
    def test_codebook(self, n, seed):
        w = _rand(jax.random.PRNGKey(seed), (n,), jnp.float32)
        q, alpha = ternary_quantize(w)
        vals = set(np.unique(np.asarray(q)).tolist())
        assert vals.issubset({-1.0, 0.0, 1.0})
        assert float(alpha) >= 0.0

    def test_zero_chunk(self):
        q, alpha = ternary_quantize(jnp.zeros((128,)))
        assert float(jnp.sum(jnp.abs(q))) == 0.0


class TestScale:
    @settings(max_examples=8, deadline=None, suppress_health_check=list(HealthCheck))
    @given(n=st.integers(2, 5000), seed=st.integers(0, 2**31 - 1))
    def test_roundtrip(self, n, seed):
        w = _rand(jax.random.PRNGKey(seed), (n,), jnp.float32, 3.0)
        s, lo, hi = chunk_scale(w)
        assert float(jnp.max(s)) <= 1.0 + 1e-5
        assert float(jnp.min(s)) >= -1.0 - 1e-5
        np.testing.assert_allclose(chunk_unscale(s, lo, hi), w, rtol=1e-4, atol=1e-4)

    @settings(max_examples=4, deadline=None, suppress_health_check=list(HealthCheck))
    @given(n=st.integers(2, 1000), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, seed):
        w = _rand(jax.random.PRNGKey(seed), (n,), jnp.float32)
        s, lo, hi = chunk_scale(w)
        sr, lor, hir = ref.chunk_scale(w)
        np.testing.assert_allclose(s, sr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(lo, lor)
        np.testing.assert_allclose(hi, hir)

    def test_constant_chunk(self):
        w = jnp.full((64,), 0.7)
        s, lo, hi = chunk_scale(w)
        out = chunk_unscale(s, lo, hi)
        np.testing.assert_allclose(out, w, atol=1e-5)
