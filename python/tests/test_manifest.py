"""Manifest consistency: what aot.py promises the Rust runtime."""

import json
import os

import jax
import pytest

from compile import aot
from compile.models import autoencoder

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ARTIFACTS, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_every_executable_file_exists(manifest):
    for name, spec in manifest["executables"].items():
        path = os.path.join(ARTIFACTS, spec["file"])
        assert os.path.exists(path), f"missing artifact for {name}"
        assert os.path.getsize(path) > 0


def test_spec_shapes_match_eval_shape(manifest):
    """Input/output specs recorded in the manifest must match what the
    graphs actually produce (the Rust runtime trusts these blindly)."""
    arts = {a.name: a for a in aot.build_artifact_specs()}
    for name, spec in manifest["executables"].items():
        art = arts[name]
        assert spec["inputs"] == art.inputs
        outs = jax.eval_shape(art.fn, *art.arg_structs())
        assert len(spec["outputs"]) == len(outs)
        for rec, o in zip(spec["outputs"], outs):
            assert tuple(rec["shape"]) == o.shape


def test_model_layer_tables(manifest):
    for mname, mcfg in manifest["models"].items():
        mod = aot.MODELS[mname]["module"]
        lay = mod.layout()
        assert mcfg["d"] == lay.total
        assert mcfg["classes"] == mod.CLASSES
        # layer table covers the flat vector exactly, in order, no gaps
        end = 0
        for rec in mcfg["layers"]:
            assert rec["offset"] == end
            end += rec["size"]
            assert rec["segment"] in ("conv", "dense")
        assert end == lay.total


def test_autoencoder_entries(manifest):
    for key, acfg in manifest["autoencoders"].items():
        chunk, ratio = acfg["chunk"], acfg["ratio"]
        assert key == f"c{chunk}_r{ratio}"
        assert acfg["code"] == chunk // ratio
        assert acfg["d"] == autoencoder.layout(chunk, ratio).total
        assert acfg["enc_dims"] == autoencoder.enc_dims(chunk, ratio)
        for ref in (acfg["encode"], acfg["decode"], acfg["train"]["name"]):
            assert ref in manifest["executables"]


def test_batched_codec_entries(manifest):
    """Batched encode/decode/ternary executables resolve and cover the
    advertised CODEC_BATCHES ladder (absent only in pre-batching
    manifests, which the Rust side also tolerates)."""
    batches = {str(n) for n in aot.CODEC_BATCHES}
    for acfg in manifest["autoencoders"].values():
        for field in ("encode_batch", "decode_batch"):
            refs = acfg.get(field, {})
            assert set(refs) == batches
            for name in refs.values():
                assert name in manifest["executables"]
    for key, sizes in manifest.get("ternary_batch", {}).items():
        assert key in manifest["ternary"]
        assert set(sizes) == batches
        for name in sizes.values():
            assert name in manifest["executables"]


def test_model_executable_refs_resolve(manifest):
    for mcfg in manifest["models"].values():
        for name in mcfg["train_step"].values():
            assert name in manifest["executables"]
        assert mcfg["train_epoch"]["name"] in manifest["executables"]
        assert mcfg["eval"]["name"] in manifest["executables"]
