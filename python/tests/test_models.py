"""Layer-2 model graph tests: shapes, layouts, and learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses, train
from compile.layout import Layout, LayerSpec
from compile.models import five_cnn, lenet

jax.config.update("jax_platform_name", "cpu")


class TestLayout:
    def test_offsets_are_contiguous(self):
        lay = lenet.layout()
        off = 0
        for spec, o in zip(lay.specs, lay.offsets):
            assert o == off
            off += spec.size
        assert lay.total == off

    def test_flatten_unflatten_roundtrip(self):
        lay = Layout(
            [LayerSpec("a", (3, 4), "conv"), LayerSpec("b", (5,), "dense")]
        )
        flat = jnp.arange(17, dtype=jnp.float32)
        params = lay.unflatten(flat)
        assert params["a"].shape == (3, 4)
        assert params["b"].shape == (5,)
        np.testing.assert_array_equal(lay.flatten(params), flat)

    def test_init_flat_statistics(self):
        lay = lenet.layout()
        flat = lay.init_flat(jax.random.PRNGKey(0))
        assert flat.shape == (lay.total,)
        # biases are zero
        params = lay.unflatten(flat)
        np.testing.assert_array_equal(params["conv1_b"], 0.0)
        # weight slices are bounded by the fan-in limit
        w = params["fc1_w"]
        limit = np.sqrt(6.0 / 256)
        assert float(jnp.max(jnp.abs(w))) <= limit + 1e-6

    def test_paper_parameter_counts(self):
        assert lenet.layout().total == 44426
        assert five_cnn.layout().total == 343951


@pytest.mark.parametrize("mod,b", [(lenet, 4), (five_cnn, 2)])
class TestForward:
    def test_logit_shape(self, mod, b):
        lay = mod.layout()
        flat = lay.init_flat(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (b, mod.INPUT_DIM))
        logits = mod.apply(lay.unflatten(flat), x)
        assert logits.shape == (b, mod.CLASSES)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestTrainStep:
    def test_loss_decreases_on_fixed_batch(self):
        mod = lenet
        lay = mod.layout()
        step = train.make_train_step(mod)
        flat = lay.init_flat(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (16, mod.INPUT_DIM)) * 0.5
        y = jnp.arange(16, dtype=jnp.int32) % mod.CLASSES
        first = None
        for _ in range(8):
            flat, loss = step(flat, x, y, jnp.float32(0.1))
            first = float(loss) if first is None else first
        assert float(loss) < first

    def test_epoch_equals_stepped_loop(self):
        mod = lenet
        lay = mod.layout()
        nb, b = 3, 8
        step = train.make_train_step(mod)
        epoch = train.make_train_epoch(mod, nb)
        flat0 = lay.init_flat(jax.random.PRNGKey(0))
        xs = jax.random.normal(jax.random.PRNGKey(1), (nb, b, mod.INPUT_DIM))
        ys = (jnp.arange(nb * b, dtype=jnp.int32) % mod.CLASSES).reshape(nb, b)
        flat_e, _ = epoch(flat0, xs, ys, jnp.float32(0.05))
        flat_s = flat0
        for i in range(nb):
            flat_s, _ = step(flat_s, xs[i], ys[i], jnp.float32(0.05))
        np.testing.assert_allclose(flat_e, flat_s, rtol=1e-5, atol=1e-6)

    def test_eval_counts(self):
        mod = lenet
        lay = mod.layout()
        ev = train.make_eval(mod)
        flat = lay.init_flat(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (32, mod.INPUT_DIM))
        y = jnp.zeros((32,), jnp.int32)
        correct, loss = ev(flat, x, y)
        assert 0.0 <= float(correct) <= 32.0
        assert np.isfinite(float(loss))


class TestLosses:
    def test_ce_matches_manual(self):
        logits = jnp.array([[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]])
        labels = jnp.array([0, 1], jnp.int32)
        got = losses.softmax_cross_entropy(logits, labels, 3)
        logp = jax.nn.log_softmax(logits)
        want = -(logp[0, 0] + logp[1, 1]) / 2
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_accuracy_count(self):
        logits = jnp.array([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
        labels = jnp.array([0, 1, 1], jnp.int32)
        assert float(losses.accuracy_count(logits, labels)) == 2.0

    def test_mi_surrogate_monotone_in_variance(self):
        k = jax.random.PRNGKey(0)
        small = jax.random.normal(k, (64, 8)) * 0.1
        large = jax.random.normal(k, (64, 8)) * 2.0
        assert float(losses.mi_surrogate(large)) > float(losses.mi_surrogate(small))
