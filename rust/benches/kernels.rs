//! Kernel-level benchmarks: the per-operation costs behind the paper's
//! Table III (client encode / server decode delay) and the training-step
//! costs behind every accuracy figure.
//!
//! Run with `cargo bench --bench kernels` (optionally `-- --ratios 4,32`).

use hcfl::prelude::*;
use hcfl::util::bench::bench;
use hcfl::util::cli::Args;
use hcfl::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let ratios = args.usize_list_or("ratios", &[4, 8, 16, 32]).unwrap();
    let budget = args.f64_or("budget", 2.0).unwrap();
    let engine = Engine::from_artifacts(
        args.str_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
        1,
    )
    .expect("run `make artifacts` first");
    let mani = engine.manifest().clone();
    let mut rng = Rng::new(1);

    println!("== L1/L2 executable micro-benchmarks (CPU PJRT, interpret-lowered Pallas) ==");

    // ---- HCFL encode/decode per chunk (Table III client/server delay) ----
    for &ratio in &ratios {
        let ae = mani.autoencoder(1024, ratio).unwrap().clone();
        let params: Vec<f32> = (0..ae.d).map(|_| rng.normal() * 0.05).collect();
        let w: Vec<f32> = (0..1024).map(|_| rng.normal() * 0.1).collect();
        let enc_out = engine
            .call(
                &ae.encode,
                vec![
                    TensorValue::vec_f32(params.clone()),
                    TensorValue::vec_f32(w.clone()),
                ],
            )
            .unwrap();
        bench(&format!("hcfl_encode c1024 r{ratio}"), budget, 200, || {
            engine
                .call(
                    &ae.encode,
                    vec![
                        TensorValue::vec_f32(params.clone()),
                        TensorValue::vec_f32(w.clone()),
                    ],
                )
                .unwrap();
        });
        let code = enc_out[0].clone();
        let (lo, hi, mu, sd) = (
            enc_out[1].scalar().unwrap(),
            enc_out[2].scalar().unwrap(),
            enc_out[3].scalar().unwrap(),
            enc_out[4].scalar().unwrap(),
        );
        bench(&format!("hcfl_decode c1024 r{ratio}"), budget, 200, || {
            engine
                .call(
                    &ae.decode,
                    vec![
                        TensorValue::vec_f32(params.clone()),
                        code.clone(),
                        TensorValue::scalar_f32(lo),
                        TensorValue::scalar_f32(hi),
                        TensorValue::scalar_f32(mu),
                        TensorValue::scalar_f32(sd),
                    ],
                )
                .unwrap();
        });
    }

    // ---- T-FedAvg ternary quantization --------------------------------
    let w1024: Vec<f32> = (0..1024).map(|_| rng.normal() * 0.1).collect();
    bench("ternary_quantize c1024", budget, 500, || {
        engine
            .call("ternary_c1024", vec![TensorValue::vec_f32(w1024.clone())])
            .unwrap();
    });

    // ---- predictor training steps (behind Figs 8-12) -------------------
    for model in ["lenet", "fivecnn"] {
        let m = mani.model(model).unwrap().clone();
        let params: Vec<f32> = (0..m.d).map(|_| rng.normal() * 0.05).collect();
        let b = m.train_epoch.batch;
        let x: Vec<f32> = (0..b * m.input_dim).map(|_| rng.uniform(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(m.classes) as i32).collect();
        let step_exec = m.train_step[&b].clone();
        bench(&format!("{model} train_step b{b}"), budget, 100, || {
            engine
                .call(
                    &step_exec,
                    vec![
                        TensorValue::vec_f32(params.clone()),
                        TensorValue::f32(x.clone(), vec![b, m.input_dim]).unwrap(),
                        TensorValue::i32(y.clone(), vec![b]).unwrap(),
                        TensorValue::scalar_f32(0.05),
                    ],
                )
                .unwrap();
        });
        let nb = m.train_epoch.n_batches;
        let xs: Vec<f32> = (0..nb * b * m.input_dim)
            .map(|_| rng.uniform(0.0, 1.0))
            .collect();
        let ys: Vec<i32> = (0..nb * b).map(|_| rng.below(m.classes) as i32).collect();
        bench(
            &format!("{model} train_epoch b{b} n{nb} (scan)"),
            budget,
            50,
            || {
                engine
                    .call(
                        &m.train_epoch.name,
                        vec![
                            TensorValue::vec_f32(params.clone()),
                            TensorValue::f32(xs.clone(), vec![nb, b, m.input_dim]).unwrap(),
                            TensorValue::i32(ys.clone(), vec![nb, b]).unwrap(),
                            TensorValue::scalar_f32(0.05),
                        ],
                    )
                    .unwrap();
            },
        );
        let eb = m.eval.batch;
        let ex: Vec<f32> = (0..eb * m.input_dim).map(|_| rng.uniform(0.0, 1.0)).collect();
        let ey: Vec<i32> = (0..eb).map(|_| rng.below(m.classes) as i32).collect();
        bench(&format!("{model} eval b{eb}"), budget, 100, || {
            engine
                .call(
                    &m.eval.name,
                    vec![
                        TensorValue::vec_f32(params.clone()),
                        TensorValue::f32(ex.clone(), vec![eb, m.input_dim]).unwrap(),
                        TensorValue::i32(ey.clone(), vec![eb]).unwrap(),
                    ],
                )
                .unwrap();
        });
    }

    // ---- server-side aggregation (pure rust hot loop) ------------------
    let d = mani.model("lenet").unwrap().d;
    let updates: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..d).map(|_| rng.normal() * 0.1).collect())
        .collect();
    bench("aggregate running-average 10x lenet", budget, 2000, || {
        let mut agg = hcfl::fl::RunningAverage::new(d);
        for u in &updates {
            agg.push(u).unwrap();
        }
        std::hint::black_box(agg.finish().unwrap());
    });
}
