//! End-to-end round benchmarks: the worker-pool client stage at large m
//! (pool vs the old spawn-per-client pattern), then one full FedAvg
//! communication round per compression scheme (the system-level numbers
//! behind the paper's Tables I-III) plus the eq.-13 modelled air-time
//! comparison.
//!
//! The client-stage section is engine-free (fake training) and always
//! runs; the per-scheme rounds need the `pjrt` feature + artifacts and
//! skip themselves otherwise.
//!
//! Run with `cargo bench --bench round`.

use std::sync::Arc;

use hcfl::compression::{Compressor, Identity, Scheme};
use hcfl::config::ExperimentConfig;
use hcfl::coordinator::pool::{ClientPool, ClientRunner, FakeTrainRunner, RoundInputs, WorkSpec};
use hcfl::coordinator::Simulation;
use hcfl::data::{synthetic, DataSpec, Partition};
use hcfl::network::LinkModel;
use hcfl::prelude::*;
use hcfl::util::bench::bench;
use hcfl::util::cli::Args;

/// The ISSUE's large-m client stage: m=1000 fake-train clients through
/// the persistent pool at several sizes, against the pre-refactor
/// spawn-one-thread-per-client pattern.  The per-client work is
/// identical (seeded fake update + identity encode), so the difference
/// is pure scheduling overhead.
fn client_stage_bench(budget: f64) {
    let d = 802;
    let m = 1000;
    println!("== client stage at m={m} (fake train, d={d}): worker pool vs spawn-per-client ==");
    // Lazy fleet: the fake runner reads only shard row counts, so a
    // 1000-client fleet costs a seed vector, not 1000 rendered shards.
    let fleet = Arc::new(synthetic(
        &DataSpec {
            classes: 10,
            n_clients: m,
            per_client: 600,
            test_n: 16,
            server_n: 8,
            partition: Partition::Iid,
            size_skew: 0.0,
            lazy_shards: true,
        },
        7,
    ));
    let runner: Arc<dyn ClientRunner> = Arc::new(FakeTrainRunner::new(
        Arc::new(Identity) as Arc<dyn Compressor>,
        fleet,
    ));
    let global = Arc::new(vec![0.1f32; d]);
    let specs: Vec<WorkSpec> = (0..m)
        .map(|slot| WorkSpec {
            slot,
            client: slot,
            seed: 0x5EED ^ ((slot as u64) << 1),
        })
        .collect();
    let round = |global: &Arc<Vec<f32>>| RoundInputs {
        global: Arc::clone(global),
        epochs: 1,
        batch: 16,
        lr: 0.05,
        encode_deltas: true,
    };

    for threads in [1usize, 4, 16] {
        let pool = ClientPool::new(Arc::clone(&runner), threads, threads).unwrap();
        bench(
            &format!("client stage m={m} [pool x{threads}]"),
            budget,
            50,
            || {
                let msgs = pool.run_clients(round(&global), &specs).unwrap();
                assert_eq!(msgs.len(), m);
            },
        );
    }

    bench(
        &format!("client stage m={m} [spawn-per-client]"),
        budget,
        50,
        || {
            let inputs = round(&global);
            let mut done = 0usize;
            std::thread::scope(|s| {
                let (tx, rx) = std::sync::mpsc::channel();
                for spec in &specs {
                    let tx = tx.clone();
                    let runner = &runner;
                    let inputs = &inputs;
                    s.spawn(move || {
                        let _ = tx.send(runner.run(spec, inputs, 0));
                    });
                }
                drop(tx);
                for msg in rx {
                    msg.unwrap();
                    done += 1;
                }
            });
            assert_eq!(done, m);
        },
    );
}

fn bench_cfg(scheme: Scheme, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.scheme = scheme;
    cfg.n_clients = 8;
    cfg.participation = 0.5;
    cfg.rounds = 1;
    cfg.local_epochs = 1;
    cfg.engine_workers = workers;
    cfg.client_threads = workers;
    cfg.data = DataSpec {
        classes: 10,
        n_clients: 8,
        per_client: 600,
        test_n: 512,
        server_n: 600,
        partition: Partition::Iid,
        size_skew: 0.0,
        lazy_shards: false,
    };
    cfg.ae.steps = 60; // bench measures the round loop, not AE training
    cfg.ae.premodel_epochs = 2;
    cfg.use_ae_cache = true;
    cfg
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let workers = args.usize_or("workers", 4).unwrap();
    let budget = args.f64_or("budget", 5.0).unwrap();

    client_stage_bench(budget);

    if !hcfl::runtime::pjrt_enabled() {
        eprintln!("skipping per-scheme round benchmarks: built without the `pjrt` feature");
        return;
    }
    let artifacts = args
        .str_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .to_string();
    if !std::path::Path::new(&artifacts).join("manifest.json").is_file() {
        eprintln!("skipping per-scheme round benchmarks: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::from_artifacts(&artifacts, workers).expect("artifacts load");

    println!(
        "\n== end-to-end round benchmarks (4 clients/round, LeNet-5, {workers} engine workers) =="
    );
    let schemes = [
        Scheme::Fedavg,
        Scheme::Ternary,
        Scheme::TopK { keep: 0.15 },
        Scheme::Hcfl { ratio: 4 },
        Scheme::Hcfl { ratio: 32 },
    ];
    let mut wire_rows: Vec<(String, usize)> = Vec::new();
    for scheme in schemes {
        let mut sim = Simulation::new(&engine, bench_cfg(scheme, workers))
            .expect("simulation setup");
        let mut t = 0usize;
        let mut wire = 0usize;
        bench(&format!("round e2e [{}]", scheme.label()), budget, 20, || {
            t += 1;
            let rec = sim.run_round(t).expect("round");
            wire = rec.up_bytes as usize / 4; // per-client
        });
        wire_rows.push((scheme.label(), wire));
    }

    // ---- eq. 13 modelled air time per scheme ---------------------------
    println!("\n== modelled per-round air time, 10 clients sharing the default cell (eq. 13) ==");
    let link = LinkModel::default();
    let base = wire_rows
        .iter()
        .find(|(n, _)| n == "FedAvg")
        .map(|(_, w)| *w)
        .unwrap_or(1);
    for (name, wire) in &wire_rows {
        println!(
            "{name:<12} {:>10} B/client  uplink {:>8.3} s  reduction x{:.2}",
            wire,
            link.uplink_time(*wire, 10),
            base as f64 / (*wire).max(1) as f64
        );
    }
}
