//! End-to-end round benchmarks: the worker-pool client stage at large m
//! (pool vs the old spawn-per-client pattern), the K≥1000 aggregation
//! fold (single-threaded streaming baseline vs the deterministic
//! reduction tree), the session-driven deadline round with cross-round
//! carry-over on vs off, the K=10k round served over real TCP through
//! the `transport` layer (server + swarm loopback), then one full
//! FedAvg communication round per compression scheme (the system-level
//! numbers behind the paper's Tables I-III) plus the eq.-13 modelled
//! air-time comparison.
//!
//! The client-stage, aggregation and session sections are engine-free
//! (fake training / pure folds) and always run; the per-scheme rounds
//! need the `pjrt` feature + artifacts and skip themselves otherwise.
//!
//! Every section's results land in `BENCH_round.json` (per-case median
//! ns + throughput; see `util::bench::write_json`) so CI can archive the
//! perf trajectory across PRs.
//!
//! Run with `cargo bench --bench round`.

use std::sync::Arc;

use hcfl::compression::simd::{self, Level};
use hcfl::compression::{Compressor, Identity, Scheme};
use hcfl::config::ExperimentConfig;
use hcfl::coordinator::clock::{calibrated_deadline, RoundPolicy};
use hcfl::coordinator::pool::{
    reduce_tree, ClientPool, ClientRunner, FakeTrainRunner, RoundInputs, WorkSpec,
    WorkerCtx, WorkerPool,
};
use hcfl::coordinator::{CarryPolicy, Simulation};
use hcfl::data::{synthetic, DataSpec, Partition};
use hcfl::fl::{finish_tree, AggregatorKind, UpdateMeta, WeightedLeaf, TREE_FAN_IN};
use hcfl::network::LinkModel;
use hcfl::prelude::*;
use hcfl::util::bench::{bench_items, write_json, BenchResult};
use hcfl::util::cli::Args;
use hcfl::util::rng::Rng;

/// Canonical LEB128 encoder (mirrors the wire packer) for building the
/// varint-decode bench input.
fn push_varint(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// The codec hot-path kernels at ~1M elements: the portable scalar
/// reference against the runtime-dispatched tier.  Returns the measured
/// (pack, unpack) speedups so `main` can enforce the `--gate-speedup`
/// floor on AVX2 hosts; on a scalar-only host (or under
/// `HCFL_FORCE_SCALAR=1`) both arms run the same code and the speedups
/// are ~1x by construction.
fn wire_kernel_bench(budget: f64, results: &mut Vec<BenchResult>) -> (f64, f64) {
    let n = 1 << 20;
    let lvl = simd::level().label();
    println!("\n== codec kernels at n={n}: scalar reference vs dispatched [{lvl}] ==");
    let mut rng = Rng::new(11);

    // speedup of the later case over the earlier, by median
    let speedup = |results: &[BenchResult]| -> f64 {
        let a = &results[results.len() - 2];
        let b = &results[results.len() - 1];
        let s = a.p50_s / b.p50_s.max(1e-12);
        println!("  -> {:.2}x vs scalar", s);
        s
    };

    // ternary 2-bit pack
    let q: Vec<i8> = (0..n).map(|_| [0i8, 1, -1][rng.below(3)]).collect();
    let mut packed = Vec::with_capacity(n / 4 + 1);
    results.push(bench_items("ternary pack 1M [scalar]", budget, 500, n, || {
        packed.clear();
        simd::scalar::pack_2bit(&q, &mut packed).unwrap();
    }));
    results.push(bench_items("ternary pack 1M [dispatched]", budget, 500, n, || {
        packed.clear();
        simd::pack_2bit(&q, &mut packed).unwrap();
    }));
    let pack_speedup = speedup(results);

    // ternary 2-bit unpack + dequantize (`packed` holds the last pack)
    let mut dst = vec![0.0f32; n];
    results.push(bench_items("ternary unpack 1M [scalar]", budget, 500, n, || {
        simd::scalar::unpack_2bit_f32(&packed, n, 0.02, &mut dst).unwrap();
    }));
    results.push(bench_items("ternary unpack 1M [dispatched]", budget, 500, n, || {
        simd::unpack_2bit_f32(&packed, n, 0.02, &mut dst).unwrap();
    }));
    let unpack_speedup = speedup(results);

    // varint decode, Top-K-shaped gaps (mostly single-byte)
    let vals: Vec<u32> = (0..n)
        .map(|i| if i % 13 == 0 { 5_000 } else { (i % 100) as u32 })
        .collect();
    let mut vbytes = Vec::new();
    for &v in &vals {
        push_varint(v, &mut vbytes);
    }
    let mut idx = vec![0u32; n];
    results.push(bench_items("varint decode 1M [scalar]", budget, 500, n, || {
        let mut pos = 0usize;
        simd::scalar::decode_varints(&vbytes, &mut pos, &mut idx).unwrap();
    }));
    results.push(bench_items("varint decode 1M [dispatched]", budget, 500, n, || {
        let mut pos = 0usize;
        simd::decode_varints(&vbytes, &mut pos, &mut idx).unwrap();
    }));
    speedup(results);

    // raw f32 wire decode (bulk LE move vs per-element)
    let floats: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let mut fbytes = Vec::new();
    simd::pack_f32_le(&floats, &mut fbytes);
    results.push(bench_items("f32-le unpack 1M [scalar]", budget, 500, n, || {
        simd::scalar::unpack_f32_le(&fbytes, &mut dst);
    }));
    results.push(bench_items("f32-le unpack 1M [dispatched]", budget, 500, n, || {
        simd::unpack_f32_le(&fbytes, &mut dst);
    }));
    speedup(results);

    // the aggregation fold's axpy
    let x: Vec<f32> = (0..n).map(|_| rng.normal() * 1e-4).collect();
    let mut acc = vec![0.0f32; n];
    results.push(bench_items("axpy add 1M [scalar]", budget, 500, n, || {
        simd::scalar::add_assign(&mut acc, &x);
    }));
    results.push(bench_items("axpy add 1M [dispatched]", budget, 500, n, || {
        simd::add_assign(&mut acc, &x);
    }));
    speedup(results);

    (pack_speedup, unpack_speedup)
}

/// The ISSUE's large-m client stage: m=1000 fake-train clients through
/// the persistent pool at several sizes, against the pre-refactor
/// spawn-one-thread-per-client pattern.  The per-client work is
/// identical (seeded fake update + identity encode + wire packing), so
/// the difference is pure scheduling overhead.
fn client_stage_bench(budget: f64, results: &mut Vec<BenchResult>) {
    let d = 802;
    let m = 1000;
    println!("== client stage at m={m} (fake train, d={d}): worker pool vs spawn-per-client ==");
    // Lazy fleet: the fake runner reads only shard row counts, so a
    // 1000-client fleet costs a seed vector, not 1000 rendered shards.
    let fleet = Arc::new(synthetic(
        &DataSpec {
            classes: 10,
            n_clients: m,
            per_client: 600,
            test_n: 16,
            server_n: 8,
            partition: Partition::Iid,
            size_skew: 0.0,
            lazy_shards: true,
        },
        7,
    ));
    let runner: Arc<dyn ClientRunner> = Arc::new(FakeTrainRunner::new(
        Arc::new(Identity) as Arc<dyn Compressor>,
        fleet,
    ));
    let global = Arc::new(vec![0.1f32; d]);
    let specs: Vec<WorkSpec> = (0..m)
        .map(|slot| WorkSpec {
            slot,
            client: slot,
            seed: 0x5EED ^ ((slot as u64) << 1),
            codec: Scheme::Fedavg.codec_tag(), // the Identity entry of the single-codec bank
        })
        .collect();
    let round = |global: &Arc<Vec<f32>>| RoundInputs {
        global: Arc::clone(global),
        epochs: 1,
        batch: 16,
        lr: 0.05,
        encode_deltas: true,
    };

    for threads in [1usize, 4, 16] {
        let pool = ClientPool::new(Arc::clone(&runner), threads, threads).unwrap();
        results.push(bench_items(
            &format!("client stage m={m} [pool x{threads}]"),
            budget,
            50,
            m,
            || {
                let msgs = pool.run_clients(round(&global), &specs).unwrap();
                assert_eq!(msgs.len(), m);
            },
        ));
    }

    results.push(bench_items(
        &format!("client stage m={m} [spawn-per-client]"),
        budget,
        50,
        m,
        || {
            let inputs = round(&global);
            let mut done = 0usize;
            std::thread::scope(|s| {
                let (tx, rx) = std::sync::mpsc::channel();
                for spec in &specs {
                    let tx = tx.clone();
                    let runner = &runner;
                    let inputs = &inputs;
                    s.spawn(move || {
                        let mut ctx = WorkerCtx {
                            thread_idx: 0,
                            engine_worker: 0,
                            scratch: Default::default(),
                        };
                        let _ = tx.send(runner.run(spec, inputs, &mut ctx));
                    });
                }
                drop(tx);
                for msg in rx {
                    msg.unwrap();
                    done += 1;
                }
            });
            assert_eq!(done, m);
        },
    ));
}

/// The ISSUE's K≥1000 aggregation fold: the pre-PR single-threaded
/// streaming mean against the reduction tree on 1, 4 and 16 pool
/// threads.  Sample-weighted leaves, the heavier of the two rules.
/// Both arms start from an owned clone of each decoded update — that is
/// what the round pipeline hands either fold — so the comparison
/// measures the fold, not an asymmetric memcpy.
fn aggregation_bench(budget: f64, results: &mut Vec<BenchResult>) {
    let k = 1024usize;
    let d = 8192usize;
    println!("\n== aggregation fold at K={k}, d={d}: streaming baseline vs reduction tree ==");
    let mut rng = Rng::new(99);
    let updates: Vec<(f64, Vec<f32>)> = (0..k)
        .map(|i| {
            (
                (100 + (i * 31) % 500) as f64,
                (0..d).map(|_| rng.normal() * 0.2).collect(),
            )
        })
        .collect();

    results.push(bench_items(
        &format!("aggregate K={k} [streaming baseline]"),
        budget,
        50,
        k,
        || {
            let mut agg = AggregatorKind::SampleWeighted.build(d);
            for (i, (w, x)) in updates.iter().enumerate() {
                let owned = x.clone();
                agg.push(
                    &owned,
                    &UpdateMeta {
                        client: i,
                        n_samples: *w as usize,
                        arrival_s: i as f64,
                    },
                )
                .unwrap();
            }
            assert_eq!(agg.finish().unwrap().len(), d);
        },
    ));

    for threads in [1usize, 4, 16] {
        let pool = WorkerPool::new(threads, threads).unwrap();
        results.push(bench_items(
            &format!("aggregate K={k} [tree x{threads}]"),
            budget,
            50,
            k,
            || {
                let leaves: Vec<WeightedLeaf> = updates
                    .iter()
                    .map(|(w, x)| WeightedLeaf::new(*w, x.clone()))
                    .collect();
                let root = reduce_tree(&pool, leaves, TREE_FAN_IN).unwrap().unwrap();
                assert_eq!(finish_tree(root).unwrap().len(), d);
            },
        ));
    }
}

/// The session-driven round at m=128 under a calibrated deadline with
/// 20% 8x stragglers: carry off (late uploads discarded, the
/// pre-session behavior) vs carry on (late uploads decoded, carried and
/// folded into the next round).  Engine-free fake training, so the
/// measured cost is the session lifecycle itself — broadcast, submit,
/// resolve, parallel decode, carry bookkeeping and the reduction tree.
fn session_round_bench(budget: f64, results: &mut Vec<BenchResult>) {
    let m = 128;
    println!("\n== session-driven deadline round at m={m}, 20% stragglers: carry off vs on ==");
    for (label, carry) in [
        ("carry off", CarryPolicy::Discard),
        (
            "carry on",
            CarryPolicy::CarryDiscounted {
                lambda: 0.5,
                max_age_rounds: 2,
            },
        ),
    ] {
        let mut cfg = ExperimentConfig::mnist(Scheme::TopK { keep: 0.1 }, 1_000_000);
        cfg.model = "fake".into();
        cfg.fake_train = true;
        cfg.n_clients = 256;
        cfg.data.n_clients = 256;
        cfg.participation = 0.5;
        cfg.batch = 16;
        cfg.data.per_client = 64;
        cfg.data.test_n = 64;
        cfg.data.server_n = 16;
        cfg.client_threads = 8;
        cfg.engine_workers = 2;
        cfg.scenario.devices = DevicePreset::Stragglers {
            frac: 0.2,
            slowdown: 8.0,
        };
        cfg.scenario.carry = carry;
        let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
        let mut sim = Simulation::new(&engine, cfg).unwrap();
        // one synchronous probe fixes the deadline's absolute scale
        let probe = sim.run_round(1).unwrap();
        let t_max = calibrated_deadline(&sim.cfg.link, &probe, 3.0);
        sim.cfg.scenario.policy = RoundPolicy::Deadline { t_max_s: t_max };
        let mut t = 1usize;
        results.push(bench_items(
            &format!("session round m={m} deadline [{label}]"),
            budget,
            50,
            m,
            || {
                t += 1;
                let rec = sim.run_round(t).expect("session round");
                assert!(rec.selected == m);
            },
        ));
    }
}

/// The K=10k round makespan: one session-driven synchronous round over
/// a 10 000-client fleet in fake-train mode — the population the SIMD +
/// zero-copy decode path is gated on.  Selection, the pooled client
/// stage, wire packing, arena decode and the reduction tree all run at
/// full scale; only the local training is faked.
fn k10_round_bench(budget: f64, results: &mut Vec<BenchResult>) {
    let m = 10_000;
    println!("\n== K=10k round makespan (fake train, TopK 10%, 8 client threads) ==");
    let mut cfg = ExperimentConfig::mnist(Scheme::TopK { keep: 0.1 }, 1_000_000);
    cfg.model = "fake".into();
    cfg.fake_train = true;
    cfg.n_clients = m;
    cfg.data.n_clients = m;
    cfg.participation = 1.0;
    cfg.batch = 16;
    cfg.data.per_client = 64;
    cfg.data.test_n = 16;
    cfg.data.server_n = 8;
    cfg.data.lazy_shards = true;
    cfg.client_threads = 8;
    cfg.engine_workers = 2;
    let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
    let mut sim = Simulation::new(&engine, cfg).unwrap();
    let mut t = 0usize;
    results.push(bench_items(
        &format!("session round m={m} [K=10k sync]"),
        budget,
        20,
        m,
        || {
            t += 1;
            let rec = sim.run_round(t).expect("K=10k round");
            assert_eq!(rec.selected, m);
        },
    ));
}

/// Flat vs hierarchical fold at K=10k and K=100k (DESIGN.md §10): the
/// same fake-train synchronous round as [`k10_round_bench`], folded by
/// the single root session and by a 16-shard edge tier.  The two arms
/// compute bit-identical global models (pinned in
/// `tests/edge_sharding.rs`), so the delta is pure fold scheduling:
/// per-shard arenas and pools against one contended arena and a single
/// `reduce_tree` over all K leaves.
fn sharded_round_bench(budget: f64, results: &mut Vec<BenchResult>) {
    for m in [10_000usize, 100_000] {
        let k_label = if m == 10_000 { "K=10k" } else { "K=100k" };
        println!("\n== {k_label} round makespan: flat fold vs 16 edge shards ==");
        for edge in [0usize, 16] {
            let mut cfg = ExperimentConfig::mnist(Scheme::TopK { keep: 0.1 }, 1_000_000);
            cfg.model = "fake".into();
            cfg.fake_train = true;
            cfg.n_clients = m;
            cfg.data.n_clients = m;
            cfg.participation = 1.0;
            cfg.batch = 16;
            cfg.data.per_client = 64;
            cfg.data.test_n = 16;
            cfg.data.server_n = 8;
            cfg.data.lazy_shards = true;
            cfg.send_exact = false;
            cfg.client_threads = 8;
            cfg.engine_workers = 2;
            cfg.edge_shards = edge;
            let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
            let mut sim = Simulation::new(&engine, cfg).unwrap();
            let mut t = 0usize;
            let arm = if edge == 0 {
                "flat".to_string()
            } else {
                format!("E={edge}")
            };
            results.push(bench_items(
                &format!("sharded round {k_label} [{arm}]"),
                budget,
                10,
                m,
                || {
                    t += 1;
                    let rec = sim.run_round(t).expect("sharded round");
                    assert_eq!(rec.selected, m);
                },
            ));
        }
    }
}

/// The transport acceptance number: the same K=10k synchronous round as
/// [`k10_round_bench`], but served over real TCP — a `RoundServer`
/// owning the session on one side, 4 swarm worker connections
/// replaying the fleet on the other (`transport`, DESIGN.md §8).  The
/// server, its listener and its session persist across iterations; each
/// iteration reconnects a fresh swarm, so the measured cost includes
/// accept + handshake, the RoundOpen broadcast, 10k framed uploads and
/// the server-side decode/fold — the full serving path.
fn loopback_bench(budget: f64, results: &mut Vec<BenchResult>) {
    let m = 10_000;
    let workers = 4;
    println!("\n== K=10k loopback round over real TCP ({workers} swarm connections) ==");
    let mut cfg = hcfl::transport::demo_config(Scheme::TopK { keep: 0.1 }, m, 1, 42);
    cfg.client_threads = 8;
    let manifest = Manifest::synthetic();
    let mut server = RoundServer::new(&manifest, cfg.clone()).expect("round server");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    results.push(bench_items(
        &format!("loopback round m={m} [K=10k tcp]"),
        budget,
        10,
        m,
        || {
            let swarm_cfg = cfg.clone();
            let swarm_addr = addr.clone();
            let swarm = std::thread::spawn(move || {
                hcfl::transport::run_swarm(&swarm_addr, &swarm_cfg, workers, 0.0)
                    .expect("swarm session")
            });
            let recs = server.serve(&listener, workers, 1).expect("loopback round");
            assert_eq!(recs[0].selected, m);
            swarm.join().expect("swarm thread");
        },
    ));
}

fn bench_cfg(scheme: Scheme, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.scheme = scheme;
    cfg.n_clients = 8;
    cfg.participation = 0.5;
    cfg.rounds = 1;
    cfg.local_epochs = 1;
    cfg.engine_workers = workers;
    cfg.client_threads = workers;
    cfg.data = DataSpec {
        classes: 10,
        n_clients: 8,
        per_client: 600,
        test_n: 512,
        server_n: 600,
        partition: Partition::Iid,
        size_skew: 0.0,
        lazy_shards: false,
    };
    cfg.ae.steps = 60; // bench measures the round loop, not AE training
    cfg.ae.premodel_epochs = 2;
    cfg.use_ae_cache = true;
    cfg
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let workers = args.usize_or("workers", 4).unwrap();
    let budget = args.f64_or("budget", 5.0).unwrap();
    let json_path = args
        .str_or("json", "BENCH_round.json")
        .to_string();
    let mut results: Vec<BenchResult> = Vec::new();

    let (pack_speedup, unpack_speedup) = wire_kernel_bench(budget, &mut results);
    client_stage_bench(budget, &mut results);
    aggregation_bench(budget, &mut results);
    session_round_bench(budget, &mut results);
    k10_round_bench(budget, &mut results);
    sharded_round_bench(budget, &mut results);
    loopback_bench(budget, &mut results);

    // `--gate-speedup X` enforces the kernel floor (the ISSUE's >=4x
    // ternary pack/unpack target) after the report is written.  Only
    // meaningful on AVX2 hosts: SSE2 leaves the unpack side scalar, and
    // on a scalar host (or under HCFL_FORCE_SCALAR=1) both arms are
    // literally the same code.
    let gate = args.f64_or("gate-speedup", 0.0).unwrap();
    let emit = |results: &[BenchResult]| {
        let path = std::path::Path::new(&json_path);
        write_json(path, "round", results).expect("write bench json");
        println!("\nwrote {} ({} cases)", path.display(), results.len());
        if gate > 0.0 && simd::level() == Level::Avx2 {
            println!(
                "kernel gate: pack {pack_speedup:.2}x, unpack {unpack_speedup:.2}x (floor {gate}x)"
            );
            if pack_speedup < gate || unpack_speedup < gate {
                eprintln!("kernel speedup below the {gate}x gate");
                std::process::exit(1);
            }
        }
    };

    if !hcfl::runtime::pjrt_enabled() {
        eprintln!("skipping per-scheme round benchmarks: built without the `pjrt` feature");
        emit(&results);
        return;
    }
    let artifacts = args
        .str_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .to_string();
    if !std::path::Path::new(&artifacts).join("manifest.json").is_file() {
        eprintln!("skipping per-scheme round benchmarks: no artifacts (run `make artifacts`)");
        emit(&results);
        return;
    }
    let engine = Engine::from_artifacts(&artifacts, workers).expect("artifacts load");

    println!(
        "\n== end-to-end round benchmarks (4 clients/round, LeNet-5, {workers} engine workers) =="
    );
    let schemes = [
        Scheme::Fedavg,
        Scheme::Ternary,
        Scheme::TopK { keep: 0.15 },
        Scheme::Hcfl { ratio: 4 },
        Scheme::Hcfl { ratio: 32 },
    ];
    let mut wire_rows: Vec<(String, usize)> = Vec::new();
    for scheme in schemes {
        let mut sim = Simulation::new(&engine, bench_cfg(scheme, workers))
            .expect("simulation setup");
        let mut t = 0usize;
        let mut wire = 0usize;
        results.push(bench_items(
            &format!("round e2e [{}]", scheme.label()),
            budget,
            20,
            4,
            || {
                t += 1;
                let rec = sim.run_round(t).expect("round");
                wire = rec.up_bytes as usize / 4; // per-client
            },
        ));
        wire_rows.push((scheme.label(), wire));
    }

    // ---- eq. 13 modelled air time per scheme ---------------------------
    println!("\n== modelled per-round air time, 10 clients sharing the default cell (eq. 13) ==");
    let link = LinkModel::default();
    let base = wire_rows
        .iter()
        .find(|(n, _)| n == "FedAvg")
        .map(|(_, w)| *w)
        .unwrap_or(1);
    for (name, wire) in &wire_rows {
        println!(
            "{name:<12} {:>10} B/client  uplink {:>8.3} s  reduction x{:.2}",
            wire,
            link.uplink_time(*wire, 10),
            base as f64 / (*wire).max(1) as f64
        );
    }
    emit(&results);
}
