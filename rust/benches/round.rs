//! End-to-end round benchmarks: one full FedAvg communication round per
//! compression scheme (the system-level numbers behind the paper's
//! Tables I-III), plus the eq.-13 modelled air-time comparison.
//!
//! Run with `cargo bench --bench round`.

use hcfl::compression::Scheme;
use hcfl::config::ExperimentConfig;
use hcfl::coordinator::Simulation;
use hcfl::data::DataSpec;
use hcfl::network::LinkModel;
use hcfl::prelude::*;
use hcfl::util::bench::bench;
use hcfl::util::cli::Args;

fn bench_cfg(scheme: Scheme, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.scheme = scheme;
    cfg.n_clients = 8;
    cfg.participation = 0.5;
    cfg.rounds = 1;
    cfg.local_epochs = 1;
    cfg.engine_workers = workers;
    cfg.data = DataSpec {
        classes: 10,
        n_clients: 8,
        per_client: 600,
        test_n: 512,
        server_n: 600,
    };
    cfg.ae.steps = 60; // bench measures the round loop, not AE training
    cfg.ae.premodel_epochs = 2;
    cfg.use_ae_cache = true;
    cfg
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let workers = args.usize_or("workers", 4).unwrap();
    let budget = args.f64_or("budget", 5.0).unwrap();
    let engine = Engine::from_artifacts(
        args.str_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
        workers,
    )
    .expect("run `make artifacts` first");

    println!("== end-to-end round benchmarks (4 clients/round, LeNet-5, {workers} engine workers) ==");
    let schemes = [
        Scheme::Fedavg,
        Scheme::Ternary,
        Scheme::TopK { keep: 0.15 },
        Scheme::Hcfl { ratio: 4 },
        Scheme::Hcfl { ratio: 32 },
    ];
    let mut wire_rows: Vec<(String, usize)> = Vec::new();
    for scheme in schemes {
        let mut sim = Simulation::new(&engine, bench_cfg(scheme, workers))
            .expect("simulation setup");
        let mut t = 0usize;
        let mut wire = 0usize;
        bench(&format!("round e2e [{}]", scheme.label()), budget, 20, || {
            t += 1;
            let rec = sim.run_round(t).expect("round");
            wire = rec.up_bytes as usize / 4; // per-client
        });
        wire_rows.push((scheme.label(), wire));
    }

    // ---- eq. 13 modelled air time per scheme ---------------------------
    println!("\n== modelled per-round air time, 10 clients sharing the default cell (eq. 13) ==");
    let link = LinkModel::default();
    let base = wire_rows
        .iter()
        .find(|(n, _)| n == "FedAvg")
        .map(|(_, w)| *w)
        .unwrap_or(1);
    for (name, wire) in &wire_rows {
        println!(
            "{name:<12} {:>10} B/client  uplink {:>8.3} s  reduction x{:.2}",
            wire,
            link.uplink_time(*wire, 10),
            base as f64 / (*wire).max(1) as f64
        );
    }
}
