//! CI bench-regression gate: compare a fresh `BENCH_round.json` against
//! the committed `BENCH_baseline.json` and fail on meaningful
//! throughput regressions.
//!
//!   bench_check <baseline.json> <fresh.json> [--tolerance 0.25]
//!               [--require-armed]
//!
//! Baseline entries with a numeric `throughput_per_s` are enforced: the
//! fresh run must reach at least `(1 - tolerance)` of the recorded
//! throughput (default tolerance 25%, generous enough for shared CI
//! runners).  Entries whose baseline throughput is `null` are
//! record-only — they pin the case *names* so renames/disappearances
//! are caught, but carry no number to regress against (the bootstrap
//! state: refresh with `cargo bench --bench round` on a quiet machine,
//! then `cp BENCH_round.json BENCH_baseline.json` and commit).  Ungated
//! cases are listed by name — and appended to the job summary when
//! `GITHUB_STEP_SUMMARY` is set — so a baseline that
//! silently enforces nothing is visible in the CI log;
//! `--require-armed` hardens that warning into a failure (for repos
//! past the bootstrap state that must never regress to record-only).
//!
//! Exit codes: 0 ok, 1 regression/missing case (or ungated cases under
//! `--require-armed`), 2 usage or unreadable input.

use std::process::exit;

use hcfl::util::json::Value;

/// `(name, throughput_per_s)` rows of a bench report.
fn load(path: &str) -> Result<Vec<(String, Option<f64>)>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let v = Value::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let results = v
        .get("results")
        .and_then(|r| r.as_arr().map(<[Value]>::to_vec))
        .map_err(|e| format!("{path}: {e}"))?;
    let mut rows = Vec::with_capacity(results.len());
    for r in &results {
        let name = r
            .get("name")
            .and_then(|n| n.as_str().map(str::to_string))
            .map_err(|e| format!("{path}: {e}"))?;
        let tput = match r.get("throughput_per_s") {
            Ok(Value::Null) | Err(_) => None,
            Ok(t) => Some(t.as_f64().map_err(|e| format!("{path}: {name}: {e}"))?),
        };
        rows.push((name, tput));
    }
    Ok(rows)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut tolerance = 0.25f64;
    let mut require_armed = false;
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--tolerance" {
            let Some(t) = argv.get(i + 1).and_then(|s| s.parse::<f64>().ok()) else {
                eprintln!("--tolerance needs a number in (0, 1)");
                exit(2);
            };
            tolerance = t;
            i += 2;
        } else if argv[i] == "--require-armed" {
            require_armed = true;
            i += 1;
        } else {
            paths.push(argv[i].clone());
            i += 1;
        }
    }
    if paths.len() != 2 || !(0.0..1.0).contains(&tolerance) {
        eprintln!(
            "usage: bench_check <baseline.json> <fresh.json> [--tolerance 0.25] [--require-armed]"
        );
        exit(2);
    }
    let baseline = match load(&paths[0]) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("cannot read baseline: {e}");
            eprintln!("bootstrap: cargo bench --bench round && cp BENCH_round.json BENCH_baseline.json");
            exit(2);
        }
    };
    let fresh = match load(&paths[1]) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("cannot read fresh report: {e}");
            exit(2);
        }
    };

    let mut failures = 0usize;
    let mut enforced = 0usize;
    let mut ungated: Vec<&str> = Vec::new();
    for (name, base_tput) in &baseline {
        let Some((_, fresh_tput)) = fresh.iter().find(|(n, _)| n == name) else {
            eprintln!("FAIL {name}: case missing from the fresh report");
            failures += 1;
            continue;
        };
        let Some(base) = base_tput else {
            println!("  ok {name}: record-only baseline (no throughput pinned)");
            ungated.push(name);
            continue;
        };
        enforced += 1;
        let Some(now) = fresh_tput else {
            eprintln!("FAIL {name}: baseline has {base:.0}/s but the fresh run reports none");
            failures += 1;
            continue;
        };
        let floor = base * (1.0 - tolerance);
        if *now < floor {
            eprintln!(
                "FAIL {name}: {now:.0}/s is {:.1}% below the {base:.0}/s baseline \
                 (tolerance {:.0}%)",
                100.0 * (1.0 - now / base),
                100.0 * tolerance
            );
            failures += 1;
        } else {
            println!("  ok {name}: {now:.0}/s vs baseline {base:.0}/s");
        }
    }
    println!(
        "bench_check: {} baseline cases, {enforced} enforced, {failures} failures",
        baseline.len()
    );
    if !ungated.is_empty() {
        eprintln!(
            "WARN: {} cases ungated (null baseline throughput — the regression gate \
             enforces nothing for them; arm with `cargo bench --bench round` on a quiet \
             machine, then `cp BENCH_round.json BENCH_baseline.json`):",
            ungated.len()
        );
        for name in &ungated {
            eprintln!("WARN:   {name}");
        }
        // Surface the still-null rows in the GitHub job summary so the
        // bootstrap debt is visible without opening the log.
        if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
            let mut md = format!(
                "### bench_check: {} record-only baseline case(s)\n\n",
                ungated.len()
            );
            for name in &ungated {
                md.push_str(&format!("- `{name}` — no throughput pinned\n"));
            }
            md.push_str(
                "\nArm them with `cargo bench --bench round` on a quiet machine, then \
                 `cp BENCH_round.json BENCH_baseline.json`.\n",
            );
            if let Err(e) = append_file(&summary, &md) {
                eprintln!("WARN: cannot write job summary {summary}: {e}");
            }
        }
        if require_armed {
            eprintln!(
                "FAIL: --require-armed set and {} cases are still record-only",
                ungated.len()
            );
            exit(1);
        }
    }
    if failures > 0 {
        exit(1);
    }
}

/// Append to the `$GITHUB_STEP_SUMMARY` file (created if absent).
fn append_file(path: &str, text: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    f.write_all(text.as_bytes())
}
