//! `hcfl-daemon`: the crash-tolerant campaign daemon (DESIGN.md §9).
//! Reads a queue file of experiment jobs, drives each campaign round by
//! round, and writes an atomic snapshot after every round — kill it at
//! any point (including `SIGKILL`) and the next invocation resumes from
//! the snapshot, producing final models bit-identical to an
//! uninterrupted run.
//!
//! ```text
//! hcfl-daemon --queue campaigns.q --dir state/ --round-hold-ms 200
//! ```
//!
//! Queue file: one job per line,
//! `name scheme clients rounds seed driver [addr conns] [edge=<E>]
//! [policy=<p>] [opt=<o>]` — scheme is `fedavg`, `topk@<keep>` or
//! `ternary`, driver is `inproc` or `tcp <addr> <conns>` (the swarm
//! dials in separately, e.g. `hcfl-swarm --redial 600`), and the
//! optional trailing tokens fold the round through `E` edge-aggregation
//! shards (DESIGN.md §10; same bits, so snapshots resume across any
//! `E`), pick a per-client codec policy (`policy=uplink@0.5`,
//! `policy=makespan@0.4`) and a server optimizer (`opt=fedavgm`,
//! `opt=fedadam`) — DESIGN.md §11.  Completed jobs (their
//! `<name>.model` exists in `--dir`) are skipped, so re-running the
//! daemon over the same queue is idempotent.
//!
//! A single job can also be given inline instead of `--queue`:
//!
//! ```text
//! hcfl-daemon --name demo --scheme topk@0.2 --clients 64 --rounds 5 \
//!             --seed 42 --policy uplink@0.5 --server-opt fedadam --dir state/
//! ```

use std::time::Duration;

use hcfl::daemon::{parse_queue, Daemon, JobDriver, JobSpec};
use hcfl::error::{HcflError, Result};
use hcfl::util::cli::Args;

fn inline_job(args: &Args) -> Result<Vec<JobSpec>> {
    let text = format!(
        "{} {} {} {} {} {}{}{}{}",
        args.str_or("name", "job"),
        args.str_or("scheme", "fedavg"),
        args.usize_or("clients", 64)?,
        args.usize_or("rounds", 3)?,
        args.u64_or("seed", 42)?,
        match args.str_or("addr", "") {
            "" => "inproc".to_string(),
            addr => format!("tcp {addr} {}", args.usize_or("conns", 4)?),
        },
        match args.usize_or("edge", 0)? {
            0 => String::new(),
            e => format!(" edge={e}"),
        },
        match args.str_or("policy", "") {
            "" => String::new(),
            p => format!(" policy={p}"),
        },
        match args.str_or("server-opt", "") {
            "" => String::new(),
            o => format!(" opt={o}"),
        }
    );
    parse_queue(&text)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let jobs = match args.str_or("queue", "") {
        "" => inline_job(&args)?,
        path => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| HcflError::Config(format!("cannot read queue {path}: {e}")))?;
            parse_queue(&text)?
        }
    };
    if jobs.is_empty() {
        return Err(HcflError::Config("queue has no jobs".into()));
    }
    let mut daemon = Daemon::new(args.str_or("dir", "daemon-state"));
    daemon.verbose = !args.flag("quiet");
    daemon.set_round_hold(Duration::from_millis(args.u64_or("round-hold-ms", 0)?));
    if daemon.verbose {
        for job in &jobs {
            let mut driver = match &job.driver {
                JobDriver::InProcess => "inproc".to_string(),
                JobDriver::Tcp { addr, conns } => format!("tcp {addr} x{conns}"),
            };
            if job.edge_shards > 0 {
                driver.push_str(&format!(", {} edge shards", job.edge_shards));
            }
            if job.policy != hcfl::control::CodecPolicy::Static {
                driver.push_str(&format!(", policy {}", job.policy.label()));
            }
            if job.server_opt != hcfl::control::ServerOptKind::Sgd {
                driver.push_str(&format!(", opt {}", job.server_opt.label()));
            }
            eprintln!(
                "hcfl-daemon: queued {} ({}, K={}, {} rounds, seed {}, {driver})",
                job.name,
                job.scheme.label(),
                job.n_clients,
                job.rounds,
                job.seed,
            );
        }
    }
    daemon.run_queue(&jobs)?;
    if daemon.verbose {
        eprintln!("hcfl-daemon: queue drained");
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("hcfl-daemon: {e}");
        std::process::exit(1);
    }
}
