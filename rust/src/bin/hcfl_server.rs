//! `hcfl-server`: the round server end of the wire transport
//! (DESIGN.md §8).  Owns an `FlSession`, accepts swarm connections and
//! pumps `begin_round → submit/mark_dropped → resolve → finalize` from
//! real sockets, carrying stragglers across rounds.
//!
//! Pair it with `hcfl-swarm` started with the same scheme/clients/seed:
//!
//! ```text
//! hcfl-server --addr 127.0.0.1:7878 --clients 1000 --rounds 3 \
//!             --conns 4 --scheme topk --keep 0.1 --seed 42
//! ```

use std::net::TcpListener;
use std::time::Duration;

use hcfl::compression::Scheme;
use hcfl::control::{CodecPolicy, ServerOptKind};
use hcfl::error::{HcflError, Result};
use hcfl::runtime::Manifest;
use hcfl::transport::{demo_config, RoundServer};
use hcfl::util::cli::Args;

fn parse_scheme(args: &Args) -> Result<Scheme> {
    match args.str_or("scheme", "topk") {
        "fedavg" => Ok(Scheme::Fedavg),
        "ternary" => Ok(Scheme::Ternary),
        "topk" => Ok(Scheme::TopK {
            keep: args.f64_or("keep", 0.1)?,
        }),
        other => Err(HcflError::Config(format!(
            "--scheme must be fedavg, topk or ternary (engine-free), got '{other}'"
        ))),
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let addr = args.str_or("addr", "127.0.0.1:7878").to_string();
    let clients = args.usize_or("clients", 1000)?;
    let rounds = args.usize_or("rounds", 3)?;
    let conns = args.usize_or("conns", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let scheme = parse_scheme(&args)?;

    let mut cfg = demo_config(scheme, clients, rounds, seed);
    // Control plane (DESIGN.md §11): a per-client codec policy and a
    // server optimizer.  The swarm must be started with the same
    // --policy so its codec bank covers every assigned tag.
    cfg.codec_policy = CodecPolicy::parse(args.str_or("policy", "static"))?;
    cfg.server_opt = ServerOptKind::parse(args.str_or("server-opt", "sgd"))?;
    let manifest = Manifest::synthetic();
    let mut server = RoundServer::new(&manifest, cfg)?;
    // Liveness guards: a client that connects and stalls before Hello
    // is retired after the handshake timeout; a connection that owes
    // updates past the round deadline is retired like a malformed one.
    // 0 means "wait forever".
    let handshake_ms = args.u64_or("handshake-timeout-ms", 30_000)?;
    server.set_handshake_timeout((handshake_ms > 0).then_some(Duration::from_millis(handshake_ms)));
    let round_ms = args.u64_or("round-deadline-ms", 0)?;
    server.set_round_deadline((round_ms > 0).then_some(Duration::from_millis(round_ms)));
    let listener = TcpListener::bind(&addr)?;
    eprintln!("hcfl-server: listening on {addr}, waiting for {conns} swarm connection(s)");
    let records = server.serve(&listener, conns, rounds)?;
    for rec in &records {
        println!(
            "round {:>3}: {}/{} aggregated, {} dropped, {} cut, {}+ carried, up {:.1} KB, \
             makespan {:.3}s",
            rec.round,
            rec.completed,
            rec.selected,
            rec.dropped,
            rec.stragglers,
            rec.carried_in,
            rec.up_bytes as f64 / 1e3,
            rec.makespan_s,
        );
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("hcfl-server: {e}");
        std::process::exit(1);
    }
}
