//! `hcfl-swarm`: the client end of the wire transport (DESIGN.md §8).
//! Dials a running `hcfl-server` with a pool of worker connections and
//! replays the simulated device fleet: seeded fake training, codec
//! encode, and (optionally) the modelled per-device delays in real
//! time.
//!
//! The scheme/clients/seed flags must match the server's exactly — both
//! ends rebuild the fleet and shard sizes from the shared seed so only
//! seeds and slots cross the wire:
//!
//! ```text
//! hcfl-swarm --addr 127.0.0.1:7878 --clients 1000 --workers 4 \
//!            --scheme topk --keep 0.1 --seed 42 --time-scale 0
//! ```

use std::time::Duration;

use hcfl::compression::Scheme;
use hcfl::control::CodecPolicy;
use hcfl::error::{HcflError, Result};
use hcfl::runtime::Manifest;
use hcfl::transport::demo_config;
use hcfl::transport::swarm::validated_swarm_with;
use hcfl::transport::SwarmOptions;
use hcfl::util::cli::Args;

fn parse_scheme(args: &Args) -> Result<Scheme> {
    match args.str_or("scheme", "topk") {
        "fedavg" => Ok(Scheme::Fedavg),
        "ternary" => Ok(Scheme::Ternary),
        "topk" => Ok(Scheme::TopK {
            keep: args.f64_or("keep", 0.1)?,
        }),
        other => Err(HcflError::Config(format!(
            "--scheme must be fedavg, topk or ternary (engine-free), got '{other}'"
        ))),
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let addr = args.str_or("addr", "127.0.0.1:7878").to_string();
    let clients = args.usize_or("clients", 1000)?;
    let workers = args.usize_or("workers", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let time_scale = args.f64_or("time-scale", 0.0)?;
    let scheme = parse_scheme(&args)?;
    // Re-dial budget: lets the swarm survive a campaign-daemon restart
    // (`hcfl-daemon`, DESIGN.md §9).  0 keeps the fail-fast default.
    let opts = SwarmOptions {
        redial_attempts: args.usize_or("redial", 0)?,
        redial_wait: Duration::from_millis(args.u64_or("redial-wait-ms", 20)?),
    };

    // `rounds` is server-paced; the swarm serves until Shutdown.
    let mut cfg = demo_config(scheme, clients, 1, seed);
    // Must match the server's --policy so the local codec bank covers
    // every tag the control plane can assign (--server-opt is
    // server-side only and needs no mirroring here).
    cfg.codec_policy = CodecPolicy::parse(args.str_or("policy", "static"))?;
    let manifest = Manifest::synthetic();
    let stats = validated_swarm_with(&manifest, &addr, &cfg, workers, time_scale, &opts)?;
    println!(
        "swarm done: {} rounds, {} updates, {:.1} KB sent",
        stats.rounds,
        stats.updates_sent,
        stats.bytes_sent as f64 / 1e3,
    );
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("hcfl-swarm: {e}");
        std::process::exit(1);
    }
}
