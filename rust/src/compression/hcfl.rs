//! The HCFL compressor: per-segment, per-chunk autoencoder codec.
//!
//! Client side (`compress`): split the flat vector into segment ranges
//! (conv / dense, dense optionally 8-way split per the paper's EMNIST
//! setup), chunk each range, and run the AE `encode` executable per chunk
//! — producing a tanh-bounded code of `chunk/ratio` floats plus (lo, hi)
//! scaling side info.
//!
//! Server side (`decompress`): run `decode` per chunk and reassemble.
//!
//! Wire accounting: `4 * code_len + 8` bytes per chunk.  The achieved
//! ("true") compression ratio is below the nominal 1:r because of the
//! side info and final-chunk padding — exactly the effect visible in the
//! paper's Tables I/II ("True Compress Ratio" < nominal).

use std::sync::Arc;

use crate::compression::{ChunkCode, CompressedUpdate, Compressor, Payload, RangeCodes, Scheme};
use crate::error::{HcflError, Result};
use crate::model::{chunk_count, extract_chunk, write_chunk, SegmentRange};
use crate::runtime::{AeMeta, Engine};
use crate::tensor::TensorValue;

/// Trained autoencoder parameters for one chunk size.
#[derive(Debug, Clone)]
pub struct AeHandle {
    pub meta: AeMeta,
    pub params: Arc<Vec<f32>>,
}

/// The HCFL codec (paper §III).
pub struct HcflCompressor {
    engine: Engine,
    ratio: usize,
    ranges: Vec<SegmentRange>,
    /// chunk size -> trained AE
    aes: std::collections::BTreeMap<usize, AeHandle>,
    /// segment type -> chunk size (from the manifest)
    chunk_of_segment: std::collections::BTreeMap<String, usize>,
}

impl HcflCompressor {
    /// Assemble from trained AE handles.  `ranges` must cover the flat
    /// vector; each range's segment must map to a chunk size with a
    /// trained AE.
    pub fn new(
        engine: Engine,
        ratio: usize,
        ranges: Vec<SegmentRange>,
        aes: Vec<AeHandle>,
        chunk_of_segment: std::collections::BTreeMap<String, usize>,
    ) -> Result<Self> {
        let aes: std::collections::BTreeMap<usize, AeHandle> =
            aes.into_iter().map(|a| (a.meta.chunk, a)).collect();
        for r in &ranges {
            let chunk = chunk_of_segment.get(&r.segment).ok_or_else(|| {
                HcflError::Config(format!("no chunk size for segment '{}'", r.segment))
            })?;
            let ae = aes.get(chunk).ok_or_else(|| {
                HcflError::Config(format!("no trained AE for chunk {chunk}"))
            })?;
            if ae.meta.ratio != ratio {
                return Err(HcflError::Config(format!(
                    "AE c{} has ratio {}, compressor wants {ratio}",
                    ae.meta.chunk, ae.meta.ratio
                )));
            }
        }
        Ok(HcflCompressor {
            engine,
            ratio,
            ranges,
            aes,
            chunk_of_segment,
        })
    }

    pub fn ratio(&self) -> usize {
        self.ratio
    }

    pub fn ranges(&self) -> &[SegmentRange] {
        &self.ranges
    }

    fn chunk_size(&self, segment: &str) -> usize {
        self.chunk_of_segment[segment]
    }
}

impl Compressor for HcflCompressor {
    fn scheme(&self) -> Scheme {
        Scheme::Hcfl { ratio: self.ratio }
    }

    fn compress(&self, flat: &[f32], worker: usize) -> Result<CompressedUpdate> {
        let mut out = Vec::with_capacity(self.ranges.len());
        let mut wire = 0usize;
        for (ri, range) in self.ranges.iter().enumerate() {
            let chunk = self.chunk_size(&range.segment);
            let ae = &self.aes[&chunk];
            let values = &flat[range.offset..range.offset + range.len];
            let n = chunk_count(range.len, chunk);
            let mut chunks = Vec::with_capacity(n);
            for i in 0..n {
                let data = extract_chunk(values, i, chunk);
                let outs = self.engine.call_on(
                    worker,
                    &ae.meta.encode,
                    vec![
                        TensorValue::vec_f32(ae.params.as_ref().clone()),
                        TensorValue::vec_f32(data),
                    ],
                )?;
                let code = outs[0].clone().into_f32()?;
                let lo = outs[1].scalar()?;
                let hi = outs[2].scalar()?;
                let mu = outs[3].scalar()?;
                let sd = outs[4].scalar()?;
                wire += 4 * code.len() + 16;
                chunks.push(ChunkCode {
                    code,
                    lo,
                    hi,
                    mu,
                    sd,
                });
            }
            out.push(RangeCodes {
                range_idx: ri,
                chunks,
            });
        }
        Ok(CompressedUpdate {
            payload: Payload::HcflCodes(out),
            wire_bytes: wire,
        })
    }

    fn decompress(
        &self,
        upd: &CompressedUpdate,
        d: usize,
        worker: usize,
    ) -> Result<Vec<f32>> {
        let codes = match &upd.payload {
            Payload::HcflCodes(c) => c,
            _ => {
                return Err(HcflError::Config(
                    "hcfl decompress got non-hcfl payload".into(),
                ))
            }
        };
        let mut flat = vec![0.0f32; d];
        for rc in codes {
            let range = self.ranges.get(rc.range_idx).ok_or_else(|| {
                HcflError::Config(format!("bad range index {}", rc.range_idx))
            })?;
            let chunk = self.chunk_size(&range.segment);
            let ae = &self.aes[&chunk];
            let dst = &mut flat[range.offset..range.offset + range.len];
            for (i, cc) in rc.chunks.iter().enumerate() {
                let outs = self.engine.call_on(
                    worker,
                    &ae.meta.decode,
                    vec![
                        TensorValue::vec_f32(ae.params.as_ref().clone()),
                        TensorValue::vec_f32(cc.code.clone()),
                        TensorValue::scalar_f32(cc.lo),
                        TensorValue::scalar_f32(cc.hi),
                        TensorValue::scalar_f32(cc.mu),
                        TensorValue::scalar_f32(cc.sd),
                    ],
                )?;
                let w_hat = outs[0].as_f32()?;
                write_chunk(dst, i, w_hat);
            }
        }
        Ok(flat)
    }
}

/// Nominal wire bytes of an HCFL update for a model of `ranges` at a
/// given ratio (used by the cost tables without running the codec).
pub fn hcfl_wire_bytes(
    ranges: &[SegmentRange],
    chunk_of_segment: &std::collections::BTreeMap<String, usize>,
    ratio: usize,
) -> usize {
    ranges
        .iter()
        .map(|r| {
            let chunk = chunk_of_segment[&r.segment];
            let n = chunk_count(r.len, chunk);
            n * (4 * (chunk / ratio) + 16)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_formula() {
        let ranges = vec![
            SegmentRange {
                segment: "conv".into(),
                label: "conv".into(),
                offset: 0,
                len: 300, // 2 chunks of 256
            },
            SegmentRange {
                segment: "dense".into(),
                label: "dense".into(),
                offset: 300,
                len: 1024, // 1 chunk of 1024
            },
        ];
        let chunks: std::collections::BTreeMap<String, usize> =
            [("conv".to_string(), 256), ("dense".to_string(), 1024)]
                .into_iter()
                .collect();
        let w = hcfl_wire_bytes(&ranges, &chunks, 4);
        // conv: 2 * (4*64 + 16) = 544 ; dense: 1 * (4*256 + 16) = 1040
        assert_eq!(w, 544 + 1040);
        // higher ratio => smaller wire
        assert!(hcfl_wire_bytes(&ranges, &chunks, 32) < w);
    }
}
