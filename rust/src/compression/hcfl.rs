//! The HCFL compressor: per-segment, chunked autoencoder codec.
//!
//! Client side (`compress`): split the flat vector into segment ranges
//! (conv / dense, dense optionally 8-way split per the paper's EMNIST
//! setup), chunk each range, and run the AE `encode` executables —
//! producing a tanh-bounded code of `chunk/ratio` floats plus 16 bytes
//! of side info per chunk.
//!
//! Server side (`decompress`): run `decode` and reassemble.
//!
//! **Batched dispatch.** A segment range of n chunks is not encoded with
//! n engine calls: the range is packed into `[batch, chunk]` tensors and
//! dispatched through the manifest's batched `encode_batch` /
//! `decode_batch` executables, greedily largest-batch-first
//! ([`plan_batches`]), falling back to the per-chunk executable for the
//! remainder — or entirely, when a manifest predates batched codecs.
//! That collapses a LeNet client's ~52 encode calls to ~6, and the AE
//! parameter vector rides along as an [`Arc`]-backed shared tensor
//! instead of being cloned into every call.
//!
//! Wire accounting: `4 * code_len + 16` bytes per chunk — the code plus
//! four f32 of side info (lo, hi, mu, sd); [`hcfl_wire_bytes`] is the
//! closed form and `compression/wire.rs` packs the byte-identical
//! buffer.  The achieved ("true") compression ratio is below the
//! nominal 1:r because of the side info and final-chunk padding —
//! exactly the effect visible in the paper's Tables I/II ("True
//! Compress Ratio" < nominal).

use std::sync::Arc;

use crate::compression::wire::{self, HcflWireLayout, RangeLayout};
use crate::compression::{
    plan_batches, CompressedUpdate, Compressor, Payload, RangeCodes, Scheme, WireScratch,
};
use crate::error::{HcflError, Result};
use crate::model::{chunk_count, extract_chunk, write_chunk, SegmentRange};
use crate::runtime::{AeMeta, Engine};
use crate::tensor::TensorValue;

/// Trained autoencoder parameters for one chunk size.
#[derive(Debug, Clone)]
pub struct AeHandle {
    pub meta: AeMeta,
    pub params: Arc<Vec<f32>>,
}

/// The HCFL codec (paper §III).
pub struct HcflCompressor {
    engine: Engine,
    ratio: usize,
    ranges: Vec<SegmentRange>,
    /// chunk size -> trained AE
    aes: std::collections::BTreeMap<usize, AeHandle>,
    /// segment type -> chunk size (from the manifest)
    chunk_of_segment: std::collections::BTreeMap<String, usize>,
}

impl HcflCompressor {
    /// Assemble from trained AE handles.  `ranges` must cover the flat
    /// vector; each range's segment must map to a chunk size with a
    /// trained AE.
    pub fn new(
        engine: Engine,
        ratio: usize,
        ranges: Vec<SegmentRange>,
        aes: Vec<AeHandle>,
        chunk_of_segment: std::collections::BTreeMap<String, usize>,
    ) -> Result<Self> {
        let aes: std::collections::BTreeMap<usize, AeHandle> =
            aes.into_iter().map(|a| (a.meta.chunk, a)).collect();
        for r in &ranges {
            let chunk = chunk_of_segment.get(&r.segment).ok_or_else(|| {
                HcflError::Config(format!("no chunk size for segment '{}'", r.segment))
            })?;
            let ae = aes.get(chunk).ok_or_else(|| {
                HcflError::Config(format!("no trained AE for chunk {chunk}"))
            })?;
            if ae.meta.ratio != ratio {
                return Err(HcflError::Config(format!(
                    "AE c{} has ratio {}, compressor wants {ratio}",
                    ae.meta.chunk, ae.meta.ratio
                )));
            }
        }
        Ok(HcflCompressor {
            engine,
            ratio,
            ranges,
            aes,
            chunk_of_segment,
        })
    }

    pub fn ratio(&self) -> usize {
        self.ratio
    }

    pub fn ranges(&self) -> &[SegmentRange] {
        &self.ranges
    }

    fn chunk_size(&self, segment: &str) -> usize {
        self.chunk_of_segment[segment]
    }

    /// The static receiver-side shape of this compressor's packed wire
    /// buffers (`wire::unpack_hcfl` needs it; it is derivable on both
    /// ends because ranges and chunk sizes are manifest configuration).
    pub fn wire_layout(&self) -> HcflWireLayout {
        HcflWireLayout {
            ranges: self
                .ranges
                .iter()
                .enumerate()
                .map(|(ri, r)| {
                    let chunk = self.chunk_size(&r.segment);
                    RangeLayout {
                        range_idx: ri,
                        n_chunks: chunk_count(r.len, chunk),
                        code_len: chunk / self.ratio,
                    }
                })
                .collect(),
        }
    }

    /// Drop every batched executable so the codec takes the per-chunk
    /// path unconditionally.  Test hook: the batched-vs-per-chunk
    /// bit-identity tests diff the two paths on the same instance.
    pub fn disable_batched(&mut self) {
        for ae in self.aes.values_mut() {
            ae.meta.encode_batch.clear();
            ae.meta.decode_batch.clear();
        }
    }

    /// Encode `batch` chunks starting at chunk index `start` of a
    /// segment slice in one engine call, appending the rows and
    /// side-info columns to `rc`.
    #[allow(clippy::too_many_arguments)]
    fn encode_batched(
        &self,
        worker: usize,
        ae: &AeHandle,
        exec: &str,
        values: &[f32],
        start: usize,
        batch: usize,
        chunk: usize,
        rc: &mut RangeCodes,
    ) -> Result<()> {
        let code_len = chunk / self.ratio;
        let mut data = vec![0.0f32; batch * chunk];
        for row in 0..batch {
            let s = (start + row) * chunk;
            let e = (s + chunk).min(values.len());
            data[row * chunk..row * chunk + (e - s)].copy_from_slice(&values[s..e]);
        }
        let outs = self.engine.call_on(
            worker,
            exec,
            vec![
                TensorValue::shared_f32(Arc::clone(&ae.params)),
                TensorValue::f32(data, vec![batch, chunk])?,
            ],
        )?;
        let codes = outs[0].as_f32()?;
        let lo = outs[1].as_f32()?;
        let hi = outs[2].as_f32()?;
        let mu = outs[3].as_f32()?;
        let sd = outs[4].as_f32()?;
        if codes.len() != batch * code_len
            || lo.len() != batch
            || hi.len() != batch
            || mu.len() != batch
            || sd.len() != batch
        {
            return Err(HcflError::Engine(format!(
                "batched encode '{exec}' returned {} codes / {}/{}/{}/{} side-info \
                 values for batch {batch}",
                codes.len(),
                lo.len(),
                hi.len(),
                mu.len(),
                sd.len()
            )));
        }
        // The batched executable's outputs already ARE the SoA columns:
        // one bulk append each, no per-row gathers.
        rc.codes.extend_from_slice(codes);
        rc.lo.extend_from_slice(lo);
        rc.hi.extend_from_slice(hi);
        rc.mu.extend_from_slice(mu);
        rc.sd.extend_from_slice(sd);
        Ok(())
    }

    /// Encode one chunk through the per-chunk executable, appending its
    /// row and side-info scalars to `rc`.
    fn encode_single(
        &self,
        worker: usize,
        ae: &AeHandle,
        values: &[f32],
        i: usize,
        chunk: usize,
        rc: &mut RangeCodes,
    ) -> Result<()> {
        let data = extract_chunk(values, i, chunk);
        let outs = self.engine.call_on(
            worker,
            &ae.meta.encode,
            vec![
                TensorValue::shared_f32(Arc::clone(&ae.params)),
                TensorValue::vec_f32(data),
            ],
        )?;
        let code = outs[0].as_f32()?;
        if code.len() != rc.code_len {
            return Err(HcflError::Engine(format!(
                "encode '{}' returned a {}-float code, expected {}",
                ae.meta.encode,
                code.len(),
                rc.code_len
            )));
        }
        rc.codes.extend_from_slice(code);
        rc.lo.push(outs[1].scalar()?);
        rc.hi.push(outs[2].scalar()?);
        rc.mu.push(outs[3].scalar()?);
        rc.sd.push(outs[4].scalar()?);
        Ok(())
    }

    /// Decode `batch` consecutive chunks of `rc` (from chunk index
    /// `start`) in one engine call and write them into `dst`.  The SoA
    /// layout makes the engine inputs straight sub-slice copies of the
    /// stored columns — no per-chunk gather loop.
    #[allow(clippy::too_many_arguments)]
    fn decode_batched(
        &self,
        worker: usize,
        ae: &AeHandle,
        exec: &str,
        rc: &RangeCodes,
        dst: &mut [f32],
        start: usize,
        batch: usize,
        chunk: usize,
    ) -> Result<()> {
        let code_len = rc.code_len;
        let codes = rc.codes[start * code_len..(start + batch) * code_len].to_vec();
        let outs = self.engine.call_on(
            worker,
            exec,
            vec![
                TensorValue::shared_f32(Arc::clone(&ae.params)),
                TensorValue::f32(codes, vec![batch, code_len])?,
                TensorValue::vec_f32(rc.lo[start..start + batch].to_vec()),
                TensorValue::vec_f32(rc.hi[start..start + batch].to_vec()),
                TensorValue::vec_f32(rc.mu[start..start + batch].to_vec()),
                TensorValue::vec_f32(rc.sd[start..start + batch].to_vec()),
            ],
        )?;
        let w_hat = outs[0].as_f32()?;
        if w_hat.len() != batch * chunk {
            return Err(HcflError::Engine(format!(
                "batched decode '{exec}' returned {} floats for batch {batch}",
                w_hat.len()
            )));
        }
        for row in 0..batch {
            write_chunk(dst, start + row, &w_hat[row * chunk..(row + 1) * chunk]);
        }
        Ok(())
    }

    /// Decode chunk `i` of `rc` through the per-chunk executable.
    fn decode_single(
        &self,
        worker: usize,
        ae: &AeHandle,
        rc: &RangeCodes,
        dst: &mut [f32],
        i: usize,
    ) -> Result<()> {
        let outs = self.engine.call_on(
            worker,
            &ae.meta.decode,
            vec![
                TensorValue::shared_f32(Arc::clone(&ae.params)),
                TensorValue::vec_f32(rc.code_row(i).to_vec()),
                TensorValue::scalar_f32(rc.lo[i]),
                TensorValue::scalar_f32(rc.hi[i]),
                TensorValue::scalar_f32(rc.mu[i]),
                TensorValue::scalar_f32(rc.sd[i]),
            ],
        )?;
        let w_hat = outs[0].as_f32()?;
        write_chunk(dst, i, w_hat);
        Ok(())
    }

    /// Decode structured chunk codes into a pre-sized flat slice —
    /// the shared body of [`Compressor::decompress`] and
    /// [`Compressor::unpack_into`].
    fn decode_codes(
        &self,
        codes: Vec<RangeCodes>,
        flat: &mut [f32],
        worker: usize,
    ) -> Result<()> {
        for rc in codes {
            let range = self.ranges.get(rc.range_idx).ok_or_else(|| {
                HcflError::Config(format!("bad range index {}", rc.range_idx))
            })?;
            let chunk = self.chunk_size(&range.segment);
            let ae = &self.aes[&chunk];
            let code_len = chunk / self.ratio;
            let n = rc.n_chunks();
            if rc.code_len != code_len
                || rc.codes.len() != n * code_len
                || rc.hi.len() != n
                || rc.mu.len() != n
                || rc.sd.len() != n
            {
                return Err(HcflError::Config(format!(
                    "hcfl range {} carries {}-float code rows ({} floats for {n} \
                     chunks), expected rows of {code_len}",
                    rc.range_idx,
                    rc.code_len,
                    rc.codes.len()
                )));
            }
            let dst = &mut flat[range.offset..range.offset + range.len];
            let sizes: Vec<usize> = ae.meta.decode_batch.keys().copied().collect();
            let mut i = 0usize;
            for batch in plan_batches(n, &sizes) {
                if batch == 1 {
                    self.decode_single(worker, ae, &rc, dst, i)?;
                } else {
                    let exec = &ae.meta.decode_batch[&batch];
                    self.decode_batched(worker, ae, exec, &rc, dst, i, batch, chunk)?;
                }
                i += batch;
            }
        }
        Ok(())
    }
}

impl Compressor for HcflCompressor {
    fn scheme(&self) -> Scheme {
        Scheme::Hcfl { ratio: self.ratio }
    }

    fn compress(&self, flat: &[f32], worker: usize) -> Result<CompressedUpdate> {
        let mut out = Vec::with_capacity(self.ranges.len());
        let mut wire = 0usize;
        for (ri, range) in self.ranges.iter().enumerate() {
            let chunk = self.chunk_size(&range.segment);
            let ae = &self.aes[&chunk];
            let values = &flat[range.offset..range.offset + range.len];
            let n = chunk_count(range.len, chunk);
            let code_len = chunk / self.ratio;
            let sizes: Vec<usize> = ae.meta.encode_batch.keys().copied().collect();
            let mut rc = RangeCodes::with_capacity(ri, code_len, n);
            let mut i = 0usize;
            for batch in plan_batches(n, &sizes) {
                if batch == 1 {
                    self.encode_single(worker, ae, values, i, chunk, &mut rc)?;
                } else {
                    let exec = &ae.meta.encode_batch[&batch];
                    self.encode_batched(worker, ae, exec, values, i, batch, chunk, &mut rc)?;
                }
                i += batch;
            }
            wire += rc.n_chunks() * (4 * code_len + 16);
            out.push(rc);
        }
        Ok(CompressedUpdate {
            payload: Payload::HcflCodes(out),
            wire_bytes: wire,
        })
    }

    fn decompress(
        &self,
        upd: CompressedUpdate,
        d: usize,
        worker: usize,
    ) -> Result<Vec<f32>> {
        let codes = match upd.payload {
            Payload::HcflCodes(c) => c,
            _ => {
                return Err(HcflError::Config(
                    "hcfl decompress got non-hcfl payload".into(),
                ))
            }
        };
        let mut flat = vec![0.0f32; d];
        self.decode_codes(codes, &mut flat, worker)?;
        Ok(flat)
    }

    fn unpack_into(
        &self,
        bytes: &[u8],
        d: usize,
        worker: usize,
        _scratch: &mut WireScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        // The AE decode executables need the structured per-chunk codes,
        // so this path still parses a `Vec<RangeCodes>` — but the
        // reconstruction is written straight into the caller's leaf
        // buffer with no intermediate flat vector.
        let codes = wire::unpack_hcfl(bytes, &self.wire_layout())?;
        out.clear();
        out.resize(d, 0.0);
        self.decode_codes(codes, out, worker)
    }
}

/// Nominal wire bytes of an HCFL update for a model of `ranges` at a
/// given ratio (used by the cost tables without running the codec).
/// `wire::pack_hcfl` produces a buffer of exactly this length — the
/// equality is pinned by `tests/wire_roundtrip.rs`.
pub fn hcfl_wire_bytes(
    ranges: &[SegmentRange],
    chunk_of_segment: &std::collections::BTreeMap<String, usize>,
    ratio: usize,
) -> usize {
    ranges
        .iter()
        .map(|r| {
            let chunk = chunk_of_segment[&r.segment];
            let n = chunk_count(r.len, chunk);
            n * (4 * (chunk / ratio) + 16)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_formula() {
        let ranges = vec![
            SegmentRange {
                segment: "conv".into(),
                label: "conv".into(),
                offset: 0,
                len: 300, // 2 chunks of 256
            },
            SegmentRange {
                segment: "dense".into(),
                label: "dense".into(),
                offset: 300,
                len: 1024, // 1 chunk of 1024
            },
        ];
        let chunks: std::collections::BTreeMap<String, usize> =
            [("conv".to_string(), 256), ("dense".to_string(), 1024)]
                .into_iter()
                .collect();
        let w = hcfl_wire_bytes(&ranges, &chunks, 4);
        // conv: 2 * (4*64 + 16) = 544 ; dense: 1 * (4*256 + 16) = 1040
        assert_eq!(w, 544 + 1040);
        // higher ratio => smaller wire
        assert!(hcfl_wire_bytes(&ranges, &chunks, 32) < w);
    }
}
