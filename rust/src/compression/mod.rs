//! Compression schemes for the model-update wire format.
//!
//! * [`Identity`] — plain FedAvg (the paper's baseline).
//! * [`hcfl::HcflCompressor`] — the paper's contribution: per-segment,
//!   per-chunk autoencoder compression (encode on the client, decode at
//!   the server).
//! * [`ternary::TernaryCompressor`] — T-FedAvg (paper [22]): 2-bit
//!   ternary weights + per-chunk scale.
//! * [`topk::TopKCompressor`] — magnitude sparsification, standing in for
//!   the CE-FedAvg / CA-DSDG family the paper cites (§I).
//!
//! Every scheme reports its exact wire size so the experiment harness can
//! reproduce the paper's communication-cost tables.
//!
//! The packed byte layouts live in [`wire`], together with the frame
//! envelope ([`wire::FrameHeader`]) that carries them over real
//! connections; [`Scheme::codec_tag`] is the envelope's single-byte
//! codec identifier.  DESIGN.md §8 is the normative byte-level spec.

pub mod hcfl;
pub mod simd;
pub mod ternary;
pub mod topk;
pub mod wire;

pub use hcfl::HcflCompressor;
pub use ternary::{RefTernaryCompressor, TernaryCompressor, REF_TERNARY_CHUNK};
pub use topk::TopKCompressor;
pub use wire::{WireScratch, WireUpdate};

use crate::error::Result;

/// Which compression scheme a run uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// Uncompressed FedAvg.
    Fedavg,
    /// HCFL at a given compression ratio (4, 8, 16, 32).
    Hcfl { ratio: usize },
    /// T-FedAvg ternary quantization.
    Ternary,
    /// Top-K magnitude sparsification keeping `keep` of the weights.
    TopK { keep: f64 },
}

impl Scheme {
    pub fn label(&self) -> String {
        match self {
            Scheme::Fedavg => "FedAvg".to_string(),
            Scheme::Hcfl { ratio } => format!("HCFL 1:{ratio}"),
            Scheme::Ternary => "T-FedAvg".to_string(),
            Scheme::TopK { keep } => format!("TopK {keep:.2}"),
        }
    }

    /// The single-byte codec identifier carried in every frame
    /// envelope ([`wire::FrameHeader::codec`]).  Both endpoints derive
    /// it from their own configuration and reject a mismatch, so a
    /// server and a swarm started with different schemes fail fast
    /// instead of mis-decoding payloads.  The values are wire protocol
    /// and must never be reused: 0 = raw, 1 = HCFL, 2 = ternary,
    /// 3 = sparse Top-K.
    pub fn codec_tag(&self) -> u8 {
        match self {
            Scheme::Fedavg => 0,
            Scheme::Hcfl { .. } => 1,
            Scheme::Ternary => 2,
            Scheme::TopK { .. } => 3,
        }
    }
}

/// All chunk codes of one segment range, structure-of-arrays: the AE
/// codes live row-major in one flat buffer and each per-chunk side-info
/// field — the affine scaling pair (lo, hi) and the scaled chunk's
/// moments (mu, sd) used by the extractor's variance-preserving
/// renormalization — in its own column.  The batched codec executables
/// take exactly these columns, so encode/decode feed the engine with
/// bulk copies instead of per-chunk gathers, and the dequant loops run
/// over contiguous f32 streams the compiler can vectorize.
///
/// The wire format is unchanged (per-chunk interleaved: `code_len`
/// code floats then lo/hi/mu/sd, 16 bytes of side info per chunk) —
/// `wire::pack_hcfl` / `wire::unpack_hcfl` transpose at the boundary,
/// and `tests/wire_roundtrip.rs` pins the packed bytes.
#[derive(Debug, Clone)]
pub struct RangeCodes {
    pub range_idx: usize,
    /// Floats per chunk code — the row width of `codes`.
    pub code_len: usize,
    /// `n_chunks × code_len` code floats, row-major.
    pub codes: Vec<f32>,
    pub lo: Vec<f32>,
    pub hi: Vec<f32>,
    pub mu: Vec<f32>,
    pub sd: Vec<f32>,
}

impl RangeCodes {
    /// An empty range with rows of `code_len`, sized for `n_chunks`.
    pub fn with_capacity(range_idx: usize, code_len: usize, n_chunks: usize) -> Self {
        RangeCodes {
            range_idx,
            code_len,
            codes: Vec::with_capacity(n_chunks * code_len),
            lo: Vec::with_capacity(n_chunks),
            hi: Vec::with_capacity(n_chunks),
            mu: Vec::with_capacity(n_chunks),
            sd: Vec::with_capacity(n_chunks),
        }
    }

    /// Chunk count (every side-info column has one entry per chunk).
    pub fn n_chunks(&self) -> usize {
        self.lo.len()
    }

    /// The `i`-th chunk's code row.
    pub fn code_row(&self, i: usize) -> &[f32] {
        &self.codes[i * self.code_len..(i + 1) * self.code_len]
    }
}

/// One ternary-quantized chunk.
#[derive(Debug, Clone)]
pub struct TernaryChunk {
    /// Values in {-1, 0, +1}; length = original chunk length (<= chunk).
    pub q: Vec<i8>,
    pub alpha: f32,
}

/// Scheme-specific compressed payload.
#[derive(Debug, Clone)]
pub enum Payload {
    Raw(Vec<f32>),
    HcflCodes(Vec<RangeCodes>),
    TernaryChunks(Vec<TernaryChunk>),
    Sparse {
        d: usize,
        idx: Vec<u32>,
        val: Vec<f32>,
    },
}

/// A compressed client update as it would travel on the wire.
#[derive(Debug, Clone)]
pub struct CompressedUpdate {
    pub payload: Payload,
    /// Exact wire size in bytes (payload only; framing ignored for all
    /// schemes equally).
    pub wire_bytes: usize,
}

/// A wire codec for model updates.
///
/// `worker` is an engine-affinity hint: calls for the same simulated
/// client pass the same index so per-worker executable caches stay warm.
pub trait Compressor: Send + Sync {
    fn scheme(&self) -> Scheme;

    /// Client side: flat parameter vector -> wire update.
    fn compress(&self, flat: &[f32], worker: usize) -> Result<CompressedUpdate>;

    /// Server side: wire update -> flat parameter vector of length `d`.
    ///
    /// Consumes the update: each payload is decoded exactly once, and
    /// ownership lets lossless codecs hand the buffer straight back
    /// instead of double-buffering every update (the FedAvg baseline
    /// used to clone the full vector here).
    fn decompress(&self, upd: CompressedUpdate, d: usize, worker: usize)
        -> Result<Vec<f32>>;

    /// Server side, zero-copy: decode a packed wire buffer (the bytes a
    /// [`WireScratch::pack_update`] produced) straight into `out`
    /// (resized to `d`) without materializing the structured
    /// [`Payload`].  Bit-identical to `unpack → decompress`; `scratch`
    /// supplies reusable internal buffers (e.g. the sparse index
    /// stream).  This is the round pipeline's decode path; the
    /// structured [`Compressor::decompress`] remains the reference.
    ///
    /// # Examples
    ///
    /// ```
    /// use hcfl::compression::{Compressor, Identity, WireScratch};
    ///
    /// let codec = Identity;
    /// let upd = codec.compress(&[1.0, -2.0], 0).unwrap();
    /// let mut scratch = WireScratch::new();
    /// let wire = scratch.pack_update(&upd.payload).unwrap();
    ///
    /// let mut out = Vec::new();
    /// codec.unpack_into(&wire.bytes, 2, 0, &mut scratch, &mut out).unwrap();
    /// assert_eq!(out, vec![1.0, -2.0]);
    /// ```
    fn unpack_into(
        &self,
        bytes: &[u8],
        d: usize,
        worker: usize,
        scratch: &mut WireScratch,
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// What the client puts on the wire (see
    /// `ExperimentConfig::encode_deltas`): the update
    /// `Δ = w_local − w_broadcast`, or the raw weights of the paper's
    /// Algorithm 1.  Scheme-independent framing shared by every codec
    /// (provided method), applied *before* [`Compressor::compress`].
    ///
    /// # Examples
    ///
    /// ```
    /// use hcfl::compression::{Compressor, Identity};
    ///
    /// let codec = Identity;
    /// let delta = codec.encode_payload(&[1.5, 2.0], &[1.0, 1.0], true);
    /// assert_eq!(delta, vec![0.5, 1.0]);
    /// let raw = codec.encode_payload(&[1.5, 2.0], &[1.0, 1.0], false);
    /// assert_eq!(raw, vec![1.5, 2.0]);
    /// ```
    fn encode_payload(&self, params: &[f32], global: &[f32], encode_deltas: bool) -> Vec<f32> {
        if encode_deltas {
            params.iter().zip(global).map(|(w, g)| w - g).collect()
        } else {
            params.to_vec()
        }
    }

    /// Server-side inverse of [`Compressor::encode_payload`]:
    /// reconstruct `ŵ = g + Δ̂` in place when delta coding is on,
    /// applied *after* [`Compressor::decompress`].
    fn decode_payload(&self, decoded: &mut [f32], global: &[f32], encode_deltas: bool) {
        if encode_deltas {
            for (v, g) in decoded.iter_mut().zip(global) {
                *v += g;
            }
        }
    }

    fn name(&self) -> String {
        self.scheme().label()
    }
}

/// Split `n` chunks into batched engine dispatches: greedily take the
/// largest available batch size that still fits, then fall back to
/// per-chunk (batch 1) calls for the remainder.  The plan length is
/// `n / max_size + O(|sizes|)` — a handful of dispatches where the
/// per-chunk path needed n, which is what collapses the codec hot path
/// from O(chunks) to O(segments) engine calls.  `sizes` must be sorted
/// ascending (BTreeMap key order); an empty slice degenerates to the
/// pure per-chunk plan.
pub fn plan_batches(n: usize, sizes: &[usize]) -> Vec<usize> {
    let mut plan = Vec::new();
    let mut rem = n;
    while rem > 0 {
        let step = sizes
            .iter()
            .rev()
            .find(|&&b| b <= rem)
            .copied()
            .unwrap_or(1);
        plan.push(step);
        rem -= step;
    }
    plan
}

/// Uncompressed FedAvg baseline: 4 bytes per weight, lossless.
#[derive(Debug, Clone, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn scheme(&self) -> Scheme {
        Scheme::Fedavg
    }

    fn compress(&self, flat: &[f32], _worker: usize) -> Result<CompressedUpdate> {
        Ok(CompressedUpdate {
            payload: Payload::Raw(flat.to_vec()),
            wire_bytes: 4 * flat.len(),
        })
    }

    fn decompress(
        &self,
        upd: CompressedUpdate,
        d: usize,
        _worker: usize,
    ) -> Result<Vec<f32>> {
        match upd.payload {
            Payload::Raw(v) => {
                debug_assert_eq!(v.len(), d);
                Ok(v)
            }
            _ => Err(crate::error::HcflError::Config(
                "identity decompress got non-raw payload".into(),
            )),
        }
    }

    fn unpack_into(
        &self,
        bytes: &[u8],
        d: usize,
        _worker: usize,
        _scratch: &mut WireScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        wire::unpack_raw_into(bytes, d, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip_is_lossless() {
        let c = Identity;
        let flat: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
        let upd = c.compress(&flat, 0).unwrap();
        assert_eq!(upd.wire_bytes, 400);
        let back = c.decompress(upd, flat.len(), 0).unwrap();
        assert_eq!(back, flat);
    }

    #[test]
    fn identity_decompress_reuses_the_payload_buffer() {
        // The consuming decompress hands the raw payload back without a
        // copy: the returned vector is the same allocation.
        let c = Identity;
        let upd = c.compress(&[1.0, 2.0, 3.0], 0).unwrap();
        let ptr = match &upd.payload {
            Payload::Raw(v) => v.as_ptr(),
            _ => unreachable!(),
        };
        let back = c.decompress(upd, 3, 0).unwrap();
        assert_eq!(back.as_ptr(), ptr);
    }

    #[test]
    fn batch_plans_cover_exactly_and_stay_logarithmic() {
        // greedy largest-first decomposition
        assert_eq!(plan_batches(41, &[2, 8, 32]), vec![32, 8, 1]);
        assert_eq!(plan_batches(11, &[2, 8, 32]), vec![8, 2, 1]);
        assert_eq!(plan_batches(3, &[2, 8, 32]), vec![2, 1]);
        assert_eq!(plan_batches(1, &[2, 8, 32]), vec![1]);
        assert_eq!(plan_batches(0, &[2, 8, 32]), Vec::<usize>::new());
        // no batched executables -> pure per-chunk fallback
        assert_eq!(plan_batches(4, &[]), vec![1, 1, 1, 1]);
        // every plan covers n exactly, and with the batch ladder the
        // dispatch count collapses to n/32 + a constant tail
        for n in 0..500usize {
            let plan = plan_batches(n, &[2, 8, 32]);
            assert_eq!(plan.iter().sum::<usize>(), n);
            assert!(
                plan.len() <= n / 32 + 8,
                "n={n}: {} dispatches",
                plan.len()
            );
        }
    }

    #[test]
    fn labels() {
        assert_eq!(Scheme::Fedavg.label(), "FedAvg");
        assert_eq!(Scheme::Hcfl { ratio: 32 }.label(), "HCFL 1:32");
        assert_eq!(Scheme::Ternary.label(), "T-FedAvg");
    }
}
