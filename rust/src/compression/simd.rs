//! Runtime-dispatched SIMD kernels for the wire hot path.
//!
//! Every packed-wire transform that runs once per client per round — the
//! 2-bit ternary symbol pack/unpack, f32-LE bulk moves, the delta-varint
//! index stream, and the weighted-leaf `axpy`/scale arithmetic of the
//! reduction tree — lives here behind one seam:
//!
//! * **Scalar** — portable Rust, the mandatory fallback and the bit-exact
//!   reference (exposed as [`scalar`] so tests and benches can pin the
//!   vector paths against it).
//! * **SSE2** — x86-64 baseline (always present on that target), used
//!   where 128-bit lanes pay: symbol packing, the fold arithmetic,
//!   varint widening.
//! * **AVX2** — runtime-detected via `is_x86_feature_detected!`; the
//!   ternary kernels process 32 symbols per iteration (16 symbols per
//!   32-bit load on the unpack side) and the fold arithmetic 8 floats.
//!
//! The dispatch level is resolved **once per process** ([`level`]) and
//! honours `HCFL_FORCE_SCALAR=1`, which pins every kernel to the scalar
//! reference (CI runs one leg this way so both paths stay tested).
//!
//! **Bit-identity contract.** For any input, every vector kernel returns
//! the exact bytes/bits of its scalar twin — the vector code uses the
//! same single IEEE operation per element (one multiply, one add, one
//! f64-widened multiply/divide), so no summation order or rounding step
//! differs.  `tests/simd_kernels.rs` pins this property on randomized
//! lengths including every remainder tail.

use std::sync::OnceLock;

use crate::error::{HcflError, Result};

/// Which kernel tier [`level`] resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Portable Rust reference (also forced by `HCFL_FORCE_SCALAR=1`).
    Scalar,
    /// 128-bit kernels; the x86-64 baseline.
    Sse2,
    /// 256-bit kernels (runtime-detected).
    Avx2,
}

impl Level {
    pub fn label(&self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
        }
    }
}

fn detect() -> Level {
    let force = std::env::var("HCFL_FORCE_SCALAR").ok();
    if force.as_deref().is_some_and(|v| !v.is_empty() && v != "0") {
        return Level::Scalar;
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Level {
    if std::is_x86_feature_detected!("avx2") {
        Level::Avx2
    } else {
        // SSE2 is part of the x86-64 baseline: no runtime check needed.
        Level::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_arch() -> Level {
    Level::Scalar
}

/// The process-wide kernel tier, resolved on first use.
pub fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

fn bad_symbol(q: i8) -> HcflError {
    HcflError::Config(format!("ternary value {q} is not in {{-1, 0, 1}}"))
}

fn bad_code() -> HcflError {
    HcflError::Config("ternary wire buffer has an invalid 0b11 symbol".into())
}

// ---------------------------------------------------------------------------
// Public dispatched API
// ---------------------------------------------------------------------------

/// Pack ternary symbols (`{-1, 0, +1}` as i8) two bits each, four per
/// byte, LSB first (`0b00` = 0, `0b01` = +1, `0b10` = −1), appending
/// `ceil(q.len()/4)` bytes to `out`; a final partial byte is
/// zero-padded.  Errors on any symbol outside the alphabet.
pub fn pack_2bit(q: &[i8], out: &mut Vec<u8>) -> Result<()> {
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::pack_2bit(q, out) },
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { sse2::pack_2bit(q, out) },
        _ => scalar::pack_2bit(q, out),
    }
}

/// Unpack the first `n` 2-bit symbols of `packed` and write the
/// dequantized values `q·alpha` into `out[..n]`.  Needs
/// `packed.len() >= ceil(n/4)`; errors on any `0b11` symbol among the
/// first `n`.  Padding bits past `n` are the caller's concern.
pub fn unpack_2bit_f32(packed: &[u8], n: usize, alpha: f32, out: &mut [f32]) -> Result<()> {
    debug_assert!(out.len() >= n && packed.len() >= n.div_ceil(4));
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::unpack_2bit_f32(packed, n, alpha, out) },
        _ => scalar::unpack_2bit_f32(packed, n, alpha, out),
    }
}

/// Append `values` as little-endian f32s (a bulk byte move on LE hosts).
pub fn pack_f32_le(values: &[f32], out: &mut Vec<u8>) {
    #[cfg(target_endian = "little")]
    {
        // An f32 slice reinterpreted as bytes IS its LE wire image.
        let bytes = unsafe {
            std::slice::from_raw_parts(values.as_ptr() as *const u8, 4 * values.len())
        };
        out.extend_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    scalar::pack_f32_le(values, out);
}

/// Decode `4·out.len()` little-endian bytes into `out` (a bulk byte
/// move on LE hosts).
pub fn unpack_f32_le(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 4 * out.len());
    #[cfg(target_endian = "little")]
    unsafe {
        std::ptr::copy_nonoverlapping(
            bytes.as_ptr(),
            out.as_mut_ptr() as *mut u8,
            bytes.len(),
        );
    }
    #[cfg(not(target_endian = "little"))]
    scalar::unpack_f32_le(bytes, out);
}

/// Decode exactly `out.len()` LEB128 varints from `bytes` starting at
/// `*pos`, advancing `*pos`.  Rejects truncated buffers, encodings that
/// overflow `u32`, and non-canonical (overlong) encodings — see
/// [`read_varint`].  The vector tiers batch runs of single-byte varints
/// (the common case for dense Top-K index gaps) eight at a time.
pub fn decode_varints(bytes: &[u8], pos: &mut usize, out: &mut [u32]) -> Result<()> {
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::decode_varints(bytes, pos, out) },
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { sse2::decode_varints(bytes, pos, out) },
        _ => scalar::decode_varints(bytes, pos, out),
    }
}

/// One hardened LEB128 read: errors on a truncated buffer, on a 5-byte
/// encoding whose final byte carries bits past `u32` (`> 0x0F`), on any
/// continuation past 5 bytes, and on overlong encodings (a multi-byte
/// varint whose final byte is `0x00` encodes its value non-minimally —
/// a forgery vector, never produced by our packer).
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let mut v = 0u32;
    let mut shift = 0u32;
    loop {
        let byte = *bytes
            .get(*pos)
            .ok_or_else(|| HcflError::Config("sparse wire buffer truncated".into()))?;
        *pos += 1;
        let payload = (byte & 0x7F) as u32;
        if shift == 28 && (payload > 0x0F || byte & 0x80 != 0) {
            return Err(HcflError::Config("sparse varint overflows u32".into()));
        }
        if shift > 0 && payload == 0 && byte & 0x80 == 0 {
            return Err(HcflError::Config(
                "sparse varint is overlong (non-canonical encoding)".into(),
            ));
        }
        v |= payload << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Scatter `idx.len()` little-endian f32 values from `bytes` into
/// `out[idx[j]]` — the Top-K sparse decode hot loop.  Requires
/// `bytes.len() >= 4 * idx.len()` and every index in range (the wire
/// layer validates both before calling; an out-of-range index panics).
/// x86 has no f32 scatter instruction, so the vector tier batches the
/// value loads 8 wide and issues the stores per lane — the store set
/// and the stored bits are identical to the scalar reference by
/// construction.
pub fn scatter_f32_le(bytes: &[u8], idx: &[u32], out: &mut [f32]) {
    debug_assert!(bytes.len() >= 4 * idx.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::scatter_f32_le(bytes, idx, out) },
        _ => scalar::scatter_f32_le(bytes, idx, out),
    }
}

/// Elementwise `acc[i] += x[i]` (the reduction-tree node fold).
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::add_assign(acc, x) },
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { sse2::add_assign(acc, x) },
        _ => scalar::add_assign(acc, x),
    }
}

/// Elementwise `x[i] = (x[i] as f64 * w) as f32` — the leaf weighting,
/// widened to f64 and rounded once per element exactly like the scalar
/// reference.
pub fn scale_f64(x: &mut [f32], w: f64) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::scale_f64(x, w) },
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { sse2::scale_f64(x, w) },
        _ => scalar::scale_f64(x, w),
    }
}

/// Elementwise `x[i] = (x[i] as f64 / w) as f32` — the root
/// normalization of the reduction tree.
pub fn div_f64(x: &mut [f32], w: f64) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::div_f64(x, w) },
        #[cfg(target_arch = "x86_64")]
        Level::Sse2 => unsafe { sse2::div_f64(x, w) },
        _ => scalar::div_f64(x, w),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference kernels
// ---------------------------------------------------------------------------

/// Portable reference implementations: the mandatory fallback tier and
/// the bit-exact oracle the vector kernels are pinned against.
pub mod scalar {
    use super::*;

    pub fn pack_2bit(q: &[i8], out: &mut Vec<u8>) -> Result<()> {
        let mut byte = 0u8;
        let mut filled = 0u32;
        for &v in q {
            let bits: u8 = match v {
                0 => 0b00,
                1 => 0b01,
                -1 => 0b10,
                other => return Err(bad_symbol(other)),
            };
            byte |= bits << (2 * filled);
            filled += 1;
            if filled == 4 {
                out.push(byte);
                byte = 0;
                filled = 0;
            }
        }
        if filled > 0 {
            out.push(byte);
        }
        Ok(())
    }

    pub fn unpack_2bit_f32(
        packed: &[u8],
        n: usize,
        alpha: f32,
        out: &mut [f32],
    ) -> Result<()> {
        unpack_2bit_f32_from(packed, 0, n, alpha, out)
    }

    /// Tail helper shared with the vector kernels: decode symbols
    /// `[start, n)`.
    pub(super) fn unpack_2bit_f32_from(
        packed: &[u8],
        start: usize,
        n: usize,
        alpha: f32,
        out: &mut [f32],
    ) -> Result<()> {
        for i in start..n {
            let bits = (packed[i / 4] >> (2 * (i % 4))) & 0b11;
            let q: f32 = match bits {
                0b00 => 0.0,
                0b01 => 1.0,
                0b10 => -1.0,
                _ => return Err(bad_code()),
            };
            out[i] = q * alpha;
        }
        Ok(())
    }

    pub fn pack_f32_le(values: &[f32], out: &mut Vec<u8>) {
        out.reserve(4 * values.len());
        for v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn unpack_f32_le(bytes: &[u8], out: &mut [f32]) {
        for (b, o) in bytes.chunks_exact(4).zip(out.iter_mut()) {
            *o = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    }

    pub fn decode_varints(bytes: &[u8], pos: &mut usize, out: &mut [u32]) -> Result<()> {
        for slot in out.iter_mut() {
            *slot = read_varint(bytes, pos)?;
        }
        Ok(())
    }

    pub fn scatter_f32_le(bytes: &[u8], idx: &[u32], out: &mut [f32]) {
        for (&i, b) in idx.iter().zip(bytes.chunks_exact(4)) {
            out[i as usize] = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    }

    pub fn add_assign(acc: &mut [f32], x: &[f32]) {
        for (a, v) in acc.iter_mut().zip(x) {
            *a += v;
        }
    }

    pub fn scale_f64(x: &mut [f32], w: f64) {
        for v in x {
            *v = (*v as f64 * w) as f32;
        }
    }

    pub fn div_f64(x: &mut [f32], w: f64) {
        for v in x {
            *v = (*v as f64 / w) as f32;
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 kernels
// ---------------------------------------------------------------------------

/// Spread the low 32 bits of `x` so bit `j` lands at bit `2j` (the
/// classic interleave ladder): packs two symbol-plane masks into the
/// 2-bit wire layout with two spreads and an OR.
#[cfg(target_arch = "x86_64")]
#[inline]
fn spread_u32(x: u32) -> u64 {
    let mut v = x as u64;
    v = (v | (v << 16)) & 0x0000_FFFF_0000_FFFF;
    v = (v | (v << 8)) & 0x00FF_00FF_00FF_00FF;
    v = (v | (v << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    v = (v | (v << 2)) & 0x3333_3333_3333_3333;
    v = (v | (v << 1)) & 0x5555_5555_5555_5555;
    v
}

#[cfg(target_arch = "x86_64")]
mod sse2 {
    use super::*;
    use core::arch::x86_64::*;

    /// 16 symbols per iteration: the +1/−1 compare masks become two
    /// movemask bit-planes, interleaved into 4 packed bytes.
    pub unsafe fn pack_2bit(q: &[i8], out: &mut Vec<u8>) -> Result<()> {
        let vec_n = q.len() & !15;
        out.reserve(q.len().div_ceil(4));
        let one = _mm_set1_epi8(1);
        let neg = _mm_set1_epi8(-1);
        let zero = _mm_setzero_si128();
        let mut i = 0usize;
        while i < vec_n {
            let v = _mm_loadu_si128(q.as_ptr().add(i) as *const __m128i);
            let m_pos = _mm_cmpeq_epi8(v, one);
            let m_neg = _mm_cmpeq_epi8(v, neg);
            let m_zero = _mm_cmpeq_epi8(v, zero);
            let valid = _mm_or_si128(_mm_or_si128(m_pos, m_neg), m_zero);
            if _mm_movemask_epi8(valid) != 0xFFFF {
                // Replay the block through the scalar kernel so the
                // error identifies the exact offending symbol.
                return scalar::pack_2bit(&q[i..], out);
            }
            let bits0 = _mm_movemask_epi8(m_pos) as u32;
            let bits1 = _mm_movemask_epi8(m_neg) as u32;
            let packed = (spread_u32(bits0) | (spread_u32(bits1) << 1)) as u32;
            out.extend_from_slice(&packed.to_le_bytes());
            i += 16;
        }
        scalar::pack_2bit(&q[vec_n..], out)
    }

    /// Widen 8 bytes to 8 u32 lanes (the single-byte-varint fast path).
    #[inline]
    pub(super) unsafe fn widen_8(bytes: *const u8, out: *mut u32) {
        let v = _mm_loadl_epi64(bytes as *const __m128i);
        let zero = _mm_setzero_si128();
        let w16 = _mm_unpacklo_epi8(v, zero);
        let lo = _mm_unpacklo_epi16(w16, zero);
        let hi = _mm_unpackhi_epi16(w16, zero);
        _mm_storeu_si128(out as *mut __m128i, lo);
        _mm_storeu_si128(out.add(4) as *mut __m128i, hi);
    }

    pub unsafe fn decode_varints(
        bytes: &[u8],
        pos: &mut usize,
        out: &mut [u32],
    ) -> Result<()> {
        let mut i = 0usize;
        while i + 8 <= out.len() && *pos + 8 <= bytes.len() {
            let w = u64::from_le_bytes(bytes[*pos..*pos + 8].try_into().unwrap());
            if w & 0x8080_8080_8080_8080 != 0 {
                out[i] = read_varint(bytes, pos)?;
                i += 1;
                continue;
            }
            widen_8(bytes.as_ptr().add(*pos), out.as_mut_ptr().add(i));
            *pos += 8;
            i += 8;
        }
        scalar::decode_varints(bytes, pos, &mut out[i..])
    }

    pub unsafe fn add_assign(acc: &mut [f32], x: &[f32]) {
        let n = acc.len() & !3;
        let mut i = 0usize;
        while i < n {
            let a = _mm_loadu_ps(acc.as_ptr().add(i));
            let b = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(acc.as_mut_ptr().add(i), _mm_add_ps(a, b));
            i += 4;
        }
        scalar::add_assign(&mut acc[n..], &x[n..]);
    }

    pub unsafe fn scale_f64(x: &mut [f32], w: f64) {
        let wv = _mm_set1_pd(w);
        let n = x.len() & !3;
        let mut i = 0usize;
        while i < n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            let lo = _mm_cvtps_pd(v);
            let hi = _mm_cvtps_pd(_mm_movehl_ps(v, v));
            let lo = _mm_cvtpd_ps(_mm_mul_pd(lo, wv));
            let hi = _mm_cvtpd_ps(_mm_mul_pd(hi, wv));
            _mm_storeu_ps(x.as_mut_ptr().add(i), _mm_movelh_ps(lo, hi));
            i += 4;
        }
        scalar::scale_f64(&mut x[n..], w);
    }

    pub unsafe fn div_f64(x: &mut [f32], w: f64) {
        let wv = _mm_set1_pd(w);
        let n = x.len() & !3;
        let mut i = 0usize;
        while i < n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            let lo = _mm_cvtps_pd(v);
            let hi = _mm_cvtps_pd(_mm_movehl_ps(v, v));
            let lo = _mm_cvtpd_ps(_mm_div_pd(lo, wv));
            let hi = _mm_cvtpd_ps(_mm_div_pd(hi, wv));
            _mm_storeu_ps(x.as_mut_ptr().add(i), _mm_movelh_ps(lo, hi));
            i += 4;
        }
        scalar::div_f64(&mut x[n..], w);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use core::arch::x86_64::*;

    /// 32 symbols per iteration: two 32-bit movemask planes interleaved
    /// into 8 packed bytes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_2bit(q: &[i8], out: &mut Vec<u8>) -> Result<()> {
        let vec_n = q.len() & !31;
        out.reserve(q.len().div_ceil(4));
        let one = _mm256_set1_epi8(1);
        let neg = _mm256_set1_epi8(-1);
        let zero = _mm256_setzero_si256();
        let mut i = 0usize;
        while i < vec_n {
            let v = _mm256_loadu_si256(q.as_ptr().add(i) as *const __m256i);
            let m_pos = _mm256_cmpeq_epi8(v, one);
            let m_neg = _mm256_cmpeq_epi8(v, neg);
            let m_zero = _mm256_cmpeq_epi8(v, zero);
            let valid = _mm256_or_si256(_mm256_or_si256(m_pos, m_neg), m_zero);
            if _mm256_movemask_epi8(valid) != -1i32 {
                return scalar::pack_2bit(&q[i..], out);
            }
            let bits0 = _mm256_movemask_epi8(m_pos) as u32;
            let bits1 = _mm256_movemask_epi8(m_neg) as u32;
            let packed = spread_u32(bits0) | (spread_u32(bits1) << 1);
            out.extend_from_slice(&packed.to_le_bytes());
            i += 32;
        }
        scalar::pack_2bit(&q[vec_n..], out)
    }

    /// 16 symbols per 32-bit load: broadcast the word, variable-shift
    /// each lane to its 2-bit field, map `0b01→+1, 0b10→−1, 0b00→0`
    /// arithmetically and multiply by the chunk scale.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_2bit_f32(
        packed: &[u8],
        n: usize,
        alpha: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let sh_lo = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
        let sh_hi = _mm256_setr_epi32(16, 18, 20, 22, 24, 26, 28, 30);
        let three = _mm256_set1_epi32(3);
        let one = _mm256_set1_epi32(1);
        let av = _mm256_set1_ps(alpha);
        let vec_n = n & !15;
        let mut bad = 0i32;
        let mut i = 0usize;
        while i < vec_n {
            let w = u32::from_le_bytes(packed[i / 4..i / 4 + 4].try_into().unwrap());
            let v = _mm256_set1_epi32(w as i32);
            for (sh, off) in [(sh_lo, 0usize), (sh_hi, 8usize)] {
                let code = _mm256_and_si256(_mm256_srlv_epi32(v, sh), three);
                bad |= _mm256_movemask_epi8(_mm256_cmpeq_epi32(code, three));
                let plus = _mm256_cvtepi32_ps(_mm256_and_si256(code, one));
                let minus = _mm256_cvtepi32_ps(_mm256_srli_epi32(code, 1));
                let f = _mm256_mul_ps(_mm256_sub_ps(plus, minus), av);
                _mm256_storeu_ps(out.as_mut_ptr().add(i + off), f);
            }
            i += 16;
        }
        if bad != 0 {
            return Err(bad_code());
        }
        scalar::unpack_2bit_f32_from(packed, vec_n, n, alpha, out)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn decode_varints(
        bytes: &[u8],
        pos: &mut usize,
        out: &mut [u32],
    ) -> Result<()> {
        let mut i = 0usize;
        while i + 8 <= out.len() && *pos + 8 <= bytes.len() {
            let w = u64::from_le_bytes(bytes[*pos..*pos + 8].try_into().unwrap());
            if w & 0x8080_8080_8080_8080 != 0 {
                out[i] = read_varint(bytes, pos)?;
                i += 1;
                continue;
            }
            let v = _mm_loadl_epi64(bytes.as_ptr().add(*pos) as *const __m128i);
            let x = _mm256_cvtepu8_epi32(v);
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, x);
            *pos += 8;
            i += 8;
        }
        scalar::decode_varints(bytes, pos, &mut out[i..])
    }

    /// 8 values per iteration: one 256-bit load of the LE value stream
    /// (x86-64 is little-endian, so the wire bytes *are* the f32 lanes),
    /// then one store per lane — AVX2 has gathers but no f32 scatter,
    /// so the store side stays scalar by necessity.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scatter_f32_le(bytes: &[u8], idx: &[u32], out: &mut [f32]) {
        let vec_k = idx.len() & !7;
        let mut vals = [0f32; 8];
        let mut j = 0usize;
        while j < vec_k {
            let v = _mm256_loadu_ps(bytes.as_ptr().add(4 * j) as *const f32);
            _mm256_storeu_ps(vals.as_mut_ptr(), v);
            for (lane, &val) in vals.iter().enumerate() {
                out[idx[j + lane] as usize] = val;
            }
            j += 8;
        }
        scalar::scatter_f32_le(&bytes[4 * vec_k..], &idx[vec_k..], out);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(acc: &mut [f32], x: &[f32]) {
        let n = acc.len() & !7;
        let mut i = 0usize;
        while i < n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            let b = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, b));
            i += 8;
        }
        scalar::add_assign(&mut acc[n..], &x[n..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_f64(x: &mut [f32], w: f64) {
        let wv = _mm256_set1_pd(w);
        let n = x.len() & !7;
        let mut i = 0usize;
        while i < n {
            let lo = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
            let hi = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i + 4)));
            let lo = _mm256_cvtpd_ps(_mm256_mul_pd(lo, wv));
            let hi = _mm256_cvtpd_ps(_mm256_mul_pd(hi, wv));
            _mm_storeu_ps(x.as_mut_ptr().add(i), lo);
            _mm_storeu_ps(x.as_mut_ptr().add(i + 4), hi);
            i += 8;
        }
        scalar::scale_f64(&mut x[n..], w);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn div_f64(x: &mut [f32], w: f64) {
        let wv = _mm256_set1_pd(w);
        let n = x.len() & !7;
        let mut i = 0usize;
        while i < n {
            let lo = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
            let hi = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i + 4)));
            let lo = _mm256_cvtpd_ps(_mm256_div_pd(lo, wv));
            let hi = _mm256_cvtpd_ps(_mm256_div_pd(hi, wv));
            _mm_storeu_ps(x.as_mut_ptr().add(i), lo);
            _mm_storeu_ps(x.as_mut_ptr().add(i + 4), hi);
            i += 8;
        }
        scalar::div_f64(&mut x[n..], w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_q(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n)
            .map(|_| match rng.below(3) {
                0 => 0i8,
                1 => 1,
                _ => -1,
            })
            .collect()
    }

    #[test]
    fn dispatch_level_is_stable() {
        assert_eq!(level(), level());
        // the label round-trips for every tier
        for l in [Level::Scalar, Level::Sse2, Level::Avx2] {
            assert!(!l.label().is_empty());
        }
    }

    #[test]
    fn pack_matches_scalar_reference() {
        let mut rng = Rng::new(3);
        for n in [0usize, 1, 3, 4, 15, 16, 17, 31, 32, 33, 63, 64, 1024, 1027] {
            let q = random_q(&mut rng, n);
            let mut a = Vec::new();
            let mut b = Vec::new();
            pack_2bit(&q, &mut a).unwrap();
            scalar::pack_2bit(&q, &mut b).unwrap();
            assert_eq!(a, b, "n={n}");
            assert_eq!(a.len(), n.div_ceil(4));
        }
    }

    #[test]
    fn pack_rejects_invalid_symbols_on_every_tier() {
        for n in [1usize, 16, 33, 64] {
            let mut q = vec![0i8; n];
            *q.last_mut().unwrap() = 2;
            let mut out = Vec::new();
            assert!(pack_2bit(&q, &mut out).is_err(), "n={n}");
        }
    }

    #[test]
    fn unpack_roundtrips_and_rejects_0b11() {
        let mut rng = Rng::new(4);
        for n in [1usize, 7, 15, 16, 17, 48, 63, 64, 2048, 2051] {
            let q = random_q(&mut rng, n);
            let mut packed = Vec::new();
            pack_2bit(&q, &mut packed).unwrap();
            let alpha = 0.375f32;
            let mut out = vec![f32::NAN; n];
            unpack_2bit_f32(&packed, n, alpha, &mut out).unwrap();
            for (o, &sym) in out.iter().zip(&q) {
                assert_eq!(o.to_bits(), (sym as f32 * alpha).to_bits());
            }
            // corrupt one symbol to 0b11
            let mut broken = packed.clone();
            broken[0] |= 0b11;
            assert!(unpack_2bit_f32(&broken, n, alpha, &mut out).is_err());
        }
    }

    #[test]
    fn varint_hardening() {
        // max u32
        let max = [0xFF, 0xFF, 0xFF, 0xFF, 0x0F];
        let mut pos = 0;
        assert_eq!(read_varint(&max, &mut pos).unwrap(), u32::MAX);
        assert_eq!(pos, 5);
        // truncated
        let mut pos = 0;
        assert!(read_varint(&[0x80], &mut pos).is_err());
        // 5-byte overflow (bits past u32)
        let mut pos = 0;
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x10], &mut pos).is_err());
        // 6-byte continuation
        let mut pos = 0;
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x8F, 0x00], &mut pos).is_err());
        // overlong zero
        let mut pos = 0;
        assert!(read_varint(&[0x80, 0x00], &mut pos).is_err());
        // canonical single zero is fine
        let mut pos = 0;
        assert_eq!(read_varint(&[0x00], &mut pos).unwrap(), 0);
    }

    #[test]
    fn fold_kernels_match_scalar_bits() {
        let mut rng = Rng::new(5);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 31, 32, 33, 1000] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut a = x.clone();
            let mut b = x.clone();
            add_assign(&mut a, &y);
            scalar::add_assign(&mut b, &y);
            assert_eq!(bits(&a), bits(&b), "add n={n}");
            let w = 0.123456789f64;
            let mut a = x.clone();
            let mut b = x.clone();
            scale_f64(&mut a, w);
            scalar::scale_f64(&mut b, w);
            assert_eq!(bits(&a), bits(&b), "scale n={n}");
            let mut a = x.clone();
            let mut b = x;
            div_f64(&mut a, w);
            scalar::div_f64(&mut b, w);
            assert_eq!(bits(&a), bits(&b), "div n={n}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
