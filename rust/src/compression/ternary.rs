//! T-FedAvg baseline (paper [22]): ternary weight quantization.
//!
//! Full chunks run through the `ternary_c1024` Pallas kernel
//! executable, batched: runs of full chunks are shipped as one
//! `[batch, chunk]` tensor through the manifest's `ternary_batch`
//! executables ([`crate::compression::plan_batches`]), with the
//! per-chunk kernel as the remainder/fallback path.  The final partial
//! chunk is quantized in Rust with identical TWN math (padding the
//! kernel input with zeros would bias delta = 0.7·mean|w|).
//!
//! Wire format: 2 bits per weight (values in {-1, 0, +1}) packed four per
//! byte, plus one f32 scale per chunk — the 16x-ish compression the paper
//! reports for T-FedAvg.  `wire::pack_ternary` emits exactly
//! [`TernaryCompressor::wire_bytes_for`] bytes.

use std::collections::BTreeMap;

use crate::compression::{
    plan_batches, wire, CompressedUpdate, Compressor, Payload, Scheme, TernaryChunk,
    WireScratch,
};
use crate::error::{HcflError, Result};
use crate::runtime::Engine;
use crate::tensor::TensorValue;

/// Ternary codec over fixed 1024-value chunks.
pub struct TernaryCompressor {
    engine: Engine,
    exec: String,
    /// batch size -> batched quantizer executable (may be empty)
    batch_execs: BTreeMap<usize, String>,
    chunk: usize,
}

impl TernaryCompressor {
    pub fn new(engine: Engine, chunk: usize) -> Result<Self> {
        let exec = engine.manifest().ternary_exec(chunk)?.to_string();
        let batch_execs = engine.manifest().ternary_batch_execs(chunk);
        Ok(TernaryCompressor {
            engine,
            exec,
            batch_execs,
            chunk,
        })
    }

    /// Test hook: force the per-chunk path (see
    /// [`crate::compression::HcflCompressor::disable_batched`]).
    pub fn disable_batched(&mut self) {
        self.batch_execs.clear();
    }

    /// Exact TWN quantization in Rust (used for the tail chunk and as the
    /// reference in tests).
    pub fn quantize_ref(w: &[f32]) -> TernaryChunk {
        let mean_abs = w.iter().map(|x| x.abs()).sum::<f32>() / w.len().max(1) as f32;
        let delta = 0.7 * mean_abs;
        let mut sum = 0.0f32;
        let mut cnt = 0usize;
        let q: Vec<i8> = w
            .iter()
            .map(|&x| {
                if x.abs() > delta {
                    sum += x.abs();
                    cnt += 1;
                    if x > 0.0 {
                        1
                    } else {
                        -1
                    }
                } else {
                    0
                }
            })
            .collect();
        let alpha = if cnt > 0 { sum / cnt as f32 } else { 0.0 };
        TernaryChunk { q, alpha }
    }

    /// Wire bytes for a vector of length `d` at this chunk size.
    pub fn wire_bytes_for(d: usize, chunk: usize) -> usize {
        let n_chunks = d.div_ceil(chunk);
        d.div_ceil(4) + 4 * n_chunks
    }

    /// Pure-Rust inverse of the wire payload: concatenated `q * alpha`
    /// per chunk.  Used by [`Compressor::decompress`] and by the
    /// engine-free codec property tests.
    pub fn decode_chunks(chunks: &[TernaryChunk], d: usize) -> Result<Vec<f32>> {
        let mut flat = Vec::with_capacity(d);
        for c in chunks {
            flat.extend(c.q.iter().map(|&q| q as f32 * c.alpha));
        }
        if flat.len() != d {
            return Err(HcflError::Config(format!(
                "ternary payload covers {} of {d} weights",
                flat.len()
            )));
        }
        Ok(flat)
    }
}

impl Compressor for TernaryCompressor {
    fn scheme(&self) -> Scheme {
        Scheme::Ternary
    }

    fn compress(&self, flat: &[f32], worker: usize) -> Result<CompressedUpdate> {
        let n_full = flat.len() / self.chunk;
        let mut chunks = Vec::with_capacity(flat.len().div_ceil(self.chunk));
        let sizes: Vec<usize> = self.batch_execs.keys().copied().collect();
        let mut i = 0usize; // full-chunk cursor
        for batch in plan_batches(n_full, &sizes) {
            let start = i * self.chunk;
            if batch == 1 {
                let slice = &flat[start..start + self.chunk];
                let outs = self.engine.call_on(
                    worker,
                    &self.exec,
                    vec![TensorValue::vec_f32(slice.to_vec())],
                )?;
                let qf = outs[0].as_f32()?;
                let alpha = outs[1].scalar()?;
                chunks.push(TernaryChunk {
                    q: qf.iter().map(|&v| v as i8).collect(),
                    alpha,
                });
            } else {
                let end = start + batch * self.chunk;
                let exec = &self.batch_execs[&batch];
                let outs = self.engine.call_on(
                    worker,
                    exec,
                    vec![TensorValue::f32(
                        flat[start..end].to_vec(),
                        vec![batch, self.chunk],
                    )?],
                )?;
                let qf = outs[0].as_f32()?;
                let alphas = outs[1].as_f32()?;
                if qf.len() != batch * self.chunk || alphas.len() != batch {
                    return Err(HcflError::Engine(format!(
                        "batched ternary '{exec}' returned {} values / {} scales \
                         for batch {batch}",
                        qf.len(),
                        alphas.len()
                    )));
                }
                for row in 0..batch {
                    chunks.push(TernaryChunk {
                        q: qf[row * self.chunk..(row + 1) * self.chunk]
                            .iter()
                            .map(|&v| v as i8)
                            .collect(),
                        alpha: alphas[row],
                    });
                }
            }
            i += batch;
        }
        // partial tail chunk: exact TWN math in Rust
        if n_full * self.chunk < flat.len() {
            chunks.push(Self::quantize_ref(&flat[n_full * self.chunk..]));
        }
        Ok(CompressedUpdate {
            wire_bytes: Self::wire_bytes_for(flat.len(), self.chunk),
            payload: Payload::TernaryChunks(chunks),
        })
    }

    fn decompress(
        &self,
        upd: CompressedUpdate,
        d: usize,
        _worker: usize,
    ) -> Result<Vec<f32>> {
        let chunks = match &upd.payload {
            Payload::TernaryChunks(c) => c,
            _ => {
                return Err(HcflError::Config(
                    "ternary decompress got wrong payload".into(),
                ))
            }
        };
        Self::decode_chunks(chunks, d)
    }

    fn unpack_into(
        &self,
        bytes: &[u8],
        d: usize,
        _worker: usize,
        _scratch: &mut WireScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        wire::unpack_ternary_into(bytes, d, self.chunk, out)
    }
}

/// The chunk size every engine-free ternary path uses (and the only one
/// the Pallas artifacts ship): the transport layer, the daemon and
/// `fake_train` runs all quantize at this granularity, so the in-process
/// and wire paths stay bit-identical.
pub const REF_TERNARY_CHUNK: usize = 1024;

/// Engine-free ternary codec: [`TernaryCompressor::quantize_ref`] — the
/// exact TWN math the kernel executables are pinned against — applied
/// per chunk in pure Rust.  Same scheme, same wire bytes, same decode as
/// the engine-backed [`TernaryCompressor`]; it exists so ternary joins
/// fedavg/top-k in the engine-free scheme set (`fake_train` and the
/// transport layer, where no engine crosses the socket).
pub struct RefTernaryCompressor {
    chunk: usize,
}

impl RefTernaryCompressor {
    /// A reference ternary codec at [`REF_TERNARY_CHUNK`].
    pub fn new() -> RefTernaryCompressor {
        RefTernaryCompressor {
            chunk: REF_TERNARY_CHUNK,
        }
    }
}

impl Default for RefTernaryCompressor {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for RefTernaryCompressor {
    fn scheme(&self) -> Scheme {
        Scheme::Ternary
    }

    fn compress(&self, flat: &[f32], _worker: usize) -> Result<CompressedUpdate> {
        let chunks: Vec<TernaryChunk> = flat
            .chunks(self.chunk)
            .map(TernaryCompressor::quantize_ref)
            .collect();
        Ok(CompressedUpdate {
            wire_bytes: TernaryCompressor::wire_bytes_for(flat.len(), self.chunk),
            payload: Payload::TernaryChunks(chunks),
        })
    }

    fn decompress(&self, upd: CompressedUpdate, d: usize, _worker: usize) -> Result<Vec<f32>> {
        let chunks = match &upd.payload {
            Payload::TernaryChunks(c) => c,
            _ => {
                return Err(HcflError::Config(
                    "ternary decompress got wrong payload".into(),
                ))
            }
        };
        TernaryCompressor::decode_chunks(chunks, d)
    }

    fn unpack_into(
        &self,
        bytes: &[u8],
        d: usize,
        _worker: usize,
        _scratch: &mut WireScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        wire::unpack_ternary_into(bytes, d, self.chunk, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_ref_basic() {
        let w = vec![1.0, -1.0, 0.01, -0.02, 0.9];
        let t = TernaryCompressor::quantize_ref(&w);
        // mean|w| = 0.586, delta = 0.41: +1, -1, 0, 0, +1
        assert_eq!(t.q, vec![1, -1, 0, 0, 1]);
        let alpha_ref = (1.0 + 1.0 + 0.9) / 3.0;
        assert!((t.alpha - alpha_ref).abs() < 1e-6);
    }

    #[test]
    fn quantize_ref_zeros() {
        let t = TernaryCompressor::quantize_ref(&[0.0; 16]);
        assert!(t.q.iter().all(|&q| q == 0));
        assert_eq!(t.alpha, 0.0);
    }

    #[test]
    fn ref_compressor_matches_the_reference_math() {
        let c = RefTernaryCompressor::new();
        let flat: Vec<f32> = (0..2500)
            .map(|i| ((i * 37) % 101) as f32 / 50.0 - 1.0)
            .collect();
        let upd = c.compress(&flat, 0).unwrap();
        assert_eq!(
            upd.wire_bytes,
            TernaryCompressor::wire_bytes_for(2500, REF_TERNARY_CHUNK)
        );
        let want: Vec<f32> = flat
            .chunks(REF_TERNARY_CHUNK)
            .flat_map(|w| {
                let t = TernaryCompressor::quantize_ref(w);
                t.q.iter().map(|&q| q as f32 * t.alpha).collect::<Vec<_>>()
            })
            .collect();
        let got = c.decompress(upd, 2500, 0).unwrap();
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn wire_bytes() {
        // 44426 weights at c1024: 11107 data bytes + 44 chunk scales
        let w = TernaryCompressor::wire_bytes_for(44426, 1024);
        assert_eq!(w, 44426usize.div_ceil(4) + 4 * 44);
        // ~16x smaller than 4 bytes/weight
        let ratio = (4 * 44426) as f64 / w as f64;
        assert!(ratio > 15.0 && ratio < 16.1, "ratio {ratio}");
    }
}
