//! Top-K magnitude sparsification baseline.
//!
//! Stands in for the sparsification family the paper cites (CE-FedAvg,
//! CA-DSDG, §I) whose achievable compression the paper describes as
//! capped around 70 % size reduction: transmitting (index, value) pairs
//! costs 8 bytes per kept weight, so keeping 15 % of weights gives a
//! ~3.3x wire reduction.  Pure Rust — no kernel needed, the hot loop is a
//! partial selection.

use crate::compression::{
    wire, CompressedUpdate, Compressor, Payload, Scheme, WireScratch,
};
use crate::error::{HcflError, Result};

/// Keep the `keep` fraction of weights with largest magnitude.
pub struct TopKCompressor {
    keep: f64,
}

impl TopKCompressor {
    pub fn new(keep: f64) -> Result<Self> {
        if !(0.0 < keep && keep <= 1.0) {
            return Err(HcflError::Config(format!(
                "topk keep fraction must be in (0,1], got {keep}"
            )));
        }
        Ok(TopKCompressor { keep })
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((d as f64 * self.keep).round() as usize).clamp(1, d)
    }
}

impl Compressor for TopKCompressor {
    fn scheme(&self) -> Scheme {
        Scheme::TopK { keep: self.keep }
    }

    fn compress(&self, flat: &[f32], _worker: usize) -> Result<CompressedUpdate> {
        let d = flat.len();
        let k = self.k_for(d);
        // Partial selection of the k largest magnitudes.
        let mut order: Vec<u32> = (0..d as u32).collect();
        let kth = k - 1;
        order.select_nth_unstable_by(kth, |&a, &b| {
            flat[b as usize]
                .abs()
                .partial_cmp(&flat[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable(); // sorted indices compress/replay better
        let val: Vec<f32> = idx.iter().map(|&i| flat[i as usize]).collect();
        Ok(CompressedUpdate {
            wire_bytes: 8 * k, // 4-byte index + 4-byte value
            payload: Payload::Sparse { d, idx, val },
        })
    }

    fn decompress(
        &self,
        upd: CompressedUpdate,
        d: usize,
        _worker: usize,
    ) -> Result<Vec<f32>> {
        match upd.payload {
            Payload::Sparse { d: dd, idx, val } => {
                if dd != d {
                    return Err(HcflError::Config(format!(
                        "sparse payload d {dd} != expected {d}"
                    )));
                }
                let mut flat = vec![0.0f32; d];
                for (&i, &v) in idx.iter().zip(&val) {
                    flat[i as usize] = v;
                }
                Ok(flat)
            }
            _ => Err(HcflError::Config(
                "topk decompress got wrong payload".into(),
            )),
        }
    }

    fn unpack_into(
        &self,
        bytes: &[u8],
        d: usize,
        _worker: usize,
        scratch: &mut WireScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        wire::unpack_sparse_into_scratch(bytes, d, scratch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let c = TopKCompressor::new(0.4).unwrap();
        let flat = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let upd = c.compress(&flat, 0).unwrap();
        assert_eq!(upd.wire_bytes, 8 * 2);
        let back = c.decompress(upd, flat.len(), 0).unwrap();
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn keep_one_hundred_percent_is_lossless() {
        let c = TopKCompressor::new(1.0).unwrap();
        let flat = vec![1.0, -2.0, 3.0];
        let upd = c.compress(&flat, 0).unwrap();
        assert_eq!(c.decompress(upd, 3, 0).unwrap(), flat);
    }

    #[test]
    fn invalid_keep_rejected() {
        assert!(TopKCompressor::new(0.0).is_err());
        assert!(TopKCompressor::new(1.5).is_err());
    }

    #[test]
    fn wrong_d_rejected() {
        let c = TopKCompressor::new(0.5).unwrap();
        let upd = c.compress(&[1.0, 2.0], 0).unwrap();
        assert!(c.decompress(upd, 3, 0).is_err());
    }
}
