//! Packed wire buffers: every [`Payload`] serialized to the actual
//! bytes it would occupy on the air.
//!
//! Until this module existed, `wire_bytes` was a per-scheme closed-form
//! formula; now it is the measured length of the packed buffer.  The
//! layouts (framing ignored for all schemes equally, exactly as the
//! formulas did):
//!
//! * **Raw** — little-endian f32s, `4·d` bytes.
//! * **HCFL** — per chunk, in range/chunk order: the code as f32-LE
//!   (`4·code_len` bytes) followed by 16 bytes of side info
//!   (lo, hi, mu, sd as f32-LE).  Total = `Σ n_chunks·(4·code_len+16)`,
//!   byte-identical to [`super::hcfl::hcfl_wire_bytes`].
//! * **Ternary** — one f32-LE scale per chunk (`4·n_chunks` bytes), then
//!   the concatenated quantized values packed 2 bits each, four per
//!   byte, LSB first (`0b00` = 0, `0b01` = +1, `0b10` = −1).  Total =
//!   `4·n_chunks + ceil(d/4)`, byte-identical to
//!   [`super::TernaryCompressor::wire_bytes_for`].
//! * **Sparse (Top-K)** — `u32` d, `u32` k, the sorted indices
//!   delta-coded as LEB128 varints (first index absolute, then gaps),
//!   then the kept values as f32-LE.  This is the one scheme whose
//!   packed size *beats* its old `8·k` formula — delta varints make the
//!   index stream sublinear for dense keeps.
//!
//! The hot loops dispatch to [`super::simd`]: symbol packing, 2-bit
//! dequantization, varint batches, and f32 bulk moves all run on the
//! widest kernel tier the host supports, with the scalar reference as
//! the mandatory fallback — outputs are bit-identical by contract.
//!
//! Packing is allocation-free in steady state: callers thread a
//! [`WireScratch`] (one per pool worker, see `coordinator/pool.rs`).
//! Beyond the legacy single pack buffer, the scratch is a small arena —
//! it recycles owned wire buffers ([`WireScratch::pack_update`] /
//! [`WireScratch::put_bytes`]) and decoded leaf vectors
//! ([`WireScratch::take_f32`] / [`WireScratch::put_f32`]) across
//! clients and rounds, so the decode → fold path allocates nothing once
//! warm.  Unpacking needs the receiver's static knowledge of the layout
//! — the model geometry the server already owns — via
//! [`HcflWireLayout`] / the `(d, chunk)` pair, mirroring how a real
//! deployment would parse a headerless payload.
//!
//! Each scheme has two decode paths with pinned-equal results: the
//! structured one (`unpack_raw`/`unpack_ternary`/…, materializing the
//! [`Payload`]) kept as the reference, and the zero-copy
//! `unpack_*_into` one that writes dequantized f32s straight into a
//! caller-provided leaf buffer without intermediate `Vec`s.
//!
//! On a real connection every payload travels inside the fixed 24-byte
//! [`FrameHeader`] envelope (magic, version, message type, codec tag,
//! flags, round id, client id, payload length, CRC-32) defined at the
//! bottom of this module and specified byte-for-byte in DESIGN.md §8;
//! the blocking frame I/O lives in [`crate::transport`].

use crate::compression::{simd, Payload, RangeCodes, TernaryChunk};
use crate::error::{HcflError, Result};

/// Spare buffers kept per pool (bounds steady-state memory: with d=802
/// f32 leaves this is ~0.8 MB per worker; larger models pay
/// proportionally but never more than the cap).
const POOL_CAP: usize = 256;

/// An update as it travels: the packed wire image, nothing else.  The
/// sender discards its structured [`Payload`] after packing; the
/// receiver decodes with `unpack_*_into` straight into a leaf buffer.
#[derive(Debug, Clone, Default)]
pub struct WireUpdate {
    pub bytes: Vec<u8>,
}

impl WireUpdate {
    /// Measured wire size — what the clock layer charges the uplink.
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// A reusable packing buffer and recycle arena.  One lives in each pool
/// worker's context so steady-state rounds pack, decode and fold with
/// zero allocation.
#[derive(Debug, Default)]
pub struct WireScratch {
    buf: Vec<u8>,
    bytes_pool: Vec<Vec<u8>>,
    f32_pool: Vec<Vec<f32>>,
    u32_buf: Vec<u32>,
}

impl WireScratch {
    pub fn new() -> WireScratch {
        WireScratch::default()
    }

    /// Pack `payload` into the internal buffer and return the packed
    /// length — the measured `wire_bytes` of the update.
    pub fn pack(&mut self, payload: &Payload) -> Result<usize> {
        self.buf.clear();
        pack_payload(payload, &mut self.buf)?;
        Ok(self.buf.len())
    }

    /// The bytes of the most recent [`WireScratch::pack`].
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Pack `payload` into an owned [`WireUpdate`], reusing a recycled
    /// buffer when one is pooled.
    pub fn pack_update(&mut self, payload: &Payload) -> Result<WireUpdate> {
        let mut bytes = self.bytes_pool.pop().unwrap_or_default();
        bytes.clear();
        pack_payload(payload, &mut bytes)?;
        Ok(WireUpdate { bytes })
    }

    /// Return a spent wire buffer to the arena (dropped past the cap).
    pub fn put_bytes(&mut self, mut bytes: Vec<u8>) {
        if self.bytes_pool.len() < POOL_CAP {
            bytes.clear();
            self.bytes_pool.push(bytes);
        }
    }

    /// Take a cleared f32 buffer (a pooled one when available) to
    /// decode a leaf into.
    pub fn take_f32(&mut self) -> Vec<f32> {
        self.f32_pool.pop().unwrap_or_default()
    }

    /// Return a spent leaf buffer to the arena (dropped past the cap).
    pub fn put_f32(&mut self, mut v: Vec<f32>) {
        if self.f32_pool.len() < POOL_CAP {
            v.clear();
            self.f32_pool.push(v);
        }
    }
}

/// Append any payload's packed form to `out`.
pub fn pack_payload(payload: &Payload, out: &mut Vec<u8>) -> Result<()> {
    match payload {
        Payload::Raw(v) => {
            pack_raw(v, out);
            Ok(())
        }
        Payload::HcflCodes(codes) => {
            pack_hcfl(codes, out);
            Ok(())
        }
        Payload::TernaryChunks(chunks) => pack_ternary(chunks, out),
        Payload::Sparse { d, idx, val } => pack_sparse(*d, idx, val, out),
    }
}

// ---------------------------------------------------------------------------
// Raw (FedAvg)
// ---------------------------------------------------------------------------

pub fn pack_raw(values: &[f32], out: &mut Vec<u8>) {
    simd::pack_f32_le(values, out);
}

fn check_raw_len(bytes: &[u8], d: usize) -> Result<()> {
    if bytes.len() != 4 * d {
        return Err(HcflError::Config(format!(
            "raw wire buffer is {} bytes, expected {}",
            bytes.len(),
            4 * d
        )));
    }
    Ok(())
}

pub fn unpack_raw(bytes: &[u8], d: usize) -> Result<Vec<f32>> {
    check_raw_len(bytes, d)?;
    let mut out = vec![0.0f32; d];
    simd::unpack_f32_le(bytes, &mut out);
    Ok(out)
}

/// Zero-copy raw decode: write the `d` floats into `out` (resized to
/// `d`) without an intermediate allocation.
pub fn unpack_raw_into(bytes: &[u8], d: usize, out: &mut Vec<f32>) -> Result<()> {
    check_raw_len(bytes, d)?;
    out.clear();
    out.resize(d, 0.0);
    simd::unpack_f32_le(bytes, out);
    Ok(())
}

// ---------------------------------------------------------------------------
// HCFL
// ---------------------------------------------------------------------------

/// The receiver-side shape of one packed HCFL range.
#[derive(Debug, Clone)]
pub struct RangeLayout {
    pub range_idx: usize,
    pub n_chunks: usize,
    pub code_len: usize,
}

/// The receiver-side shape of a whole packed HCFL update, derivable
/// from the compressor's static configuration (see
/// [`super::HcflCompressor::wire_layout`]).
#[derive(Debug, Clone)]
pub struct HcflWireLayout {
    pub ranges: Vec<RangeLayout>,
}

impl HcflWireLayout {
    /// Packed size in bytes (equals `hcfl_wire_bytes`).
    pub fn packed_len(&self) -> usize {
        self.ranges
            .iter()
            .map(|r| r.n_chunks * (4 * r.code_len + 16))
            .sum()
    }
}

/// Pack SoA range codes into the per-chunk interleaved wire form
/// (`code_len` code floats, then lo/hi/mu/sd) — byte-identical to the
/// pre-SoA layout, pinned by `tests/wire_roundtrip.rs`.
pub fn pack_hcfl(codes: &[RangeCodes], out: &mut Vec<u8>) {
    for rc in codes {
        for i in 0..rc.n_chunks() {
            simd::pack_f32_le(rc.code_row(i), out);
            out.extend_from_slice(&rc.lo[i].to_le_bytes());
            out.extend_from_slice(&rc.hi[i].to_le_bytes());
            out.extend_from_slice(&rc.mu[i].to_le_bytes());
            out.extend_from_slice(&rc.sd[i].to_le_bytes());
        }
    }
}

pub fn unpack_hcfl(bytes: &[u8], layout: &HcflWireLayout) -> Result<Vec<RangeCodes>> {
    if bytes.len() != layout.packed_len() {
        return Err(HcflError::Config(format!(
            "hcfl wire buffer is {} bytes, layout expects {}",
            bytes.len(),
            layout.packed_len()
        )));
    }
    let mut pos = 0usize;
    let mut read_f32 = |pos: &mut usize| -> f32 {
        let v = f32::from_le_bytes([
            bytes[*pos],
            bytes[*pos + 1],
            bytes[*pos + 2],
            bytes[*pos + 3],
        ]);
        *pos += 4;
        v
    };
    let mut out = Vec::with_capacity(layout.ranges.len());
    for r in &layout.ranges {
        let mut rc = RangeCodes::with_capacity(r.range_idx, r.code_len, r.n_chunks);
        for _ in 0..r.n_chunks {
            let row_start = rc.codes.len();
            rc.codes.resize(row_start + r.code_len, 0.0);
            simd::unpack_f32_le(
                &bytes[pos..pos + 4 * r.code_len],
                &mut rc.codes[row_start..],
            );
            pos += 4 * r.code_len;
            rc.lo.push(read_f32(&mut pos));
            rc.hi.push(read_f32(&mut pos));
            rc.mu.push(read_f32(&mut pos));
            rc.sd.push(read_f32(&mut pos));
        }
        out.push(rc);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Ternary
// ---------------------------------------------------------------------------

pub fn pack_ternary(chunks: &[TernaryChunk], out: &mut Vec<u8>) -> Result<()> {
    for c in chunks {
        out.extend_from_slice(&c.alpha.to_le_bytes());
    }
    // The symbol stream is bit-continuous across chunks.  A chunk that
    // starts byte-aligned (always, for the codec's multiple-of-4 chunk
    // size) goes through the vector kernel; any straggling symbols are
    // carried bitwise exactly like the original scalar packer.
    let mut byte = 0u8;
    let mut filled = 0u32;
    for c in chunks {
        let mut rest: &[i8] = &c.q;
        if filled == 0 {
            let aligned = rest.len() & !3;
            simd::pack_2bit(&rest[..aligned], out)?;
            rest = &rest[aligned..];
        }
        for &q in rest {
            let bits: u8 = match q {
                0 => 0b00,
                1 => 0b01,
                -1 => 0b10,
                other => {
                    return Err(HcflError::Config(format!(
                        "ternary value {other} is not in {{-1, 0, 1}}"
                    )))
                }
            };
            byte |= bits << (2 * filled);
            filled += 1;
            if filled == 4 {
                out.push(byte);
                byte = 0;
                filled = 0;
            }
        }
    }
    if filled > 0 {
        out.push(byte);
    }
    Ok(())
}

fn check_ternary_len(bytes: &[u8], d: usize, chunk: usize) -> Result<usize> {
    let n_chunks = d.div_ceil(chunk);
    let expect = 4 * n_chunks + d.div_ceil(4);
    if bytes.len() != expect {
        return Err(HcflError::Config(format!(
            "ternary wire buffer is {} bytes, expected {expect}",
            bytes.len()
        )));
    }
    Ok(n_chunks)
}

/// Padding bits past `d` must be zero for the buffer to be canonical.
fn check_ternary_padding(packed: &[u8], d: usize) -> Result<()> {
    if d % 4 != 0 {
        let tail = packed[d / 4] >> (2 * (d % 4));
        if tail != 0 {
            return Err(HcflError::Config(
                "ternary wire buffer has non-zero padding bits".into(),
            ));
        }
    }
    Ok(())
}

pub fn unpack_ternary(bytes: &[u8], d: usize, chunk: usize) -> Result<Vec<TernaryChunk>> {
    let n_chunks = check_ternary_len(bytes, d, chunk)?;
    let alphas: Vec<f32> = bytes[..4 * n_chunks]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let packed = &bytes[4 * n_chunks..];
    let mut q_all = Vec::with_capacity(d);
    for i in 0..d {
        let bits = (packed[i / 4] >> (2 * (i % 4))) & 0b11;
        q_all.push(match bits {
            0b00 => 0i8,
            0b01 => 1,
            0b10 => -1,
            _ => {
                return Err(HcflError::Config(
                    "ternary wire buffer has an invalid 0b11 symbol".into(),
                ))
            }
        });
    }
    check_ternary_padding(packed, d)?;
    let mut out = Vec::with_capacity(n_chunks);
    for (i, alpha) in alphas.into_iter().enumerate() {
        let start = i * chunk;
        let end = ((i + 1) * chunk).min(d);
        out.push(TernaryChunk {
            q: q_all[start..end].to_vec(),
            alpha,
        });
    }
    Ok(out)
}

/// Zero-copy ternary decode: dequantize the whole update straight into
/// `out` (resized to `d`) — no `Vec<TernaryChunk>`, no `Vec<i8>`.  Same
/// validation as [`unpack_ternary`]: exact length, no `0b11` symbols,
/// zero padding bits.
pub fn unpack_ternary_into(
    bytes: &[u8],
    d: usize,
    chunk: usize,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n_chunks = check_ternary_len(bytes, d, chunk)?;
    out.clear();
    out.resize(d, 0.0);
    let packed = &bytes[4 * n_chunks..];
    for i in 0..n_chunks {
        let alpha = f32::from_le_bytes([
            bytes[4 * i],
            bytes[4 * i + 1],
            bytes[4 * i + 2],
            bytes[4 * i + 3],
        ]);
        let start = i * chunk;
        let end = ((i + 1) * chunk).min(d);
        if start % 4 == 0 {
            simd::unpack_2bit_f32(&packed[start / 4..], end - start, alpha, &mut out[start..end])?;
        } else {
            // chunk sizes that are not a multiple of 4 leave chunks
            // bit-misaligned; decode those positions via the scalar
            // reference on the global symbol index
            for j in start..end {
                let bits = (packed[j / 4] >> (2 * (j % 4))) & 0b11;
                let q: f32 = match bits {
                    0b00 => 0.0,
                    0b01 => 1.0,
                    0b10 => -1.0,
                    _ => {
                        return Err(HcflError::Config(
                            "ternary wire buffer has an invalid 0b11 symbol".into(),
                        ))
                    }
                };
                out[j] = q * alpha;
            }
        }
    }
    check_ternary_padding(packed, d)
}

// ---------------------------------------------------------------------------
// Sparse (Top-K)
// ---------------------------------------------------------------------------

fn push_varint(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// One hardened LEB128 read (see [`simd::read_varint`] for the exact
/// rejection rules: truncation, u32 overflow, overlong encodings).
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    simd::read_varint(bytes, pos)
}

pub fn pack_sparse(d: usize, idx: &[u32], val: &[f32], out: &mut Vec<u8>) -> Result<()> {
    if idx.len() != val.len() {
        return Err(HcflError::Config(format!(
            "sparse payload has {} indices but {} values",
            idx.len(),
            val.len()
        )));
    }
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
    let mut prev: Option<u32> = None;
    for &i in idx {
        match prev {
            None => push_varint(i, out),
            Some(p) => {
                if i <= p {
                    return Err(HcflError::Config(
                        "sparse indices must be strictly ascending".into(),
                    ));
                }
                push_varint(i - p, out);
            }
        }
        prev = Some(i);
    }
    simd::pack_f32_le(val, out);
    Ok(())
}

/// Decode the sparse header + delta-varint index stream shared by both
/// sparse decode paths.  On return `idx` holds the absolute indices
/// (validated in-bounds and non-wrapping) and `*pos` points at the
/// value block.
fn unpack_sparse_indices(
    bytes: &[u8],
    idx: &mut Vec<u32>,
    pos: &mut usize,
) -> Result<(usize, usize)> {
    if bytes.len() < 8 {
        return Err(HcflError::Config("sparse wire buffer truncated".into()));
    }
    let d = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
    let k = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    // each index costs at least one varint byte plus four value bytes:
    // reject forged headers before allocating k slots
    if bytes.len() < 8 + 5 * k {
        return Err(HcflError::Config(format!(
            "sparse wire buffer is {} bytes, too short for k={k}",
            bytes.len()
        )));
    }
    *pos = 8;
    idx.clear();
    idx.resize(k, 0);
    simd::decode_varints(bytes, pos, idx)?;
    // delta → absolute, rejecting wrap-around and out-of-range indices
    let mut prev = 0u32;
    for (i, slot) in idx.iter_mut().enumerate() {
        let v = if i == 0 {
            *slot
        } else {
            prev.checked_add(*slot).ok_or_else(|| {
                HcflError::Config("sparse index stream overflows u32".into())
            })?
        };
        if v as usize >= d {
            return Err(HcflError::Config(format!(
                "sparse index {v} out of range for d={d}"
            )));
        }
        *slot = v;
        prev = v;
    }
    if bytes.len() != *pos + 4 * k {
        return Err(HcflError::Config(format!(
            "sparse wire buffer is {} bytes, expected {}",
            bytes.len(),
            *pos + 4 * k
        )));
    }
    Ok((d, k))
}

pub fn unpack_sparse(bytes: &[u8]) -> Result<Payload> {
    let mut idx = Vec::new();
    let mut pos = 0usize;
    let (d, k) = unpack_sparse_indices(bytes, &mut idx, &mut pos)?;
    let mut val = vec![0.0f32; k];
    simd::unpack_f32_le(&bytes[pos..], &mut val);
    Ok(Payload::Sparse { d, idx, val })
}

/// Zero-copy sparse decode: zero-fill `out` (resized to `d`) and
/// scatter the kept values into it directly, with the index stream
/// decoded into the caller's reusable `idx_scratch` — no `Payload`
/// materialized.  The wire header's `d` must match the expected one.
pub fn unpack_sparse_into(
    bytes: &[u8],
    d: usize,
    idx_scratch: &mut Vec<u32>,
    out: &mut Vec<f32>,
) -> Result<()> {
    let mut pos = 0usize;
    let (wire_d, k) = unpack_sparse_indices(bytes, idx_scratch, &mut pos)?;
    if wire_d != d {
        return Err(HcflError::Config(format!(
            "sparse wire buffer is for d={wire_d}, expected d={d}"
        )));
    }
    out.clear();
    out.resize(d, 0.0);
    simd::scatter_f32_le(&bytes[pos..], idx_scratch, out);
    debug_assert_eq!(idx_scratch.len(), k);
    Ok(())
}

/// Decode a sparse wire buffer into `out` using the scratch arena's
/// internal index buffer (the form the codec trait calls).
pub fn unpack_sparse_into_scratch(
    bytes: &[u8],
    d: usize,
    scratch: &mut WireScratch,
    out: &mut Vec<f32>,
) -> Result<()> {
    let mut idx = std::mem::take(&mut scratch.u32_buf);
    let res = unpack_sparse_into(bytes, d, &mut idx, out);
    scratch.u32_buf = idx;
    res
}

// ---------------------------------------------------------------------------
// Frame envelope (transport layer)
// ---------------------------------------------------------------------------

/// Frame magic: the ASCII bytes `HCFL` read as a little-endian u32
/// (`0x4C464348`), i.e. the literal bytes `48 43 46 4C` on the wire.
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"HCFL");

/// The only protocol version this build speaks; anything else is
/// rejected at parse time.
pub const FRAME_VERSION: u8 = 1;

/// Packed envelope size on the wire, always exactly this many bytes.
pub const FRAME_HEADER_LEN: usize = 24;

/// Frame flag bit 0: an `Update` payload carries a trailing
/// exact-params block (uncompressed f32s for server-side
/// reconstruction-MSE instrumentation).
pub const FLAG_EXACT_PARAMS: u8 = 0b0000_0001;

/// The message types of the round protocol (DESIGN.md §8).  The
/// numeric values are the wire encoding and must never be reused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgType {
    /// Client → server, first frame on a connection: announces a swarm
    /// worker (worker index in the `client` field, empty payload).
    Hello = 1,
    /// Server → client: round parameters, this connection's work
    /// assignments and the broadcast global model.
    RoundOpen = 2,
    /// Client → server: one finished assignment — the packed wire
    /// update plus its metadata.
    Update = 3,
    /// Server → client: the round resolved and finalized (empty
    /// payload).
    RoundDone = 4,
    /// Server → client: the session is over, close the connection
    /// (empty payload).
    Shutdown = 5,
}

impl MsgType {
    /// Decode a wire byte, rejecting unknown message types.
    pub fn from_u8(v: u8) -> Result<MsgType> {
        match v {
            1 => Ok(MsgType::Hello),
            2 => Ok(MsgType::RoundOpen),
            3 => Ok(MsgType::Update),
            4 => Ok(MsgType::RoundDone),
            5 => Ok(MsgType::Shutdown),
            other => Err(HcflError::Config(format!(
                "frame has unknown message type {other}"
            ))),
        }
    }
}

/// The fixed 24-byte envelope in front of every payload on a real
/// connection.  All fields little-endian; byte offsets:
///
/// | off | size | field                                   |
/// |-----|------|-----------------------------------------|
/// | 0   | 4    | magic [`FRAME_MAGIC`] (`48 43 46 4C`)   |
/// | 4   | 1    | version [`FRAME_VERSION`]               |
/// | 5   | 1    | message type ([`MsgType`])              |
/// | 6   | 1    | codec tag ([`super::Scheme::codec_tag`])|
/// | 7   | 1    | flags ([`FLAG_EXACT_PARAMS`])           |
/// | 8   | 4    | round id                                |
/// | 12  | 4    | client id (worker index on `Hello`)     |
/// | 16  | 4    | payload length in bytes                 |
/// | 20  | 4    | CRC-32 of the payload ([`crc32`])       |
///
/// The header itself is not covered by the CRC — a corrupted header is
/// caught by the magic/version/type checks or by the payload checksum
/// failing against the wrong length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// What kind of message the payload is.
    pub msg_type: MsgType,
    /// The session's codec tag; receivers reject a mismatch against
    /// their configured scheme before touching the payload.
    pub codec: u8,
    /// Per-message flag bits (currently only [`FLAG_EXACT_PARAMS`]).
    pub flags: u8,
    /// Round the message belongs to (0 on `Hello`).
    pub round: u32,
    /// Simulated client id, or the worker index on `Hello`.
    pub client: u32,
    /// Payload length in bytes (may be 0).
    pub len: u32,
    /// CRC-32 (IEEE, reflected) of the payload bytes; 0 for an empty
    /// payload.
    pub crc: u32,
}

impl FrameHeader {
    /// Build a header for `payload`, computing its length and CRC.
    pub fn for_payload(
        msg_type: MsgType,
        codec: u8,
        flags: u8,
        round: u32,
        client: u32,
        payload: &[u8],
    ) -> FrameHeader {
        FrameHeader {
            msg_type,
            codec,
            flags,
            round,
            client,
            len: payload.len() as u32,
            crc: crc32(payload),
        }
    }

    /// Serialize to the 24 wire bytes.
    pub fn pack(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut b = [0u8; FRAME_HEADER_LEN];
        b[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        b[4] = FRAME_VERSION;
        b[5] = self.msg_type as u8;
        b[6] = self.codec;
        b[7] = self.flags;
        b[8..12].copy_from_slice(&self.round.to_le_bytes());
        b[12..16].copy_from_slice(&self.client.to_le_bytes());
        b[16..20].copy_from_slice(&self.len.to_le_bytes());
        b[20..24].copy_from_slice(&self.crc.to_le_bytes());
        b
    }

    /// Parse 24 wire bytes, rejecting bad magic, unknown versions and
    /// unknown message types.  Length and CRC are validated by the
    /// frame reader once the payload is in hand.
    pub fn parse(bytes: &[u8; FRAME_HEADER_LEN]) -> Result<FrameHeader> {
        let magic = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        if magic != FRAME_MAGIC {
            return Err(HcflError::Config(format!(
                "frame has bad magic {magic:#010x} (expected {FRAME_MAGIC:#010x})"
            )));
        }
        if bytes[4] != FRAME_VERSION {
            return Err(HcflError::Config(format!(
                "frame has unsupported protocol version {} (expected {FRAME_VERSION})",
                bytes[4]
            )));
        }
        Ok(FrameHeader {
            msg_type: MsgType::from_u8(bytes[5])?,
            codec: bytes[6],
            flags: bytes[7],
            round: u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]),
            client: u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]),
            len: u32::from_le_bytes([bytes[16], bytes[17], bytes[18], bytes[19]]),
            crc: u32::from_le_bytes([bytes[20], bytes[21], bytes[22], bytes[23]]),
        })
    }
}

const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32/IEEE (reflected, polynomial `0xEDB88320`, init and final
/// XOR `0xFFFFFFFF`) — the same variant as zlib/Ethernet, hand-rolled
/// over a const table to keep the crate dependency-free.  An empty
/// input hashes to 0.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip_and_length() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let mut out = Vec::new();
        pack_raw(&v, &mut out);
        assert_eq!(out.len(), 16);
        assert_eq!(unpack_raw(&out, 4).unwrap(), v);
        assert!(unpack_raw(&out, 3).is_err());
        let mut into = Vec::new();
        unpack_raw_into(&out, 4, &mut into).unwrap();
        assert_eq!(into, v);
    }

    #[test]
    fn ternary_symbols_pack_four_per_byte() {
        let chunks = vec![
            TernaryChunk {
                q: vec![0, 1, -1, 0, 1],
                alpha: 0.5,
            },
            TernaryChunk {
                q: vec![-1, -1],
                alpha: 0.25,
            },
        ];
        let mut out = Vec::new();
        pack_ternary(&chunks, &mut out).unwrap();
        // 2 alphas (8 B) + 7 symbols packed into 2 bytes
        assert_eq!(out.len(), 8 + 2);
        // chunk size 5: first chunk full, second is the 2-wide tail
        let back = unpack_ternary(&out, 7, 5).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].q, chunks[0].q);
        assert_eq!(back[1].q, chunks[1].q);
        assert_eq!(back[0].alpha, 0.5);
        assert_eq!(back[1].alpha, 0.25);
        // the zero-copy path agrees bit-for-bit with decode-the-chunks
        let mut direct = Vec::new();
        unpack_ternary_into(&out, 7, 5, &mut direct).unwrap();
        let expect: Vec<f32> = back
            .iter()
            .flat_map(|c| c.q.iter().map(|&q| q as f32 * c.alpha))
            .collect();
        assert_eq!(
            direct.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            expect.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn ternary_rejects_invalid_symbols() {
        let mut out = Vec::new();
        let bad = vec![TernaryChunk {
            q: vec![2],
            alpha: 1.0,
        }];
        assert!(pack_ternary(&bad, &mut out).is_err());
        // and in bulk, where the vector kernel screens the block
        let mut q = vec![0i8; 64];
        q[40] = 3;
        let bad = vec![TernaryChunk { q, alpha: 1.0 }];
        let mut out = Vec::new();
        assert!(pack_ternary(&bad, &mut out).is_err());
    }

    #[test]
    fn ternary_rejects_nonzero_padding() {
        // d=5 leaves 3 padding symbols in the last byte
        let chunks = vec![TernaryChunk {
            q: vec![1, -1, 0, 1, 1],
            alpha: 1.0,
        }];
        let mut out = Vec::new();
        pack_ternary(&chunks, &mut out).unwrap();
        let mut corrupt = out.clone();
        let last = corrupt.len() - 1;
        corrupt[last] |= 0b01 << 2; // garbage in an unused symbol slot
        assert!(unpack_ternary(&out, 5, 8).is_ok());
        assert!(unpack_ternary(&corrupt, 5, 8).is_err());
        let mut buf = Vec::new();
        assert!(unpack_ternary_into(&corrupt, 5, 8, &mut buf).is_err());
    }

    #[test]
    fn sparse_varints_round_trip() {
        let idx = vec![0u32, 1, 5, 300, 70_000];
        let val = vec![1.0f32, -2.0, 3.0, -4.0, 5.0];
        let mut out = Vec::new();
        pack_sparse(100_000, &idx, &val, &mut out).unwrap();
        // delta varints beat the old fixed 4 B/index accounting
        assert!(out.len() < 8 + 8 * idx.len());
        match unpack_sparse(&out).unwrap() {
            Payload::Sparse { d, idx: i, val: v } => {
                assert_eq!(d, 100_000);
                assert_eq!(i, idx);
                assert_eq!(v, val);
            }
            _ => unreachable!(),
        }
        // the scatter path produces the same dense vector
        let mut dense = Vec::new();
        let mut iscratch = Vec::new();
        unpack_sparse_into(&out, 100_000, &mut iscratch, &mut dense).unwrap();
        assert_eq!(dense.len(), 100_000);
        for (i, v) in idx.iter().zip(&val) {
            assert_eq!(dense[*i as usize], *v);
        }
        // header d mismatch is rejected
        assert!(unpack_sparse_into(&out, 99_999, &mut iscratch, &mut dense).is_err());
        // non-ascending indices are a packing bug, not a wire format
        let mut junk = Vec::new();
        assert!(pack_sparse(10, &[3, 3], &[1.0, 2.0], &mut junk).is_err());
    }

    #[test]
    fn sparse_rejects_out_of_range_index() {
        // hand-build a buffer whose only index is >= d
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&10u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        push_varint(10, &mut bytes); // index 10 with d=10: out of range
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(unpack_sparse(&bytes).is_err());
        let mut dense = Vec::new();
        let mut iscratch = Vec::new();
        assert!(unpack_sparse_into(&bytes, 10, &mut iscratch, &mut dense).is_err());
    }

    #[test]
    fn scratch_reuses_its_buffer() {
        let mut scratch = WireScratch::new();
        let p = Payload::Raw(vec![0.5f32; 256]);
        let n1 = scratch.pack(&p).unwrap();
        assert_eq!(n1, 1024);
        let cap = scratch.buf.capacity();
        let ptr = scratch.buf.as_ptr();
        for _ in 0..10 {
            assert_eq!(scratch.pack(&p).unwrap(), 1024);
        }
        assert_eq!(scratch.buf.capacity(), cap);
        assert_eq!(scratch.buf.as_ptr(), ptr);
    }

    #[test]
    fn arena_recycles_buffers() {
        let mut scratch = WireScratch::new();
        let p = Payload::Raw(vec![0.5f32; 64]);
        let upd = scratch.pack_update(&p).unwrap();
        assert_eq!(upd.wire_bytes(), 256);
        let ptr = upd.bytes.as_ptr();
        scratch.put_bytes(upd.into_bytes());
        // the next pack reuses the recycled allocation
        let upd2 = scratch.pack_update(&p).unwrap();
        assert_eq!(upd2.bytes.as_ptr(), ptr);
        // same story for leaf buffers
        let mut leaf = scratch.take_f32();
        leaf.resize(100, 1.0);
        let lptr = leaf.as_ptr();
        scratch.put_f32(leaf);
        let leaf2 = scratch.take_f32();
        assert!(leaf2.is_empty());
        assert_eq!(leaf2.as_ptr(), lptr);
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // zlib/IEEE reference values
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn frame_header_round_trips() {
        let h = FrameHeader::for_payload(MsgType::Update, 3, FLAG_EXACT_PARAMS, 7, 42, b"abc");
        assert_eq!(h.len, 3);
        assert_eq!(h.crc, crc32(b"abc"));
        let packed = h.pack();
        assert_eq!(&packed[0..4], b"HCFL");
        assert_eq!(packed[4], FRAME_VERSION);
        let back = FrameHeader::parse(&packed).unwrap();
        assert_eq!(back, h);
    }

    #[test]
    fn frame_header_rejects_garbage() {
        let good = FrameHeader::for_payload(MsgType::Hello, 0, 0, 0, 1, b"").pack();
        let mut bad_magic = good;
        bad_magic[0] ^= 0xFF;
        assert!(FrameHeader::parse(&bad_magic).is_err());
        let mut bad_version = good;
        bad_version[4] = 99;
        assert!(FrameHeader::parse(&bad_version).is_err());
        let mut bad_type = good;
        bad_type[5] = 0;
        assert!(FrameHeader::parse(&bad_type).is_err());
        bad_type[5] = 6;
        assert!(FrameHeader::parse(&bad_type).is_err());
    }
}
