//! Packed wire buffers: every [`Payload`] serialized to the actual
//! bytes it would occupy on the air.
//!
//! Until this module existed, `wire_bytes` was a per-scheme closed-form
//! formula; now it is the measured length of the packed buffer.  The
//! layouts (framing ignored for all schemes equally, exactly as the
//! formulas did):
//!
//! * **Raw** — little-endian f32s, `4·d` bytes.
//! * **HCFL** — per chunk, in range/chunk order: the code as f32-LE
//!   (`4·code_len` bytes) followed by 16 bytes of side info
//!   (lo, hi, mu, sd as f32-LE).  Total = `Σ n_chunks·(4·code_len+16)`,
//!   byte-identical to [`super::hcfl::hcfl_wire_bytes`].
//! * **Ternary** — one f32-LE scale per chunk (`4·n_chunks` bytes), then
//!   the concatenated quantized values packed 2 bits each, four per
//!   byte, LSB first (`0b00` = 0, `0b01` = +1, `0b10` = −1).  Total =
//!   `4·n_chunks + ceil(d/4)`, byte-identical to
//!   [`super::TernaryCompressor::wire_bytes_for`].
//! * **Sparse (Top-K)** — `u32` d, `u32` k, the sorted indices
//!   delta-coded as LEB128 varints (first index absolute, then gaps),
//!   then the kept values as f32-LE.  This is the one scheme whose
//!   packed size *beats* its old `8·k` formula — delta varints make the
//!   index stream sublinear for dense keeps.
//!
//! Packing is allocation-free in steady state: callers thread a
//! [`WireScratch`] (one per pool worker, see `coordinator/pool.rs`)
//! whose internal buffer is reused across rounds.  Unpacking needs the
//! receiver's static knowledge of the layout — the model geometry the
//! server already owns — via [`HcflWireLayout`] / the `(d, chunk)` pair,
//! mirroring how a real deployment would parse a headerless payload.

use crate::compression::{ChunkCode, Payload, RangeCodes, TernaryChunk};
use crate::error::{HcflError, Result};

/// A reusable packing buffer.  One lives in each pool worker's context
/// so steady-state rounds measure wire sizes with zero allocation.
#[derive(Debug, Default)]
pub struct WireScratch {
    buf: Vec<u8>,
}

impl WireScratch {
    pub fn new() -> WireScratch {
        WireScratch { buf: Vec::new() }
    }

    /// Pack `payload` into the internal buffer and return the packed
    /// length — the measured `wire_bytes` of the update.
    pub fn pack(&mut self, payload: &Payload) -> Result<usize> {
        self.buf.clear();
        pack_payload(payload, &mut self.buf)?;
        Ok(self.buf.len())
    }

    /// The bytes of the most recent [`WireScratch::pack`].
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Append any payload's packed form to `out`.
pub fn pack_payload(payload: &Payload, out: &mut Vec<u8>) -> Result<()> {
    match payload {
        Payload::Raw(v) => {
            pack_raw(v, out);
            Ok(())
        }
        Payload::HcflCodes(codes) => {
            pack_hcfl(codes, out);
            Ok(())
        }
        Payload::TernaryChunks(chunks) => pack_ternary(chunks, out),
        Payload::Sparse { d, idx, val } => pack_sparse(*d, idx, val, out),
    }
}

// ---------------------------------------------------------------------------
// Raw (FedAvg)
// ---------------------------------------------------------------------------

pub fn pack_raw(values: &[f32], out: &mut Vec<u8>) {
    out.reserve(4 * values.len());
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn unpack_raw(bytes: &[u8], d: usize) -> Result<Vec<f32>> {
    if bytes.len() != 4 * d {
        return Err(HcflError::Config(format!(
            "raw wire buffer is {} bytes, expected {}",
            bytes.len(),
            4 * d
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

// ---------------------------------------------------------------------------
// HCFL
// ---------------------------------------------------------------------------

/// The receiver-side shape of one packed HCFL range.
#[derive(Debug, Clone)]
pub struct RangeLayout {
    pub range_idx: usize,
    pub n_chunks: usize,
    pub code_len: usize,
}

/// The receiver-side shape of a whole packed HCFL update, derivable
/// from the compressor's static configuration (see
/// [`super::HcflCompressor::wire_layout`]).
#[derive(Debug, Clone)]
pub struct HcflWireLayout {
    pub ranges: Vec<RangeLayout>,
}

impl HcflWireLayout {
    /// Packed size in bytes (equals `hcfl_wire_bytes`).
    pub fn packed_len(&self) -> usize {
        self.ranges
            .iter()
            .map(|r| r.n_chunks * (4 * r.code_len + 16))
            .sum()
    }
}

pub fn pack_hcfl(codes: &[RangeCodes], out: &mut Vec<u8>) {
    for rc in codes {
        for cc in &rc.chunks {
            for v in &cc.code {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&cc.lo.to_le_bytes());
            out.extend_from_slice(&cc.hi.to_le_bytes());
            out.extend_from_slice(&cc.mu.to_le_bytes());
            out.extend_from_slice(&cc.sd.to_le_bytes());
        }
    }
}

pub fn unpack_hcfl(bytes: &[u8], layout: &HcflWireLayout) -> Result<Vec<RangeCodes>> {
    if bytes.len() != layout.packed_len() {
        return Err(HcflError::Config(format!(
            "hcfl wire buffer is {} bytes, layout expects {}",
            bytes.len(),
            layout.packed_len()
        )));
    }
    let mut pos = 0usize;
    let mut read_f32 = |bytes: &[u8]| -> f32 {
        let v = f32::from_le_bytes([
            bytes[pos],
            bytes[pos + 1],
            bytes[pos + 2],
            bytes[pos + 3],
        ]);
        pos += 4;
        v
    };
    let mut out = Vec::with_capacity(layout.ranges.len());
    for r in &layout.ranges {
        let mut chunks = Vec::with_capacity(r.n_chunks);
        for _ in 0..r.n_chunks {
            let code: Vec<f32> = (0..r.code_len).map(|_| read_f32(bytes)).collect();
            let lo = read_f32(bytes);
            let hi = read_f32(bytes);
            let mu = read_f32(bytes);
            let sd = read_f32(bytes);
            chunks.push(ChunkCode {
                code,
                lo,
                hi,
                mu,
                sd,
            });
        }
        out.push(RangeCodes {
            range_idx: r.range_idx,
            chunks,
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Ternary
// ---------------------------------------------------------------------------

pub fn pack_ternary(chunks: &[TernaryChunk], out: &mut Vec<u8>) -> Result<()> {
    for c in chunks {
        out.extend_from_slice(&c.alpha.to_le_bytes());
    }
    let mut byte = 0u8;
    let mut filled = 0u32;
    for c in chunks {
        for &q in &c.q {
            let bits: u8 = match q {
                0 => 0b00,
                1 => 0b01,
                -1 => 0b10,
                other => {
                    return Err(HcflError::Config(format!(
                        "ternary value {other} is not in {{-1, 0, 1}}"
                    )))
                }
            };
            byte |= bits << (2 * filled);
            filled += 1;
            if filled == 4 {
                out.push(byte);
                byte = 0;
                filled = 0;
            }
        }
    }
    if filled > 0 {
        out.push(byte);
    }
    Ok(())
}

pub fn unpack_ternary(bytes: &[u8], d: usize, chunk: usize) -> Result<Vec<TernaryChunk>> {
    let n_chunks = d.div_ceil(chunk);
    let expect = 4 * n_chunks + d.div_ceil(4);
    if bytes.len() != expect {
        return Err(HcflError::Config(format!(
            "ternary wire buffer is {} bytes, expected {expect}",
            bytes.len()
        )));
    }
    let alphas: Vec<f32> = bytes[..4 * n_chunks]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let packed = &bytes[4 * n_chunks..];
    let mut q_all = Vec::with_capacity(d);
    for i in 0..d {
        let bits = (packed[i / 4] >> (2 * (i % 4))) & 0b11;
        q_all.push(match bits {
            0b00 => 0i8,
            0b01 => 1,
            0b10 => -1,
            _ => {
                return Err(HcflError::Config(
                    "ternary wire buffer has an invalid 0b11 symbol".into(),
                ))
            }
        });
    }
    // padding bits past d must be zero for the buffer to be canonical
    if d % 4 != 0 {
        let tail = packed[d / 4] >> (2 * (d % 4));
        if tail != 0 {
            return Err(HcflError::Config(
                "ternary wire buffer has non-zero padding bits".into(),
            ));
        }
    }
    let mut out = Vec::with_capacity(n_chunks);
    for (i, alpha) in alphas.into_iter().enumerate() {
        let start = i * chunk;
        let end = ((i + 1) * chunk).min(d);
        out.push(TernaryChunk {
            q: q_all[start..end].to_vec(),
            alpha,
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Sparse (Top-K)
// ---------------------------------------------------------------------------

fn push_varint(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let mut v = 0u32;
    let mut shift = 0u32;
    loop {
        let byte = *bytes
            .get(*pos)
            .ok_or_else(|| HcflError::Config("sparse wire buffer truncated".into()))?;
        *pos += 1;
        if shift >= 32 {
            return Err(HcflError::Config("sparse varint overflows u32".into()));
        }
        v |= ((byte & 0x7F) as u32) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

pub fn pack_sparse(d: usize, idx: &[u32], val: &[f32], out: &mut Vec<u8>) -> Result<()> {
    if idx.len() != val.len() {
        return Err(HcflError::Config(format!(
            "sparse payload has {} indices but {} values",
            idx.len(),
            val.len()
        )));
    }
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
    let mut prev: Option<u32> = None;
    for &i in idx {
        match prev {
            None => push_varint(i, out),
            Some(p) => {
                if i <= p {
                    return Err(HcflError::Config(
                        "sparse indices must be strictly ascending".into(),
                    ));
                }
                push_varint(i - p, out);
            }
        }
        prev = Some(i);
    }
    for v in val {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

pub fn unpack_sparse(bytes: &[u8]) -> Result<Payload> {
    if bytes.len() < 8 {
        return Err(HcflError::Config("sparse wire buffer truncated".into()));
    }
    let d = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
    let k = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    let mut pos = 8usize;
    let mut idx = Vec::with_capacity(k);
    let mut prev = 0u32;
    for i in 0..k {
        let delta = read_varint(bytes, &mut pos)?;
        let v = if i == 0 { delta } else { prev + delta };
        idx.push(v);
        prev = v;
    }
    if bytes.len() != pos + 4 * k {
        return Err(HcflError::Config(format!(
            "sparse wire buffer is {} bytes, expected {}",
            bytes.len(),
            pos + 4 * k
        )));
    }
    let val: Vec<f32> = bytes[pos..]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Payload::Sparse { d, idx, val })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip_and_length() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let mut out = Vec::new();
        pack_raw(&v, &mut out);
        assert_eq!(out.len(), 16);
        assert_eq!(unpack_raw(&out, 4).unwrap(), v);
        assert!(unpack_raw(&out, 3).is_err());
    }

    #[test]
    fn ternary_symbols_pack_four_per_byte() {
        let chunks = vec![
            TernaryChunk {
                q: vec![0, 1, -1, 0, 1],
                alpha: 0.5,
            },
            TernaryChunk {
                q: vec![-1, -1],
                alpha: 0.25,
            },
        ];
        let mut out = Vec::new();
        pack_ternary(&chunks, &mut out).unwrap();
        // 2 alphas (8 B) + 7 symbols packed into 2 bytes
        assert_eq!(out.len(), 8 + 2);
        // chunk size 5: first chunk full, second is the 2-wide tail
        let back = unpack_ternary(&out, 7, 5).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].q, chunks[0].q);
        assert_eq!(back[1].q, chunks[1].q);
        assert_eq!(back[0].alpha, 0.5);
        assert_eq!(back[1].alpha, 0.25);
    }

    #[test]
    fn ternary_rejects_invalid_symbols() {
        let mut out = Vec::new();
        let bad = vec![TernaryChunk {
            q: vec![2],
            alpha: 1.0,
        }];
        assert!(pack_ternary(&bad, &mut out).is_err());
    }

    #[test]
    fn sparse_varints_round_trip() {
        let idx = vec![0u32, 1, 5, 300, 70_000];
        let val = vec![1.0f32, -2.0, 3.0, -4.0, 5.0];
        let mut out = Vec::new();
        pack_sparse(100_000, &idx, &val, &mut out).unwrap();
        // delta varints beat the old fixed 4 B/index accounting
        assert!(out.len() < 8 + 8 * idx.len());
        match unpack_sparse(&out).unwrap() {
            Payload::Sparse { d, idx: i, val: v } => {
                assert_eq!(d, 100_000);
                assert_eq!(i, idx);
                assert_eq!(v, val);
            }
            _ => unreachable!(),
        }
        // non-ascending indices are a packing bug, not a wire format
        let mut junk = Vec::new();
        assert!(pack_sparse(10, &[3, 3], &[1.0, 2.0], &mut junk).is_err());
    }

    #[test]
    fn scratch_reuses_its_buffer() {
        let mut scratch = WireScratch::new();
        let p = Payload::Raw(vec![0.5f32; 256]);
        let n1 = scratch.pack(&p).unwrap();
        assert_eq!(n1, 1024);
        let cap = scratch.buf.capacity();
        let ptr = scratch.buf.as_ptr();
        for _ in 0..10 {
            assert_eq!(scratch.pack(&p).unwrap(), 1024);
        }
        assert_eq!(scratch.buf.capacity(), cap);
        assert_eq!(scratch.buf.as_ptr(), ptr);
    }
}
