//! Experiment configuration: one struct drives the whole simulation, with
//! presets mirroring the paper's settings (§VI-A "Initial implementation
//! details": 100 clients, C = 0.1, E = 5, B = 64, lr = 0.01).

use crate::compression::Scheme;
use crate::control::{CodecPolicy, ServerOptKind};
use crate::coordinator::clock::RoundPolicy;
use crate::coordinator::session::CarryPolicy;
use crate::data::DataSpec;
use crate::error::{HcflError, Result};
use crate::fl::AggregatorKind;
use crate::hcfl::AeTrainConfig;
use crate::network::{DevicePreset, LinkModel};
use crate::runtime::Manifest;

/// The round-execution scenario: which devices participate, when the
/// server closes the round, and how surviving updates are folded.
///
/// The default reproduces the paper's Algorithm 1 exactly: homogeneous
/// reference devices, fully synchronous rounds, uniform-mean aggregation.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    pub policy: RoundPolicy,
    pub aggregator: AggregatorKind,
    pub devices: DevicePreset,
    /// What happens to uploads the policy cuts: discard (the paper's
    /// implicit rule) or decode and fold into a later round with
    /// staleness-discounted weights (`coordinator::session`).
    pub carry: CarryPolicy,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            policy: RoundPolicy::Synchronous,
            aggregator: AggregatorKind::UniformMean,
            devices: DevicePreset::Homogeneous,
            carry: CarryPolicy::Discard,
        }
    }
}

impl ScenarioConfig {
    /// Straggler study preset: `frac` of devices `slowdown`x slower,
    /// rounds cut at `deadline_s` seconds, uniform aggregation.
    pub fn stragglers(frac: f64, slowdown: f64, deadline_s: f64) -> ScenarioConfig {
        ScenarioConfig {
            policy: RoundPolicy::Deadline { t_max_s: deadline_s },
            aggregator: AggregatorKind::UniformMean,
            devices: DevicePreset::Stragglers { frac, slowdown },
            carry: CarryPolicy::Discard,
        }
    }

    pub fn label(&self) -> String {
        let carry = if self.carry.carries() {
            format!(" / {}", self.carry.label())
        } else {
            String::new()
        };
        format!(
            "{} / {} / {:?}{carry}",
            self.policy.label(),
            self.aggregator.label(),
            self.devices
        )
    }

    fn validate(&self) -> Result<()> {
        match &self.policy {
            RoundPolicy::Synchronous => {}
            RoundPolicy::Deadline { t_max_s } => {
                if !t_max_s.is_finite() || *t_max_s <= 0.0 {
                    return Err(HcflError::Config(format!(
                        "deadline t_max_s must be positive, got {t_max_s}"
                    )));
                }
            }
            RoundPolicy::FastestM { m } => {
                if *m == 0 {
                    return Err(HcflError::Config("fastest-m needs m >= 1".into()));
                }
            }
        }
        match &self.devices {
            DevicePreset::Homogeneous => {}
            DevicePreset::Stragglers { frac, slowdown } => {
                if !(0.0..=1.0).contains(frac) {
                    return Err(HcflError::Config(format!(
                        "straggler frac must be in [0, 1], got {frac}"
                    )));
                }
                if !slowdown.is_finite() || *slowdown < 1.0 {
                    return Err(HcflError::Config(format!(
                        "straggler slowdown must be >= 1, got {slowdown}"
                    )));
                }
            }
            DevicePreset::Iot { sigma, dropout_p } => {
                if !sigma.is_finite() || *sigma < 0.0 {
                    return Err(HcflError::Config(format!(
                        "iot sigma must be >= 0, got {sigma}"
                    )));
                }
                if !(0.0..1.0).contains(dropout_p) {
                    return Err(HcflError::Config(format!(
                        "dropout_p must be in [0, 1), got {dropout_p}"
                    )));
                }
            }
        }
        if let AggregatorKind::StalenessDiscounted { lambda } = self.aggregator {
            if !lambda.is_finite() || lambda < 0.0 {
                return Err(HcflError::Config(format!(
                    "staleness lambda must be >= 0, got {lambda}"
                )));
            }
        }
        if let CarryPolicy::CarryDiscounted {
            lambda,
            max_age_rounds,
        } = &self.carry
        {
            if !lambda.is_finite() || *lambda < 0.0 {
                return Err(HcflError::Config(format!(
                    "carry lambda must be >= 0, got {lambda}"
                )));
            }
            if *max_age_rounds == 0 {
                return Err(HcflError::Config(
                    "carry max_age_rounds must be >= 1 (0 is CarryPolicy::Discard)".into(),
                ));
            }
        }
        Ok(())
    }
}

/// Full configuration of one FL run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model name in the manifest ("lenet" | "fivecnn").
    pub model: String,
    pub scheme: Scheme,
    /// Total client population K.
    pub n_clients: usize,
    /// Participation fraction C; m = max(1, K*C) clients per round.
    pub participation: f64,
    pub rounds: usize,
    /// Local epochs E.
    pub local_epochs: usize,
    /// Local mini-batch size B (must be baked into an executable).
    pub batch: usize,
    pub lr: f32,
    /// 8 for the paper's EMNIST dense segmentation, 1 otherwise.
    pub dense_parts: usize,
    pub seed: u64,
    /// PJRT engine worker threads (executable caches / PJRT clients).
    pub engine_workers: usize,
    /// Client-stage worker pool size: persistent threads that execute
    /// surviving clients' train+encode work each round (no per-client
    /// spawns).  Round results are bit-identical for any value; size it
    /// to the host's cores for throughput.
    pub client_threads: usize,
    /// Edge-aggregation shards E (DESIGN.md §10).  0 (the default) folds
    /// the round flat in one session; E >= 1 partitions the round's
    /// decode + fold across E edge folders, each with its own worker
    /// slice, then a root fold over the partials.  Bit-identical to the
    /// flat fold for any value — size it so K/E leaves fit one shard's
    /// wall-clock budget.
    pub edge_shards: usize,
    /// Replace engine-backed local training with a deterministic
    /// pure-Rust fake update (global + seeded noise) and skip
    /// evaluation.  Lets the full round pipeline — pool, device layer,
    /// clock, aggregation, accounting — run without PJRT artifacts (CI
    /// smoke runs, large-m benches, determinism tests).  Requires an
    /// engine-free scheme (fedavg / topk).
    pub fake_train: bool,
    pub data: DataSpec,
    pub ae: AeTrainConfig,
    /// Reuse trained AEs from `<artifacts>/cache` when available.
    pub use_ae_cache: bool,
    /// Compress the server->client broadcast too.
    ///
    /// The paper's deployment (Fig. 3) has encoders on clients and the
    /// single decoder at the server, so the physical downlink is
    /// uncompressed (default `false`); its cost tables nevertheless count
    /// both directions encoded, so the Table I/II harness sets this to
    /// `true` to mirror the paper's accounting.  See DESIGN.md §4.
    pub compress_downlink: bool,
    /// Encode the client's *update* `Δ = w_local − w_broadcast` instead
    /// of the raw weights of the paper's Algorithm 1.
    ///
    /// An under-complete AE reconstructs `ŵ ≈ ρ·w` with ρ < 1; on raw
    /// weights that multiplicative shrinkage does NOT average out across
    /// clients and the global model decays geometrically (measured in
    /// EXPERIMENTS.md).  Encoding Δ — which the server adds back onto the
    /// global it already holds — turns the same shrinkage into a benign
    /// effective-learning-rate scale, which is what makes the paper's
    /// reported convergence achievable.  `false` reproduces Algorithm 1
    /// literally (ablation).  See DESIGN.md §4.
    pub encode_deltas: bool,
    /// Ship each client's exact post-training parameters to the server
    /// next to the compressed payload, enabling the reconstruction-MSE
    /// instrumentation (`RoundRecord::recon_mse`).
    ///
    /// In the in-process `Simulation` the side channel is free: the
    /// exact params never touch a wire and are *not* counted in
    /// `up_bytes`.  Over the real transport (DESIGN.md §8) the sidecar
    /// genuinely crosses the socket — a raw `4 + 4·d`-byte block per
    /// update that defeats the compression being measured — so the
    /// round server only requests it when this is set, and then counts
    /// its bytes in `up_bytes` and in the modelled uplink time.  The
    /// experiment presets keep it on (the paper's tables report
    /// reconstruction error); `transport::demo_config` turns it off.
    pub send_exact: bool,
    pub link: LinkModel,
    /// Round-execution scenario (devices, round policy, aggregation).
    pub scenario: ScenarioConfig,
    /// Per-round, per-client codec selection (`control::assign_codecs`).
    /// `Static` reproduces the single-codec fleet; the adaptive policies
    /// move slow-uplink clients onto a heavier codec.  `scheme` stays
    /// the base codec (downlink, handshake, fast clients).
    pub codec_policy: CodecPolicy,
    /// Server-side optimizer applied between the aggregated round
    /// result and the global-model install (`Sgd` = plain install).
    pub server_opt: ServerOptKind,
}

impl ExperimentConfig {
    /// Small sanity run: LeNet, 8 clients, a few rounds of HCFL 1:8.
    pub fn quickstart() -> ExperimentConfig {
        ExperimentConfig {
            model: "lenet".into(),
            scheme: Scheme::Hcfl { ratio: 8 },
            n_clients: 8,
            participation: 0.5,
            rounds: 5,
            local_epochs: 1,
            batch: 64,
            lr: 0.05,
            dense_parts: 1,
            seed: 7,
            engine_workers: 2,
            client_threads: 2,
            edge_shards: 0,
            fake_train: false,
            data: DataSpec::mnist(8),
            ae: AeTrainConfig::default(),
            use_ae_cache: true,
            compress_downlink: false,
            encode_deltas: true,
            send_exact: true,
            link: LinkModel::default(),
            scenario: ScenarioConfig::default(),
            codec_policy: CodecPolicy::Static,
            server_opt: ServerOptKind::Sgd,
        }
    }

    /// The paper's MNIST/LeNet-5 setting (§VI-A), scaled by `rounds`.
    pub fn mnist(scheme: Scheme, rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            model: "lenet".into(),
            scheme,
            n_clients: 100,
            participation: 0.1,
            rounds,
            local_epochs: 5,
            batch: 64,
            lr: 0.05,
            dense_parts: 1,
            seed: 42,
            engine_workers: 4,
            client_threads: 4,
            edge_shards: 0,
            fake_train: false,
            data: DataSpec::mnist(100),
            ae: AeTrainConfig::default(),
            use_ae_cache: true,
            compress_downlink: false,
            encode_deltas: true,
            send_exact: true,
            link: LinkModel::default(),
            scenario: ScenarioConfig::default(),
            codec_policy: CodecPolicy::Static,
            server_opt: ServerOptKind::Sgd,
        }
    }

    /// The paper's EMNIST/5-CNN setting with 8-way dense segmentation.
    pub fn emnist(scheme: Scheme, rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            model: "fivecnn".into(),
            scheme,
            n_clients: 100,
            participation: 0.1,
            rounds,
            local_epochs: 5,
            batch: 64,
            lr: 0.05,
            dense_parts: 8,
            seed: 42,
            engine_workers: 4,
            client_threads: 4,
            edge_shards: 0,
            fake_train: false,
            data: DataSpec::emnist(100),
            ae: AeTrainConfig::default(),
            use_ae_cache: true,
            compress_downlink: false,
            encode_deltas: true,
            send_exact: true,
            link: LinkModel::default(),
            scenario: ScenarioConfig::default(),
            codec_policy: CodecPolicy::Static,
            server_opt: ServerOptKind::Sgd,
        }
    }

    /// Participating clients per round.
    pub fn m(&self) -> usize {
        ((self.n_clients as f64 * self.participation).round() as usize)
            .clamp(1, self.n_clients)
    }

    /// Validate against the manifest (batch sizes baked, model known,
    /// AEs available for the requested ratio, shard geometry feasible).
    pub fn validate(&self, manifest: &Manifest) -> Result<()> {
        let model = manifest.model(&self.model)?;
        if self.n_clients == 0 || self.rounds == 0 || self.local_epochs == 0 {
            return Err(HcflError::Config(
                "n_clients, rounds and local_epochs must be positive".into(),
            ));
        }
        if self.data.n_clients != self.n_clients {
            return Err(HcflError::Config(format!(
                "data spec has {} clients, config has {}",
                self.data.n_clients, self.n_clients
            )));
        }
        let epoch_ok = self.batch == model.train_epoch.batch
            && self.data.per_client >= model.train_epoch.batch * model.train_epoch.n_batches;
        let step_ok =
            model.train_step.contains_key(&self.batch) && self.data.per_client >= self.batch;
        if !epoch_ok && !step_ok {
            return Err(HcflError::Config(format!(
                "batch {} is not runnable: baked step batches {:?}, epoch batch {}",
                self.batch,
                model.train_step.keys().collect::<Vec<_>>(),
                model.train_epoch.batch
            )));
        }
        if self.data.test_n % model.eval.batch != 0 {
            return Err(HcflError::Config(format!(
                "test_n {} must be a multiple of eval batch {}",
                self.data.test_n, model.eval.batch
            )));
        }
        if self.fake_train {
            // Every client class the policy can produce must upload with
            // an engine-free codec — an engine-backed scheme anywhere in
            // the menu would need PJRT artifacts mid-round.
            for (class, scheme) in self.codec_policy.classes(self.scheme) {
                if !matches!(
                    scheme,
                    Scheme::Fedavg | Scheme::TopK { .. } | Scheme::Ternary
                ) {
                    return Err(HcflError::Config(format!(
                        "fake_train supports only engine-free schemes \
                         (fedavg/topk/ternary), but the `{class}` class of policy \
                         `{}` uses {}",
                        self.codec_policy.label(),
                        scheme.label()
                    )));
                }
            }
        } else {
            // Engine-backed runs: every HCFL entry anywhere in the
            // policy's menu needs its autoencoders baked.
            for scheme in self.codec_policy.menu(self.scheme) {
                if let Scheme::Hcfl { ratio } = scheme {
                    for chunk in manifest.chunks.values() {
                        manifest.autoencoder(*chunk, ratio)?;
                    }
                }
            }
        }
        if self.dense_parts == 0 {
            return Err(HcflError::Config("dense_parts must be >= 1".into()));
        }
        if self.client_threads == 0 {
            return Err(HcflError::Config("client_threads must be >= 1".into()));
        }
        if self.edge_shards > 4096 {
            return Err(HcflError::Config(format!(
                "edge_shards {} is past the 4096 cap (each shard owns a worker pool)",
                self.edge_shards
            )));
        }
        self.data.partition.validate(self.data.classes)?;
        let skew = self.data.size_skew;
        if !skew.is_finite() || !(0.0..=0.5).contains(&skew) {
            return Err(HcflError::Config(format!(
                "size_skew must be in [0, 0.5], got {skew}"
            )));
        }
        if skew > 0.0 {
            // Worst-case shard under largest-remainder apportionment;
            // every shard must still form at least one training batch.
            let min_rows =
                (self.data.per_client as f64 * (1.0 - skew) / (1.0 + skew)).floor() as usize;
            if min_rows.saturating_sub(1) < self.batch {
                return Err(HcflError::Config(format!(
                    "size_skew {skew} can shrink a {}-row shard below batch {}",
                    self.data.per_client, self.batch
                )));
            }
        }
        self.codec_policy.validate()?;
        self.server_opt.validate()?;
        self.scenario.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m_rounding() {
        let mut cfg = ExperimentConfig::quickstart();
        cfg.n_clients = 100;
        cfg.participation = 0.1;
        assert_eq!(cfg.m(), 10);
        cfg.participation = 0.0;
        assert_eq!(cfg.m(), 1);
        cfg.participation = 1.0;
        assert_eq!(cfg.m(), 100);
    }

    #[test]
    fn default_scenario_is_algorithm_1() {
        let s = ScenarioConfig::default();
        assert_eq!(s.policy, RoundPolicy::Synchronous);
        assert_eq!(s.aggregator, AggregatorKind::UniformMean);
        assert_eq!(s.devices, DevicePreset::Homogeneous);
        assert_eq!(s.carry, CarryPolicy::Discard);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn scenario_validation_rejects_bad_knobs() {
        let bad = [
            ScenarioConfig {
                policy: RoundPolicy::Deadline { t_max_s: 0.0 },
                ..ScenarioConfig::default()
            },
            ScenarioConfig {
                policy: RoundPolicy::FastestM { m: 0 },
                ..ScenarioConfig::default()
            },
            ScenarioConfig::stragglers(1.5, 8.0, 1.0),
            ScenarioConfig {
                devices: DevicePreset::Stragglers {
                    frac: 0.3,
                    slowdown: 0.5,
                },
                ..ScenarioConfig::default()
            },
            ScenarioConfig {
                devices: DevicePreset::Iot {
                    sigma: 0.5,
                    dropout_p: 1.0,
                },
                ..ScenarioConfig::default()
            },
            ScenarioConfig {
                aggregator: AggregatorKind::StalenessDiscounted { lambda: -1.0 },
                ..ScenarioConfig::default()
            },
            ScenarioConfig {
                carry: CarryPolicy::CarryDiscounted {
                    lambda: -0.5,
                    max_age_rounds: 2,
                },
                ..ScenarioConfig::default()
            },
            ScenarioConfig {
                carry: CarryPolicy::CarryDiscounted {
                    lambda: 0.5,
                    max_age_rounds: 0,
                },
                ..ScenarioConfig::default()
            },
        ];
        for s in bad {
            assert!(s.validate().is_err(), "accepted invalid scenario {s:?}");
        }
        assert!(ScenarioConfig::stragglers(0.3, 8.0, 1.0).validate().is_ok());
        let carrying = ScenarioConfig {
            carry: CarryPolicy::CarryDiscounted {
                lambda: 0.5,
                max_age_rounds: 2,
            },
            ..ScenarioConfig::default()
        };
        assert!(carrying.validate().is_ok());
        assert!(carrying.label().contains("carry"));
        assert!(!ScenarioConfig::default().label().contains("carry"));
    }

    #[test]
    fn fake_train_gates_every_policy_class() {
        let manifest = Manifest::synthetic();
        let mut cfg = crate::transport::demo_config(Scheme::Fedavg, 8, 2, 1);
        assert!(cfg.validate(&manifest).is_ok());
        // an engine-free slow codec is fine...
        cfg.codec_policy = CodecPolicy::ThresholdByUplink {
            cutoff: 1.0,
            slow: Scheme::Ternary,
        };
        cfg.server_opt = ServerOptKind::DEFAULT_ADAM;
        assert!(cfg.validate(&manifest).is_ok());
        // ...an engine-backed one is rejected, naming the class
        cfg.codec_policy = CodecPolicy::ThresholdByUplink {
            cutoff: 1.0,
            slow: Scheme::Hcfl { ratio: 8 },
        };
        let err = cfg.validate(&manifest).unwrap_err().to_string();
        assert!(err.contains("slow-uplink"), "error must name the class: {err}");
        assert!(err.contains("HCFL"), "error must name the scheme: {err}");
        // bad policy knobs are caught too
        cfg.codec_policy = CodecPolicy::ThresholdByUplink {
            cutoff: -1.0,
            slow: Scheme::Ternary,
        };
        assert!(cfg.validate(&manifest).is_err());
    }

    #[test]
    fn presets_are_paper_shaped() {
        let c = ExperimentConfig::mnist(Scheme::Fedavg, 100);
        assert_eq!(c.n_clients, 100);
        assert_eq!(c.m(), 10);
        assert_eq!(c.local_epochs, 5);
        assert_eq!(c.batch, 64);
        let e = ExperimentConfig::emnist(Scheme::Ternary, 10);
        assert_eq!(e.dense_parts, 8);
        assert_eq!(e.data.classes, 47);
    }
}
