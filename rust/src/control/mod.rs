//! The per-round adaptive control plane: per-client codec selection and
//! the pluggable server-side optimizer (ROADMAP "adaptive control
//! loop"; the resource-allocation problem of arXiv:2206.06976 paired
//! with FedOpt-style server optimization, arXiv:2206.11448).
//!
//! Two independent decisions live here, both made on the driver thread
//! once per round:
//!
//! * **Codec selection** ([`CodecPolicy`] / [`assign_codecs`]): given
//!   each selected client's [`DeviceProfile`] and the model dimension,
//!   pick the codec that client uploads with this round.  Slow uplinks
//!   get a heavier codec; fast ones keep the base scheme.  The decision
//!   is a **pure function** of `(policy, base scheme, fleet, selection,
//!   d, link)` — no wall-clock input, no RNG — so every driver
//!   (in-process, TCP, resumed-from-snapshot) and every `client_threads`
//!   value derives the identical assignment vector.
//! * **Server optimization** ([`ServerOptKind`] / [`ServerOptKind::apply`]):
//!   between the aggregated round result and the global-model install,
//!   treat `aggregated − global` as a pseudo-gradient and run it through
//!   a server optimizer (`Sgd` = plain install, `FedAvgM` = server
//!   momentum, `FedAdam` = server Adam with persistent m/v state).  The
//!   state is part of the campaign snapshot (DESIGN.md §9.2 v2), so
//!   kill-and-resume stays bit-identical.

use std::sync::Arc;

use crate::compression::{Compressor, Scheme, TernaryCompressor, REF_TERNARY_CHUNK};
use crate::error::{HcflError, Result};
use crate::network::{DeviceFleet, LinkModel};

/// How the round's codecs are chosen across the selected clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecPolicy {
    /// Every client uses the experiment's base scheme (today's behavior).
    Static,
    /// Clients whose `uplink_mult` is below `cutoff` upload with `slow`;
    /// everyone else keeps the base scheme.
    ThresholdByUplink {
        /// Uplink-multiplier threshold (the reference device is 1.0).
        cutoff: f64,
        /// The codec handed to slow-uplink clients.
        slow: Scheme,
    },
    /// Minimize the predicted round makespan under a fleet distortion
    /// budget: clients are ranked by predicted upload time (slowest
    /// first) and greedily moved to `heavy` while the fleet's mean
    /// distortion proxy stays within `budget`.
    MakespanUnderDistortion {
        /// Ceiling on the mean per-client distortion proxy (0..=1).
        budget: f64,
        /// The codec assigned to the slowest clients.
        heavy: Scheme,
    },
}

impl CodecPolicy {
    /// Parse a policy token (`static`, `uplink@<cutoff>`,
    /// `makespan@<budget>`); the non-base codec defaults to ternary, the
    /// heaviest engine-free scheme.
    pub fn parse(tok: &str) -> Result<CodecPolicy> {
        if tok == "static" {
            return Ok(CodecPolicy::Static);
        }
        if let Some(c) = tok.strip_prefix("uplink@") {
            let cutoff: f64 = c.parse().map_err(|_| {
                HcflError::Config(format!("bad uplink policy cutoff `{c}`"))
            })?;
            return Ok(CodecPolicy::ThresholdByUplink {
                cutoff,
                slow: Scheme::Ternary,
            });
        }
        if let Some(b) = tok.strip_prefix("makespan@") {
            let budget: f64 = b.parse().map_err(|_| {
                HcflError::Config(format!("bad makespan policy budget `{b}`"))
            })?;
            return Ok(CodecPolicy::MakespanUnderDistortion {
                budget,
                heavy: Scheme::Ternary,
            });
        }
        Err(HcflError::Config(format!(
            "codec policy `{tok}` must be `static`, `uplink@<cutoff>` or `makespan@<budget>`"
        )))
    }

    /// Stable label for CSV columns and queue files.
    pub fn label(&self) -> String {
        match self {
            CodecPolicy::Static => "static".into(),
            CodecPolicy::ThresholdByUplink { cutoff, .. } => format!("uplink@{cutoff}"),
            CodecPolicy::MakespanUnderDistortion { budget, .. } => format!("makespan@{budget}"),
        }
    }

    /// Reject nonsensical knobs (config validation).
    pub fn validate(&self) -> Result<()> {
        match self {
            CodecPolicy::Static => Ok(()),
            CodecPolicy::ThresholdByUplink { cutoff, .. } => {
                if !cutoff.is_finite() || *cutoff <= 0.0 {
                    return Err(HcflError::Config(format!(
                        "uplink policy cutoff must be finite and > 0, got {cutoff}"
                    )));
                }
                Ok(())
            }
            CodecPolicy::MakespanUnderDistortion { budget, .. } => {
                if !budget.is_finite() || !(0.0..=1.0).contains(budget) {
                    return Err(HcflError::Config(format!(
                        "makespan policy distortion budget must be in [0, 1], got {budget}"
                    )));
                }
                Ok(())
            }
        }
    }

    /// The client classes this policy can produce and the scheme each
    /// uploads with — the base class first.  Validation walks this to
    /// gate engine-backed schemes out of engine-free runs with an error
    /// naming the offending class.
    pub fn classes(&self, base: Scheme) -> Vec<(&'static str, Scheme)> {
        match self {
            CodecPolicy::Static => vec![("all clients", base)],
            CodecPolicy::ThresholdByUplink { slow, .. } => {
                vec![("fast-uplink", base), ("slow-uplink", *slow)]
            }
            CodecPolicy::MakespanUnderDistortion { heavy, .. } => {
                vec![("within-budget", base), ("slowest-upload", *heavy)]
            }
        }
    }

    /// The distinct schemes this policy can assign (deduplicated by
    /// codec tag, base first) — what the compressor banks must cover.
    pub fn menu(&self, base: Scheme) -> Vec<Scheme> {
        let mut out: Vec<Scheme> = Vec::new();
        for (_, s) in self.classes(base) {
            if !out.iter().any(|o| o.codec_tag() == s.codec_tag()) {
                out.push(s);
            }
        }
        out
    }
}

/// Predicted on-air upload size of one update under `scheme` — the
/// closed forms of DESIGN.md §5, used only for *ranking* clients inside
/// [`assign_codecs`] (the billed `up_bytes` are always measured buffer
/// lengths).  Top-K assumes ~2 varint bytes per index.
pub fn predicted_wire_bytes(scheme: &Scheme, d: usize) -> usize {
    match scheme {
        Scheme::Fedavg => 4 * d,
        Scheme::Hcfl { ratio } => 4 * d.div_ceil((*ratio).max(1)) + 16,
        Scheme::Ternary => TernaryCompressor::wire_bytes_for(d, REF_TERNARY_CHUNK),
        Scheme::TopK { keep } => {
            let k = ((keep * d as f64).ceil() as usize).clamp(1, d);
            8 + 6 * k
        }
    }
}

/// A unitless per-client distortion proxy in [0, 1]: 0 = lossless, 1 =
/// everything discarded.  Top-K drops a `1 − keep` fraction of the
/// coordinates; ternary keeps signs plus one scale per chunk; HCFL's
/// autoencoder reconstruction sits in between.  Only *differences* of
/// these constants matter (the greedy budget walk), not their absolute
/// calibration.
pub fn distortion_proxy(scheme: &Scheme) -> f64 {
    match scheme {
        Scheme::Fedavg => 0.0,
        Scheme::Hcfl { .. } => 0.5,
        Scheme::Ternary => 0.75,
        Scheme::TopK { keep } => (1.0 - keep).clamp(0.0, 1.0),
    }
}

/// Assign one scheme per selection slot.  Pure in its arguments: no
/// clock, no RNG — the same `(policy, base, fleet, selected, d, link)`
/// always yields the same vector, which is what keeps the in-process,
/// TCP and resumed drivers bit-identical.  Every selected slot gets an
/// assignment (including devices the dropout stream will later kill),
/// so the decision never depends on the dropout realization.
pub fn assign_codecs(
    policy: &CodecPolicy,
    base: Scheme,
    fleet: &DeviceFleet,
    selected: &[usize],
    d: usize,
    link: &LinkModel,
) -> Vec<Scheme> {
    match policy {
        CodecPolicy::Static => vec![base; selected.len()],
        CodecPolicy::ThresholdByUplink { cutoff, slow } => selected
            .iter()
            .map(|&k| {
                if fleet.profile(k).uplink_mult < *cutoff {
                    *slow
                } else {
                    base
                }
            })
            .collect(),
        CodecPolicy::MakespanUnderDistortion { budget, heavy } => {
            let n = selected.len();
            let mut out = vec![base; n];
            if n == 0 {
                return out;
            }
            let base_bytes = predicted_wire_bytes(&base, d);
            let heavy_bytes = predicted_wire_bytes(heavy, d);
            if heavy_bytes >= base_bytes {
                return out; // heavier codec buys nothing
            }
            let extra = distortion_proxy(heavy) - distortion_proxy(&base);
            if extra <= 0.0 {
                // No distortion cost: everyone takes the smaller codec.
                for s in &mut out {
                    *s = *heavy;
                }
                return out;
            }
            // Rank slots slowest predicted upload first.  All times are
            // positive finite f64s, so their bit patterns order exactly
            // like the values; slot index breaks exact ties.
            let mut order: Vec<(u64, usize)> = selected
                .iter()
                .enumerate()
                .map(|(slot, &k)| {
                    let t = link.uplink_time(base_bytes, n)
                        / fleet.profile(k).uplink_mult.max(1e-9);
                    (t.to_bits(), slot)
                })
                .collect();
            order.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut distortion = distortion_proxy(&base) * n as f64;
            let cap = *budget * n as f64;
            for &(_, slot) in &order {
                if distortion + extra > cap + 1e-12 {
                    break;
                }
                out[slot] = *heavy;
                distortion += extra;
            }
            out
        }
    }
}

/// A codec-tag-indexed table of compressors: the per-client replacement
/// for the session's single `Arc<dyn Compressor>`.  Tags are the wire
/// protocol's [`Scheme::codec_tag`] values (0–3).
#[derive(Clone)]
pub struct CodecBank {
    base: u8,
    slots: [Option<Arc<dyn Compressor>>; 4],
}

impl CodecBank {
    /// A bank holding only the base compressor (the static install).
    pub fn single(base: Arc<dyn Compressor>) -> CodecBank {
        let tag = base.scheme().codec_tag();
        let mut bank = CodecBank {
            base: tag,
            slots: [None, None, None, None],
        };
        bank.slots[tag as usize] = Some(base);
        bank
    }

    /// Register a compressor under its own scheme's codec tag.
    pub fn insert(&mut self, c: Arc<dyn Compressor>) {
        let tag = c.scheme().codec_tag();
        self.slots[tag as usize] = Some(c);
    }

    /// The base scheme's codec tag (the downlink / handshake codec).
    pub fn base_tag(&self) -> u8 {
        self.base
    }

    /// The base compressor.
    pub fn base(&self) -> &Arc<dyn Compressor> {
        self.slots[self.base as usize]
            .as_ref()
            .expect("the base compressor is registered at construction")
    }

    /// Look up the compressor for a codec tag; a tag outside the bank is
    /// a typed error (a forged or mis-assigned update).
    pub fn get(&self, tag: u8) -> Result<&Arc<dyn Compressor>> {
        self.slots
            .get(tag as usize)
            .and_then(|s| s.as_ref())
            .ok_or_else(|| {
                HcflError::Config(format!("codec tag {tag} is not in this run's codec bank"))
            })
    }
}

/// The server-side optimizer applied between the aggregated round
/// result and the global-model install.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServerOptKind {
    /// Install the aggregate as-is (today's behavior).
    Sgd,
    /// Server momentum: `m ← β·m + Δ`, install `g + m`.
    FedAvgM {
        /// Momentum decay β in [0, 1).
        beta: f64,
    },
    /// Server Adam on the pseudo-gradient `Δ = aggregate − g`:
    /// `m ← β1·m + (1−β1)Δ`, `v ← β2·v + (1−β2)Δ²`, install
    /// `g + η·m / (√v + ε)`.
    FedAdam {
        /// First-moment decay.
        beta1: f64,
        /// Second-moment decay.
        beta2: f64,
        /// Server learning rate.
        eta: f64,
        /// Denominator floor.
        eps: f64,
    },
}

impl ServerOptKind {
    /// Default FedAvgM momentum.
    pub const DEFAULT_BETA: f64 = 0.9;
    /// Default FedAdam hyperparameters.
    pub const DEFAULT_ADAM: ServerOptKind = ServerOptKind::FedAdam {
        beta1: 0.9,
        beta2: 0.99,
        eta: 0.1,
        eps: 1e-8,
    };

    /// Parse an optimizer token (`sgd`, `fedavgm`, `fedavgm@<beta>`,
    /// `fedadam`, `fedadam@<eta>`).
    pub fn parse(tok: &str) -> Result<ServerOptKind> {
        if tok == "sgd" {
            return Ok(ServerOptKind::Sgd);
        }
        if tok == "fedavgm" {
            return Ok(ServerOptKind::FedAvgM {
                beta: Self::DEFAULT_BETA,
            });
        }
        if let Some(b) = tok.strip_prefix("fedavgm@") {
            let beta: f64 = b
                .parse()
                .map_err(|_| HcflError::Config(format!("bad fedavgm beta `{b}`")))?;
            return Ok(ServerOptKind::FedAvgM { beta });
        }
        if tok == "fedadam" {
            return Ok(Self::DEFAULT_ADAM);
        }
        if let Some(e) = tok.strip_prefix("fedadam@") {
            let eta: f64 = e
                .parse()
                .map_err(|_| HcflError::Config(format!("bad fedadam eta `{e}`")))?;
            return Ok(ServerOptKind::FedAdam {
                beta1: 0.9,
                beta2: 0.99,
                eta,
                eps: 1e-8,
            });
        }
        Err(HcflError::Config(format!(
            "server optimizer `{tok}` must be `sgd`, `fedavgm[@beta]` or `fedadam[@eta]`"
        )))
    }

    /// Stable label for CSV columns and queue files.
    pub fn label(&self) -> &'static str {
        match self {
            ServerOptKind::Sgd => "sgd",
            ServerOptKind::FedAvgM { .. } => "fedavgm",
            ServerOptKind::FedAdam { .. } => "fedadam",
        }
    }

    /// The snapshot fingerprint tag (DESIGN.md §9.2): 0 sgd, 1 fedavgm,
    /// 2 fedadam.  These values are on-disk format and must never be
    /// reused.
    pub fn tag(&self) -> u8 {
        match self {
            ServerOptKind::Sgd => 0,
            ServerOptKind::FedAvgM { .. } => 1,
            ServerOptKind::FedAdam { .. } => 2,
        }
    }

    /// Reject nonsensical knobs (config validation).
    pub fn validate(&self) -> Result<()> {
        match self {
            ServerOptKind::Sgd => Ok(()),
            ServerOptKind::FedAvgM { beta } => {
                if !beta.is_finite() || !(0.0..1.0).contains(beta) {
                    return Err(HcflError::Config(format!(
                        "fedavgm beta must be in [0, 1), got {beta}"
                    )));
                }
                Ok(())
            }
            ServerOptKind::FedAdam {
                beta1,
                beta2,
                eta,
                eps,
            } => {
                for (name, b) in [("beta1", beta1), ("beta2", beta2)] {
                    if !b.is_finite() || !(0.0..1.0).contains(b) {
                        return Err(HcflError::Config(format!(
                            "fedadam {name} must be in [0, 1), got {b}"
                        )));
                    }
                }
                if !eta.is_finite() || *eta <= 0.0 {
                    return Err(HcflError::Config(format!(
                        "fedadam eta must be finite and > 0, got {eta}"
                    )));
                }
                if !eps.is_finite() || *eps <= 0.0 {
                    return Err(HcflError::Config(format!(
                        "fedadam eps must be finite and > 0, got {eps}"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Apply the optimizer to one round's aggregate.  `global` is the
    /// pre-round model, `aggregated` the fold result; the return value
    /// is what the server installs.  Sequential f64 arithmetic on the
    /// driver thread, so the result is bit-identical for any
    /// `client_threads` / edge-shard / driver combination.
    pub fn apply(
        &self,
        state: &mut ServerOptState,
        global: &[f32],
        aggregated: Vec<f32>,
    ) -> Result<Vec<f32>> {
        let d = global.len();
        if aggregated.len() != d {
            return Err(HcflError::Config(format!(
                "server-opt aggregate has {} weights, global has {d}",
                aggregated.len()
            )));
        }
        match self {
            ServerOptKind::Sgd => Ok(aggregated),
            ServerOptKind::FedAvgM { beta } => {
                state.ensure(d, false)?;
                let mut out = aggregated;
                for i in 0..d {
                    let delta = out[i] as f64 - global[i] as f64;
                    let m = beta * state.m[i] as f64 + delta;
                    state.m[i] = m as f32;
                    out[i] = (global[i] as f64 + m) as f32;
                }
                Ok(out)
            }
            ServerOptKind::FedAdam {
                beta1,
                beta2,
                eta,
                eps,
            } => {
                state.ensure(d, true)?;
                let mut out = aggregated;
                for i in 0..d {
                    let delta = out[i] as f64 - global[i] as f64;
                    let m = beta1 * state.m[i] as f64 + (1.0 - beta1) * delta;
                    let v = beta2 * state.v[i] as f64 + (1.0 - beta2) * delta * delta;
                    state.m[i] = m as f32;
                    state.v[i] = v as f32;
                    out[i] = (global[i] as f64 + eta * m / (v.sqrt() + eps)) as f32;
                }
                Ok(out)
            }
        }
    }
}

/// The server optimizer's persistent moment vectors (empty until the
/// optimizer first runs; `Sgd` never populates them).  Snapshot v2
/// carries both, so a killed FedAdam campaign resumes bit-identically.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServerOptState {
    /// First moment (momentum), one f32 per model weight.
    pub m: Vec<f32>,
    /// Second moment (FedAdam only), one f32 per model weight.
    pub v: Vec<f32>,
}

impl ServerOptState {
    /// An empty state (fresh campaign, or `Sgd`).
    pub fn empty() -> ServerOptState {
        ServerOptState::default()
    }

    /// True when the optimizer has not run yet.
    pub fn is_empty(&self) -> bool {
        self.m.is_empty() && self.v.is_empty()
    }

    fn ensure(&mut self, d: usize, need_v: bool) -> Result<()> {
        Self::size("m", &mut self.m, d)?;
        if need_v {
            Self::size("v", &mut self.v, d)?;
        }
        Ok(())
    }

    fn size(name: &str, vec: &mut Vec<f32>, d: usize) -> Result<()> {
        if vec.is_empty() {
            vec.resize(d, 0.0);
            return Ok(());
        }
        if vec.len() != d {
            return Err(HcflError::Config(format!(
                "server-opt {name} state has {} entries, model has {d}",
                vec.len()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::DevicePreset;

    fn iot_fleet(n: usize) -> DeviceFleet {
        let preset = DevicePreset::Iot {
            sigma: 0.8,
            dropout_p: 0.0,
        };
        DeviceFleet::sample(n, &preset, 42)
    }

    #[test]
    fn policy_parse_label_round_trips() {
        for tok in ["static", "uplink@0.5", "makespan@0.25"] {
            let p = CodecPolicy::parse(tok).unwrap();
            assert_eq!(p.label(), tok);
            p.validate().unwrap();
        }
        assert!(CodecPolicy::parse("bogus").is_err());
        assert!(CodecPolicy::parse("uplink@x").is_err());
        assert!(CodecPolicy::parse("uplink@0").unwrap().validate().is_err());
        assert!(CodecPolicy::parse("makespan@2").unwrap().validate().is_err());
    }

    #[test]
    fn opt_parse_label_and_tags() {
        assert_eq!(ServerOptKind::parse("sgd").unwrap(), ServerOptKind::Sgd);
        assert_eq!(
            ServerOptKind::parse("fedavgm").unwrap(),
            ServerOptKind::FedAvgM { beta: 0.9 }
        );
        assert_eq!(
            ServerOptKind::parse("fedadam").unwrap(),
            ServerOptKind::DEFAULT_ADAM
        );
        let custom = ServerOptKind::parse("fedadam@0.5").unwrap();
        assert!(matches!(custom, ServerOptKind::FedAdam { eta, .. } if eta == 0.5));
        assert!(ServerOptKind::parse("adamw").is_err());
        assert!(ServerOptKind::parse("fedavgm@1.5").unwrap().validate().is_err());
        let tags: Vec<u8> = [
            ServerOptKind::Sgd,
            ServerOptKind::FedAvgM { beta: 0.9 },
            ServerOptKind::DEFAULT_ADAM,
        ]
        .iter()
        .map(|k| k.tag())
        .collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }

    #[test]
    fn threshold_policy_splits_on_uplink_and_is_pure() {
        let fleet = iot_fleet(64);
        let selected: Vec<usize> = (0..64).collect();
        let policy = CodecPolicy::ThresholdByUplink {
            cutoff: 1.0,
            slow: Scheme::Ternary,
        };
        let link = LinkModel::default();
        let a = assign_codecs(&policy, Scheme::Fedavg, &fleet, &selected, 802, &link);
        let b = assign_codecs(&policy, Scheme::Fedavg, &fleet, &selected, 802, &link);
        assert_eq!(a, b, "assignment must be a pure function of its inputs");
        let slow = a.iter().filter(|s| **s == Scheme::Ternary).count();
        assert!(slow > 0 && slow < 64, "sigma-spread fleet must mix codecs");
        for (slot, &k) in selected.iter().enumerate() {
            let want = fleet.profile(k).uplink_mult < 1.0;
            assert_eq!(a[slot] == Scheme::Ternary, want, "slot {slot}");
        }
    }

    #[test]
    fn makespan_policy_moves_slowest_first_within_budget() {
        let fleet = iot_fleet(40);
        let selected: Vec<usize> = (0..40).collect();
        let link = LinkModel::default();
        let policy = CodecPolicy::MakespanUnderDistortion {
            budget: 0.25,
            heavy: Scheme::Ternary,
        };
        let got = assign_codecs(&policy, Scheme::Fedavg, &fleet, &selected, 802, &link);
        let heavy: Vec<usize> = (0..40).filter(|&s| got[s] == Scheme::Ternary).collect();
        // budget 0.25 over proxy 0.75 per heavy client => floor(40/3) = 13
        assert_eq!(heavy.len(), 13);
        // every heavy client's uplink is no faster than every light one's
        let slowest_light = heavy
            .iter()
            .map(|&s| fleet.profile(selected[s]).uplink_mult)
            .fold(f64::MIN, f64::max);
        for s in 0..40 {
            if got[s] == Scheme::Fedavg {
                assert!(fleet.profile(selected[s]).uplink_mult >= slowest_light);
            }
        }
        // a zero budget assigns nothing; a free heavy codec assigns all
        let strict = CodecPolicy::MakespanUnderDistortion {
            budget: 0.0,
            heavy: Scheme::Ternary,
        };
        let none = assign_codecs(&strict, Scheme::Fedavg, &fleet, &selected, 802, &link);
        assert!(none.iter().all(|s| *s == Scheme::Fedavg));
    }

    #[test]
    fn bank_lookup_gates_unregistered_tags() {
        use crate::compression::Identity;
        let bank = CodecBank::single(Arc::new(Identity));
        assert_eq!(bank.base_tag(), 0);
        assert!(bank.get(0).is_ok());
        assert!(bank.get(2).is_err());
        assert!(bank.get(9).is_err());
    }

    #[test]
    fn sgd_installs_the_aggregate_unchanged() {
        let mut state = ServerOptState::empty();
        let global = vec![1.0f32, 2.0];
        let out = ServerOptKind::Sgd
            .apply(&mut state, &global, vec![3.0, 4.0])
            .unwrap();
        assert_eq!(out, vec![3.0, 4.0]);
        assert!(state.is_empty());
    }

    #[test]
    fn fedavgm_accumulates_momentum() {
        let kind = ServerOptKind::FedAvgM { beta: 0.5 };
        let mut state = ServerOptState::empty();
        let global = vec![0.0f32; 2];
        // round 1: delta = 1 => m = 1, install 1
        let g1 = kind.apply(&mut state, &global, vec![1.0, 1.0]).unwrap();
        assert_eq!(g1, vec![1.0, 1.0]);
        assert_eq!(state.m, vec![1.0, 1.0]);
        assert!(state.v.is_empty());
        // round 2 from g1: delta = 1 again => m = 1.5, install g1 + 1.5
        let g2 = kind.apply(&mut state, &g1, vec![2.0, 2.0]).unwrap();
        assert_eq!(g2, vec![2.5, 2.5]);
        assert_eq!(state.m, vec![1.5, 1.5]);
    }

    #[test]
    fn fedadam_fills_both_moments_and_is_resumable() {
        let kind = ServerOptKind::DEFAULT_ADAM;
        let mut state = ServerOptState::empty();
        let global = vec![0.0f32; 3];
        let g1 = kind
            .apply(&mut state, &global, vec![0.1, -0.2, 0.3])
            .unwrap();
        assert_eq!(state.m.len(), 3);
        assert_eq!(state.v.len(), 3);
        assert!(g1.iter().all(|v| v.is_finite()));
        // resuming from the stored f32 state reproduces the next step
        let mut resumed = state.clone();
        let a = kind.apply(&mut state, &g1, vec![0.2, 0.0, 0.1]).unwrap();
        let b = kind.apply(&mut resumed, &g1, vec![0.2, 0.0, 0.1]).unwrap();
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(state, resumed);
    }

    #[test]
    fn state_dimension_mismatch_is_rejected() {
        let kind = ServerOptKind::FedAvgM { beta: 0.9 };
        let mut state = ServerOptState {
            m: vec![0.0; 2],
            v: Vec::new(),
        };
        assert!(kind.apply(&mut state, &[0.0; 3], vec![0.0; 3]).is_err());
        assert!(ServerOptKind::Sgd
            .apply(&mut ServerOptState::empty(), &[0.0; 3], vec![0.0; 2])
            .is_err());
    }
}
