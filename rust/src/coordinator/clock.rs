//! The round clock: given each selected client's modelled compute + air
//! time, a [`RoundPolicy`] decides which uploads the server folds in and
//! how long the round lasts.
//!
//! All round-level cost accounting flows through this layer (it replaces
//! the old `network::CostLedger`): modelled per-client times are built
//! from *exact* per-client byte counts and [`DeviceProfile`] multipliers,
//! and the round makespan is the slowest *surviving* client's arrival —
//! not the mean, which is what hides stragglers at IoT scale.
//!
//! Determinism: the modelled compute time is the round's reference
//! compute time (mean measured train+encode wall time) scaled by each
//! device's `compute_mult`, so *relative* comparisons — arrival order,
//! `FastestM` survivor sets, aggregation order — depend only on the
//! seeded device fleet, never on OS scheduling noise.  Absolute
//! `Deadline` cutoffs still interact with the host's measured speed,
//! which is why drivers calibrate `t_max_s` from a probe round
//! ([`calibrated_deadline`]) instead of hard-coding seconds.

use crate::metrics::RoundRecord;
use crate::network::{DeviceProfile, LinkModel};

/// When the server closes a round.
#[derive(Debug, Clone, PartialEq)]
pub enum RoundPolicy {
    /// Wait for every (non-dropped) upload — Algorithm 1 of the paper.
    Synchronous,
    /// Semi-synchronous: cut clients whose modelled arrival exceeds
    /// `t_max_s` seconds after broadcast.
    Deadline { t_max_s: f64 },
    /// Fold only the first `m` modelled arrivals.
    FastestM { m: usize },
}

impl RoundPolicy {
    pub fn label(&self) -> String {
        match self {
            RoundPolicy::Synchronous => "sync".to_string(),
            RoundPolicy::Deadline { t_max_s } => format!("deadline {t_max_s:.3}s"),
            RoundPolicy::FastestM { m } => format!("fastest-{m}"),
        }
    }
}

/// One selected client's modelled round timeline.
#[derive(Debug, Clone)]
pub struct ClientTiming {
    /// Global client id.
    pub client: usize,
    /// Selection slot (tie-break so equal arrivals order deterministically).
    pub order: usize,
    /// Modelled broadcast receive time (seconds).
    pub downlink_s: f64,
    /// Modelled local train + encode time (seconds).
    pub compute_s: f64,
    /// Modelled upload air time (seconds).
    pub uplink_s: f64,
    /// The device vanished this round: nothing arrives at the server.
    pub dropped: bool,
}

impl ClientTiming {
    /// When the client's upload finishes arriving at the server.
    pub fn arrival_s(&self) -> f64 {
        self.downlink_s + self.compute_s + self.uplink_s
    }
}

/// Build one client's timing from its exact upload size and profile.
///
/// The cell is shared: each transmitting client gets `1/transmitting` of
/// the uplink and each selected client `1/selected` of the downlink,
/// scaled by the device's rate multipliers (paper eq. 13 generalized).
#[allow(clippy::too_many_arguments)]
pub fn client_timing(
    link: &LinkModel,
    profile: &DeviceProfile,
    client: usize,
    order: usize,
    up_bytes: usize,
    down_bytes: usize,
    reference_compute_s: f64,
    selected: usize,
    transmitting: usize,
    dropped: bool,
) -> ClientTiming {
    ClientTiming {
        client,
        order,
        downlink_s: link.downlink_time(down_bytes, selected) / profile.downlink_mult.max(1e-9),
        compute_s: reference_compute_s * profile.compute_mult,
        uplink_s: link.uplink_time(up_bytes, transmitting) / profile.uplink_mult.max(1e-9),
        dropped,
    }
}

/// What the policy decided for one round.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Indices into the `timings` slice, in modelled arrival order; only
    /// these uploads reach the aggregator.
    pub survivors: Vec<usize>,
    /// Indices of the alive-but-cut uploads, in modelled arrival order.
    /// Resolution used to discard these identities; sessions need them
    /// to attribute carried-over updates without recomputing timings.
    pub late: Vec<usize>,
    /// Selected clients that vanished (device dropout).
    pub dropped: usize,
    /// Alive clients cut by the policy (deadline miss / not in fastest
    /// m); always `late.len()`.
    pub stragglers: usize,
    /// Modelled round duration: the slowest surviving arrival, or the
    /// full deadline whenever any selected upload never made it (the
    /// server cannot know it should stop waiting earlier).
    pub makespan_s: f64,
}

/// Deadline calibrated from a synchronous probe round's record: the
/// shared broadcast time plus `factor`x the reference device's compute +
/// uplink, so it keeps every reference device and cuts exactly the
/// devices slowed by more than `factor`.  Reconstructed from recorded
/// byte counts (wire sizes are content-independent, so every client's
/// equal) and the recorded reference compute time — unlike the probe's
/// makespan this does not depend on whether a straggler happened to be
/// selected.
pub fn calibrated_deadline(link: &LinkModel, probe: &RoundRecord, factor: f64) -> f64 {
    let m = probe.selected.max(1);
    // up_bytes only covers the clients that actually transmitted, and
    // the uplink cell is shared by exactly those clients.
    let tx = probe.selected.saturating_sub(probe.dropped).max(1);
    let per_up = (probe.up_bytes as f64 / tx as f64).round() as usize;
    let per_down = (probe.down_bytes as f64 / m as f64).round() as usize;
    let up_s = link.uplink_time(per_up, tx);
    let down_s = link.downlink_time(per_down, m);
    down_s + factor * (probe.client_time_s + up_s)
}

/// Apply `policy` to the selected clients' modelled timelines.
///
/// Dropout modelling: `Synchronous` and `FastestM` assume the link
/// layer detects a vanished device (connection teardown / NACK), so the
/// round ends once every *alive* upload is in.  `Deadline` additionally
/// bounds slowness, which is NOT detectable — a slow upload and a dead
/// one look the same until `t_max_s` passes, so any missing upload
/// makes that policy wait out the full deadline.
pub fn resolve(policy: &RoundPolicy, timings: &[ClientTiming]) -> RoundOutcome {
    let dropped = timings.iter().filter(|t| t.dropped).count();
    // Alive uploads in modelled arrival order, selection order on ties.
    let mut alive: Vec<usize> = (0..timings.len()).filter(|&i| !timings[i].dropped).collect();
    alive.sort_by(|&a, &b| {
        timings[a]
            .arrival_s()
            .partial_cmp(&timings[b].arrival_s())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(timings[a].order.cmp(&timings[b].order))
    });

    let (survivors, late, makespan_s) = match policy {
        RoundPolicy::Synchronous => {
            let makespan = alive
                .last()
                .map(|&i| timings[i].arrival_s())
                .unwrap_or(0.0);
            (alive, Vec::new(), makespan)
        }
        RoundPolicy::Deadline { t_max_s } => {
            let (survivors, late): (Vec<usize>, Vec<usize>) = alive
                .iter()
                .copied()
                .partition(|&i| timings[i].arrival_s() <= *t_max_s);
            // See resolve()'s doc: slowness is undetectable, so any
            // missing upload — cut or dropped — means waiting out t_max.
            let makespan = if !late.is_empty() || dropped > 0 {
                *t_max_s
            } else {
                survivors
                    .last()
                    .map(|&i| timings[i].arrival_s())
                    .unwrap_or(0.0)
            };
            (survivors, late, makespan)
        }
        RoundPolicy::FastestM { m } => {
            let keep = (*m).min(alive.len());
            let late: Vec<usize> = alive[keep..].to_vec();
            let survivors: Vec<usize> = alive[..keep].to_vec();
            let makespan = survivors
                .last()
                .map(|&i| timings[i].arrival_s())
                .unwrap_or(0.0);
            (survivors, late, makespan)
        }
    };

    let stragglers = late.len();
    RoundOutcome {
        survivors,
        late,
        dropped,
        stragglers,
        makespan_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing(order: usize, compute_s: f64, dropped: bool) -> ClientTiming {
        ClientTiming {
            client: 100 + order,
            order,
            downlink_s: 0.1,
            compute_s,
            uplink_s: 0.2,
            dropped,
        }
    }

    #[test]
    fn synchronous_waits_for_slowest_alive() {
        let ts = vec![timing(0, 1.0, false), timing(1, 5.0, false), timing(2, 2.0, true)];
        let out = resolve(&RoundPolicy::Synchronous, &ts);
        assert_eq!(out.survivors, vec![0, 1]); // arrival order
        assert_eq!(out.dropped, 1);
        assert_eq!(out.stragglers, 0);
        assert!((out.makespan_s - 5.3).abs() < 1e-12);
    }

    #[test]
    fn deadline_cuts_stragglers_and_holds_until_t_max() {
        let ts = vec![timing(0, 1.0, false), timing(1, 5.0, false), timing(2, 2.0, false)];
        let out = resolve(&RoundPolicy::Deadline { t_max_s: 3.0 }, &ts);
        assert_eq!(out.survivors, vec![0, 2]);
        assert_eq!(out.late, vec![1], "cut identities must survive resolution");
        assert_eq!(out.stragglers, 1);
        assert_eq!(out.dropped, 0);
        // someone was cut: the server waited out the whole deadline
        assert_eq!(out.makespan_s, 3.0);

        // generous deadline: nobody cut, round ends at slowest arrival
        let out = resolve(&RoundPolicy::Deadline { t_max_s: 100.0 }, &ts);
        assert_eq!(out.survivors, vec![0, 2, 1]);
        assert_eq!(out.stragglers, 0);
        assert!((out.makespan_s - 5.3).abs() < 1e-12);
    }

    #[test]
    fn deadline_waits_out_dropouts_too() {
        // A dropped device is indistinguishable from a straggler until
        // the deadline passes: even with every alive upload in early,
        // the round lasts the full t_max.
        let ts = vec![timing(0, 1.0, false), timing(1, 1.0, true)];
        let out = resolve(&RoundPolicy::Deadline { t_max_s: 50.0 }, &ts);
        assert_eq!(out.survivors, vec![0]);
        assert_eq!(out.dropped, 1);
        assert_eq!(out.stragglers, 0);
        assert_eq!(out.makespan_s, 50.0);
    }

    #[test]
    fn deadline_can_leave_no_survivors() {
        let ts = vec![timing(0, 10.0, false), timing(1, 20.0, false)];
        let out = resolve(&RoundPolicy::Deadline { t_max_s: 0.5 }, &ts);
        assert!(out.survivors.is_empty());
        assert_eq!(out.late, vec![0, 1]); // arrival order
        assert_eq!(out.stragglers, 2);
        assert_eq!(out.makespan_s, 0.5);
    }

    #[test]
    fn fastest_m_takes_first_arrivals() {
        let ts = vec![
            timing(0, 4.0, false),
            timing(1, 1.0, false),
            timing(2, 3.0, false),
            timing(3, 2.0, true),
        ];
        let out = resolve(&RoundPolicy::FastestM { m: 2 }, &ts);
        assert_eq!(out.survivors, vec![1, 2]);
        assert_eq!(out.late, vec![0]); // client 0 was alive but too slow
        assert_eq!(out.stragglers, 1);
        assert_eq!(out.dropped, 1);
        assert!((out.makespan_s - 3.3).abs() < 1e-12);

        // m larger than the alive set degrades to synchronous
        let out = resolve(&RoundPolicy::FastestM { m: 10 }, &ts);
        assert_eq!(out.survivors.len(), 3);
        assert_eq!(out.stragglers, 0);
    }

    #[test]
    fn equal_arrivals_order_by_selection_slot() {
        let ts = vec![timing(0, 1.0, false), timing(1, 1.0, false), timing(2, 1.0, false)];
        let out = resolve(&RoundPolicy::Synchronous, &ts);
        assert_eq!(out.survivors, vec![0, 1, 2]);
    }

    #[test]
    fn timing_uses_exact_bytes_and_profile() {
        let link = LinkModel {
            uplink_bps: 8e6,
            downlink_bps: 8e6,
        };
        let slow = DeviceProfile {
            uplink_mult: 0.5,
            downlink_mult: 1.0,
            compute_mult: 4.0,
            dropout_p: 0.0,
        };
        // 1 MB over 1/10th of the cell at half rate: 20 s on the air.
        let t = client_timing(&link, &slow, 3, 0, 1_000_000, 0, 1.5, 10, 10, false);
        assert!((t.uplink_s - 20.0).abs() < 1e-9);
        assert!((t.compute_s - 6.0).abs() < 1e-12);
        assert_eq!(t.downlink_s, 0.0);
        assert!((t.arrival_s() - 26.0).abs() < 1e-9);
    }

    #[test]
    fn calibrated_deadline_matches_reference_path() {
        let link = LinkModel {
            uplink_bps: 8e6,
            downlink_bps: 8e6,
        };
        // 4 clients, 1 MB up and 2 MB down each, 0.5 s reference compute.
        let probe = RoundRecord {
            round: 1,
            accuracy: 0.5,
            loss: 1.0,
            recon_mse: 0.0,
            up_bytes: 4_000_000,
            down_bytes: 8_000_000,
            selected: 4,
            completed: 4,
            dropped: 0,
            stragglers: 0,
            carried_in: 0,
            carried_out: 0,
            carried_expired: 0,
            makespan_s: 99.0, // deliberately unused by the calibration
            client_time_s: 0.5,
            server_time_s: 0.0,
            comm_time_s: 0.0,
            wall_time_s: 0.0,
        };
        // per-client: up 1 MB at 2 Mbit/s = 4 s; down 2 MB at 2 Mbit/s = 8 s
        let t_max = calibrated_deadline(&link, &probe, 3.0);
        assert!((t_max - (8.0 + 3.0 * (0.5 + 4.0))).abs() < 1e-9, "{t_max}");
        // the reference device itself always makes this deadline
        let fleet =
            crate::network::DeviceFleet::sample(4, &crate::network::DevicePreset::Homogeneous, 1);
        let t = client_timing(
            &link,
            fleet.profile(0),
            0,
            0,
            1_000_000,
            2_000_000,
            0.5,
            4,
            4,
            false,
        );
        assert!(t.arrival_s() < t_max);
    }

    #[test]
    fn policy_labels() {
        assert_eq!(RoundPolicy::Synchronous.label(), "sync");
        assert_eq!(RoundPolicy::FastestM { m: 5 }.label(), "fastest-5");
        assert!(RoundPolicy::Deadline { t_max_s: 1.25 }.label().contains("1.250"));
    }
}
