//! The edge-aggregation tier: one round's decode + fold sharded across
//! `E` independent edge folders (DESIGN.md §10).
//!
//! A flat round folds all `K` decoded leaves through one
//! [`reduce_tree`] on one [`WorkerPool`].  That single session is the
//! scaling ceiling near `K = 10k`: every decode job contends on one
//! scratch arena and the fold is one thread-pool wide.  The
//! [`EdgeAggregator`] splits the round's leaf sequence (carried leaves
//! first, then fresh survivors in arrival order — exactly the flat
//! order) into `E` contiguous shards.  Each shard decodes and folds on
//! its **own** [`WorkerPool`] (own worker threads, own
//! [`WireScratch`](crate::compression::WireScratch) arenas, so shards
//! never contend on one arena lock), produces one partial
//! [`WeightedLeaf`] per owned subtree, and the root folds the partials
//! with the same [`TREE_FAN_IN`] rule.
//!
//! # The leaf-order invariant
//!
//! `f32` addition is not associative, so an arbitrary `E`-way split
//! would change the sum.  The shard boundaries are therefore aligned to
//! **fan-in subtrees**: [`ShardPlan`] picks the largest subtree size
//! `8^l` that still leaves at least `E` subtrees, and each shard owns a
//! contiguous run of subtrees.  A shard's local level-by-level fold of
//! one subtree performs *exactly* the combines the flat
//! [`reduce_tree`] performs inside that subtree (slice starts are
//! `8^l`-aligned, so every group boundary coincides; the trailing
//! partial subtree ends at the global tail, where the flat tree has the
//! same partial groups).  Concatenating the per-subtree partials in
//! subtree order reproduces the flat tree's level-`l` node list, and
//! the root fold computes the remaining levels — the two-level result
//! is bit-identical to the flat fold for any `E`.

use std::time::Instant;

use crate::coordinator::pool::{reduce_tree, WorkerCtx, WorkerPool};
use crate::error::{HcflError, Result};
use crate::fl::{WeightedLeaf, TREE_FAN_IN};

/// A deferred survivor decode: runs on a shard worker and yields the
/// weighted leaf plus its `(recon_contribution, decode_seconds)` stats.
pub type DecodeJob = Box<dyn FnOnce(&mut WorkerCtx) -> Result<(WeightedLeaf, f64, f64)> + Send>;

/// How one round's leaf sequence maps onto shards: the fan-in-aligned
/// subtree size and which contiguous subtree run each shard owns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Total leaves in the round (carried + fresh survivors).
    pub n_leaves: usize,
    /// Subtree size: the largest power of the fan-in that still leaves
    /// at least `n_shards` subtrees (1 when leaves are scarce).
    pub subtree: usize,
    /// Number of edge shards the plan distributes over.
    pub n_shards: usize,
    /// `ceil(n_leaves / subtree)` — one partial leaf per subtree.
    pub n_subtrees: usize,
}

impl ShardPlan {
    /// Plan `n_leaves` over `n_shards` shards with the given fan-in.
    ///
    /// Grows the subtree size by `fan_in` while (a) a full subtree still
    /// fits in the leaf count and (b) at least `n_shards` subtrees
    /// remain, so every shard can own work whenever `n_leaves >=
    /// n_shards`.
    pub fn new(n_leaves: usize, fan_in: usize, n_shards: usize) -> ShardPlan {
        debug_assert!(fan_in >= 2 && n_shards >= 1);
        let mut subtree = 1usize;
        while subtree * fan_in <= n_leaves && n_leaves.div_ceil(subtree * fan_in) >= n_shards {
            subtree *= fan_in;
        }
        ShardPlan {
            n_leaves,
            subtree,
            n_shards,
            n_subtrees: n_leaves.div_ceil(subtree),
        }
    }

    /// The contiguous subtree run `[lo, hi)` owned by `shard`.
    pub fn subtree_range(&self, shard: usize) -> (usize, usize) {
        debug_assert!(shard < self.n_shards);
        (
            shard * self.n_subtrees / self.n_shards,
            (shard + 1) * self.n_subtrees / self.n_shards,
        )
    }

    /// The leaf index range `[lo, hi)` owned by `shard`.  `lo` is always
    /// subtree-aligned; the final shard's `hi` clamps to `n_leaves`.
    pub fn leaf_range(&self, shard: usize) -> (usize, usize) {
        let (st_lo, st_hi) = self.subtree_range(shard);
        (
            st_lo * self.subtree,
            (st_hi * self.subtree).min(self.n_leaves),
        )
    }
}

/// The outcome of one sharded round fold.
pub struct EdgeFold {
    /// The folded root (weights still summed — pass through
    /// [`finish_tree`](crate::fl::finish_tree)), or `None` for an empty
    /// round.
    pub root: Option<WeightedLeaf>,
    /// Per-survivor `(recon_contribution, decode_seconds)` in global
    /// arrival order — shard slices are contiguous, so concatenating
    /// them in shard order restores the flat order and the sequential
    /// `f64` accumulation downstream stays bit-identical.
    pub stats: Vec<(f64, f64)>,
    /// Summed fold seconds across shards plus the root fold (total
    /// server-side fold work, not overlapped wall time).
    pub fold_s: f64,
}

/// `E` edge folders, each owning a private [`WorkerPool`] slice.
///
/// Construction splits the configured `client_threads` budget across
/// shards (`ceil(client_threads / E)`, min 1 per shard), so the total
/// worker count stays near the flat pipeline's while every shard keeps
/// its own scratch arena.
pub struct EdgeAggregator {
    pools: Vec<WorkerPool>,
}

impl EdgeAggregator {
    /// Build `n_shards` edge folders over a `client_threads` budget.
    pub fn new(n_shards: usize, client_threads: usize, engine_workers: usize) -> Result<Self> {
        if n_shards == 0 {
            return Err(HcflError::Config(
                "edge aggregation needs at least one shard".into(),
            ));
        }
        let per_shard = client_threads.div_ceil(n_shards).max(1);
        let mut pools = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            pools.push(WorkerPool::new(per_shard, engine_workers)?);
        }
        Ok(EdgeAggregator { pools })
    }

    /// Number of edge shards.
    pub fn n_shards(&self) -> usize {
        self.pools.len()
    }

    /// The pool the root session borrows for work outside the sharded
    /// fold (late-arrival decode, snapshot restore).
    pub fn root_pool(&self) -> &WorkerPool {
        &self.pools[0]
    }

    /// Decode + fold one round: `carried` leaves (already weighted, in
    /// carry order) followed by `jobs` (fresh survivors in arrival
    /// order) — the same leaf sequence the flat pipeline folds.
    ///
    /// Shards run concurrently on their own pools; the root then folds
    /// the per-subtree partials.  Bit-identical to decoding the jobs in
    /// order and calling [`reduce_tree`] over the whole sequence.
    pub fn fold_round(&self, carried: Vec<WeightedLeaf>, jobs: Vec<DecodeJob>) -> Result<EdgeFold> {
        let n_carried = carried.len();
        let n = n_carried + jobs.len();
        if n == 0 {
            return Ok(EdgeFold {
                root: None,
                stats: Vec::new(),
                fold_s: 0.0,
            });
        }
        let plan = ShardPlan::new(n, TREE_FAN_IN, self.pools.len());

        // Slice the conceptual leaf sequence (carried ++ fresh) into the
        // per-shard contiguous runs the plan dictates.
        let mut carried = carried.into_iter();
        let mut jobs = jobs.into_iter();
        let mut shards: Vec<(Vec<WeightedLeaf>, Vec<DecodeJob>)> =
            Vec::with_capacity(self.pools.len());
        for k in 0..self.pools.len() {
            let (lo, hi) = plan.leaf_range(k);
            let n_car = hi.min(n_carried) - lo.min(n_carried);
            let n_fresh = (hi - lo) - n_car;
            shards.push((
                carried.by_ref().take(n_car).collect(),
                jobs.by_ref().take(n_fresh).collect(),
            ));
        }

        // Drive every shard concurrently, each pinned to its own pool.
        let subtree = plan.subtree;
        let results: Vec<Result<ShardFold>> = std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .into_iter()
                .zip(&self.pools)
                .map(|((car, work), pool)| scope.spawn(move || shard_fold(pool, car, work, subtree)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(HcflError::Engine("edge shard panicked".into())))
                })
                .collect()
        });

        // Partials concatenate in shard (= subtree) order; stats in
        // shard order restore the global survivor order.
        let mut partials = Vec::with_capacity(plan.n_subtrees);
        let mut stats = Vec::with_capacity(n - n_carried);
        let mut fold_s = 0.0f64;
        for res in results {
            let shard = res?;
            partials.extend(shard.partials);
            stats.extend(shard.stats);
            fold_s += shard.fold_s;
        }
        let t_root = Instant::now();
        let root = reduce_tree(&self.pools[0], partials, TREE_FAN_IN)?;
        fold_s += t_root.elapsed().as_secs_f64();
        Ok(EdgeFold {
            root,
            stats,
            fold_s,
        })
    }
}

struct ShardFold {
    /// One partial per owned subtree, in subtree order.
    partials: Vec<WeightedLeaf>,
    /// Per-job `(recon, decode_s)` in this shard's job order.
    stats: Vec<(f64, f64)>,
    fold_s: f64,
}

/// One shard's work: scatter the decode jobs on the shard pool, then
/// fold each owned subtree to a single partial leaf.
fn shard_fold(
    pool: &WorkerPool,
    carried: Vec<WeightedLeaf>,
    jobs: Vec<DecodeJob>,
    subtree: usize,
) -> Result<ShardFold> {
    let mut stats = Vec::with_capacity(jobs.len());
    let mut leaves = carried;
    leaves.reserve(jobs.len());
    if !jobs.is_empty() {
        for res in pool.scatter(jobs)? {
            let (leaf, recon, decode_s) = res?;
            stats.push((recon, decode_s));
            leaves.push(leaf);
        }
    }
    let t0 = Instant::now();
    let mut partials = Vec::with_capacity(leaves.len().div_ceil(subtree.max(1)));
    let mut iter = leaves.into_iter().peekable();
    while iter.peek().is_some() {
        let chunk: Vec<WeightedLeaf> = iter.by_ref().take(subtree).collect();
        // `reduce_tree` on one subtree performs exactly the flat tree's
        // in-subtree combines; a single-leaf chunk passes through
        // untouched (no arithmetic).
        if let Some(node) = reduce_tree(pool, chunk, TREE_FAN_IN)? {
            partials.push(node);
        }
    }
    Ok(ShardFold {
        partials,
        stats,
        fold_s: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::{combine_leaves, finish_tree};
    use crate::util::rng::Rng;

    /// Sequential reference mirroring `reduce_tree`'s level-by-level
    /// grouping, with no pools involved.
    fn tree_fold_ref(mut nodes: Vec<WeightedLeaf>, fan_in: usize) -> Option<WeightedLeaf> {
        while nodes.len() > 1 {
            let mut next = Vec::new();
            let mut iter = nodes.into_iter().peekable();
            while iter.peek().is_some() {
                let group: Vec<WeightedLeaf> = iter.by_ref().take(fan_in).collect();
                next.push(combine_leaves(group).unwrap());
            }
            nodes = next;
        }
        nodes.pop()
    }

    fn make_inputs(n: usize, d: usize, seed: u64) -> Vec<(f64, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let w = 1.0 + (i % 7) as f64 * 0.25;
                let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                (w, v)
            })
            .collect()
    }

    fn leaves_of(inputs: &[(f64, Vec<f32>)]) -> Vec<WeightedLeaf> {
        inputs
            .iter()
            .map(|(w, v)| WeightedLeaf::new(*w, v.clone()))
            .collect()
    }

    fn jobs_of(inputs: &[(f64, Vec<f32>)]) -> Vec<DecodeJob> {
        inputs
            .iter()
            .map(|(w, v)| {
                let (w, v) = (*w, v.clone());
                let job: DecodeJob = Box::new(move |_ctx| Ok((WeightedLeaf::new(w, v), 0.0, 0.0)));
                job
            })
            .collect()
    }

    fn assert_leaf_bits(a: &WeightedLeaf, b: &WeightedLeaf) {
        assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        assert_eq!(a.sum.len(), b.sum.len());
        for (x, y) in a.sum.iter().zip(&b.sum) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn shard_plan_partitions_leaves_exactly() {
        for &(n, e) in &[
            (0usize, 1usize),
            (1, 1),
            (1, 16),
            (7, 4),
            (8, 4),
            (9, 2),
            (10, 16),
            (64, 4),
            (65, 2),
            (100, 16),
            (1000, 2),
            (100_000, 16),
        ] {
            let plan = ShardPlan::new(n, TREE_FAN_IN, e);
            assert_eq!(plan.n_subtrees, n.div_ceil(plan.subtree));
            let mut cursor = 0usize;
            for k in 0..e {
                let (lo, hi) = plan.leaf_range(k);
                assert_eq!(lo, cursor, "n={n} e={e} shard {k}");
                assert!(hi >= lo);
                assert_eq!(lo % plan.subtree, 0, "shard start must be aligned");
                cursor = hi;
            }
            assert_eq!(cursor, n, "ranges must cover all leaves (n={n} e={e})");
        }
    }

    #[test]
    fn shard_plan_keeps_all_shards_busy_when_leaves_suffice() {
        // K=100k over 16 shards: the plan must not collapse to one
        // giant subtree.
        let plan = ShardPlan::new(100_000, TREE_FAN_IN, 16);
        assert_eq!(plan.subtree, 4096);
        assert_eq!(plan.n_subtrees, 25);
        for k in 0..16 {
            let (lo, hi) = plan.leaf_range(k);
            assert!(hi > lo, "shard {k} owns no leaves");
        }
    }

    #[test]
    fn empty_round_folds_to_none() {
        let edge = EdgeAggregator::new(4, 4, 1).unwrap();
        let fold = edge.fold_round(Vec::new(), Vec::new()).unwrap();
        assert!(fold.root.is_none());
        assert!(fold.stats.is_empty());
    }

    #[test]
    fn sharded_fold_is_bit_identical_to_flat_fold() {
        let flat_pool = WorkerPool::new(4, 1).unwrap();
        // Sweep leaf counts across the degenerate shapes the satellite
        // calls out: E > leaves, single-leaf shards, empty shards, and
        // partial trailing subtrees.
        for &e in &[1usize, 3, 4, 16] {
            let edge = EdgeAggregator::new(e, 4, 1).unwrap();
            for &n in &[1usize, 2, 5, 8, 9, 10, 17, 64, 65, 100, 200] {
                let inputs = make_inputs(n, 33, 0xED6E ^ ((n as u64) << 8) ^ (e as u64));
                let flat = reduce_tree(&flat_pool, leaves_of(&inputs), TREE_FAN_IN)
                    .unwrap()
                    .unwrap();
                let reference = tree_fold_ref(leaves_of(&inputs), TREE_FAN_IN).unwrap();
                assert_leaf_bits(&flat, &reference);

                let fold = edge.fold_round(Vec::new(), jobs_of(&inputs)).unwrap();
                let root = fold.root.unwrap();
                assert_leaf_bits(&root, &flat);
                assert_eq!(fold.stats.len(), n);
                // The folded model itself must match too.
                let a = finish_tree(flat).unwrap();
                let b = finish_tree(root).unwrap();
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn carried_leaves_enter_the_tree_before_fresh_survivors() {
        let flat_pool = WorkerPool::new(2, 1).unwrap();
        for &(n_car, n_fresh, e) in &[
            (3usize, 7usize, 4usize),
            (5, 0, 4),  // zero-survivor round, carried only
            (0, 1, 16), // single survivor, E >> leaves
            (2, 30, 3),
            (12, 52, 16),
        ] {
            let car_inputs = make_inputs(n_car, 17, 0xCA44 + n_car as u64 + e as u64);
            let fresh_inputs = make_inputs(n_fresh, 17, 0xF4E5 + n_fresh as u64 + e as u64);
            let mut flat_leaves = leaves_of(&car_inputs);
            flat_leaves.extend(leaves_of(&fresh_inputs));
            let flat = reduce_tree(&flat_pool, flat_leaves, TREE_FAN_IN).unwrap();

            let edge = EdgeAggregator::new(e, 4, 1).unwrap();
            let fold = edge
                .fold_round(leaves_of(&car_inputs), jobs_of(&fresh_inputs))
                .unwrap();
            match (flat, fold.root) {
                (Some(a), Some(b)) => assert_leaf_bits(&a, &b),
                (None, None) => {}
                _ => panic!("flat and sharded disagree on emptiness"),
            }
            assert_eq!(fold.stats.len(), n_fresh);
        }
    }

    #[test]
    fn zero_shards_is_rejected() {
        assert!(EdgeAggregator::new(0, 4, 1).is_err());
    }
}
