//! The round coordinator: wires data, compressor, clients and server into
//! a layered round-execution pipeline driven through the event-driven
//! [`session`] lifecycle.
//!
//! Per round, [`Simulation::run_round`] pumps the stages through one
//! [`FlSession`] round:
//!
//! 1. **begin_round** ([`session`]) — the server broadcasts the global
//!    model (the paper's tables count both directions encoded, see
//!    `ExperimentConfig::compress_downlink`) and ingests the previous
//!    round's [`session::CarryOver`], expiring what aged out;
//! 2. **device layer** — each selected client's [`DeviceProfile`] decides
//!    whether it drops out this round (seeded, per-round stream);
//! 3. **client stage** ([`pool`]) — surviving clients train locally and
//!    encode their updates on a persistent pool of `client_threads`
//!    workers (each pinned to a PJRT engine worker for executable-cache
//!    affinity).  A round enqueues one seeded [`pool::WorkSpec`] per
//!    survivor and performs **zero thread spawns**, so m=1000 rounds at
//!    K=10k cost the same scheduling overhead as m=4; results are
//!    bit-identical for any pool size.  Every update's `wire_bytes` is
//!    the measured length of its packed wire buffer
//!    (`compression/wire.rs`), packed into the worker's reusable
//!    scratch;
//! 4. **submit + resolve** — every arrival becomes a
//!    [`session::ClientUpdate`] (exact per-client byte counts and device
//!    profiles become modelled compute + air times via [`clock`]), and
//!    the configured [`clock::RoundPolicy`] splits arrivals into
//!    survivors and late uploads;
//! 5. **finalize** — survivors decode in parallel on the same pool,
//!    become weight-scaled leaves in modelled arrival order behind any
//!    carried-in leaves, and fold through a fixed-fan-in reduction tree
//!    ([`pool::reduce_tree`]) whose shape depends only on arrival order —
//!    bit-identical for any pool size; late uploads become the next
//!    round's carry-over when [`session::CarryPolicy`] allows;
//! 6. **evaluation** — the installed global model is scored (skipped in
//!    `fake_train` smoke mode, which has no engine to score on).
//!
//! Compute times in [`RoundRecord`] are measured; air times come from the
//! link model (eq. 13) scaled by per-device rate multipliers.
//!
//! [`DeviceProfile`]: crate::network::DeviceProfile

pub mod clock;
pub mod edge;
pub mod pool;
pub mod session;

use std::sync::Arc;
use std::time::Instant;

use self::pool::{
    ClientMsg, ClientPool, ClientRunner, FakeTrainRunner, RoundInputs, TrainEncodeRunner,
    WorkSpec,
};
pub use self::edge::EdgeAggregator;
pub use self::session::{CarryOver, CarryPolicy, FlSession};
use crate::compression::Compressor;
use crate::config::ExperimentConfig;
use crate::control::{self, ServerOptState};
use crate::coordinator::clock::{client_timing, ClientTiming};
use crate::coordinator::session::{build_codec_bank, ClientUpdate};
use crate::data::{synthetic, FlData};
use crate::error::Result;
use crate::fl::{select_clients, LocalTrainer, Server};
use crate::metrics::{RoundRecord, RunReport};
use crate::network::DeviceFleet;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use crate::util::stats;

/// The per-round seed stream: independent of the selection and training
/// RNGs, so device dropouts and per-client work seeds never perturb the
/// learning trajectory.  Public so regression tests can replay a round's
/// client stage outside the simulation.
pub fn round_seed(seed: u64, t: usize) -> u64 {
    seed ^ (t as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// A fully-wired FL simulation.
pub struct Simulation {
    engine: Engine,
    pub cfg: ExperimentConfig,
    pub data: Arc<FlData>,
    trainer: LocalTrainer,
    session: FlSession,
    carry: CarryOver,
    fleet: DeviceFleet,
    pool: ClientPool,
    /// `Some` when `cfg.edge_shards > 0`: the two-level sharded fold.
    edge: Option<EdgeAggregator>,
    rng: Rng,
    /// Print one line per round to stderr.
    pub verbose: bool,
}

impl Simulation {
    /// Build the simulation: generate data, sample the device fleet, spin
    /// up the compressor (training autoencoders for HCFL schemes), the
    /// client worker pool, and the server session.
    pub fn new(engine: &Engine, cfg: ExperimentConfig) -> Result<Simulation> {
        cfg.validate(engine.manifest())?;
        let mut data_spec = cfg.data.clone();
        data_spec.n_clients = cfg.n_clients;
        let data = Arc::new(synthetic(&data_spec, cfg.seed));
        let trainer = LocalTrainer::new(engine, &cfg.model)?;
        let mut rng = Rng::new(cfg.seed);
        let server = Server::new(&trainer.model, &mut rng);
        let fleet = DeviceFleet::sample(cfg.n_clients, &cfg.scenario.devices, cfg.seed);
        // The HCFL pre-model must start from this run's actual init so
        // the compressor is trained on the trajectory it will compress.
        // The bank holds every codec the policy can assign (base first).
        let bank = build_codec_bank(engine, &cfg, &data, &server.global.flat)?;
        let mut session = FlSession::new(
            server,
            Arc::clone(bank.base()),
            cfg.scenario.aggregator.clone(),
            cfg.scenario.carry.clone(),
            cfg.encode_deltas,
            cfg.compress_downlink,
        );
        session.set_codec_bank(bank.clone());
        session.set_server_opt(cfg.server_opt);
        let runner: Arc<dyn ClientRunner> = if cfg.fake_train {
            Arc::new(FakeTrainRunner::with_bank(bank, Arc::clone(&data)))
        } else {
            Arc::new(TrainEncodeRunner::with_bank(
                trainer.clone(),
                bank,
                Arc::clone(&data),
            ))
        };
        let pool = ClientPool::new(runner, cfg.client_threads, engine.n_workers())?;
        let edge = match cfg.edge_shards {
            0 => None,
            e => Some(EdgeAggregator::new(
                e,
                cfg.client_threads,
                engine.n_workers(),
            )?),
        };
        Ok(Simulation {
            engine: engine.clone(),
            cfg,
            data,
            trainer,
            session,
            carry: CarryOver::empty(),
            fleet,
            pool,
            edge,
            rng,
            verbose: false,
        })
    }

    /// Current global model.
    pub fn global(&self) -> &[f32] {
        self.session.global()
    }

    /// The wire codec (owned by the session).
    pub fn compressor(&self) -> &Arc<dyn Compressor> {
        self.session.compressor()
    }

    /// The engine this simulation runs on.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The server-side session (carry policy, global model).
    pub fn session(&self) -> &FlSession {
        &self.session
    }

    /// The sampled device population.
    pub fn fleet(&self) -> &DeviceFleet {
        &self.fleet
    }

    /// Client-stage pool size.
    pub fn client_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// Edge shard count (0 = flat single-session fold).
    pub fn edge_shards(&self) -> usize {
        self.edge.as_ref().map_or(0, EdgeAggregator::n_shards)
    }

    /// Late updates currently in flight toward a future round.
    pub fn carry_pending(&self) -> usize {
        self.carry.len()
    }

    /// The in-flight carry-over, for snapshotting between rounds.
    pub fn carry(&self) -> &CarryOver {
        &self.carry
    }

    /// The selection-RNG cursor — with the global model and the
    /// carry-over, the only state that crosses rounds
    /// (`crate::daemon::snapshot`).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Rewind onto a snapshot taken after some round's `finalize`:
    /// overwrite the three pieces of cross-round state so the next
    /// `run_round(t)` continues the interrupted campaign bit-identically
    /// — everything else a round touches is a pure function of
    /// `(cfg.seed, t)` (DESIGN.md §9).
    pub fn restore(
        &mut self,
        global: Vec<f32>,
        carry: CarryOver,
        rng_state: [u64; 4],
        opt_state: ServerOptState,
    ) -> Result<()> {
        self.session.restore_global(global)?;
        self.session.restore_opt_state(opt_state);
        self.carry = carry;
        self.rng = Rng::from_state(rng_state);
        Ok(())
    }

    /// The server optimizer's persistent moment state — with the global
    /// model, carry-over and RNG cursor, the cross-round state a
    /// campaign snapshot must capture (DESIGN.md §9.2 v2).
    pub fn opt_state(&self) -> &ServerOptState {
        self.session.opt_state()
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for t in 1..=self.cfg.rounds {
            let rec = self.run_round(t)?;
            if self.verbose {
                let part = if rec.completed < rec.selected || rec.carried_in > 0 {
                    format!(
                        " [{}/{} agg, {} dropped, {} cut, {}+ carried]",
                        rec.completed, rec.selected, rec.dropped, rec.stragglers,
                        rec.carried_in
                    )
                } else {
                    String::new()
                };
                eprintln!(
                    "[{}] round {t:>3}: acc {:.4} loss {:.4} recon {:.2e} up {:.1} KB{part}",
                    self.session.compressor().name(),
                    rec.accuracy,
                    rec.loss,
                    rec.recon_mse,
                    rec.up_bytes as f64 / 1e3,
                );
            }
            rounds.push(rec);
        }
        Ok(RunReport {
            scheme: self.session.compressor().name(),
            model: self.cfg.model.clone(),
            rounds,
        })
    }

    /// One communication round: a thin driver that pumps the staged
    /// pipeline through the session lifecycle
    /// (`begin_round → submit/mark_dropped → resolve → finalize`).
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let selected = select_clients(self.cfg.n_clients, self.cfg.participation, &mut self.rng);
        let m = selected.len();

        // ---- control plane: one codec per selected slot ----------------
        // A pure function of (policy, base scheme, fleet, selection, d,
        // link) — decided before the dropout stream runs, so assignments
        // never depend on the dropout realization and every driver
        // derives the identical vector.
        let codecs = control::assign_codecs(
            &self.cfg.codec_policy,
            self.cfg.scheme,
            &self.fleet,
            &selected,
            self.session.d(),
            &self.cfg.link,
        );

        // ---- the session opens the round: broadcast + carry ingest -----
        // Scenario knobs stay live-read from `cfg` (drivers calibrate
        // the policy — and may flip aggregation/carry — after a probe
        // round).  Note: a round that errors past this point drops the
        // in-flight carry-over with the abandoned session; a failed
        // round is fatal to the run, not retryable.
        self.session.set_scenario(
            self.cfg.scenario.aggregator.clone(),
            self.cfg.scenario.carry.clone(),
        );
        let carry = std::mem::take(&mut self.carry);
        let mut round = self.session.begin_round(t, carry)?;

        // ---- device layer (dropouts) -----------------------------------
        // A per-round stream independent of selection and training RNGs,
        // so heterogeneity presets never perturb the learning trajectory.
        let round_seed = round_seed(self.cfg.seed, t);
        let mut drop_rng = Rng::new(round_seed ^ 0x0D10_D0A7_5EED_0001);
        let dropped: Vec<bool> = selected
            .iter()
            .map(|&k| drop_rng.next_f64() < self.fleet.profile(k).dropout_p)
            .collect();

        // ---- client stage through the worker pool ----------------------
        // One seeded work item per surviving client; no thread spawns.
        let specs: Vec<WorkSpec> = selected
            .iter()
            .enumerate()
            .filter(|&(slot, _)| !dropped[slot])
            .map(|(slot, &k)| WorkSpec {
                slot,
                client: k,
                seed: round_seed ^ ((k as u64) << 1),
                codec: codecs[slot].codec_tag(),
            })
            .collect();
        let round_inputs = RoundInputs {
            global: Arc::clone(round.global()),
            epochs: self.cfg.local_epochs,
            batch: self.cfg.batch,
            lr: self.cfg.lr,
            encode_deltas: self.cfg.encode_deltas,
        };
        let mut msgs: Vec<Option<ClientMsg>> = Vec::with_capacity(m);
        msgs.resize_with(m, || None);
        for msg in self.pool.run_clients(round_inputs, &specs)? {
            let slot = msg.slot;
            msgs[slot] = Some(msg);
        }

        // ---- pump arrivals into the session in arrival order -----------
        // Modelled compute time = the round's reference compute time
        // (mean measured train+encode) scaled per device, so survivor
        // sets and aggregation order are deterministic under OS
        // scheduling noise.
        let measured: Vec<f64> = msgs.iter().flatten().map(|msg| msg.train_s).collect();
        let reference_compute_s = stats::mean(&measured);
        let transmitting = measured.len();
        let down_bytes = round.down_bytes();
        for (slot, &k) in selected.iter().enumerate() {
            let up = msgs[slot]
                .as_ref()
                .map(|msg| msg.update.wire_bytes())
                .unwrap_or(0);
            let timing: ClientTiming = client_timing(
                &self.cfg.link,
                self.fleet.profile(k),
                k,
                slot,
                up,
                down_bytes,
                reference_compute_s,
                m,
                transmitting,
                dropped[slot],
            );
            match msgs[slot].take() {
                Some(msg) => round.submit(ClientUpdate {
                    payload: msg.update,
                    n_samples: msg.n_samples,
                    timing,
                    exact: if self.cfg.send_exact {
                        msg.exact
                    } else {
                        Vec::new()
                    },
                    // In-process the exact side channel is free: only the
                    // packed payload is modelled on the air.
                    extra_up_bytes: 0,
                    train_s: msg.train_s,
                    codec: codecs[slot].codec_tag(),
                }),
                None => round.mark_dropped(timing),
            }
        }

        // ---- resolve + finalize: policy, decode, tree fold, carry ------
        let resolved = round.resolve(&self.cfg.scenario.policy);
        let (mut rec, carry) = match &self.edge {
            Some(edge) => resolved.finalize_sharded(edge)?,
            None => resolved.finalize(self.pool.workers())?,
        };
        self.carry = carry;

        // ---- evaluation ------------------------------------------------
        let (accuracy, loss) = if self.cfg.fake_train {
            // Fake training has no engine to score on; the smoke pipeline
            // measures traffic, participation and timing — not learning.
            (0.0, 0.0)
        } else {
            self.trainer
                .evaluate(self.session.global(), &self.data.test, 0)?
        };
        rec.accuracy = accuracy;
        rec.loss = loss;
        rec.wall_time_s = wall0.elapsed().as_secs_f64();
        Ok(rec)
    }
}
