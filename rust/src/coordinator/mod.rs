//! The round coordinator: wires data, compressor, clients and server into
//! a layered round-execution pipeline.
//!
//! Per round, the stages run in order:
//!
//! 1. **broadcast** — the server ships the global model; the paper's
//!    tables count both directions encoded, see [`broadcast`];
//! 2. **device layer** — each selected client's [`DeviceProfile`] decides
//!    whether it drops out this round (seeded, per-round stream);
//! 3. **client stage** ([`pool`]) — surviving clients train locally and
//!    encode their updates on a persistent pool of `client_threads`
//!    workers (each pinned to a PJRT engine worker for executable-cache
//!    affinity).  A round enqueues one seeded [`pool::WorkSpec`] per
//!    survivor and performs **zero thread spawns**, so m=1000 rounds at
//!    K=10k cost the same scheduling overhead as m=4; results are
//!    bit-identical for any pool size.  Every update's `wire_bytes` is
//!    the measured length of its packed wire buffer
//!    (`compression/wire.rs`), packed into the worker's reusable
//!    scratch;
//! 4. **round clock** ([`clock`]) — exact per-client byte counts and
//!    device profiles become modelled compute + air times, and the
//!    configured [`clock::RoundPolicy`] picks the surviving uploads and
//!    the round makespan;
//! 5. **aggregation** — survivors decode in parallel on the same pool,
//!    become weight-scaled leaves in modelled arrival order, and fold
//!    through a fixed-fan-in reduction tree ([`pool::reduce_tree`])
//!    whose shape depends only on arrival order — bit-identical for any
//!    pool size;
//! 6. **evaluation** — the installed global model is scored (skipped in
//!    `fake_train` smoke mode, which has no engine to score on).
//!
//! Compute times in [`RoundRecord`] are measured; air times come from the
//! link model (eq. 13) scaled by per-device rate multipliers.
//!
//! [`DeviceProfile`]: crate::network::DeviceProfile

pub mod clock;
pub mod pool;

use std::sync::Arc;
use std::time::Instant;

use self::pool::{
    reduce_tree, ClientMsg, ClientPool, ClientRunner, FakeTrainRunner, RoundInputs,
    TrainEncodeRunner, WorkSpec, WorkerCtx,
};
use crate::compression::{
    Compressor, HcflCompressor, Identity, Scheme, TernaryCompressor, TopKCompressor,
    WireScratch,
};
use crate::config::ExperimentConfig;
use crate::coordinator::clock::{client_timing, resolve, ClientTiming};
use crate::data::{synthetic, FlData};
use crate::error::Result;
use crate::fl::{
    finish_tree, select_clients, LocalTrainer, Server, UpdateMeta, WeightedLeaf,
    TREE_FAN_IN,
};
use crate::hcfl::prepare_autoencoders;
use crate::metrics::{RoundRecord, RunReport};
use crate::model::{merge_segment_ranges, split_dense};
use crate::network::DeviceFleet;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use crate::util::stats;

/// A fully-wired FL simulation.
pub struct Simulation {
    engine: Engine,
    pub cfg: ExperimentConfig,
    pub data: Arc<FlData>,
    compressor: Arc<dyn Compressor>,
    trainer: LocalTrainer,
    server: Server,
    fleet: DeviceFleet,
    pool: ClientPool,
    rng: Rng,
    /// Print one line per round to stderr.
    pub verbose: bool,
}

impl Simulation {
    /// Build the simulation: generate data, sample the device fleet, spin
    /// up the compressor (training autoencoders for HCFL schemes), the
    /// client worker pool, and the server.
    pub fn new(engine: &Engine, cfg: ExperimentConfig) -> Result<Simulation> {
        cfg.validate(engine.manifest())?;
        let mut data_spec = cfg.data.clone();
        data_spec.n_clients = cfg.n_clients;
        let data = Arc::new(synthetic(&data_spec, cfg.seed));
        let trainer = LocalTrainer::new(engine, &cfg.model)?;
        let mut rng = Rng::new(cfg.seed);
        let server = Server::new(&trainer.model, &mut rng);
        let fleet = DeviceFleet::sample(cfg.n_clients, &cfg.scenario.devices, cfg.seed);
        // The HCFL pre-model must start from this run's actual init so
        // the compressor is trained on the trajectory it will compress.
        let compressor = build_compressor(engine, &cfg, &data, &server.global.flat)?;
        let runner: Arc<dyn ClientRunner> = if cfg.fake_train {
            Arc::new(FakeTrainRunner::new(
                Arc::clone(&compressor),
                Arc::clone(&data),
            ))
        } else {
            Arc::new(TrainEncodeRunner::new(
                trainer.clone(),
                Arc::clone(&compressor),
                Arc::clone(&data),
            ))
        };
        let pool = ClientPool::new(runner, cfg.client_threads, engine.n_workers())?;
        Ok(Simulation {
            engine: engine.clone(),
            cfg,
            data,
            compressor,
            trainer,
            server,
            fleet,
            pool,
            rng,
            verbose: false,
        })
    }

    /// Current global model.
    pub fn global(&self) -> &[f32] {
        &self.server.global.flat
    }

    pub fn compressor(&self) -> &Arc<dyn Compressor> {
        &self.compressor
    }

    /// The engine this simulation runs on.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The sampled device population.
    pub fn fleet(&self) -> &DeviceFleet {
        &self.fleet
    }

    /// Client-stage pool size.
    pub fn client_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for t in 1..=self.cfg.rounds {
            let rec = self.run_round(t)?;
            if self.verbose {
                let part = if rec.completed < rec.selected {
                    format!(
                        " [{}/{} agg, {} dropped, {} cut]",
                        rec.completed, rec.selected, rec.dropped, rec.stragglers
                    )
                } else {
                    String::new()
                };
                eprintln!(
                    "[{}] round {t:>3}: acc {:.4} loss {:.4} recon {:.2e} up {:.1} KB{part}",
                    self.compressor.name(),
                    rec.accuracy,
                    rec.loss,
                    rec.recon_mse,
                    rec.up_bytes as f64 / 1e3,
                );
            }
            rounds.push(rec);
        }
        Ok(RunReport {
            scheme: self.compressor.name(),
            model: self.cfg.model.clone(),
            rounds,
        })
    }

    /// One communication round through the staged pipeline.
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let d = self.trainer.model.d;
        let selected = select_clients(self.cfg.n_clients, self.cfg.participation, &mut self.rng);
        let m = selected.len();

        // ---- stage 1: broadcast ----------------------------------------
        let (global_recv, down_bytes) = broadcast(
            self.compressor.as_ref(),
            &self.server.global.flat,
            self.cfg.compress_downlink,
        )?;

        // ---- stage 2: device layer (dropouts) --------------------------
        // A per-round stream independent of selection and training RNGs,
        // so heterogeneity presets never perturb the learning trajectory.
        let round_seed = self.cfg.seed ^ (t as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
        let mut drop_rng = Rng::new(round_seed ^ 0x0D10_D0A7_5EED_0001);
        let dropped: Vec<bool> = selected
            .iter()
            .map(|&k| drop_rng.next_f64() < self.fleet.profile(k).dropout_p)
            .collect();

        // ---- stage 3: client stage through the worker pool -------------
        // One seeded work item per surviving client; no thread spawns.
        let specs: Vec<WorkSpec> = selected
            .iter()
            .enumerate()
            .filter(|&(slot, _)| !dropped[slot])
            .map(|(slot, &k)| WorkSpec {
                slot,
                client: k,
                seed: round_seed ^ ((k as u64) << 1),
            })
            .collect();
        let round_inputs = RoundInputs {
            global: Arc::clone(&global_recv),
            epochs: self.cfg.local_epochs,
            batch: self.cfg.batch,
            lr: self.cfg.lr,
            encode_deltas: self.cfg.encode_deltas,
        };
        let mut msgs: Vec<Option<ClientMsg>> = Vec::with_capacity(m);
        msgs.resize_with(m, || None);
        for msg in self.pool.run_clients(round_inputs, &specs)? {
            let slot = msg.slot;
            msgs[slot] = Some(msg);
        }

        // ---- stage 4: round clock --------------------------------------
        // Modelled compute time = the round's reference compute time (mean
        // measured train+encode) scaled per device, so survivor sets and
        // aggregation order are deterministic under OS scheduling noise.
        let measured: Vec<f64> = msgs.iter().flatten().map(|msg| msg.train_s).collect();
        let reference_compute_s = stats::mean(&measured);
        let transmitting = measured.len();
        let timings: Vec<ClientTiming> = selected
            .iter()
            .enumerate()
            .map(|(slot, &k)| {
                let up = msgs[slot].as_ref().map(|msg| msg.update.wire_bytes).unwrap_or(0);
                client_timing(
                    &self.cfg.link,
                    self.fleet.profile(k),
                    k,
                    slot,
                    up,
                    down_bytes,
                    reference_compute_s,
                    m,
                    transmitting,
                    dropped[slot],
                )
            })
            .collect();
        let outcome = resolve(&self.cfg.scenario.policy, &timings);

        // Uplink byte accounting must happen before stage 5 consumes the
        // survivor messages: every transmitting client's upload hits the
        // air even when the policy later ignores it.
        let up_bytes: u64 = msgs
            .iter()
            .flatten()
            .map(|msg| msg.update.wire_bytes as u64)
            .sum();

        // ---- stage 5: parallel decode + reduction-tree aggregation -----
        // Survivors decode on the pool (each thread against its pinned
        // engine worker), become weight-scaled leaves in modelled arrival
        // order, and fold through the fixed-fan-in reduction tree.  The
        // tree shape and every per-node summation order depend only on
        // the arrival order, so the result is bit-identical for any
        // `client_threads` (tests/pool_determinism.rs).
        let kind = self.cfg.scenario.aggregator.clone();
        let t0_arrival = outcome
            .survivors
            .first()
            .map(|&i| timings[i].arrival_s())
            .unwrap_or(0.0);
        let encode_deltas = self.cfg.encode_deltas;
        let mut jobs = Vec::with_capacity(outcome.survivors.len());
        for &i in &outcome.survivors {
            let msg = msgs[i].take().expect("survivor sent an update");
            let meta = UpdateMeta {
                client: timings[i].client,
                n_samples: msg.n_samples,
                arrival_s: timings[i].arrival_s(),
            };
            let compressor = Arc::clone(&self.compressor);
            let global = Arc::clone(&global_recv);
            let kind = kind.clone();
            jobs.push(
                move |ctx: &mut WorkerCtx| -> Result<(WeightedLeaf, f64, f64)> {
                    // Only the server's real work (decode + weighting) is
                    // timed; the reconstruction MSE is simulation-only
                    // instrumentation and stays outside the measured
                    // server time, as before the pool.
                    let t0 = Instant::now();
                    let mut decoded =
                        compressor.decompress(msg.update, d, ctx.engine_worker)?;
                    decode_payload(&mut decoded, &global, encode_deltas);
                    let mut decode_s = t0.elapsed().as_secs_f64();
                    let recon = mse(&decoded, &msg.exact);
                    let t1 = Instant::now();
                    let w = kind.weight(&meta, t0_arrival)?;
                    let leaf = WeightedLeaf::new(w, decoded);
                    decode_s += t1.elapsed().as_secs_f64();
                    Ok((leaf, recon, decode_s))
                },
            );
        }
        let mut leaves = Vec::with_capacity(jobs.len());
        let mut recon_sum = 0.0f64;
        // Summed per-survivor decode time (the pre-pool semantics: total
        // server-side work, not overlapped wall time) ...
        let mut server_time_s = 0.0f64;
        for res in self.pool.workers().scatter(jobs)? {
            let (leaf, recon, decode_s) = res?;
            recon_sum += recon;
            server_time_s += decode_s;
            leaves.push(leaf);
        }
        let completed = leaves.len();
        // ... plus the aggregation fold itself.
        let t_fold = Instant::now();
        if let Some(root) = reduce_tree(self.pool.workers(), leaves, TREE_FAN_IN)? {
            self.server.install(finish_tree(root)?)?;
        }
        // else: every upload was lost to dropout/policy; the round is
        // wasted air time and the global model carries over unchanged.
        server_time_s += t_fold.elapsed().as_secs_f64();

        // ---- stage 6: evaluation ---------------------------------------
        let (accuracy, loss) = if self.cfg.fake_train {
            // Fake training has no engine to score on; the smoke pipeline
            // measures traffic, participation and timing — not learning.
            (0.0, 0.0)
        } else {
            self.trainer
                .evaluate(&self.server.global.flat, &self.data.test, 0)?
        };

        // Cost accounting (clock layer outputs, exact per-client bytes):
        // air time covers all alive clients — capped at the makespan,
        // past which cut transmissions stop.  The broadcast reaches all
        // m selected.
        let comm_time_s = timings
            .iter()
            .filter(|tm| !tm.dropped)
            .map(|tm| tm.downlink_s + tm.uplink_s)
            .fold(0.0, f64::max)
            .min(outcome.makespan_s);

        Ok(RoundRecord {
            round: t,
            accuracy,
            loss,
            recon_mse: recon_sum / completed.max(1) as f64,
            up_bytes,
            down_bytes: (down_bytes * m) as u64,
            selected: m,
            completed,
            dropped: outcome.dropped,
            stragglers: outcome.stragglers,
            makespan_s: outcome.makespan_s,
            client_time_s: reference_compute_s,
            server_time_s,
            comm_time_s,
            wall_time_s: wall0.elapsed().as_secs_f64(),
        })
    }
}

/// Stage-1 broadcast: the payload every client receives plus the
/// accounted wire size.
///
/// Paper Fig. 3 puts the only decoder at the server, so the broadcast
/// itself is always exact; `compress_downlink=true` additionally
/// *accounts* the broadcast at the encoded wire size — the measured
/// length of the packed wire buffer (`compression/wire.rs`), mirroring
/// the paper's symmetric Tables I/II.  The returned payload is
/// therefore the exact global model in both cases.
pub fn broadcast(
    compressor: &dyn Compressor,
    global: &[f32],
    compress_downlink: bool,
) -> Result<(Arc<Vec<f32>>, usize)> {
    let down_bytes = if compress_downlink {
        let upd = compressor.compress(global, 0)?;
        WireScratch::new().pack(&upd.payload)?
    } else {
        4 * global.len()
    };
    Ok((Arc::new(global.to_vec()), down_bytes))
}

/// What the client puts on the wire (see `ExperimentConfig::encode_deltas`):
/// the update `Δ = w_local − w_broadcast`, or the raw weights of the
/// paper's Algorithm 1.
pub fn encode_payload(params: &[f32], global: &[f32], encode_deltas: bool) -> Vec<f32> {
    if encode_deltas {
        params.iter().zip(global).map(|(w, g)| w - g).collect()
    } else {
        params.to_vec()
    }
}

/// Server-side inverse of [`encode_payload`]: reconstruct `ŵ = g + Δ̂`
/// in place when delta coding is on.
pub fn decode_payload(decoded: &mut [f32], global: &[f32], encode_deltas: bool) {
    if encode_deltas {
        for (v, g) in decoded.iter_mut().zip(global) {
            *v += g;
        }
    }
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Construct the configured compression scheme (training HCFL
/// autoencoders on the server dataset when needed).
pub fn build_compressor(
    engine: &Engine,
    cfg: &ExperimentConfig,
    data: &FlData,
    init_params: &[f32],
) -> Result<Arc<dyn Compressor>> {
    match cfg.scheme {
        Scheme::Fedavg => Ok(Arc::new(Identity)),
        Scheme::Ternary => Ok(Arc::new(TernaryCompressor::new(engine.clone(), 1024)?)),
        Scheme::TopK { keep } => Ok(Arc::new(TopKCompressor::new(keep)?)),
        Scheme::Hcfl { ratio } => {
            let model = engine.manifest().model(&cfg.model)?;
            let ranges = split_dense(&merge_segment_ranges(&model.layers), cfg.dense_parts);
            let chunk_of_segment = engine.manifest().chunks.clone();
            let cache_dir = engine.manifest().dir.join("cache");
            let mut ae_cfg = cfg.ae.clone();
            // Match the pre-model's per-client epochs to the run's E so
            // snapshot delta magnitudes match what will be compressed.
            ae_cfg.premodel_local_epochs = cfg.local_epochs;
            let aes = prepare_autoencoders(
                engine,
                &cfg.model,
                &data.server,
                &ranges,
                &chunk_of_segment,
                ratio,
                &ae_cfg,
                cfg.use_ae_cache.then_some(cache_dir.as_path()),
                init_params,
                cfg.encode_deltas,
            )?;
            Ok(Arc::new(HcflCompressor::new(
                engine.clone(),
                ratio,
                ranges,
                aes,
                chunk_of_segment,
            )?))
        }
    }
}
