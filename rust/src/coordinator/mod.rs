//! The round coordinator: wires data, compressor, clients and server into
//! the synchronous FedAvg loop of Algorithm 1.
//!
//! Per round:
//! 1. the server compresses the global model for the downlink (the
//!    paper's tables count both directions encoded);
//! 2. the m selected clients train locally **in parallel** (one OS thread
//!    per client, pinned round-robin to PJRT engine workers for
//!    executable-cache affinity) and upload compressed updates;
//! 3. the server decodes updates in FIFO arrival order (paper §III-B)
//!    and folds them into the running average;
//! 4. the aggregated model is installed and evaluated.
//!
//! All timing in [`RoundRecord`] is measured, except the air time which
//! comes from the link model (eq. 13).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::compression::{Compressor, HcflCompressor, Identity, Scheme, TernaryCompressor, TopKCompressor};
use crate::config::ExperimentConfig;
use crate::data::{synthetic, FlData};
use crate::error::{HcflError, Result};
use crate::fl::{select_clients, LocalTrainer, RunningAverage, Server};
use crate::hcfl::prepare_autoencoders;
use crate::metrics::{RoundRecord, RunReport};
use crate::model::{merge_segment_ranges, split_dense};
use crate::runtime::Engine;
use crate::util::rng::Rng;

struct ClientMsg {
    update: crate::compression::CompressedUpdate,
    /// Exact post-training parameters (simulation-only side channel used
    /// to measure reconstruction error at the server).
    exact: Vec<f32>,
    client_time_s: f64,
}

/// A fully-wired FL simulation.
pub struct Simulation {
    engine: Engine,
    pub cfg: ExperimentConfig,
    pub data: FlData,
    compressor: Arc<dyn Compressor>,
    trainer: LocalTrainer,
    server: Server,
    rng: Rng,
    /// Print one line per round to stderr.
    pub verbose: bool,
}

impl Simulation {
    /// Build the simulation: generate data, spin up the compressor
    /// (training autoencoders for HCFL schemes), initialize the server.
    pub fn new(engine: &Engine, cfg: ExperimentConfig) -> Result<Simulation> {
        cfg.validate(engine.manifest())?;
        let mut data_spec = cfg.data.clone();
        data_spec.n_clients = cfg.n_clients;
        let data = synthetic(&data_spec, cfg.seed);
        let trainer = LocalTrainer::new(engine, &cfg.model)?;
        let mut rng = Rng::new(cfg.seed);
        let server = Server::new(&trainer.model, &mut rng);
        // The HCFL pre-model must start from this run's actual init so
        // the compressor is trained on the trajectory it will compress.
        let compressor = build_compressor(engine, &cfg, &data, &server.global.flat)?;
        Ok(Simulation {
            engine: engine.clone(),
            cfg,
            data,
            compressor,
            trainer,
            server,
            rng,
            verbose: false,
        })
    }

    /// Current global model.
    pub fn global(&self) -> &[f32] {
        &self.server.global.flat
    }

    pub fn compressor(&self) -> &Arc<dyn Compressor> {
        &self.compressor
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for t in 1..=self.cfg.rounds {
            let rec = self.run_round(t)?;
            if self.verbose {
                eprintln!(
                    "[{}] round {t:>3}: acc {:.4} loss {:.4} recon {:.2e} up {:.1} KB",
                    self.compressor.name(),
                    rec.accuracy,
                    rec.loss,
                    rec.recon_mse,
                    rec.up_bytes as f64 / 1e3,
                );
            }
            rounds.push(rec);
        }
        Ok(RunReport {
            scheme: self.compressor.name(),
            model: self.cfg.model.clone(),
            rounds,
        })
    }

    /// One synchronous communication round.
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        let wall0 = Instant::now();
        let d = self.trainer.model.d;
        let selected = select_clients(self.cfg.n_clients, self.cfg.participation, &mut self.rng);
        let m = selected.len();

        // ---- downlink ----------------------------------------------------
        // Paper Fig. 3 puts the only decoder at the server, so the
        // broadcast itself is always exact; `compress_downlink=true`
        // additionally *accounts* the broadcast at the encoded wire size,
        // mirroring the paper's symmetric Tables I/II.
        let global_recv = Arc::new(self.server.global.flat.clone());
        let down_bytes = if self.cfg.compress_downlink {
            self.compressor
                .compress(&self.server.global.flat, 0)?
                .wire_bytes
        } else {
            4 * d
        };

        // ---- parallel client updates -----------------------------------
        let (tx, rx) = mpsc::channel::<Result<ClientMsg>>();
        let trainer = &self.trainer;
        let compressor = &self.compressor;
        let data = &self.data;
        let cfg = &self.cfg;
        let n_workers = self.engine.n_workers();
        let round_seed = cfg.seed ^ (t as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
        let failures = AtomicUsize::new(0);

        let mut server_time_s = 0.0f64;
        let mut up_bytes = 0u64;
        let mut recon_sum = 0.0f64;
        let mut client_times = Vec::with_capacity(m);
        let mut agg = RunningAverage::new(d);

        std::thread::scope(|s| -> Result<()> {
            for (slot, &k) in selected.iter().enumerate() {
                let tx = tx.clone();
                let global_recv = Arc::clone(&global_recv);
                let failures = &failures;
                s.spawn(move || {
                    let worker = slot % n_workers;
                    let mut crng = Rng::new(round_seed ^ (k as u64) << 1);
                    let started = Instant::now();
                    let result = (|| -> Result<ClientMsg> {
                        let out = trainer.train(
                            &global_recv,
                            &data.shards[k],
                            cfg.local_epochs,
                            cfg.batch,
                            cfg.lr,
                            &mut crng,
                            worker,
                        )?;
                        // Delta coding (see ExperimentConfig::encode_deltas):
                        // the wire carries Δ = w_local − w_broadcast.
                        let payload: Vec<f32> = if cfg.encode_deltas {
                            out.params
                                .iter()
                                .zip(global_recv.iter())
                                .map(|(w, g)| w - g)
                                .collect()
                        } else {
                            out.params.clone()
                        };
                        let update = compressor.compress(&payload, worker)?;
                        Ok(ClientMsg {
                            update,
                            exact: out.params,
                            client_time_s: started.elapsed().as_secs_f64(),
                        })
                    })();
                    if result.is_err() {
                        failures.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = tx.send(result);
                });
            }
            drop(tx);

            // ---- server: FIFO decode + running-average aggregation ------
            for msg in rx {
                let msg = msg?;
                let t0 = Instant::now();
                let mut decoded = self.compressor.decompress(&msg.update, d, 0)?;
                if self.cfg.encode_deltas {
                    for (v, g) in decoded.iter_mut().zip(global_recv.iter()) {
                        *v += g;
                    }
                }
                server_time_s += t0.elapsed().as_secs_f64();
                recon_sum += mse(&decoded, &msg.exact);
                up_bytes += msg.update.wire_bytes as u64;
                client_times.push(msg.client_time_s);
                let t1 = Instant::now();
                agg.push(&decoded)?;
                server_time_s += t1.elapsed().as_secs_f64();
            }
            Ok(())
        })?;

        if failures.load(Ordering::Relaxed) > 0 {
            return Err(HcflError::Engine(format!(
                "{} client(s) failed in round {t}",
                failures.load(Ordering::Relaxed)
            )));
        }

        self.server.install(agg.finish()?)?;

        // ---- evaluation -------------------------------------------------
        let (accuracy, loss) =
            self.trainer
                .evaluate(&self.server.global.flat, &self.data.test, 0)?;

        let per_client_up = if m > 0 { up_bytes as usize / m } else { 0 };
        let comm_time_s = self.cfg.link.uplink_time(per_client_up, m)
            + self.cfg.link.downlink_time(down_bytes, m);

        Ok(RoundRecord {
            round: t,
            accuracy,
            loss,
            recon_mse: recon_sum / m.max(1) as f64,
            up_bytes,
            down_bytes: (down_bytes * m) as u64,
            client_time_s: crate::util::stats::mean(&client_times),
            server_time_s,
            comm_time_s,
            wall_time_s: wall0.elapsed().as_secs_f64(),
        })
    }
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Construct the configured compression scheme (training HCFL
/// autoencoders on the server dataset when needed).
pub fn build_compressor(
    engine: &Engine,
    cfg: &ExperimentConfig,
    data: &FlData,
    init_params: &[f32],
) -> Result<Arc<dyn Compressor>> {
    match cfg.scheme {
        Scheme::Fedavg => Ok(Arc::new(Identity)),
        Scheme::Ternary => Ok(Arc::new(TernaryCompressor::new(engine.clone(), 1024)?)),
        Scheme::TopK { keep } => Ok(Arc::new(TopKCompressor::new(keep)?)),
        Scheme::Hcfl { ratio } => {
            let model = engine.manifest().model(&cfg.model)?;
            let ranges = split_dense(&merge_segment_ranges(&model.layers), cfg.dense_parts);
            let chunk_of_segment = engine.manifest().chunks.clone();
            let cache_dir = engine.manifest().dir.join("cache");
            let mut ae_cfg = cfg.ae.clone();
            // Match the pre-model's per-client epochs to the run's E so
            // snapshot delta magnitudes match what will be compressed.
            ae_cfg.premodel_local_epochs = cfg.local_epochs;
            let aes = prepare_autoencoders(
                engine,
                &cfg.model,
                &data.server,
                &ranges,
                &chunk_of_segment,
                ratio,
                &ae_cfg,
                cfg.use_ae_cache.then_some(cache_dir.as_path()),
                init_params,
                cfg.encode_deltas,
            )?;
            Ok(Arc::new(HcflCompressor::new(
                engine.clone(),
                ratio,
                ranges,
                aes,
                chunk_of_segment,
            )?))
        }
    }
}
