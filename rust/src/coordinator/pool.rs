//! Persistent worker pool: the client stage and the server's parallel
//! decode + reduction-tree aggregation share one set of threads.
//!
//! The pre-pool coordinator spawned one OS thread per selected client per
//! round, which caps the `scenarios` sweep far below the paper's K=10k
//! regime (m=1000 surviving clients meant 1000 thread spawns *per
//! round*).  [`WorkerPool`] spawns `client_threads` workers once per
//! [`crate::coordinator::Simulation`]; every stage scatters closures onto
//! the shared queue and collects exactly as many results back — zero
//! spawns on the round path.  Each pool thread owns a [`WorkerCtx`]: its
//! pinned PJRT engine worker (`thread_idx % engine_workers`, so
//! per-worker executable caches stay warm across rounds) and a reusable
//! [`WireScratch`] so steady-state wire packing allocates nothing.
//!
//! Determinism: a client work item carries its selection slot and its
//! private RNG seed (`round_seed ^ (client_id << 1)`, unchanged from the
//! spawn-per-client implementation), so a client's result never depends
//! on which pool thread ran it, in what order, or how many threads
//! exist — per-round results are bit-identical for any pool size
//! (guarded by `tests/pool_determinism.rs`).  The same argument covers
//! [`reduce_tree`]: the tree shape and every node's summation order are
//! pure functions of the leaf order, and threads only decide *when* a
//! node is computed, never *what* it sums.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::compression::{Compressor, WireScratch, WireUpdate};
use crate::control::CodecBank;
use crate::data::FlData;
use crate::error::{HcflError, Result};
use crate::fl::{combine_leaves_recycled, LocalTrainer, WeightedLeaf};
use crate::util::rng::Rng;

/// Per-thread state a pool worker hands to every task it runs.
pub struct WorkerCtx {
    /// Index of this pool thread.
    pub thread_idx: usize,
    /// The PJRT engine worker this thread pins its calls to.
    pub engine_worker: usize,
    /// Reusable wire-packing buffer (grown once, reused every round).
    pub scratch: WireScratch,
}

type Task = Box<dyn FnOnce(&mut WorkerCtx) + Send>;

/// A fixed pool of worker threads over a shared closure queue.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (>= 1), each pinned to engine worker
    /// `thread_idx % engine_workers`.
    pub fn new(threads: usize, engine_workers: usize) -> Result<WorkerPool> {
        let threads = threads.max(1);
        let engine_workers = engine_workers.max(1);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(threads);
        for w in 0..threads {
            let rx = Arc::clone(&rx);
            let join = std::thread::Builder::new()
                .name(format!("client-pool-{w}"))
                .spawn(move || {
                    let mut ctx = WorkerCtx {
                        thread_idx: w,
                        engine_worker: w % engine_workers,
                        scratch: WireScratch::new(),
                    };
                    loop {
                        // Hold the queue lock only while dequeuing; recv
                        // blocks between stages and ends when the pool
                        // drops.
                        let task = {
                            let Ok(queue) = rx.lock() else { break };
                            match queue.recv() {
                                Ok(task) => task,
                                Err(_) => break,
                            }
                        };
                        task(&mut ctx);
                    }
                })
                .map_err(|e| HcflError::Engine(format!("client pool spawn failed: {e}")))?;
            workers.push(join);
        }
        Ok(WorkerPool {
            tx: Some(tx),
            workers,
        })
    }

    /// Pool size.
    pub fn n_threads(&self) -> usize {
        self.workers.len()
    }

    /// Scatter `jobs` across the pool and gather every result, returned
    /// in job order (a barrier: blocks until the whole batch ran).
    /// Results must be independent of which thread runs a job and when —
    /// callers own that invariant; the pool only moves closures.
    pub fn scatter<T, F>(&self, jobs: Vec<F>) -> Result<Vec<T>>
    where
        T: Send + 'static,
        F: FnOnce(&mut WorkerCtx) -> T + Send + 'static,
    {
        let n = jobs.len();
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| HcflError::Engine("worker pool is shut down".into()))?;
        let (reply_tx, reply_rx) = mpsc::channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let reply = reply_tx.clone();
            tx.send(Box::new(move |ctx: &mut WorkerCtx| {
                // A dead receiver means the batch was abandoned.
                let _ = reply.send((i, job(ctx)));
            }))
            .map_err(|_| HcflError::Engine("worker pool queue disconnected".into()))?;
        }
        drop(reply_tx);
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for _ in 0..n {
            let (i, out) = reply_rx
                .recv()
                .map_err(|_| HcflError::Engine("worker pool worker vanished".into()))?;
            slots[i] = Some(out);
        }
        Ok(slots
            .into_iter()
            .map(|s| s.expect("every job reported exactly once"))
            .collect())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the queue; workers exit at the next recv
        for join in self.workers.drain(..) {
            let _ = join.join();
        }
    }
}

/// Fold weighted leaves through the fixed-fan-in reduction tree, level
/// by level, each level's nodes computed in parallel on the pool.
/// Returns `None` for an empty leaf set.  Bit-identical for any pool
/// size: group boundaries are `fan_in`-sized arrival-order slices and
/// [`combine_leaves_recycled`] folds each group left-to-right, so no
/// arithmetic depends on scheduling.
pub fn reduce_tree(
    pool: &WorkerPool,
    mut nodes: Vec<WeightedLeaf>,
    fan_in: usize,
) -> Result<Option<WeightedLeaf>> {
    if fan_in < 2 {
        return Err(HcflError::Config(format!(
            "reduction tree fan-in must be >= 2, got {fan_in}"
        )));
    }
    while nodes.len() > 1 {
        let mut groups: Vec<Vec<WeightedLeaf>> =
            Vec::with_capacity(nodes.len().div_ceil(fan_in));
        let mut iter = nodes.into_iter().peekable();
        while iter.peek().is_some() {
            groups.push(iter.by_ref().take(fan_in).collect());
        }
        let jobs: Vec<_> = groups
            .into_iter()
            .map(|group| {
                move |ctx: &mut WorkerCtx| {
                    // fold the group, then hand the spent child buffers
                    // back to this worker's arena for the next decode
                    let mut spent = Vec::new();
                    let node = combine_leaves_recycled(group, &mut spent);
                    for buf in spent {
                        ctx.scratch.put_f32(buf);
                    }
                    node
                }
            })
            .collect();
        nodes = pool.scatter(jobs)?.into_iter().collect::<Result<Vec<_>>>()?;
    }
    Ok(nodes.pop())
}

/// One client's contribution to a round, as reported by the client stage.
pub struct ClientMsg {
    /// Selection slot of the sender (index into the round's selection).
    pub slot: usize,
    /// The packed wire buffer — what actually travels.  The structured
    /// payload is discarded client-side after packing; the server
    /// decodes with `Compressor::unpack_into`.
    pub update: WireUpdate,
    /// Exact post-training parameters (simulation-only side channel used
    /// to measure reconstruction error at the server).
    pub exact: Vec<f32>,
    /// Samples on the client's shard (FedAvg n_k).
    pub n_samples: usize,
    /// Measured local train + encode wall time, seconds.
    pub train_s: f64,
}

/// One unit of client work; everything that identifies the computation,
/// so results are independent of scheduling.
#[derive(Debug, Clone, Copy)]
pub struct WorkSpec {
    /// Selection slot within the round.
    pub slot: usize,
    /// Global client id.
    pub client: usize,
    /// The client's private RNG seed for this round.
    pub seed: u64,
    /// The codec tag this client was assigned for the round
    /// ([`crate::compression::Scheme::codec_tag`]) — the control plane's
    /// per-client decision, part of the work identity so results stay
    /// scheduling-independent.
    pub codec: u8,
}

/// Round-constant inputs shared by every work item of one round.
pub struct RoundInputs {
    /// The broadcast global model every client starts from.
    pub global: Arc<Vec<f32>>,
    /// Local epochs E.
    pub epochs: usize,
    /// Local mini-batch size B.
    pub batch: usize,
    pub lr: f32,
    /// Put `Δ = w_local − w_broadcast` on the wire instead of raw weights.
    pub encode_deltas: bool,
}

/// What a pool thread does with one work item.  `ctx` carries the
/// thread's pinned engine worker and its reusable wire scratch.
pub trait ClientRunner: Send + Sync {
    fn run(&self, spec: &WorkSpec, round: &RoundInputs, ctx: &mut WorkerCtx)
        -> Result<ClientMsg>;
}

/// The client stage: a [`WorkerPool`] plus the runner it drives.
pub struct ClientPool {
    pool: WorkerPool,
    runner: Arc<dyn ClientRunner>,
}

impl ClientPool {
    /// Spawn `threads` workers (>= 1), each pinned to engine worker
    /// `thread_idx % engine_workers`.
    pub fn new(
        runner: Arc<dyn ClientRunner>,
        threads: usize,
        engine_workers: usize,
    ) -> Result<ClientPool> {
        Ok(ClientPool {
            pool: WorkerPool::new(threads, engine_workers)?,
            runner,
        })
    }

    /// Pool size.
    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// The underlying pool — the aggregation stage runs its parallel
    /// decode and [`reduce_tree`] on the same threads.
    pub fn workers(&self) -> &WorkerPool {
        &self.pool
    }

    /// Run one round's client stage: scatter every spec, collect exactly
    /// as many results (in spec order — callers index by
    /// [`ClientMsg::slot`]).  The whole batch always completes (so no
    /// stale reply can leak into a later round); the first error in spec
    /// order is returned.
    pub fn run_clients(&self, round: RoundInputs, specs: &[WorkSpec]) -> Result<Vec<ClientMsg>> {
        let round = Arc::new(round);
        let jobs: Vec<_> = specs
            .iter()
            .map(|&spec| {
                let runner = Arc::clone(&self.runner);
                let round = Arc::clone(&round);
                move |ctx: &mut WorkerCtx| runner.run(&spec, &round, ctx)
            })
            .collect();
        self.pool.scatter(jobs)?.into_iter().collect()
    }
}

/// The real client stage: local SGD through the engine, then wire
/// encoding.  `wire_bytes` is the measured packed-buffer length, not a
/// formula (see `compression/wire.rs`).
pub struct TrainEncodeRunner {
    trainer: LocalTrainer,
    bank: CodecBank,
    data: Arc<FlData>,
}

impl TrainEncodeRunner {
    pub fn new(
        trainer: LocalTrainer,
        compressor: Arc<dyn Compressor>,
        data: Arc<FlData>,
    ) -> TrainEncodeRunner {
        Self::with_bank(trainer, CodecBank::single(compressor), data)
    }

    /// A runner over a multi-codec bank (adaptive policies): each work
    /// item encodes with the compressor its `codec` tag selects.
    pub fn with_bank(
        trainer: LocalTrainer,
        bank: CodecBank,
        data: Arc<FlData>,
    ) -> TrainEncodeRunner {
        TrainEncodeRunner {
            trainer,
            bank,
            data,
        }
    }
}

impl ClientRunner for TrainEncodeRunner {
    fn run(
        &self,
        spec: &WorkSpec,
        round: &RoundInputs,
        ctx: &mut WorkerCtx,
    ) -> Result<ClientMsg> {
        let compressor = self.bank.get(spec.codec)?;
        let shard = self.data.shard(spec.client);
        let mut crng = Rng::new(spec.seed);
        let started = Instant::now();
        let out = self.trainer.train(
            &round.global,
            &shard,
            round.epochs,
            round.batch,
            round.lr,
            &mut crng,
            ctx.engine_worker,
        )?;
        let payload =
            compressor.encode_payload(&out.params, &round.global, round.encode_deltas);
        let update = compressor.compress(&payload, ctx.engine_worker)?;
        Ok(ClientMsg {
            slot: spec.slot,
            update: ctx.scratch.pack_update(&update.payload)?,
            exact: out.params,
            n_samples: shard.n,
            train_s: started.elapsed().as_secs_f64(),
        })
    }
}

/// Engine-free stand-in for local training: the "update" is the global
/// model plus seeded Gaussian noise scaled by the learning rate.
/// Deterministic in the work item's seed, so it drives the full
/// pool → clock → aggregation pipeline (CI smoke runs, large-m benches,
/// determinism tests) without PJRT artifacts.  Shard pixels are never
/// rendered — only the client's row count is read (FedAvg `n_k` for the
/// aggregation layer), so a lazy K=10k fleet costs nothing here.
pub struct FakeTrainRunner {
    bank: CodecBank,
    data: Arc<FlData>,
}

impl FakeTrainRunner {
    pub fn new(compressor: Arc<dyn Compressor>, data: Arc<FlData>) -> FakeTrainRunner {
        Self::with_bank(CodecBank::single(compressor), data)
    }

    /// A runner over a multi-codec bank (adaptive policies): each work
    /// item encodes with the compressor its `codec` tag selects.
    pub fn with_bank(bank: CodecBank, data: Arc<FlData>) -> FakeTrainRunner {
        FakeTrainRunner { bank, data }
    }
}

impl ClientRunner for FakeTrainRunner {
    fn run(
        &self,
        spec: &WorkSpec,
        round: &RoundInputs,
        ctx: &mut WorkerCtx,
    ) -> Result<ClientMsg> {
        let compressor = self.bank.get(spec.codec)?;
        let mut crng = Rng::new(spec.seed);
        let started = Instant::now();
        let scale = round.lr * (round.epochs.max(1) as f32).sqrt() * 0.1;
        let params: Vec<f32> = round
            .global
            .iter()
            .map(|g| g + scale * crng.normal())
            .collect();
        let payload =
            compressor.encode_payload(&params, &round.global, round.encode_deltas);
        let update = compressor.compress(&payload, ctx.engine_worker)?;
        Ok(ClientMsg {
            slot: spec.slot,
            update: ctx.scratch.pack_update(&update.payload)?,
            exact: params,
            n_samples: self.data.shard_rows(spec.client),
            train_s: started.elapsed().as_secs_f64(),
        })
    }
}
