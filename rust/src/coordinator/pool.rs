//! Persistent worker-pool client stage.
//!
//! The pre-pool coordinator spawned one OS thread per selected client per
//! round, which caps the `scenarios` sweep far below the paper's K=10k
//! regime (m=1000 surviving clients meant 1000 thread spawns *per
//! round*).  The pool spawns `client_threads` workers once per
//! [`crate::coordinator::Simulation`]; every round pushes one
//! [`WorkSpec`] per surviving client onto a shared queue and collects
//! exactly as many [`ClientMsg`]s back — zero spawns on the round path.
//!
//! Determinism: a work item carries its selection slot and its private
//! RNG seed (`round_seed ^ (client_id << 1)`, unchanged from the
//! spawn-per-client implementation), so a client's result never depends
//! on which pool thread ran it, in what order, or how many threads
//! exist — per-round results are bit-identical for any pool size
//! (guarded by `tests/pool_determinism.rs`).  Each pool thread pins to
//! one PJRT engine worker (`thread_idx % engine_workers`) so per-worker
//! executable caches stay warm across rounds.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::compression::{CompressedUpdate, Compressor};
use crate::coordinator::encode_payload;
use crate::data::FlData;
use crate::error::{HcflError, Result};
use crate::fl::LocalTrainer;
use crate::util::rng::Rng;

/// One client's contribution to a round, as reported by the client stage.
pub struct ClientMsg {
    /// Selection slot of the sender (index into the round's selection).
    pub slot: usize,
    pub update: CompressedUpdate,
    /// Exact post-training parameters (simulation-only side channel used
    /// to measure reconstruction error at the server).
    pub exact: Vec<f32>,
    /// Samples on the client's shard (FedAvg n_k).
    pub n_samples: usize,
    /// Measured local train + encode wall time, seconds.
    pub train_s: f64,
}

/// One unit of client work; everything that identifies the computation,
/// so results are independent of scheduling.
#[derive(Debug, Clone, Copy)]
pub struct WorkSpec {
    /// Selection slot within the round.
    pub slot: usize,
    /// Global client id.
    pub client: usize,
    /// The client's private RNG seed for this round.
    pub seed: u64,
}

/// Round-constant inputs shared by every work item of one round.
pub struct RoundInputs {
    /// The broadcast global model every client starts from.
    pub global: Arc<Vec<f32>>,
    /// Local epochs E.
    pub epochs: usize,
    /// Local mini-batch size B.
    pub batch: usize,
    pub lr: f32,
    /// Put `Δ = w_local − w_broadcast` on the wire instead of raw weights.
    pub encode_deltas: bool,
}

/// What a pool thread does with one work item.
pub trait ClientRunner: Send + Sync {
    fn run(&self, spec: &WorkSpec, round: &RoundInputs, engine_worker: usize)
        -> Result<ClientMsg>;
}

struct WorkItem {
    spec: WorkSpec,
    round: Arc<RoundInputs>,
    reply: mpsc::Sender<Result<ClientMsg>>,
}

/// A fixed pool of client-stage worker threads over a shared work queue.
pub struct ClientPool {
    tx: Option<mpsc::Sender<WorkItem>>,
    workers: Vec<JoinHandle<()>>,
}

impl ClientPool {
    /// Spawn `threads` workers (>= 1), each pinned to engine worker
    /// `thread_idx % engine_workers`.
    pub fn new(
        runner: Arc<dyn ClientRunner>,
        threads: usize,
        engine_workers: usize,
    ) -> Result<ClientPool> {
        let threads = threads.max(1);
        let engine_workers = engine_workers.max(1);
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(threads);
        for w in 0..threads {
            let rx = Arc::clone(&rx);
            let runner = Arc::clone(&runner);
            let engine_worker = w % engine_workers;
            let join = std::thread::Builder::new()
                .name(format!("client-pool-{w}"))
                .spawn(move || loop {
                    // Hold the queue lock only while dequeuing; recv
                    // blocks between rounds and ends when the pool drops.
                    let item = {
                        let Ok(queue) = rx.lock() else { break };
                        match queue.recv() {
                            Ok(item) => item,
                            Err(_) => break,
                        }
                    };
                    let result = runner.run(&item.spec, &item.round, engine_worker);
                    // A dead receiver means the round was abandoned.
                    let _ = item.reply.send(result);
                })
                .map_err(|e| HcflError::Engine(format!("client pool spawn failed: {e}")))?;
            workers.push(join);
        }
        Ok(ClientPool {
            tx: Some(tx),
            workers,
        })
    }

    /// Pool size.
    pub fn n_threads(&self) -> usize {
        self.workers.len()
    }

    /// Run one round's client stage: enqueue every spec, collect exactly
    /// as many results.  Results come back in completion order — callers
    /// index by `ClientMsg::slot`.  On failure the whole batch is drained
    /// first (so no stale reply can leak into a later round), then the
    /// first error is returned.
    pub fn run_clients(&self, round: RoundInputs, specs: &[WorkSpec]) -> Result<Vec<ClientMsg>> {
        let round = Arc::new(round);
        let (reply_tx, reply_rx) = mpsc::channel::<Result<ClientMsg>>();
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| HcflError::Engine("client pool is shut down".into()))?;
        for &spec in specs {
            tx.send(WorkItem {
                spec,
                round: Arc::clone(&round),
                reply: reply_tx.clone(),
            })
            .map_err(|_| HcflError::Engine("client pool queue disconnected".into()))?;
        }
        drop(reply_tx);
        let mut out = Vec::with_capacity(specs.len());
        let mut first_err: Option<HcflError> = None;
        for _ in 0..specs.len() {
            let reply = reply_rx
                .recv()
                .map_err(|_| HcflError::Engine("client pool worker vanished".into()))?;
            match reply {
                Ok(msg) => out.push(msg),
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(out)
    }
}

impl Drop for ClientPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the queue; workers exit at the next recv
        for join in self.workers.drain(..) {
            let _ = join.join();
        }
    }
}

/// The real client stage: local SGD through the engine, then wire
/// encoding, exactly as the spawn-per-client implementation did.
pub struct TrainEncodeRunner {
    trainer: LocalTrainer,
    compressor: Arc<dyn Compressor>,
    data: Arc<FlData>,
}

impl TrainEncodeRunner {
    pub fn new(
        trainer: LocalTrainer,
        compressor: Arc<dyn Compressor>,
        data: Arc<FlData>,
    ) -> TrainEncodeRunner {
        TrainEncodeRunner {
            trainer,
            compressor,
            data,
        }
    }
}

impl ClientRunner for TrainEncodeRunner {
    fn run(
        &self,
        spec: &WorkSpec,
        round: &RoundInputs,
        engine_worker: usize,
    ) -> Result<ClientMsg> {
        let shard = self.data.shard(spec.client);
        let mut crng = Rng::new(spec.seed);
        let started = Instant::now();
        let out = self.trainer.train(
            &round.global,
            &shard,
            round.epochs,
            round.batch,
            round.lr,
            &mut crng,
            engine_worker,
        )?;
        let payload = encode_payload(&out.params, &round.global, round.encode_deltas);
        let update = self.compressor.compress(&payload, engine_worker)?;
        Ok(ClientMsg {
            slot: spec.slot,
            update,
            exact: out.params,
            n_samples: shard.n,
            train_s: started.elapsed().as_secs_f64(),
        })
    }
}

/// Engine-free stand-in for local training: the "update" is the global
/// model plus seeded Gaussian noise scaled by the learning rate.
/// Deterministic in the work item's seed, so it drives the full
/// pool → clock → aggregation pipeline (CI smoke runs, large-m benches,
/// determinism tests) without PJRT artifacts.  Shard pixels are never
/// rendered — only the client's row count is read (FedAvg `n_k` for the
/// aggregation layer), so a lazy K=10k fleet costs nothing here.
pub struct FakeTrainRunner {
    compressor: Arc<dyn Compressor>,
    data: Arc<FlData>,
}

impl FakeTrainRunner {
    pub fn new(compressor: Arc<dyn Compressor>, data: Arc<FlData>) -> FakeTrainRunner {
        FakeTrainRunner { compressor, data }
    }
}

impl ClientRunner for FakeTrainRunner {
    fn run(
        &self,
        spec: &WorkSpec,
        round: &RoundInputs,
        engine_worker: usize,
    ) -> Result<ClientMsg> {
        let mut crng = Rng::new(spec.seed);
        let started = Instant::now();
        let scale = round.lr * (round.epochs.max(1) as f32).sqrt() * 0.1;
        let params: Vec<f32> = round
            .global
            .iter()
            .map(|g| g + scale * crng.normal())
            .collect();
        let payload = encode_payload(&params, &round.global, round.encode_deltas);
        let update = self.compressor.compress(&payload, engine_worker)?;
        Ok(ClientMsg {
            slot: spec.slot,
            update,
            exact: params,
            n_samples: self.data.shard_rows(spec.client),
            train_s: started.elapsed().as_secs_f64(),
        })
    }
}
