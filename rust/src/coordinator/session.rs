//! The event-driven round lifecycle: a typed session state machine for
//! the server side of one communication round, plus the cross-round
//! carry-over of late (straggler) uploads.
//!
//! The old API was one blocking call — a round began, resolved and
//! aggregated inside `Simulation::run_round` with no seam for an update
//! to outlive it, so deadline and fastest-m policies discarded every
//! late upload: at IoT scale that wastes exactly the client compute HCFL
//! exists to make affordable.  The session turns the round into an
//! explicit lifecycle any driver can pump — the simulator, the
//! engine-free `fake_train` path, and a future real transport all share
//! it:
//!
//! ```text
//! FlSession::begin_round(t, carry)      ──> RoundSession<Open>
//!   submit(ClientUpdate)*                    (one per arrival)
//!   mark_dropped(ClientTiming)*              (one per vanished device)
//!   resolve(&RoundPolicy)               ──> RoundSession<Resolved>
//!   finalize(&WorkerPool)               ──> (RoundRecord, CarryOver)
//!   (or finalize_sharded(&EdgeAggregator) for the two-level edge fold)
//! ```
//!
//! The typestate makes illegal transitions unrepresentable: only an
//! `Open` session accepts arrivals, only a `Resolved` one can finalize,
//! and `finalize` consumes the session.  Dropping an unfinalized session
//! is safe — nothing touches the global model before `finalize`.
//!
//! **Carry-over.**  With [`CarryPolicy::CarryDiscounted`], `finalize`
//! decodes the round's late arrivals instead of discarding them and
//! returns them in a [`CarryOver`]; the driver hands that to the next
//! `begin_round`.  A carried update keeps its *rebased* arrival time —
//! its original modelled arrival minus one round makespan per round it
//! has been in flight — so the next round's `resolve` treats it like
//! any other upload: it folds when it lands before the round closes
//! (`t_max` for `Deadline`, the last fresh survivor for `FastestM`,
//! always for `Synchronous`) and is carried again otherwise, until
//! `max_age_rounds` expires it.  When it folds, its weight is
//!
//! ```text
//! w = base_weight × exp(-lambda × age_rounds)
//! ```
//!
//! where `base_weight` is [`AggregatorKind::weight`] evaluated in its
//! *birth* round against that round's freshness reference (the same
//! `t0_arrival` rule the streaming and tree folds share), and the
//! exponential is the cross-round staleness discount.  Carried leaves
//! enter the reduction tree *before* the fresh survivors, in arrival
//! order — they reached the server first — so the tree shape and every
//! per-node summation order stay pure functions of the leaf order and
//! the fold remains bit-identical for any `client_threads`
//! (`tests/session_carryover.rs`).

use std::sync::Arc;
use std::time::Instant;

use crate::compression::{
    Compressor, HcflCompressor, Identity, RefTernaryCompressor, Scheme, TernaryCompressor,
    TopKCompressor, WireScratch, WireUpdate,
};
use crate::config::ExperimentConfig;
use crate::control::{CodecBank, ServerOptKind, ServerOptState};
use crate::coordinator::clock::{resolve, ClientTiming, RoundOutcome, RoundPolicy};
use crate::coordinator::edge::{DecodeJob, EdgeAggregator};
use crate::coordinator::pool::{reduce_tree, WorkerCtx, WorkerPool};
use crate::data::FlData;
use crate::error::Result;
use crate::fl::{
    finish_tree, AggregatorKind, Server, UpdateMeta, WeightedLeaf, TREE_FAN_IN,
};
use crate::hcfl::prepare_autoencoders;
use crate::metrics::RoundRecord;
use crate::model::{merge_segment_ranges, split_dense};
use crate::runtime::Engine;
use crate::util::stats;

/// What happens to uploads that miss the round policy's cut.
#[derive(Debug, Clone, PartialEq)]
pub enum CarryPolicy {
    /// Late uploads are wasted air time (the pre-session behavior, and
    /// the paper's implicit rule).
    Discard,
    /// Decode late uploads and fold them into the round they finally
    /// reach, down-weighted by `exp(-lambda * age_rounds)`; updates
    /// older than `max_age_rounds` rounds expire unfolded.
    CarryDiscounted { lambda: f64, max_age_rounds: usize },
}

impl CarryPolicy {
    /// Whether late uploads survive the round at all.
    pub fn carries(&self) -> bool {
        matches!(self, CarryPolicy::CarryDiscounted { .. })
    }

    pub fn label(&self) -> String {
        match self {
            CarryPolicy::Discard => "discard".to_string(),
            CarryPolicy::CarryDiscounted {
                lambda,
                max_age_rounds,
            } => format!("carry l={lambda:.2} age<={max_age_rounds}"),
        }
    }
}

/// One arrival at the server: the encoded wire payload plus everything
/// the clock layer modelled about its journey.
pub struct ClientUpdate {
    /// The packed wire buffer as it came off the air.
    pub payload: WireUpdate,
    /// Samples on the sender's shard (FedAvg `n_k`).
    pub n_samples: usize,
    /// The sender's modelled round timeline (carries the arrival time
    /// and the selection-slot tie-break).
    pub timing: ClientTiming,
    /// Exact post-training parameters for reconstruction-error
    /// instrumentation (empty disables).  In-process drivers pass them
    /// as a free side channel; the transport ships them only when
    /// `ExperimentConfig::send_exact` asks for them.
    pub exact: Vec<f32>,
    /// Uplink bytes this arrival cost beyond its packed payload — the
    /// transport's exact-params sidecar when enabled (DESIGN.md §8.4).
    /// Counted into `RoundRecord::up_bytes`; 0 on the in-process path,
    /// where nothing but the payload is modelled on the air.
    pub extra_up_bytes: usize,
    /// Measured client train+encode wall time, seconds.
    pub train_s: f64,
    /// The codec tag this upload was encoded with — the control plane's
    /// per-client assignment ([`crate::compression::Scheme::codec_tag`]).
    /// The server decodes it with the matching bank entry.
    pub codec: u8,
}

/// A decoded-but-late update in flight between rounds.
#[derive(Debug, Clone)]
pub struct CarriedUpdate {
    /// Global client id of the sender.
    pub client: usize,
    /// Samples on the sender's shard.
    pub n_samples: usize,
    /// Round the update was trained in.
    pub born_round: usize,
    /// The birth round's aggregation weight ([`AggregatorKind::weight`]
    /// against the birth round's freshness reference): what the update
    /// would have weighed had it made the cut.
    pub base_weight: f64,
    /// Arrival time on the *current* round's clock: the original
    /// modelled arrival minus one round makespan per round already
    /// missed.
    pub arrival_s: f64,
    /// Decoded (and delta-reconstructed) parameters, ready to weight.
    pub decoded: Vec<f32>,
}

/// Late updates that outlive their round.  `finalize` returns it, the
/// driver hands it to the next `begin_round` — the explicit flow is the
/// transport seam: a real deployment persists this between rounds.
#[derive(Debug, Clone, Default)]
pub struct CarryOver {
    /// In arrival order: re-carried (oldest first), then newly late.
    pub updates: Vec<CarriedUpdate>,
}

impl CarryOver {
    /// The empty carry-over every run starts from.
    pub fn empty() -> CarryOver {
        CarryOver::default()
    }

    pub fn len(&self) -> usize {
        self.updates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.updates.is_empty()
    }
}

/// The server side of a multi-round FL run: owns the global model and
/// the round-lifecycle state machine.  One `FlSession` outlives every
/// round; each round is a [`RoundSession`] borrowed from it.
pub struct FlSession {
    server: Server,
    bank: CodecBank,
    aggregator: AggregatorKind,
    carry: CarryPolicy,
    encode_deltas: bool,
    compress_downlink: bool,
    opt: ServerOptKind,
    opt_state: ServerOptState,
}

impl FlSession {
    pub fn new(
        server: Server,
        compressor: Arc<dyn Compressor>,
        aggregator: AggregatorKind,
        carry: CarryPolicy,
        encode_deltas: bool,
        compress_downlink: bool,
    ) -> FlSession {
        FlSession {
            server,
            bank: CodecBank::single(compressor),
            aggregator,
            carry,
            encode_deltas,
            compress_downlink,
            opt: ServerOptKind::Sgd,
            opt_state: ServerOptState::empty(),
        }
    }

    /// Current global model.
    pub fn global(&self) -> &[f32] {
        &self.server.global.flat
    }

    /// Model dimensionality.
    pub fn d(&self) -> usize {
        self.server.model.d
    }

    /// The base scheme's compressor (downlink / handshake codec).
    pub fn compressor(&self) -> &Arc<dyn Compressor> {
        self.bank.base()
    }

    /// Replace the codec table with a multi-codec bank (adaptive
    /// policies): each arrival decodes with the bank entry its codec tag
    /// selects.  The bank's base stays the downlink codec.
    pub fn set_codec_bank(&mut self, bank: CodecBank) {
        self.bank = bank;
    }

    /// Install the server-side optimizer applied between the aggregated
    /// round result and the global-model install (default `Sgd`).
    pub fn set_server_opt(&mut self, opt: ServerOptKind) {
        self.opt = opt;
    }

    /// The optimizer's persistent moment state (snapshotted by the
    /// campaign daemon, DESIGN.md §9.2 v2).
    pub fn opt_state(&self) -> &ServerOptState {
        &self.opt_state
    }

    /// Overwrite the optimizer state from a campaign snapshot.
    pub fn restore_opt_state(&mut self, state: ServerOptState) {
        self.opt_state = state;
    }

    pub fn carry_policy(&self) -> &CarryPolicy {
        &self.carry
    }

    /// Overwrite the global model from a campaign snapshot
    /// (`daemon::snapshot`, DESIGN.md §9).  Dimension-checked by
    /// `Server::install`; the session holds no other cross-round state,
    /// so this plus the driver's carry-over and RNG cursor is a full
    /// rewind.
    pub fn restore_global(&mut self, params: Vec<f32>) -> Result<()> {
        self.server.install(params)
    }

    /// Re-sync the scenario knobs a driver may tune between rounds.
    /// The codebase's calibration idiom mutates `Simulation::cfg` after
    /// construction (a probe round fixes the deadline's time scale);
    /// `run_round` calls this so the aggregation rule and carry policy
    /// stay as live as the round policy.
    pub fn set_scenario(&mut self, aggregator: AggregatorKind, carry: CarryPolicy) {
        self.aggregator = aggregator;
        self.carry = carry;
    }

    /// Open round `t`: broadcast the global model (accounted per the
    /// downlink rule, see `ExperimentConfig::compress_downlink`) and
    /// ingest the previous round's carry-over, expiring updates older
    /// than the carry policy allows.
    ///
    /// # Examples
    ///
    /// A minimal driver: open a round against an identity codec, observe
    /// the broadcast, and resolve it with no arrivals (every selected
    /// device vanished this round).
    ///
    /// ```
    /// use std::sync::Arc;
    ///
    /// use hcfl::compression::Identity;
    /// use hcfl::coordinator::clock::RoundPolicy;
    /// use hcfl::coordinator::pool::WorkerPool;
    /// use hcfl::coordinator::session::{CarryOver, CarryPolicy, FlSession};
    /// use hcfl::fl::{AggregatorKind, Server};
    /// use hcfl::runtime::Manifest;
    /// use hcfl::util::rng::Rng;
    ///
    /// # fn main() -> hcfl::error::Result<()> {
    /// let model = Manifest::synthetic().model("fake")?.clone();
    /// let server = Server::new(&model, &mut Rng::new(5));
    /// let mut fl = FlSession::new(
    ///     server,
    ///     Arc::new(Identity),
    ///     AggregatorKind::UniformMean,
    ///     CarryPolicy::Discard,
    ///     true,  // encode_deltas
    ///     false, // compress_downlink: account the raw 4*d broadcast
    /// );
    ///
    /// let round = fl.begin_round(1, CarryOver::empty())?;
    /// assert_eq!(round.down_bytes(), 4 * round.global().len());
    ///
    /// // No submit()/mark_dropped() calls: the round still resolves and
    /// // finalizes cleanly, leaving the global model untouched.
    /// let pool = WorkerPool::new(1, 1)?;
    /// let (record, carry) = round.resolve(&RoundPolicy::Synchronous).finalize(&pool)?;
    /// assert_eq!(record.completed, 0);
    /// assert!(carry.is_empty());
    /// # Ok(())
    /// # }
    /// ```
    pub fn begin_round(&mut self, t: usize, carry: CarryOver) -> Result<RoundSession<'_, Open>> {
        let wall0 = Instant::now();
        let down_bytes = if self.compress_downlink {
            let upd = self.bank.base().compress(&self.server.global.flat, 0)?;
            WireScratch::new().pack(&upd.payload)?
        } else {
            4 * self.server.global.flat.len()
        };
        let global = Arc::new(self.server.global.flat.clone());
        let mut carried = Vec::with_capacity(carry.updates.len());
        let mut expired = 0usize;
        for u in carry.updates {
            let keep = match &self.carry {
                CarryPolicy::Discard => false,
                CarryPolicy::CarryDiscounted { max_age_rounds, .. } => {
                    t.saturating_sub(u.born_round) <= *max_age_rounds
                }
            };
            if keep {
                carried.push(u);
            } else {
                expired += 1;
            }
        }
        Ok(RoundSession {
            fl: self,
            t,
            wall0,
            state: Open {
                global,
                down_bytes,
                carried,
                expired,
                timings: Vec::new(),
                arrivals: Vec::new(),
                train_s: Vec::new(),
            },
        })
    }
}

/// The payload half of a submitted arrival (timing lives in `timings`).
struct ArrivalData {
    payload: WireUpdate,
    n_samples: usize,
    exact: Vec<f32>,
    extra_up_bytes: usize,
    codec: u8,
}

/// State of a round that is accepting arrivals.
pub struct Open {
    global: Arc<Vec<f32>>,
    down_bytes: usize,
    carried: Vec<CarriedUpdate>,
    expired: usize,
    timings: Vec<ClientTiming>,
    /// Parallel to `timings`; `None` marks a dropped device.
    arrivals: Vec<Option<ArrivalData>>,
    train_s: Vec<f64>,
}

/// State of a round whose policy has split arrivals into survivors and
/// late uploads.
pub struct Resolved {
    global: Arc<Vec<f32>>,
    down_bytes: usize,
    fold_carried: Vec<CarriedUpdate>,
    carry_again: Vec<CarriedUpdate>,
    expired: usize,
    timings: Vec<ClientTiming>,
    arrivals: Vec<Option<ArrivalData>>,
    train_s: Vec<f64>,
    outcome: RoundOutcome,
    makespan_s: f64,
}

/// Which fold pipeline `finalize` drives: the flat single-pool path or
/// the two-level edge-sharded path (`coordinator::edge`).  Both produce
/// bit-identical results for the same leaf order.
#[derive(Clone, Copy)]
enum Folder<'a> {
    Flat(&'a WorkerPool),
    Sharded(&'a EdgeAggregator),
}

impl<'a> Folder<'a> {
    /// The pool driving work outside the survivor fold (the late-arrival
    /// decode batch).
    fn late_pool(&self) -> &'a WorkerPool {
        match self {
            Folder::Flat(pool) => pool,
            Folder::Sharded(edge) => edge.root_pool(),
        }
    }
}

/// One round of the session state machine; `S` is [`Open`] or
/// [`Resolved`].
pub struct RoundSession<'s, S> {
    fl: &'s mut FlSession,
    t: usize,
    wall0: Instant,
    state: S,
}

impl<S> RoundSession<'_, S> {
    /// The round number this session was opened for.
    pub fn round(&self) -> usize {
        self.t
    }
}

impl<'s> RoundSession<'s, Open> {
    /// The broadcast payload every selected client starts from (always
    /// the exact global model — paper Fig. 3 puts the only decoder at
    /// the server).
    pub fn global(&self) -> &Arc<Vec<f32>> {
        &self.state.global
    }

    /// Accounted per-client broadcast wire size.
    pub fn down_bytes(&self) -> usize {
        self.state.down_bytes
    }

    /// Carried updates from previous rounds still in flight (after
    /// expiry).
    pub fn carried_pending(&self) -> usize {
        self.state.carried.len()
    }

    /// Carried updates expired unfolded at `begin_round`.
    pub fn expired(&self) -> usize {
        self.state.expired
    }

    /// Record one upload reaching the server.  Submission order does not
    /// matter: `resolve` orders arrivals by modelled arrival time with
    /// the selection-slot tie-break.
    pub fn submit(&mut self, u: ClientUpdate) {
        debug_assert!(!u.timing.dropped, "a dropped device cannot submit");
        self.state.train_s.push(u.train_s);
        self.state.timings.push(u.timing);
        self.state.arrivals.push(Some(ArrivalData {
            payload: u.payload,
            n_samples: u.n_samples,
            exact: u.exact,
            extra_up_bytes: u.extra_up_bytes,
            codec: u.codec,
        }));
    }

    /// Record a selected device that vanished this round: nothing
    /// arrives, but the round still accounts its broadcast and — under
    /// `Deadline` — waits out the full `t_max` for it.
    pub fn mark_dropped(&mut self, timing: ClientTiming) {
        debug_assert!(timing.dropped, "mark_dropped needs a dropped timing");
        self.state.timings.push(timing);
        self.state.arrivals.push(None);
    }

    /// Apply the round policy: split fresh arrivals into survivors and
    /// late, and the carried updates into fold-now and carry-again.
    pub fn resolve(self, policy: &RoundPolicy) -> RoundSession<'s, Resolved> {
        let Open {
            global,
            down_bytes,
            carried,
            expired,
            timings,
            arrivals,
            train_s,
        } = self.state;
        let outcome = resolve(policy, &timings);

        // When the round closes for a carried upload: the deadline is
        // absolute, fastest-m closes at its last fresh survivor, and a
        // synchronous server waits for everything it knows is in flight.
        // A fastest-m round with no fresh survivors cannot close at its
        // m-th arrival — the in-flight carried uploads are the only
        // arrivals, so the server waits for them (otherwise they would
        // rebase by a zero makespan and age out without ever getting a
        // chance to fold).
        let close = match policy {
            RoundPolicy::Synchronous => f64::INFINITY,
            RoundPolicy::Deadline { t_max_s } => *t_max_s,
            RoundPolicy::FastestM { .. } if outcome.survivors.is_empty() => f64::INFINITY,
            RoundPolicy::FastestM { .. } => outcome.makespan_s,
        };
        let mut fold_carried = Vec::new();
        let mut carry_again = Vec::new();
        for u in carried {
            if u.arrival_s <= close {
                fold_carried.push(u);
            } else {
                carry_again.push(u);
            }
        }
        // A folded carried upload can land after the last fresh
        // survivor; the round cannot close before it does.
        let mut makespan_s = outcome.makespan_s;
        for u in &fold_carried {
            makespan_s = makespan_s.max(u.arrival_s);
        }
        // An in-flight carried upload is indistinguishable from a
        // straggler: a deadline round waits out the full t_max for it.
        if let RoundPolicy::Deadline { t_max_s } = policy {
            if !carry_again.is_empty() {
                makespan_s = *t_max_s;
            }
        }
        // Rebase what stays in flight onto the next round's clock.
        for u in &mut carry_again {
            u.arrival_s -= makespan_s;
        }

        RoundSession {
            fl: self.fl,
            t: self.t,
            wall0: self.wall0,
            state: Resolved {
                global,
                down_bytes,
                fold_carried,
                carry_again,
                expired,
                timings,
                arrivals,
                train_s,
                outcome,
                makespan_s,
            },
        }
    }
}

impl RoundSession<'_, Resolved> {
    /// What the policy decided (survivor/late index sets, counts).
    pub fn outcome(&self) -> &RoundOutcome {
        &self.state.outcome
    }

    /// Global client ids of the policy's survivors, in arrival order.
    pub fn survivor_clients(&self) -> Vec<usize> {
        self.state
            .outcome
            .survivors
            .iter()
            .map(|&i| self.state.timings[i].client)
            .collect()
    }

    /// Global client ids of the alive-but-cut uploads, in arrival order.
    pub fn late_clients(&self) -> Vec<usize> {
        self.state
            .outcome
            .late
            .iter()
            .map(|&i| self.state.timings[i].client)
            .collect()
    }

    /// Carried updates that fold into this round's tree.
    pub fn carried_in(&self) -> usize {
        self.state.fold_carried.len()
    }

    /// Carried updates expired unfolded at `begin_round`.
    pub fn expired(&self) -> usize {
        self.state.expired
    }

    /// Decode survivors in parallel on the pool, fold carried leaves and
    /// fresh survivors through the fixed-fan-in reduction tree, install
    /// the aggregated model, and hand back the round record plus the
    /// carry-over for the next round.
    pub fn finalize(self, pool: &WorkerPool) -> Result<(RoundRecord, CarryOver)> {
        self.finalize_fold(Folder::Flat(pool))
    }

    /// Sharded variant of [`finalize`](Self::finalize): decode + fold
    /// through an [`EdgeAggregator`]'s two-level pipeline (each shard on
    /// its own pool, partials folded at the root).  Bit-identical to the
    /// flat path for any shard count — the leaf order is the same and the
    /// shard boundaries are fan-in-subtree aligned (`coordinator::edge`).
    pub fn finalize_sharded(self, edge: &EdgeAggregator) -> Result<(RoundRecord, CarryOver)> {
        self.finalize_fold(Folder::Sharded(edge))
    }

    fn finalize_fold(self, folder: Folder<'_>) -> Result<(RoundRecord, CarryOver)> {
        let Resolved {
            global,
            down_bytes,
            fold_carried,
            mut carry_again,
            expired,
            timings,
            mut arrivals,
            train_s,
            outcome,
            makespan_s,
        } = self.state;
        let fl = self.fl;
        let t = self.t;
        let d = fl.server.model.d;
        let m = timings.len();

        // Uplink accounting covers every transmitting client: cut and
        // carried uploads hit the air whether or not they fold here.
        // `extra_up_bytes` is the transport's exact-params sidecar
        // (zero in-process).
        let up_bytes: u64 = arrivals
            .iter()
            .flatten()
            .map(|a| (a.payload.wire_bytes() + a.extra_up_bytes) as u64)
            .sum();
        let reference_compute_s = stats::mean(&train_s);
        // The freshness reference: the first surviving arrival, as
        // before the session.  When the policy cuts *everyone*, the
        // survivors' fold never reads it, but the late-decode path
        // still freezes base weights against it — use the earliest
        // alive arrival so a staleness rule measures lateness relative
        // to the round's own fastest upload, never the clock origin.
        let t0_arrival = outcome
            .survivors
            .first()
            .or(outcome.late.first())
            .map(|&i| timings[i].arrival_s())
            .unwrap_or(0.0);

        // ---- parallel decode: fresh survivors become weighted leaves --
        // Only the server's real work (decode + weighting) is timed; the
        // reconstruction MSE is simulation-only instrumentation and
        // stays outside the measured server time.
        let kind = fl.aggregator.clone();
        let encode_deltas = fl.encode_deltas;
        let mut jobs: Vec<DecodeJob> = Vec::with_capacity(outcome.survivors.len());
        for &i in &outcome.survivors {
            let arr = arrivals[i].take().expect("survivor submitted an update");
            let meta = UpdateMeta {
                client: timings[i].client,
                n_samples: arr.n_samples,
                arrival_s: timings[i].arrival_s(),
            };
            // Per-arrival codec: look the bank entry up on the driver
            // thread so a forged tag fails before any job is scattered.
            let compressor = Arc::clone(fl.bank.get(arr.codec)?);
            let global = Arc::clone(&global);
            let kind = kind.clone();
            jobs.push(Box::new(
                move |ctx: &mut WorkerCtx| -> Result<(WeightedLeaf, f64, f64)> {
                    let t0 = Instant::now();
                    // zero-copy decode: the packed bytes dequantize
                    // straight into a pooled leaf buffer, and the spent
                    // wire buffer goes back to this worker's arena
                    let mut decoded = ctx.scratch.take_f32();
                    compressor.unpack_into(
                        &arr.payload.bytes,
                        d,
                        ctx.engine_worker,
                        &mut ctx.scratch,
                        &mut decoded,
                    )?;
                    ctx.scratch.put_bytes(arr.payload.into_bytes());
                    compressor.decode_payload(&mut decoded, &global, encode_deltas);
                    let mut decode_s = t0.elapsed().as_secs_f64();
                    let recon = if arr.exact.is_empty() {
                        0.0
                    } else {
                        mse(&decoded, &arr.exact)
                    };
                    let t1 = Instant::now();
                    let w = kind.weight(&meta, t0_arrival)?;
                    let leaf = WeightedLeaf::new(w, decoded);
                    decode_s += t1.elapsed().as_secs_f64();
                    Ok((leaf, recon, decode_s))
                },
            ));
        }
        let completed = jobs.len();
        let mut recon_sum = 0.0f64;
        // Summed per-survivor decode time: total server-side work, not
        // overlapped wall time (the pre-pool semantics).
        let mut server_time_s = 0.0f64;

        // ---- parallel decode: late arrivals become carry-over ---------
        // Decoded *now*, against this round's broadcast — a late delta
        // must be reconstructed on the global model its client trained
        // from.  Its base weight is this round's AggregatorKind::weight,
        // frozen before the update leaves its birth round.
        if fl.carry.carries() {
            let mut late_jobs = Vec::with_capacity(outcome.late.len());
            for &i in &outcome.late {
                let arr = arrivals[i].take().expect("late client submitted an update");
                let meta = UpdateMeta {
                    client: timings[i].client,
                    n_samples: arr.n_samples,
                    arrival_s: timings[i].arrival_s(),
                };
                let rebased_arrival = timings[i].arrival_s() - makespan_s;
                let compressor = Arc::clone(fl.bank.get(arr.codec)?);
                let global = Arc::clone(&global);
                let kind = kind.clone();
                late_jobs.push(move |ctx: &mut WorkerCtx| -> Result<(CarriedUpdate, f64)> {
                    let t0 = Instant::now();
                    let mut decoded = ctx.scratch.take_f32();
                    compressor.unpack_into(
                        &arr.payload.bytes,
                        d,
                        ctx.engine_worker,
                        &mut ctx.scratch,
                        &mut decoded,
                    )?;
                    ctx.scratch.put_bytes(arr.payload.into_bytes());
                    compressor.decode_payload(&mut decoded, &global, encode_deltas);
                    let base_weight = kind.weight(&meta, t0_arrival)?;
                    let decode_s = t0.elapsed().as_secs_f64();
                    Ok((
                        CarriedUpdate {
                            client: meta.client,
                            n_samples: meta.n_samples,
                            born_round: t,
                            base_weight,
                            arrival_s: rebased_arrival,
                            decoded,
                        },
                        decode_s,
                    ))
                });
            }
            for res in folder.late_pool().scatter(late_jobs)? {
                let (carried, decode_s) = res?;
                server_time_s += decode_s;
                carry_again.push(carried);
            }
        }
        let carried_out = carry_again.len();

        // ---- reduction tree: carried leaves first, in arrival order ---
        // The carry discount is sequential f64 arithmetic, so carried
        // weights — like the tree shape — never depend on the pool size.
        let lambda = match &fl.carry {
            CarryPolicy::CarryDiscounted { lambda, .. } => *lambda,
            CarryPolicy::Discard => 0.0,
        };
        let carried_in = fold_carried.len();
        let mut leaves = Vec::with_capacity(carried_in + completed);
        for u in fold_carried {
            let age = t.saturating_sub(u.born_round).max(1);
            let w = u.base_weight * (-lambda * age as f64).exp();
            leaves.push(WeightedLeaf::new(w, u.decoded));
        }
        // If no root comes back, every upload was lost to dropout/policy
        // and nothing was carried in; the round is wasted air time and
        // the global model carries over unchanged.
        match folder {
            Folder::Flat(pool) => {
                for res in pool.scatter(jobs)? {
                    let (leaf, recon, decode_s) = res?;
                    recon_sum += recon;
                    server_time_s += decode_s;
                    leaves.push(leaf);
                }
                let t_fold = Instant::now();
                if let Some(root) = reduce_tree(pool, leaves, TREE_FAN_IN)? {
                    let aggregated = finish_tree(root)?;
                    let next =
                        fl.opt
                            .apply(&mut fl.opt_state, &fl.server.global.flat, aggregated)?;
                    fl.server.install(next)?;
                }
                server_time_s += t_fold.elapsed().as_secs_f64();
            }
            Folder::Sharded(edge) => {
                // Carried leaves enter the tree first, as in the flat
                // arm; per-survivor stats come back in global arrival
                // order, so the sequential f64 accumulations match too.
                let fold = edge.fold_round(leaves, jobs)?;
                for &(recon, decode_s) in &fold.stats {
                    recon_sum += recon;
                    server_time_s += decode_s;
                }
                if let Some(root) = fold.root {
                    let aggregated = finish_tree(root)?;
                    let next =
                        fl.opt
                            .apply(&mut fl.opt_state, &fl.server.global.flat, aggregated)?;
                    fl.server.install(next)?;
                }
                server_time_s += fold.fold_s;
            }
        }

        // Cost accounting (clock layer outputs, exact per-client bytes):
        // air time covers all alive clients — capped at the makespan,
        // past which cut transmissions stop.  The broadcast reaches all
        // m selected.
        let comm_time_s = timings
            .iter()
            .filter(|tm| !tm.dropped)
            .map(|tm| tm.downlink_s + tm.uplink_s)
            .fold(0.0, f64::max)
            .min(makespan_s);

        let record = RoundRecord {
            round: t,
            // Evaluation is an engine concern; the driver fills these in.
            accuracy: 0.0,
            loss: 0.0,
            recon_mse: recon_sum / completed.max(1) as f64,
            up_bytes,
            down_bytes: (down_bytes * m) as u64,
            selected: m,
            completed,
            dropped: outcome.dropped,
            stragglers: outcome.stragglers,
            carried_in,
            carried_out,
            carried_expired: expired,
            makespan_s,
            client_time_s: reference_compute_s,
            server_time_s,
            comm_time_s,
            wall_time_s: self.wall0.elapsed().as_secs_f64(),
        };
        Ok((
            record,
            CarryOver {
                updates: carry_again,
            },
        ))
    }
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Construct the configured base compression scheme (training HCFL
/// autoencoders on the server dataset when needed).
pub fn build_compressor(
    engine: &Engine,
    cfg: &ExperimentConfig,
    data: &FlData,
    init_params: &[f32],
) -> Result<Arc<dyn Compressor>> {
    build_compressor_for(engine, cfg.scheme, cfg, data, init_params)
}

/// Every codec the configured policy can assign, as a tag-indexed bank
/// (base scheme first; adaptive policies add their heavy codec).
pub fn build_codec_bank(
    engine: &Engine,
    cfg: &ExperimentConfig,
    data: &FlData,
    init_params: &[f32],
) -> Result<CodecBank> {
    let mut bank = CodecBank::single(build_compressor_for(
        engine,
        cfg.scheme,
        cfg,
        data,
        init_params,
    )?);
    for scheme in cfg.codec_policy.menu(cfg.scheme) {
        if scheme.codec_tag() != cfg.scheme.codec_tag() {
            bank.insert(build_compressor_for(engine, scheme, cfg, data, init_params)?);
        }
    }
    Ok(bank)
}

/// Construct one scheme's compressor.  `fake_train` runs swap the
/// engine-backed ternary codec for the bit-identical pure-Rust
/// reference, so no PJRT executable is touched on the engine-free path.
fn build_compressor_for(
    engine: &Engine,
    scheme: Scheme,
    cfg: &ExperimentConfig,
    data: &FlData,
    init_params: &[f32],
) -> Result<Arc<dyn Compressor>> {
    match scheme {
        Scheme::Fedavg => Ok(Arc::new(Identity)),
        Scheme::Ternary if cfg.fake_train => Ok(Arc::new(RefTernaryCompressor::new())),
        Scheme::Ternary => Ok(Arc::new(TernaryCompressor::new(engine.clone(), 1024)?)),
        Scheme::TopK { keep } => Ok(Arc::new(TopKCompressor::new(keep)?)),
        Scheme::Hcfl { ratio } => {
            let model = engine.manifest().model(&cfg.model)?;
            let ranges = split_dense(&merge_segment_ranges(&model.layers), cfg.dense_parts);
            let chunk_of_segment = engine.manifest().chunks.clone();
            let cache_dir = engine.manifest().dir.join("cache");
            let mut ae_cfg = cfg.ae.clone();
            // Match the pre-model's per-client epochs to the run's E so
            // snapshot delta magnitudes match what will be compressed.
            ae_cfg.premodel_local_epochs = cfg.local_epochs;
            let aes = prepare_autoencoders(
                engine,
                &cfg.model,
                &data.server,
                &ranges,
                &chunk_of_segment,
                ratio,
                &ae_cfg,
                cfg.use_ae_cache.then_some(cache_dir.as_path()),
                init_params,
                cfg.encode_deltas,
            )?;
            Ok(Arc::new(HcflCompressor::new(
                engine.clone(),
                ratio,
                ranges,
                aes,
                chunk_of_segment,
            )?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::rng::Rng;

    fn session(carry: CarryPolicy) -> FlSession {
        let model = Manifest::synthetic().model("fake").unwrap().clone();
        let mut rng = Rng::new(5);
        let server = Server::new(&model, &mut rng);
        FlSession::new(
            server,
            Arc::new(Identity),
            AggregatorKind::UniformMean,
            carry,
            true,
            false,
        )
    }

    fn carried(born_round: usize, arrival_s: f64) -> CarriedUpdate {
        CarriedUpdate {
            client: 7,
            n_samples: 10,
            born_round,
            base_weight: 1.0,
            arrival_s,
            decoded: vec![0.0; 4],
        }
    }

    #[test]
    fn begin_round_expires_by_age() {
        let mut fl = session(CarryPolicy::CarryDiscounted {
            lambda: 0.5,
            max_age_rounds: 2,
        });
        let carry = CarryOver {
            updates: vec![carried(1, 0.5), carried(3, 0.5), carried(4, 0.5)],
        };
        let round = fl.begin_round(5, carry).unwrap();
        // ages 4, 2, 1 against max_age 2: the first expires
        assert_eq!(round.carried_pending(), 2);
        assert_eq!(round.expired(), 1);
    }

    #[test]
    fn discard_policy_drops_any_carry_over() {
        let mut fl = session(CarryPolicy::Discard);
        let carry = CarryOver {
            updates: vec![carried(1, 0.5)],
        };
        let round = fl.begin_round(2, carry).unwrap();
        assert_eq!(round.carried_pending(), 0);
        assert_eq!(round.expired(), 1);
    }

    #[test]
    fn carried_folds_under_every_policy_close_rule() {
        let mut fl = session(CarryPolicy::CarryDiscounted {
            lambda: 0.5,
            max_age_rounds: 3,
        });
        // an empty fastest-m round cannot close at its m-th arrival:
        // the carried upload is the only arrival and folds
        let round = fl
            .begin_round(
                2,
                CarryOver {
                    updates: vec![carried(1, 5.0)],
                },
            )
            .unwrap();
        let resolved = round.resolve(&RoundPolicy::FastestM { m: 3 });
        assert_eq!(resolved.carried_in(), 1);
        // a synchronous server waits for everything it knows is in
        // flight, however late
        let round = fl
            .begin_round(
                3,
                CarryOver {
                    updates: vec![carried(2, 123.0)],
                },
            )
            .unwrap();
        let resolved = round.resolve(&RoundPolicy::Synchronous);
        assert_eq!(resolved.carried_in(), 1);
    }

    #[test]
    fn carry_policy_labels() {
        assert_eq!(CarryPolicy::Discard.label(), "discard");
        assert!(!CarryPolicy::Discard.carries());
        let c = CarryPolicy::CarryDiscounted {
            lambda: 0.25,
            max_age_rounds: 3,
        };
        assert!(c.carries());
        assert!(c.label().contains("0.25"));
        assert!(c.label().contains('3'));
    }
}
