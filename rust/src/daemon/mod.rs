//! The campaign daemon: a resident scheduler that owns a queue of
//! experiment jobs and drives each campaign round by round, writing an
//! atomic [`snapshot::CampaignSnapshot`] after every `finalize` so a
//! crash — up to and including `SIGKILL` — loses at most the round in
//! flight (DESIGN.md §9).
//!
//! The shape is a classic two-actor daemon: the **scheduler**
//! ([`Daemon::run_queue`]) pops jobs off the queue and persists final
//! outputs, while a per-job **worker** thread owns the campaign state
//! (an in-process [`Simulation`] or a socket-driven
//! [`RoundServer`]) and reports progress over an event bus of
//! [`DaemonEvent`]s.  On restart the scheduler skips jobs whose
//! `<name>.model` output already exists and workers resume interrupted
//! campaigns from their `<name>.snap` file — fingerprint-checked, then
//! restored through the `Simulation::restore` / `RoundServer::restore`
//! seam — continuing at round `rounds_done + 1` bit-identically to a
//! run that was never interrupted (`tests/daemon_resume.rs`).
//!
//! State directory layout (all paths under the daemon's `dir`):
//!
//! | file            | meaning                                        |
//! |-----------------|------------------------------------------------|
//! | `<name>.snap`   | latest between-round snapshot (crash cursor)   |
//! | `<name>.model`  | final global model, raw little-endian f32 bits |
//! | `<name>.csv`    | per-round records seen by the finishing process|
//!
//! A resumed job's CSV covers the rounds the finishing process drove
//! (earlier rounds died with the killed process's memory); the model
//! file and snapshot chain are the bit-exact artifacts.

pub mod snapshot;

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Duration;

use self::snapshot::CampaignSnapshot;
use crate::compression::Scheme;
use crate::config::ExperimentConfig;
use crate::control::{CodecPolicy, ServerOptKind, ServerOptState};
use crate::coordinator::{CarryOver, Simulation};
use crate::error::{HcflError, Result};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::{Engine, Manifest};
use crate::transport::{demo_config, RoundServer};

/// How a job's rounds are driven.
#[derive(Debug, Clone, PartialEq)]
pub enum JobDriver {
    /// The in-process [`Simulation`] driver (no sockets).
    InProcess,
    /// A [`RoundServer`] bound to `addr`, serving `conns` swarm
    /// connections.  The swarm dials in from outside the daemon (give
    /// it a re-dial budget so it survives a daemon restart —
    /// [`crate::transport::SwarmOptions`]).
    Tcp {
        /// Listen address, e.g. `127.0.0.1:7700`.  Fixed per job so a
        /// resumed daemon rebinds the same port the swarm re-dials.
        addr: String,
        /// Swarm connections to accept before round 1 (and again after
        /// every resume).
        conns: usize,
    },
}

/// One queued experiment: a named, seeded, engine-free campaign.
/// The name keys every state file, so it must be unique in a queue.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Unique job name (state file stem).
    pub name: String,
    /// Compression scheme (engine-free: FedAvg, Top-K or ternary).
    pub scheme: Scheme,
    /// Fleet size (K).
    pub n_clients: usize,
    /// Campaign length in rounds.
    pub rounds: usize,
    /// Experiment seed.
    pub seed: u64,
    /// In-process or socket-driven.
    pub driver: JobDriver,
    /// Edge-aggregation shards E (0 = flat fold).  Bit-identical to the
    /// flat fold, so a snapshot taken under any E resumes under any
    /// other (DESIGN.md §10).
    pub edge_shards: usize,
    /// Per-client codec policy (`Static` keeps the single-scheme
    /// behavior; see [`CodecPolicy`]).
    pub policy: CodecPolicy,
    /// Server-side optimizer applied at the global-model install
    /// (DESIGN.md §11); part of the snapshot fingerprint.
    pub server_opt: ServerOptKind,
}

impl JobSpec {
    /// The job's full experiment configuration: the shared server/swarm
    /// demo recipe ([`demo_config`]), which both the worker and any
    /// external swarm rebuild from the same four values, plus the job's
    /// edge shard count.
    pub fn config(&self) -> ExperimentConfig {
        let mut cfg = demo_config(self.scheme, self.n_clients, self.rounds, self.seed);
        cfg.edge_shards = self.edge_shards;
        cfg.codec_policy = self.policy;
        cfg.server_opt = self.server_opt;
        cfg
    }
}

/// What a worker reports onto the scheduler's event bus.
#[derive(Debug)]
pub enum DaemonEvent {
    /// A round finalized; its snapshot is already on disk.
    RoundDone {
        /// Job name.
        job: String,
        /// The finalized round's record.
        record: RoundRecord,
    },
    /// The campaign completed; final state rides along for the
    /// scheduler to persist.
    JobDone {
        /// Job name.
        job: String,
        /// Records of every round this process drove.
        records: Vec<RoundRecord>,
        /// The final global model.
        global: Vec<f32>,
    },
    /// The worker gave up; the snapshot stays on disk for a later
    /// resume.
    JobFailed {
        /// Job name.
        job: String,
        /// Rendered error.
        error: String,
    },
}

/// Parse a queue file: one job per line,
/// `name scheme clients rounds seed driver [addr conns] [edge=<E>]
/// [policy=<p>] [opt=<o>]`, where `scheme` is `fedavg`, `topk@<keep>`
/// or `ternary`, `driver` is `inproc` or `tcp <addr> <conns>`, and the
/// optional trailing tokens (any order) enable `E`-way edge-sharded
/// aggregation, a per-client codec policy
/// ([`CodecPolicy::parse`], e.g. `policy=uplink@0.5:ternary`) and a
/// server optimizer ([`ServerOptKind::parse`], e.g. `opt=fedadam`).
/// `#` starts a comment; blank lines are skipped.
pub fn parse_queue(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs: Vec<JobSpec> = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let n = i + 1;
        let mut f: Vec<&str> = line.split_whitespace().collect();
        // The optional `key=value` tokens ride at the end of any driver
        // form, in any order; strip them before the positional match
        // below.
        let mut edge_shards = 0usize;
        let mut policy = CodecPolicy::Static;
        let mut server_opt = ServerOptKind::Sgd;
        while let Some(tok) = f.last().copied() {
            if let Some(e) = tok.strip_prefix("edge=") {
                edge_shards = e.parse().map_err(|_| {
                    HcflError::Config(format!("queue line {n}: bad edge shard count `{e}`"))
                })?;
            } else if let Some(p) = tok.strip_prefix("policy=") {
                policy = CodecPolicy::parse(p)
                    .map_err(|e| HcflError::Config(format!("queue line {n}: {e}")))?;
            } else if let Some(o) = tok.strip_prefix("opt=") {
                server_opt = ServerOptKind::parse(o)
                    .map_err(|e| HcflError::Config(format!("queue line {n}: {e}")))?;
            } else {
                break;
            }
            f.pop();
        }
        if f.len() < 6 {
            return Err(HcflError::Config(format!(
                "queue line {n}: expected `name scheme clients rounds seed driver [addr conns] \
                 [edge=<E>] [policy=<p>] [opt=<o>]`, got `{line}`"
            )));
        }
        let scheme = parse_job_scheme(f[1])
            .map_err(|e| HcflError::Config(format!("queue line {n}: {e}")))?;
        let n_clients: usize = f[2]
            .parse()
            .map_err(|_| HcflError::Config(format!("queue line {n}: bad clients `{}`", f[2])))?;
        let rounds: usize = f[3]
            .parse()
            .map_err(|_| HcflError::Config(format!("queue line {n}: bad rounds `{}`", f[3])))?;
        let seed: u64 = f[4]
            .parse()
            .map_err(|_| HcflError::Config(format!("queue line {n}: bad seed `{}`", f[4])))?;
        let driver = match (f[5], f.len()) {
            ("inproc", 6) => JobDriver::InProcess,
            ("tcp", 8) => JobDriver::Tcp {
                addr: f[6].to_string(),
                conns: f[7].parse().map_err(|_| {
                    HcflError::Config(format!("queue line {n}: bad conns `{}`", f[7]))
                })?,
            },
            _ => {
                return Err(HcflError::Config(format!(
                    "queue line {n}: driver must be `inproc` or `tcp <addr> <conns>`"
                )))
            }
        };
        if jobs.iter().any(|j| j.name == f[0]) {
            return Err(HcflError::Config(format!(
                "queue line {n}: duplicate job name `{}` (names key the state files)",
                f[0]
            )));
        }
        jobs.push(JobSpec {
            name: f[0].to_string(),
            scheme,
            n_clients,
            rounds,
            seed,
            driver,
            edge_shards,
            policy,
            server_opt,
        });
    }
    Ok(jobs)
}

fn parse_job_scheme(tok: &str) -> std::result::Result<Scheme, String> {
    if tok == "fedavg" {
        return Ok(Scheme::Fedavg);
    }
    if let Some(keep) = tok.strip_prefix("topk@") {
        let keep: f64 = keep
            .parse()
            .map_err(|_| format!("bad topk keep `{keep}`"))?;
        if !(keep > 0.0 && keep <= 1.0) {
            return Err(format!("topk keep must be in (0, 1], got {keep}"));
        }
        return Ok(Scheme::TopK { keep });
    }
    if tok == "ternary" {
        return Ok(Scheme::Ternary);
    }
    Err(format!(
        "scheme `{tok}` must be `fedavg`, `topk@<keep>` or `ternary` (the daemon is engine-free)"
    ))
}

/// The resident scheduler: owns the state directory and drives queued
/// jobs one at a time, each on its own worker thread.
pub struct Daemon {
    dir: PathBuf,
    round_hold: Duration,
    /// Print one line per event to stderr.
    pub verbose: bool,
}

impl Daemon {
    /// A daemon rooted at state directory `dir` (created on first run).
    pub fn new(dir: impl Into<PathBuf>) -> Daemon {
        Daemon {
            dir: dir.into(),
            round_hold: Duration::ZERO,
            verbose: false,
        }
    }

    /// Pause this long after each snapshot before opening the next
    /// round.  Zero (the default) runs flat out; CI's kill-and-resume
    /// smoke widens the between-round window with this so `SIGKILL`
    /// reliably lands between rounds.
    pub fn set_round_hold(&mut self, hold: Duration) {
        self.round_hold = hold;
    }

    /// The state directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn snap_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.snap"))
    }

    fn model_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.model"))
    }

    fn csv_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.csv"))
    }

    /// Drive every queued job to completion, in order.  Jobs whose
    /// model output already exists are skipped; jobs with a snapshot on
    /// disk resume from it.  The first failing job aborts the queue
    /// (its snapshot stays for the next invocation).
    pub fn run_queue(&self, jobs: &[JobSpec]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        for job in jobs {
            self.run_job(job)?;
        }
        Ok(())
    }

    /// Run (or resume, or skip) one job to completion.
    pub fn run_job(&self, job: &JobSpec) -> Result<()> {
        let model_path = self.model_path(&job.name);
        if model_path.exists() {
            if self.verbose {
                eprintln!("[daemon] {}: output exists, skipping", job.name);
            }
            return Ok(());
        }
        std::fs::create_dir_all(&self.dir)?;
        let (tx, rx) = mpsc::channel::<DaemonEvent>();
        let worker_job = job.clone();
        let snap_path = self.snap_path(&job.name);
        let hold = self.round_hold;
        let worker = std::thread::Builder::new()
            .name(format!("hcfl-job-{}", job.name))
            .spawn(move || {
                let res = job_worker(&worker_job, &snap_path, hold, &tx);
                if let Err(e) = &res {
                    let _ = tx.send(DaemonEvent::JobFailed {
                        job: worker_job.name.clone(),
                        error: e.to_string(),
                    });
                }
                res
            })
            .map_err(|e| HcflError::Engine(format!("job worker spawn failed: {e}")))?;

        let mut done: Option<(Vec<RoundRecord>, Vec<f32>)> = None;
        for ev in rx {
            match ev {
                DaemonEvent::RoundDone { job, record } => {
                    if self.verbose {
                        eprintln!(
                            "[daemon] {job}: round {} done ({}/{} agg, up {} B)",
                            record.round, record.completed, record.selected, record.up_bytes
                        );
                    }
                }
                DaemonEvent::JobDone {
                    job,
                    records,
                    global,
                } => {
                    if self.verbose {
                        eprintln!("[daemon] {job}: campaign complete ({} rounds)", records.len());
                    }
                    done = Some((records, global));
                }
                DaemonEvent::JobFailed { job, error } => {
                    if self.verbose {
                        eprintln!("[daemon] {job}: failed: {error}");
                    }
                }
            }
        }
        worker
            .join()
            .map_err(|_| HcflError::Engine("job worker panicked".into()))??;
        let (records, global) = done.ok_or_else(|| {
            HcflError::Engine("job worker exited without reporting JobDone".into())
        })?;

        // Persist outputs, then drop the snapshot: once the model file
        // exists the job is complete and restarts skip it.
        let report = RunReport {
            scheme: job.scheme.label(),
            model: "fake".into(),
            rounds: records,
        };
        report.write_csv(self.csv_path(&job.name))?;
        write_model_atomic(&model_path, &global)?;
        let _ = std::fs::remove_file(self.snap_path(&job.name));
        Ok(())
    }
}

/// Final-model file: raw little-endian f32 bit patterns, written with
/// the same tmp + rename rule as snapshots (its existence marks the
/// job complete, so it must never be observed torn).
fn write_model_atomic(path: &Path, global: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(4 * global.len());
    for v in global {
        bytes.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Freeze a campaign's cross-round state after round `rounds_done`.
fn freeze(
    cfg: &ExperimentConfig,
    rounds_done: usize,
    rng: [u64; 4],
    global: &[f32],
    carry: &CarryOver,
    opt: &ServerOptState,
) -> CampaignSnapshot {
    CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: global.len() as u64,
        rounds_done: rounds_done as u64,
        rng,
        global: global.to_vec(),
        carry: carry.clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: opt.m.clone(),
        opt_v: opt.v.clone(),
    }
}

/// The worker half of the bus: drive one campaign round by round,
/// snapshotting after every `finalize`.
fn job_worker(
    job: &JobSpec,
    snap_path: &Path,
    hold: Duration,
    tx: &mpsc::Sender<DaemonEvent>,
) -> Result<()> {
    let cfg = job.config();
    match &job.driver {
        JobDriver::InProcess => {
            let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers)?;
            let mut sim = Simulation::new(&engine, cfg.clone())?;
            let mut start = 1usize;
            if snap_path.exists() {
                let snap = CampaignSnapshot::load(snap_path)?;
                snap.check(&cfg, sim.global().len())?;
                if snap.rounds_done > cfg.rounds as u64 {
                    return Err(HcflError::Snapshot(format!(
                        "snapshot is {} rounds into a {}-round campaign",
                        snap.rounds_done, cfg.rounds
                    )));
                }
                start = snap.rounds_done as usize + 1;
                sim.restore(
                    snap.global,
                    snap.carry,
                    snap.rng,
                    ServerOptState {
                        m: snap.opt_m,
                        v: snap.opt_v,
                    },
                )?;
            }
            let mut records = Vec::with_capacity(cfg.rounds + 1 - start);
            for t in start..=cfg.rounds {
                let rec = sim.run_round(t)?;
                freeze(
                    &cfg,
                    t,
                    sim.rng_state(),
                    sim.global(),
                    sim.carry(),
                    sim.opt_state(),
                )
                .write_atomic(snap_path)?;
                let _ = tx.send(DaemonEvent::RoundDone {
                    job: job.name.clone(),
                    record: rec.clone(),
                });
                records.push(rec);
                if t < cfg.rounds && !hold.is_zero() {
                    std::thread::sleep(hold);
                }
            }
            let _ = tx.send(DaemonEvent::JobDone {
                job: job.name.clone(),
                records,
                global: sim.global().to_vec(),
            });
            Ok(())
        }
        JobDriver::Tcp { addr, conns } => {
            let manifest = Manifest::synthetic();
            let mut server = RoundServer::new(&manifest, cfg.clone())?;
            let mut start = 1usize;
            if snap_path.exists() {
                let snap = CampaignSnapshot::load(snap_path)?;
                snap.check(&cfg, server.global().len())?;
                if snap.rounds_done > cfg.rounds as u64 {
                    return Err(HcflError::Snapshot(format!(
                        "snapshot is {} rounds into a {}-round campaign",
                        snap.rounds_done, cfg.rounds
                    )));
                }
                start = snap.rounds_done as usize + 1;
                server.restore(
                    snap.global,
                    snap.carry,
                    snap.rng,
                    ServerOptState {
                        m: snap.opt_m,
                        v: snap.opt_v,
                    },
                )?;
            }
            let listener = TcpListener::bind(addr.as_str())?;
            let mut link = server.accept_swarm(&listener, *conns)?;
            let mut records = Vec::with_capacity(cfg.rounds + 1 - start);
            for t in start..=cfg.rounds {
                let rec = server.serve_round(&mut link, t)?;
                freeze(
                    &cfg,
                    t,
                    server.rng_state(),
                    server.global(),
                    server.carry(),
                    server.opt_state(),
                )
                .write_atomic(snap_path)?;
                let _ = tx.send(DaemonEvent::RoundDone {
                    job: job.name.clone(),
                    record: rec.clone(),
                });
                records.push(rec);
                if t < cfg.rounds && !hold.is_zero() {
                    std::thread::sleep(hold);
                }
            }
            server.finish(link, cfg.rounds);
            let _ = tx.send(DaemonEvent::JobDone {
                job: job.name.clone(),
                records,
                global: server.global().to_vec(),
            });
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_parses_both_drivers_and_comments() {
        let text = "\
# campaign queue
alpha fedavg 32 4 7 inproc
beta topk@0.1 64 3 11 tcp 127.0.0.1:7700 4  # socket job
gamma topk@0.2 128 2 5 inproc edge=4
delta fedavg 64 2 9 tcp 127.0.0.1:7701 2 edge=16
eps ternary 16 2 3 inproc policy=uplink@0.5 opt=fedadam
zeta fedavg 32 2 5 tcp 127.0.0.1:7702 2 opt=fedavgm edge=8 policy=makespan@0.4
";
        let jobs = parse_queue(text).unwrap();
        assert_eq!(jobs.len(), 6);
        assert_eq!(jobs[0].name, "alpha");
        assert_eq!(jobs[0].scheme, Scheme::Fedavg);
        assert_eq!(jobs[0].driver, JobDriver::InProcess);
        assert_eq!(jobs[0].edge_shards, 0);
        assert_eq!(jobs[1].scheme, Scheme::TopK { keep: 0.1 });
        assert_eq!(
            jobs[1].driver,
            JobDriver::Tcp {
                addr: "127.0.0.1:7700".into(),
                conns: 4
            }
        );
        assert_eq!(jobs[1].rounds, 3);
        assert_eq!(jobs[1].seed, 11);
        assert_eq!(jobs[1].edge_shards, 0);
        assert_eq!(jobs[2].driver, JobDriver::InProcess);
        assert_eq!(jobs[2].edge_shards, 4);
        assert_eq!(jobs[2].config().edge_shards, 4);
        assert_eq!(jobs[3].edge_shards, 16);
        assert_eq!(
            jobs[3].driver,
            JobDriver::Tcp {
                addr: "127.0.0.1:7701".into(),
                conns: 2
            }
        );
        assert_eq!(jobs[3].policy, CodecPolicy::Static);
        assert_eq!(jobs[3].server_opt, ServerOptKind::Sgd);
        assert_eq!(jobs[4].scheme, Scheme::Ternary);
        assert_eq!(
            jobs[4].policy,
            CodecPolicy::ThresholdByUplink {
                cutoff: 0.5,
                slow: Scheme::Ternary
            }
        );
        assert_eq!(jobs[4].server_opt, ServerOptKind::DEFAULT_ADAM);
        assert_eq!(jobs[4].config().codec_policy, jobs[4].policy);
        // trailing key=value tokens parse in any order
        assert_eq!(jobs[5].edge_shards, 8);
        assert_eq!(
            jobs[5].policy,
            CodecPolicy::MakespanUnderDistortion {
                budget: 0.4,
                heavy: Scheme::Ternary
            }
        );
        assert_eq!(
            jobs[5].server_opt,
            ServerOptKind::FedAvgM {
                beta: ServerOptKind::DEFAULT_BETA
            }
        );
    }

    #[test]
    fn queue_rejects_malformed_lines() {
        for bad in [
            "x fedavg 32 4",                       // too few fields
            "x hcfl@8 32 4 7 inproc",              // engine-bound scheme
            "x topk@0 32 4 7 inproc",              // keep out of range
            "x fedavg 32 4 7 warp",                // unknown driver
            "x fedavg 32 4 7 tcp 127.0.0.1:7700",  // tcp missing conns
            "x fedavg 32 4 7 inproc extra",        // trailing field
            "x fedavg 32 4 7 inproc edge=zap",     // bad edge count
            "x fedavg 32 4 7 edge=4",              // edge cannot replace driver
            "x fedavg 32 4 7 inproc policy=warp",  // unknown policy
            "x fedavg 32 4 7 inproc opt=warp",     // unknown optimizer
            "x fedavg 32 4 7 policy=static opt=sgd", // tokens cannot replace driver
            "a fedavg 32 4 7 inproc\na fedavg 8 2 9 inproc", // dup name
        ] {
            assert!(parse_queue(bad).is_err(), "accepted: {bad}");
        }
    }
}
