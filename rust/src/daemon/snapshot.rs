//! Atomic campaign snapshots: the crash-tolerance substrate of the
//! daemon (DESIGN.md §9).
//!
//! A snapshot captures, after some round's `finalize`, the complete
//! cross-round state of a campaign — everything a resumed driver needs
//! to continue bit-identically:
//!
//! * the global model's f32 bit patterns,
//! * the in-flight [`CarryOver`] entries,
//! * the index of the last finalized round,
//! * the selection-RNG cursor ([`crate::util::rng::Rng::state`]),
//! * the server optimizer's moment vectors
//!   ([`crate::control::ServerOptState`], version 2).
//!
//! Everything else a round touches (dropout streams, work seeds, the
//! timing model) is a pure function of `(cfg.seed, t)` and needs no
//! persistence.  The byte layout is hand-rolled little-endian
//! plain-struct serialization — no serde, per the crate's zero-dep
//! rule — with a leading magic/version/fingerprint and a trailing
//! CRC-32 ([`crate::compression::wire::crc32`]).  Decoding is
//! all-or-nothing: any truncation, corruption or fingerprint mismatch
//! yields [`HcflError::Snapshot`] and no state is touched.
//!
//! Writes are atomic on POSIX filesystems: the encoding is written and
//! fsynced to a sibling `<path>.tmp`, then `rename(2)`d over the real
//! path, so a reader (including a resumed daemon) only ever observes
//! either the previous complete snapshot or the new one — never a
//! torn write.

use std::path::{Path, PathBuf};

use crate::compression::wire::crc32;
use crate::config::ExperimentConfig;
use crate::coordinator::session::CarriedUpdate;
use crate::coordinator::CarryOver;
use crate::error::{HcflError, Result};

/// A campaign's complete cross-round state, frozen between rounds.
///
/// `seed`, `codec`, `n_clients` and `d` are the config fingerprint: a
/// snapshot only restores into a campaign whose configuration derives
/// the very same per-round streams (see [`CampaignSnapshot::check`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSnapshot {
    /// The experiment seed every stream derives from.
    pub seed: u64,
    /// The scheme's wire codec tag (`Scheme::codec_tag`).
    pub codec: u8,
    /// Fleet size (K).
    pub n_clients: u64,
    /// Model dimensionality.
    pub d: u64,
    /// Rounds finalized before this snapshot; the resume point is
    /// `rounds_done + 1`.
    pub rounds_done: u64,
    /// The selection-RNG cursor after `rounds_done` rounds.
    pub rng: [u64; 4],
    /// The global model after `rounds_done` rounds.
    pub global: Vec<f32>,
    /// Late updates in flight toward round `rounds_done + 1`.
    pub carry: CarryOver,
    /// The server optimizer's tag
    /// ([`crate::control::ServerOptKind::tag`]); part of the
    /// fingerprint.  Version-1 snapshots decode as 0 (`Sgd`).
    pub opt_tag: u8,
    /// The optimizer's first-moment vector after `rounds_done` rounds
    /// (empty for `Sgd`, or before the first optimizer step).
    pub opt_m: Vec<f32>,
    /// The optimizer's second-moment vector (FedAdam only).
    pub opt_v: Vec<f32>,
}

/// Leading magic: "HSNP" (Hcfl SNaPshot).
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"HSNP";
/// Format version; bumped on any layout change.  Version 2 appends the
/// server-optimizer block (tag + moment vectors) after the carry
/// entries; version-1 snapshots still decode, as plain-SGD state.
pub const SNAPSHOT_VERSION: u32 = 2;

/// Fixed-size prefix: magic, version, fingerprint, round index, RNG
/// cursor, global length — the minimum a well-formed snapshot can be
/// (plus the carry count and trailing CRC).  Kept at the version-1
/// floor so old snapshots pass the length gate.
const FIXED_LEN: usize = 4 + 4 + 8 + 1 + 8 + 8 + 8 + 32 + 8 + 8 + 4;

fn snap_err(what: &str) -> HcflError {
    HcflError::Snapshot(what.to_string())
}

/// Little-endian cursor over a CRC-verified body.
struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.off < n {
            return Err(snap_err("snapshot body shorter than its own counts"));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn f64_bits(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn finish(&self) -> Result<()> {
        if self.off != self.buf.len() {
            return Err(snap_err("trailing bytes after snapshot payload"));
        }
        Ok(())
    }
}

impl CampaignSnapshot {
    /// Serialize to the normative §9 byte layout (CRC included).
    pub fn encode(&self) -> Vec<u8> {
        let carry_f32s: usize = self.carry.updates.iter().map(|u| u.decoded.len()).sum();
        let mut out = Vec::with_capacity(
            FIXED_LEN
                + 4 * self.global.len()
                + 48 * self.carry.updates.len()
                + 4 * carry_f32s
                + 17
                + 4 * (self.opt_m.len() + self.opt_v.len()),
        );
        out.extend_from_slice(&SNAPSHOT_MAGIC);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.push(self.codec);
        out.extend_from_slice(&self.n_clients.to_le_bytes());
        out.extend_from_slice(&self.d.to_le_bytes());
        out.extend_from_slice(&self.rounds_done.to_le_bytes());
        for w in self.rng {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&(self.global.len() as u64).to_le_bytes());
        for v in &self.global {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        out.extend_from_slice(&(self.carry.updates.len() as u64).to_le_bytes());
        for u in &self.carry.updates {
            out.extend_from_slice(&(u.client as u64).to_le_bytes());
            out.extend_from_slice(&(u.n_samples as u64).to_le_bytes());
            out.extend_from_slice(&(u.born_round as u64).to_le_bytes());
            out.extend_from_slice(&u.base_weight.to_bits().to_le_bytes());
            out.extend_from_slice(&u.arrival_s.to_bits().to_le_bytes());
            out.extend_from_slice(&(u.decoded.len() as u64).to_le_bytes());
            for v in &u.decoded {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        out.push(self.opt_tag);
        out.extend_from_slice(&(self.opt_m.len() as u64).to_le_bytes());
        for v in &self.opt_m {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        out.extend_from_slice(&(self.opt_v.len() as u64).to_le_bytes());
        for v in &self.opt_v {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and verify a snapshot.  All-or-nothing: short input, bad
    /// magic, unknown version, CRC mismatch and trailing garbage all
    /// return [`HcflError::Snapshot`] without producing a value.
    pub fn decode(bytes: &[u8]) -> Result<CampaignSnapshot> {
        if bytes.len() < FIXED_LEN {
            return Err(snap_err("snapshot truncated"));
        }
        if bytes[..4] != SNAPSHOT_MAGIC {
            return Err(snap_err("bad snapshot magic"));
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != 1 && version != SNAPSHOT_VERSION {
            return Err(HcflError::Snapshot(format!(
                "unsupported snapshot version {version} (want 1..={SNAPSHOT_VERSION})"
            )));
        }
        // Verify the checksum before trusting any embedded count, so a
        // corrupt length can never drive a bogus allocation or a
        // partial parse.
        let (body, tail) = bytes.split_at(bytes.len() - 4);
        let want = u32::from_le_bytes(tail.try_into().unwrap());
        if crc32(body) != want {
            return Err(snap_err("snapshot checksum mismatch"));
        }
        let mut r = Reader { buf: body, off: 8 };
        let seed = r.u64()?;
        let codec = r.u8()?;
        let n_clients = r.u64()?;
        let d = r.u64()?;
        let rounds_done = r.u64()?;
        let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let n_global = r.u64()? as usize;
        let global = r.f32s(n_global)?;
        let n_carry = r.u64()? as usize;
        let mut updates = Vec::with_capacity(n_carry.min(1 << 20));
        for _ in 0..n_carry {
            let client = r.u64()? as usize;
            let n_samples = r.u64()? as usize;
            let born_round = r.u64()? as usize;
            let base_weight = r.f64_bits()?;
            let arrival_s = r.f64_bits()?;
            let n_decoded = r.u64()? as usize;
            let decoded = r.f32s(n_decoded)?;
            updates.push(CarriedUpdate {
                client,
                n_samples,
                born_round,
                base_weight,
                arrival_s,
                decoded,
            });
        }
        // Version-1 snapshots predate the server optimizer: they resume
        // as plain SGD with no accumulated moments.
        let (opt_tag, opt_m, opt_v) = if version >= 2 {
            let tag = r.u8()?;
            let n_m = r.u64()? as usize;
            let m = r.f32s(n_m)?;
            let n_v = r.u64()? as usize;
            let v = r.f32s(n_v)?;
            (tag, m, v)
        } else {
            (0, Vec::new(), Vec::new())
        };
        r.finish()?;
        Ok(CampaignSnapshot {
            seed,
            codec,
            n_clients,
            d,
            rounds_done,
            rng,
            global,
            carry: CarryOver { updates },
            opt_tag,
            opt_m,
            opt_v,
        })
    }

    /// Verify the fingerprint against the campaign about to resume: the
    /// seed, codec, fleet size and model dimensionality must all match,
    /// or the restored streams would silently diverge from the
    /// interrupted run.
    pub fn check(&self, cfg: &ExperimentConfig, d: usize) -> Result<()> {
        if self.seed != cfg.seed
            || self.codec != cfg.scheme.codec_tag()
            || self.n_clients != cfg.n_clients as u64
            || self.d != d as u64
            || self.opt_tag != cfg.server_opt.tag()
        {
            return Err(HcflError::Snapshot(format!(
                "snapshot fingerprint mismatch: snapshot (seed {}, codec {}, K {}, d {}, opt {}) \
                 vs campaign (seed {}, codec {}, K {}, d {}, opt {})",
                self.seed,
                self.codec,
                self.n_clients,
                self.d,
                self.opt_tag,
                cfg.seed,
                cfg.scheme.codec_tag(),
                cfg.n_clients,
                d,
                cfg.server_opt.tag()
            )));
        }
        if self.global.len() as u64 != self.d {
            return Err(snap_err("snapshot global length disagrees with its own d"));
        }
        Ok(())
    }

    /// Write the snapshot atomically: encode, write + fsync a sibling
    /// `<path>.tmp`, then rename over `path`.  A crash at any point
    /// leaves either the previous snapshot or this one — never a torn
    /// file.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        let bytes = self.encode();
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read and decode a snapshot file.
    pub fn load(path: &Path) -> Result<CampaignSnapshot> {
        let bytes = std::fs::read(path)?;
        CampaignSnapshot::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CampaignSnapshot {
        CampaignSnapshot {
            seed: 42,
            codec: 1,
            n_clients: 64,
            d: 4,
            rounds_done: 3,
            rng: [1, 2, 3, 4],
            global: vec![0.5, -1.25, f32::from_bits(0x7F80_0001), 0.0],
            carry: CarryOver {
                updates: vec![CarriedUpdate {
                    client: 9,
                    n_samples: 57,
                    born_round: 2,
                    base_weight: 0.75,
                    arrival_s: -1.5,
                    decoded: vec![1.0, 2.0, 3.0, 4.0],
                }],
            },
            opt_tag: 2,
            opt_m: vec![0.125, -0.5, 0.0, 2.0],
            opt_v: vec![0.25, 0.0625, 0.0, 4.0],
        }
    }

    #[test]
    fn encode_decode_round_trips_bit_exact() {
        let snap = sample();
        let bytes = snap.encode();
        let back = CampaignSnapshot::decode(&bytes).unwrap();
        // PartialEq on f32 vecs compares values; the NaN payload above
        // needs a bit-level check too.
        assert_eq!(back.rng, snap.rng);
        assert_eq!(back.rounds_done, snap.rounds_done);
        assert_eq!(
            back.global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            snap.global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
        assert_eq!(back.carry.updates.len(), 1);
        assert_eq!(back.carry.updates[0].decoded, snap.carry.updates[0].decoded);
        assert_eq!(back.carry.updates[0].base_weight, 0.75);
        assert_eq!(back.opt_tag, snap.opt_tag);
        assert_eq!(back.opt_m, snap.opt_m);
        assert_eq!(back.opt_v, snap.opt_v);
    }

    #[test]
    fn version_1_snapshots_still_load_as_plain_sgd() {
        let mut snap = sample();
        snap.opt_tag = 0;
        snap.opt_m.clear();
        snap.opt_v.clear();
        let v2 = snap.encode();
        // A real v1 file is the v2 body minus the optimizer block (tag
        // byte + two zero-length u64s = 17 bytes), stamped version 1.
        let mut v1 = v2[..v2.len() - 4 - 17].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        let crc = crc32(&v1);
        v1.extend_from_slice(&crc.to_le_bytes());
        let back = CampaignSnapshot::decode(&v1).unwrap();
        assert_eq!(back.rounds_done, snap.rounds_done);
        assert_eq!(back.carry.updates.len(), 1);
        assert_eq!(back.opt_tag, 0);
        assert!(back.opt_m.is_empty() && back.opt_v.is_empty());
    }

    #[test]
    fn truncation_and_corruption_are_typed_errors() {
        let bytes = sample().encode();
        // every possible truncation point
        for cut in 0..bytes.len() {
            let err = CampaignSnapshot::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, HcflError::Snapshot(_)),
                "cut {cut}: {err}"
            );
        }
        // every single-byte corruption (skip none: magic, version,
        // counts, payload and CRC must all be caught)
        for i in 0..bytes.len() {
            let mut evil = bytes.clone();
            evil[i] ^= 0xFF;
            let err = CampaignSnapshot::decode(&evil).unwrap_err();
            assert!(matches!(err, HcflError::Snapshot(_)), "byte {i}: {err}");
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(
            CampaignSnapshot::decode(&long).unwrap_err(),
            HcflError::Snapshot(_)
        ));
    }

    #[test]
    fn atomic_write_then_load() {
        let dir = std::env::temp_dir().join(format!("hcfl-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.snap");
        let snap = sample();
        snap.write_atomic(&path).unwrap();
        // overwrite with a later snapshot: rename replaces in place
        let mut later = snap.clone();
        later.rounds_done = 4;
        later.write_atomic(&path).unwrap();
        let back = CampaignSnapshot::load(&path).unwrap();
        assert_eq!(back.rounds_done, 4);
        assert!(!path.with_extension("snap.tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
