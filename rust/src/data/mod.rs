//! Synthetic datasets standing in for MNIST / EMNIST (DESIGN.md §4).
//!
//! No network access is available, so we generate deterministic 28x28
//! grayscale class-conditional images: each class owns a procedural
//! template of oriented strokes (drawn from a class-seeded PRNG) and each
//! sample perturbs the template with translation, per-stroke jitter and
//! pixel noise.  The result is an IID, easily-learnable-but-not-trivial
//! classification task with exactly the tensor shapes of the paper's
//! datasets — which is all the paper's evaluation uses them for.

mod synth;

pub use synth::{render_sample, ClassTemplate};

use crate::error::{HcflError, Result};
use crate::util::rng::Rng;

pub const IMG_SIDE: usize = 28;
pub const IMG_DIM: usize = IMG_SIDE * IMG_SIDE;

/// A labelled dataset (row-major images, one label per row).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn empty(dim: usize, classes: usize) -> Dataset {
        Dataset {
            x: Vec::new(),
            y: Vec::new(),
            n: 0,
            dim,
            classes,
        }
    }

    /// Gather rows `idx` into a dense (x, y) batch.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Split into `n_batches` contiguous batches of exactly `batch` rows
    /// after a seeded shuffle (rows beyond `n_batches * batch` are unused
    /// that epoch, matching FedAvg's per-round subsampling).
    pub fn epoch_batches(
        &self,
        batch: usize,
        n_batches: usize,
        rng: &mut Rng,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let need = batch * n_batches;
        if need > self.n {
            return Err(HcflError::Data(format!(
                "epoch needs {need} rows, shard has {}",
                self.n
            )));
        }
        let mut idx: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut idx);
        idx.truncate(need);
        Ok(self.gather(&idx))
    }
}

/// Specification of a synthetic federated dataset.
#[derive(Debug, Clone)]
pub struct DataSpec {
    pub classes: usize,
    pub n_clients: usize,
    /// Samples per client shard (600 for "MNIST", 1128 for "EMNIST").
    pub per_client: usize,
    /// Held-out test set size (multiple of the eval batch).
    pub test_n: usize,
    /// Small server-side dataset for HCFL pre-model training (§III-D).
    pub server_n: usize,
}

impl DataSpec {
    /// Synthetic MNIST geometry (paper §VI-A).
    pub fn mnist(n_clients: usize) -> DataSpec {
        DataSpec {
            classes: 10,
            n_clients,
            per_client: 600,
            test_n: 1024,
            server_n: 600,
        }
    }

    /// Synthetic EMNIST-47 geometry (paper §VI-A).
    pub fn emnist(n_clients: usize) -> DataSpec {
        DataSpec {
            classes: 47,
            n_clients,
            per_client: 1128,
            test_n: 1024,
            server_n: 1128,
        }
    }
}

/// The full federated data layout: IID client shards + test + server set.
#[derive(Debug, Clone)]
pub struct FlData {
    pub shards: Vec<Dataset>,
    pub test: Dataset,
    pub server: Dataset,
    pub spec: DataSpec,
}

/// Generate the synthetic federated dataset.  Every shard is IID: samples
/// are drawn from the same class-template distribution with a per-shard
/// RNG stream (paper §II-A assumes IID clients).
pub fn synthetic(spec: &DataSpec, seed: u64) -> FlData {
    let mut root = Rng::new(seed ^ 0x5EED_DA7A);
    let templates: Vec<ClassTemplate> = (0..spec.classes)
        .map(|c| ClassTemplate::new(seed, c))
        .collect();

    let make_set = |n: usize, rng: &mut Rng| -> Dataset {
        let mut x = Vec::with_capacity(n * IMG_DIM);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(spec.classes);
            let img = render_sample(&templates[c], rng);
            x.extend_from_slice(&img);
            y.push(c as i32);
        }
        Dataset {
            x,
            y,
            n,
            dim: IMG_DIM,
            classes: spec.classes,
        }
    };

    let shards = (0..spec.n_clients)
        .map(|k| {
            let mut rng = root.fork(k as u64 + 1);
            make_set(spec.per_client, &mut rng)
        })
        .collect();
    let mut test_rng = root.fork(0xABCD);
    let test = make_set(spec.test_n, &mut test_rng);
    let mut server_rng = root.fork(0xFEED);
    let server = make_set(spec.server_n, &mut server_rng);

    FlData {
        shards,
        test,
        server,
        spec: spec.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = DataSpec {
            classes: 10,
            n_clients: 3,
            per_client: 32,
            test_n: 16,
            server_n: 8,
        };
        let a = synthetic(&spec, 42);
        let b = synthetic(&spec, 42);
        let c = synthetic(&spec, 43);
        assert_eq!(a.shards.len(), 3);
        assert_eq!(a.shards[0].n, 32);
        assert_eq!(a.shards[0].x.len(), 32 * IMG_DIM);
        assert_eq!(a.test.n, 16);
        assert_eq!(a.shards[1].x, b.shards[1].x);
        assert_ne!(a.shards[1].x, c.shards[1].x);
        // shards differ from each other
        assert_ne!(a.shards[0].x, a.shards[1].x);
    }

    #[test]
    fn pixel_range_and_label_range() {
        let spec = DataSpec {
            classes: 47,
            n_clients: 1,
            per_client: 64,
            test_n: 8,
            server_n: 8,
        };
        let d = synthetic(&spec, 7);
        let shard = &d.shards[0];
        assert!(shard.x.iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!(shard.y.iter().all(|&c| (0..47).contains(&c)));
        // more than one class present
        let mut seen = shard.y.clone();
        seen.sort_unstable();
        seen.dedup();
        assert!(seen.len() > 5);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class pixel distance must be well below inter-class
        // distance, otherwise the task is unlearnable.
        let t0 = ClassTemplate::new(1, 0);
        let t1 = ClassTemplate::new(1, 1);
        let mut rng = Rng::new(9);
        let a0 = render_sample(&t0, &mut rng);
        let b0 = render_sample(&t0, &mut rng);
        let a1 = render_sample(&t1, &mut rng);
        let dist = |u: &[f32], v: &[f32]| -> f32 {
            u.iter().zip(v).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        assert!(dist(&a0, &b0) < dist(&a0, &a1), "intra >= inter class distance");
    }

    #[test]
    fn gather_and_epoch_batches() {
        let spec = DataSpec {
            classes: 10,
            n_clients: 1,
            per_client: 40,
            test_n: 8,
            server_n: 8,
        };
        let d = synthetic(&spec, 3);
        let shard = &d.shards[0];
        let (x, y) = shard.gather(&[0, 5, 7]);
        assert_eq!(x.len(), 3 * IMG_DIM);
        assert_eq!(y.len(), 3);

        let mut rng = Rng::new(1);
        let (ex, ey) = shard.epoch_batches(8, 4, &mut rng).unwrap();
        assert_eq!(ex.len(), 32 * IMG_DIM);
        assert_eq!(ey.len(), 32);
        // too-large epoch is rejected
        assert!(shard.epoch_batches(8, 6, &mut rng).is_err());
    }
}
