//! Synthetic datasets standing in for MNIST / EMNIST (DESIGN.md §4).
//!
//! No network access is available, so we generate deterministic 28x28
//! grayscale class-conditional images: each class owns a procedural
//! template of oriented strokes (drawn from a class-seeded PRNG) and each
//! sample perturbs the template with translation, per-stroke jitter and
//! pixel noise.  The result is an easily-learnable-but-not-trivial
//! classification task with exactly the tensor shapes of the paper's
//! datasets — which is all the paper's evaluation uses them for.
//!
//! How samples distribute over clients is the [`Partition`] layer's job:
//! IID (paper §II-A, the default), McMahan-style label shards, or
//! Dirichlet class proportions.  Shards can be materialized up front
//! (`Eager`, small K) or regenerated per access from per-shard seeds
//! (`Lazy`, the K=10k regime — an eager MNIST-geometry fleet at K=10k
//! would hold ~19 GB of pixels).  Both modes are bit-identical.

mod partition;
mod synth;

pub use partition::{label_entropy, Partition};
pub use synth::{render_sample, ClassTemplate};

use std::borrow::Cow;
use std::sync::Arc;

use crate::error::{HcflError, Result};
use crate::util::rng::Rng;

pub const IMG_SIDE: usize = 28;
pub const IMG_DIM: usize = IMG_SIDE * IMG_SIDE;

/// A labelled dataset (row-major images, one label per row).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn empty(dim: usize, classes: usize) -> Dataset {
        Dataset {
            x: Vec::new(),
            y: Vec::new(),
            n: 0,
            dim,
            classes,
        }
    }

    /// Gather rows `idx` into a dense (x, y) batch.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Split into `n_batches` contiguous batches of exactly `batch` rows
    /// after a seeded shuffle (rows beyond `n_batches * batch` are unused
    /// that epoch, matching FedAvg's per-round subsampling).
    pub fn epoch_batches(
        &self,
        batch: usize,
        n_batches: usize,
        rng: &mut Rng,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let need = batch * n_batches;
        if need > self.n {
            return Err(HcflError::Data(format!(
                "epoch needs {need} rows, shard has {}",
                self.n
            )));
        }
        let mut idx: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut idx);
        idx.truncate(need);
        Ok(self.gather(&idx))
    }
}

/// Specification of a synthetic federated dataset.
#[derive(Debug, Clone)]
pub struct DataSpec {
    pub classes: usize,
    pub n_clients: usize,
    /// Samples per client shard (600 for "MNIST", 1128 for "EMNIST").
    pub per_client: usize,
    /// Held-out test set size (multiple of the eval batch).
    pub test_n: usize,
    /// Small server-side dataset for HCFL pre-model training (§III-D).
    pub server_n: usize,
    /// How client shards relate to the global label distribution.
    pub partition: Partition,
    /// Shard-size heterogeneity in [0, 0.5]: client `k` holds a share of
    /// the total sample budget proportional to `1 + size_skew · u_k`
    /// with seeded `u_k ~ U[-1, 1)`, apportioned by largest remainder so
    /// the total is conserved exactly (`n_clients · per_client` rows).
    /// 0 (default) keeps every shard at exactly `per_client` rows — with
    /// equal shards, `SampleWeighted` aggregation degenerates to the
    /// uniform mean, so the non-IID arms set this to see the difference.
    pub size_skew: f64,
    /// Regenerate shards on demand from per-shard seeds instead of
    /// materializing all of them up front.  Mandatory at the K=10k
    /// regime; bit-identical to eager generation.
    pub lazy_shards: bool,
}

impl DataSpec {
    /// Synthetic MNIST geometry (paper §VI-A).
    pub fn mnist(n_clients: usize) -> DataSpec {
        DataSpec {
            classes: 10,
            n_clients,
            per_client: 600,
            test_n: 1024,
            server_n: 600,
            partition: Partition::Iid,
            size_skew: 0.0,
            lazy_shards: false,
        }
    }

    /// Synthetic EMNIST-47 geometry (paper §VI-A).
    pub fn emnist(n_clients: usize) -> DataSpec {
        DataSpec {
            classes: 47,
            n_clients,
            per_client: 1128,
            test_n: 1024,
            server_n: 1128,
            partition: Partition::Iid,
            size_skew: 0.0,
            lazy_shards: false,
        }
    }
}

/// The full federated data layout: client shards (eager or lazy) plus
/// the IID test and server sets.
#[derive(Debug, Clone)]
pub struct FlData {
    shards: ShardSource,
    pub test: Dataset,
    pub server: Dataset,
    pub spec: DataSpec,
}

#[derive(Debug, Clone)]
enum ShardSource {
    /// All shards materialized (laptop-scale K).
    Eager(Vec<Dataset>),
    /// Shards rebuilt per access from per-shard seeds (the K=10k regime).
    Lazy(ShardGen),
}

/// Deterministic per-shard generator: everything needed to rebuild any
/// client's shard in isolation, bit-identical to eager generation.
#[derive(Debug, Clone)]
struct ShardGen {
    templates: Arc<Vec<ClassTemplate>>,
    partition: Partition,
    classes: usize,
    /// Per-shard row counts (all equal to `per_client` unless
    /// `size_skew` > 0; total always `n_clients * per_client`).
    sizes: Arc<Vec<usize>>,
    /// Per-shard RNG seeds, precomputed so shard `k` never depends on
    /// generating shards `0..k` first.
    seeds: Arc<Vec<u64>>,
}

impl ShardGen {
    fn generate(&self, k: usize) -> Dataset {
        let mut rng = Rng::new(self.seeds[k]);
        generate_shard(
            &self.partition,
            &self.templates,
            self.classes,
            self.sizes[k],
            &mut rng,
        )
    }
}

/// Apportion the total sample budget over clients: equal shards for
/// `size_skew == 0`, otherwise largest-remainder rounding of seeded
/// weights `1 + size_skew · U[-1, 1)` — the total is conserved exactly
/// and the draw comes from its own stream, so shard seeds, templates and
/// the test/server sets never move when the skew changes.
fn shard_sizes(spec: &DataSpec, seed: u64) -> Vec<usize> {
    let total = spec.n_clients * spec.per_client;
    if spec.size_skew == 0.0 || spec.n_clients == 0 {
        return vec![spec.per_client; spec.n_clients];
    }
    let mut rng = Rng::new(seed ^ 0x517E_0F5E_ED00_0001);
    let weights: Vec<f64> = (0..spec.n_clients)
        .map(|_| 1.0 + spec.size_skew * (2.0 * rng.next_f64() - 1.0))
        .collect();
    let weight_sum: f64 = weights.iter().sum();
    let mut sizes = Vec::with_capacity(spec.n_clients);
    let mut remainders: Vec<(f64, usize)> = Vec::with_capacity(spec.n_clients);
    let mut assigned = 0usize;
    for (k, w) in weights.iter().enumerate() {
        let exact = total as f64 * w / weight_sum;
        let floor = exact.floor() as usize;
        sizes.push(floor);
        assigned += floor;
        remainders.push((exact - floor as f64, k));
    }
    // hand the leftover rows to the largest remainders (ties by client id)
    remainders.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    let mut leftover = total - assigned;
    for &(_, k) in &remainders {
        if leftover == 0 {
            break;
        }
        sizes[k] += 1;
        leftover -= 1;
    }
    sizes
}

fn generate_shard(
    partition: &Partition,
    templates: &[ClassTemplate],
    classes: usize,
    per_client: usize,
    rng: &mut Rng,
) -> Dataset {
    let mut x = Vec::with_capacity(per_client * IMG_DIM);
    let mut y = Vec::with_capacity(per_client);
    match partition {
        // The pre-partition IID stream, preserved bit for bit: label draw
        // and render interleave per sample.
        Partition::Iid => {
            for _ in 0..per_client {
                let c = rng.below(classes);
                x.extend_from_slice(&render_sample(&templates[c], rng));
                y.push(c as i32);
            }
        }
        p => {
            let labels = p.client_labels(classes, per_client, rng);
            for &c in &labels {
                x.extend_from_slice(&render_sample(&templates[c], rng));
                y.push(c as i32);
            }
        }
    }
    Dataset {
        x,
        y,
        n: per_client,
        dim: IMG_DIM,
        classes,
    }
}

impl FlData {
    /// Client `k`'s shard: borrowed when eager, regenerated when lazy.
    pub fn shard(&self, k: usize) -> Cow<'_, Dataset> {
        match &self.shards {
            ShardSource::Eager(v) => Cow::Borrowed(&v[k]),
            ShardSource::Lazy(g) => Cow::Owned(g.generate(k)),
        }
    }

    /// Number of client shards.
    pub fn n_shards(&self) -> usize {
        self.spec.n_clients
    }

    /// Rows on client `k`'s shard (FedAvg `n_k`), without generating it.
    pub fn shard_rows(&self, k: usize) -> usize {
        match &self.shards {
            ShardSource::Eager(v) => v[k].n,
            ShardSource::Lazy(g) => g.sizes[k],
        }
    }

    /// Whether shards are rebuilt per access instead of held in memory.
    pub fn is_lazy(&self) -> bool {
        matches!(self.shards, ShardSource::Lazy(_))
    }
}

/// Generate the synthetic federated dataset.  Client shards follow the
/// spec's [`Partition`]; the test and server sets always sample the
/// global IID mix (they model the server's own data, paper §III-D).
pub fn synthetic(spec: &DataSpec, seed: u64) -> FlData {
    let mut root = Rng::new(seed ^ 0x5EED_DA7A);
    let templates: Arc<Vec<ClassTemplate>> = Arc::new(
        (0..spec.classes)
            .map(|c| ClassTemplate::new(seed, c))
            .collect(),
    );

    // Per-shard seeds reproduce the historical `root.fork(k + 1)` stream
    // exactly, but are precomputed so a lazy source can rebuild shard k
    // in isolation (and so eager == lazy bit for bit).
    let seeds: Arc<Vec<u64>> = Arc::new(
        (0..spec.n_clients)
            .map(|k| root.next_u64() ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect(),
    );
    let shard_gen = ShardGen {
        templates: Arc::clone(&templates),
        partition: spec.partition.clone(),
        classes: spec.classes,
        sizes: Arc::new(shard_sizes(spec, seed)),
        seeds,
    };
    let shards = if spec.lazy_shards {
        ShardSource::Lazy(shard_gen)
    } else {
        ShardSource::Eager((0..spec.n_clients).map(|k| shard_gen.generate(k)).collect())
    };

    let make_set = |n: usize, rng: &mut Rng| -> Dataset {
        let mut x = Vec::with_capacity(n * IMG_DIM);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(spec.classes);
            let img = render_sample(&templates[c], rng);
            x.extend_from_slice(&img);
            y.push(c as i32);
        }
        Dataset {
            x,
            y,
            n,
            dim: IMG_DIM,
            classes: spec.classes,
        }
    };

    let mut test_rng = root.fork(0xABCD);
    let test = make_set(spec.test_n, &mut test_rng);
    let mut server_rng = root.fork(0xFEED);
    let server = make_set(spec.server_n, &mut server_rng);

    FlData {
        shards,
        test,
        server,
        spec: spec.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n_clients: usize, per_client: usize) -> DataSpec {
        DataSpec {
            classes: 10,
            n_clients,
            per_client,
            test_n: 16,
            server_n: 8,
            partition: Partition::Iid,
            size_skew: 0.0,
            lazy_shards: false,
        }
    }

    #[test]
    fn shapes_and_determinism() {
        let mut s = spec(3, 32);
        s.test_n = 16;
        let a = synthetic(&s, 42);
        let b = synthetic(&s, 42);
        let c = synthetic(&s, 43);
        assert_eq!(a.n_shards(), 3);
        assert_eq!(a.shard(0).n, 32);
        assert_eq!(a.shard(0).x.len(), 32 * IMG_DIM);
        assert_eq!(a.test.n, 16);
        assert_eq!(a.shard(1).x, b.shard(1).x);
        assert_ne!(a.shard(1).x, c.shard(1).x);
        // shards differ from each other
        assert_ne!(a.shard(0).x, a.shard(1).x);
    }

    #[test]
    fn pixel_range_and_label_range() {
        let s = DataSpec {
            classes: 47,
            n_clients: 1,
            per_client: 64,
            test_n: 8,
            server_n: 8,
            partition: Partition::Iid,
            size_skew: 0.0,
            lazy_shards: false,
        };
        let d = synthetic(&s, 7);
        let shard = d.shard(0);
        assert!(shard.x.iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!(shard.y.iter().all(|&c| (0..47).contains(&c)));
        // more than one class present
        let mut seen = shard.y.clone();
        seen.sort_unstable();
        seen.dedup();
        assert!(seen.len() > 5);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class pixel distance must be well below inter-class
        // distance, otherwise the task is unlearnable.
        let t0 = ClassTemplate::new(1, 0);
        let t1 = ClassTemplate::new(1, 1);
        let mut rng = Rng::new(9);
        let a0 = render_sample(&t0, &mut rng);
        let b0 = render_sample(&t0, &mut rng);
        let a1 = render_sample(&t1, &mut rng);
        let dist = |u: &[f32], v: &[f32]| -> f32 {
            u.iter().zip(v).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        assert!(dist(&a0, &b0) < dist(&a0, &a1), "intra >= inter class distance");
    }

    #[test]
    fn gather_and_epoch_batches() {
        let d = synthetic(&spec(1, 40), 3);
        let shard = d.shard(0);
        let (x, y) = shard.gather(&[0, 5, 7]);
        assert_eq!(x.len(), 3 * IMG_DIM);
        assert_eq!(y.len(), 3);

        let mut rng = Rng::new(1);
        let (ex, ey) = shard.epoch_batches(8, 4, &mut rng).unwrap();
        assert_eq!(ex.len(), 32 * IMG_DIM);
        assert_eq!(ey.len(), 32);
        // too-large epoch is rejected
        assert!(shard.epoch_batches(8, 6, &mut rng).is_err());
    }

    #[test]
    fn size_skew_conserves_the_total_budget_exactly() {
        let mut s = spec(9, 100);
        s.size_skew = 0.4;
        let sizes = shard_sizes(&s, 5);
        assert_eq!(sizes.len(), 9);
        assert_eq!(sizes.iter().sum::<usize>(), 900);
        // genuinely unequal, but bounded by the weight envelope
        assert!(sizes.iter().any(|&n| n != 100));
        for &n in &sizes {
            let lo = (100.0 * (1.0 - s.size_skew) / (1.0 + s.size_skew)).floor() as usize;
            assert!(n >= lo.saturating_sub(1), "shard of {n} rows below floor");
        }
        // deterministic, and independent of the shard-content streams
        assert_eq!(sizes, shard_sizes(&s, 5));
        let data = synthetic(&s, 5);
        for (k, &n) in sizes.iter().enumerate() {
            assert_eq!(data.shard_rows(k), n);
            assert_eq!(data.shard(k).n, n);
            assert_eq!(data.shard(k).y.len(), n);
        }
        // skew must not move the test/server sets or the shard seeds
        let mut equal = s.clone();
        equal.size_skew = 0.0;
        let base = synthetic(&equal, 5);
        assert_eq!(base.test.x, data.test.x);
        assert_eq!(base.server.x, data.server.x);
    }

    #[test]
    fn lazy_source_is_bit_identical_to_eager() {
        let mut s = spec(4, 24);
        s.partition = Partition::Dirichlet { alpha: 0.4 };
        s.size_skew = 0.3;
        let eager = synthetic(&s, 77);
        s.lazy_shards = true;
        let lazy = synthetic(&s, 77);
        assert!(!eager.is_lazy() && lazy.is_lazy());
        // out-of-order lazy access must not matter
        for k in [3usize, 0, 2, 1] {
            assert_eq!(eager.shard(k).x, lazy.shard(k).x);
            assert_eq!(eager.shard(k).y, lazy.shard(k).y);
        }
        assert_eq!(eager.test.x, lazy.test.x);
        assert_eq!(eager.server.x, lazy.server.x);
    }
}
