//! Client data partition schemes (IID and non-IID label skew).
//!
//! The paper's evaluation assumes IID clients (§II-A), but the round
//! policies (`Deadline` / `FastestM`) and `SampleWeighted` aggregation
//! only show their effects once the *surviving* client set is biased —
//! which requires heterogeneous shards.  Two standard label-skew schemes
//! from the compression-aided-FL literature sit next to the IID baseline:
//!
//! * [`Partition::LabelShards`] — McMahan-style pathological non-IID:
//!   every client holds exactly `shards_per_client` distinct labels.
//! * [`Partition::Dirichlet`] — per-client class proportions drawn from
//!   `Dir(alpha, …, alpha)`; small `alpha` concentrates each shard on a
//!   few labels, `alpha → ∞` approaches the IID class balance.
//!
//! Every scheme conserves rows exactly (a client's shard always has
//! `per_client` samples) and derives all randomness from the client's own
//! seeded stream, so shards can be generated lazily and out of order.

use crate::error::{HcflError, Result};
use crate::util::rng::Rng;

/// How client shards relate to the global label distribution.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum Partition {
    /// Every shard samples the same class-uniform mix (paper §II-A).
    #[default]
    Iid,
    /// Each client holds exactly `shards_per_client` distinct labels,
    /// dealt in near-equal proportions (pathological non-IID).
    LabelShards { shards_per_client: usize },
    /// Per-client class proportions `p ~ Dir(alpha, …, alpha)`.
    Dirichlet { alpha: f64 },
}

impl Partition {
    pub fn label(&self) -> String {
        match self {
            Partition::Iid => "iid".to_string(),
            Partition::LabelShards { shards_per_client } => {
                format!("shards-{shards_per_client}")
            }
            Partition::Dirichlet { alpha } => format!("dirichlet-{alpha}"),
        }
    }

    pub fn validate(&self, classes: usize) -> Result<()> {
        match self {
            Partition::Iid => Ok(()),
            Partition::LabelShards { shards_per_client } => {
                if *shards_per_client == 0 || *shards_per_client > classes {
                    return Err(HcflError::Config(format!(
                        "label-shards needs 1 <= shards_per_client <= {classes} \
                         (the class count), got {shards_per_client}"
                    )));
                }
                Ok(())
            }
            Partition::Dirichlet { alpha } => {
                if !alpha.is_finite() || *alpha <= 0.0 {
                    return Err(HcflError::Config(format!(
                        "dirichlet alpha must be positive and finite, got {alpha}"
                    )));
                }
                Ok(())
            }
        }
    }

    /// The label sequence of one client's shard: always exactly
    /// `per_client` entries in `[0, classes)`, drawn from the client's
    /// own RNG stream.
    pub fn client_labels(&self, classes: usize, per_client: usize, rng: &mut Rng) -> Vec<usize> {
        match self {
            Partition::Iid => (0..per_client).map(|_| rng.below(classes)).collect(),
            Partition::LabelShards { shards_per_client } => {
                let spc = (*shards_per_client).clamp(1, classes);
                let own = rng.choose(classes, spc);
                // Deal rows round-robin over the client's labels: label
                // counts differ by at most one row, rows conserved exactly.
                (0..per_client).map(|i| own[i % spc]).collect()
            }
            Partition::Dirichlet { alpha } => {
                // p ~ Dir(alpha): normalized Gamma(alpha, 1) draws.
                let gammas: Vec<f64> = (0..classes).map(|_| rng.gamma(*alpha)).collect();
                let total: f64 = gammas.iter().sum();
                if !(total.is_finite() && total > 0.0) {
                    // Extreme alpha can underflow every gamma draw to 0:
                    // the limit distribution is a single seeded class.
                    let c = rng.below(classes);
                    return vec![c; per_client];
                }
                let mut cdf = Vec::with_capacity(classes);
                let mut acc = 0.0;
                for g in &gammas {
                    acc += g / total;
                    cdf.push(acc);
                }
                (0..per_client)
                    .map(|_| {
                        let u = rng.next_f64();
                        cdf.iter().position(|&c| u < c).unwrap_or(classes - 1)
                    })
                    .collect()
            }
        }
    }
}

/// Shannon entropy (nats) of a label multiset — the standard skew
/// measure for partition schemes: `ln(classes)` is perfectly balanced,
/// 0 is a single-label shard.
pub fn label_entropy(y: &[i32], classes: usize) -> f64 {
    let mut counts = vec![0usize; classes];
    for &c in y {
        counts[c as usize] += 1;
    }
    let n = y.len().max(1) as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_bounds() {
        assert!(Partition::Iid.validate(10).is_ok());
        assert!(Partition::LabelShards { shards_per_client: 2 }.validate(10).is_ok());
        assert!(Partition::LabelShards { shards_per_client: 0 }.validate(10).is_err());
        assert!(Partition::LabelShards { shards_per_client: 11 }.validate(10).is_err());
        assert!(Partition::Dirichlet { alpha: 0.3 }.validate(10).is_ok());
        assert!(Partition::Dirichlet { alpha: 0.0 }.validate(10).is_err());
        assert!(Partition::Dirichlet { alpha: f64::NAN }.validate(10).is_err());
    }

    #[test]
    fn labels_conserve_rows_and_stay_in_range() {
        let schemes = [
            Partition::Iid,
            Partition::LabelShards { shards_per_client: 3 },
            Partition::Dirichlet { alpha: 0.2 },
        ];
        for p in schemes {
            let mut rng = Rng::new(9);
            let labels = p.client_labels(10, 137, &mut rng);
            assert_eq!(labels.len(), 137, "{p:?}");
            assert!(labels.iter().all(|&c| c < 10), "{p:?}");
        }
    }

    #[test]
    fn entropy_extremes() {
        let uniform: Vec<i32> = (0..100).map(|i| i % 10).collect();
        assert!((label_entropy(&uniform, 10) - (10f64).ln()).abs() < 1e-12);
        let single = vec![3i32; 100];
        assert_eq!(label_entropy(&single, 10), 0.0);
    }

    #[test]
    fn partition_labels() {
        assert_eq!(Partition::Iid.label(), "iid");
        assert_eq!(Partition::LabelShards { shards_per_client: 2 }.label(), "shards-2");
        assert!(Partition::Dirichlet { alpha: 0.3 }.label().starts_with("dirichlet-"));
    }
}
