//! Procedural 28x28 glyph renderer.
//!
//! Each class owns a template of 3-5 strokes (line segments with a width
//! and an intensity) placed by a class-seeded PRNG.  A sample renders the
//! template with a global translation, small per-endpoint jitter, and
//! additive pixel noise, then clamps to [0, 1].  Distances from pixel to
//! segment use the exact point-segment distance, giving smooth
//! anti-aliased strokes.

use crate::util::rng::Rng;

use super::{IMG_DIM, IMG_SIDE};

/// One stroke of a glyph: a thick line segment.
#[derive(Debug, Clone, Copy)]
pub struct Stroke {
    pub x0: f32,
    pub y0: f32,
    pub x1: f32,
    pub y1: f32,
    /// Gaussian half-width in pixels.
    pub width: f32,
    /// Peak intensity in [0.55, 1.0].
    pub intensity: f32,
}

/// Per-class procedural template.
#[derive(Debug, Clone)]
pub struct ClassTemplate {
    pub class: usize,
    pub strokes: Vec<Stroke>,
}

impl ClassTemplate {
    /// Deterministic template for (dataset seed, class id).
    pub fn new(seed: u64, class: usize) -> ClassTemplate {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ (class as u64) << 17);
        let n_strokes = 3 + rng.below(3); // 3..=5
        let margin = 5.0;
        let span = IMG_SIDE as f32 - 2.0 * margin;
        let strokes = (0..n_strokes)
            .map(|_| {
                let x0 = margin + rng.uniform(0.0, span);
                let y0 = margin + rng.uniform(0.0, span);
                // Bias towards long strokes so classes differ macroscopically.
                let angle = rng.uniform(0.0, std::f32::consts::TAU);
                let len = rng.uniform(8.0, 16.0);
                let x1 = (x0 + len * angle.cos()).clamp(2.0, IMG_SIDE as f32 - 3.0);
                let y1 = (y0 + len * angle.sin()).clamp(2.0, IMG_SIDE as f32 - 3.0);
                Stroke {
                    x0,
                    y0,
                    x1,
                    y1,
                    width: rng.uniform(0.9, 1.6),
                    intensity: rng.uniform(0.55, 1.0),
                }
            })
            .collect();
        ClassTemplate { class, strokes }
    }
}

fn point_segment_dist2(px: f32, py: f32, s: &Stroke) -> f32 {
    let dx = s.x1 - s.x0;
    let dy = s.y1 - s.y0;
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - s.x0) * dx + (py - s.y0) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let cx = s.x0 + t * dx;
    let cy = s.y0 + t * dy;
    (px - cx) * (px - cx) + (py - cy) * (py - cy)
}

/// Render one sample of a class: template + translation + jitter + noise.
pub fn render_sample(template: &ClassTemplate, rng: &mut Rng) -> Vec<f32> {
    let tx = rng.uniform(-2.0, 2.0);
    let ty = rng.uniform(-2.0, 2.0);
    // Per-sample jittered copy of the strokes.
    let strokes: Vec<Stroke> = template
        .strokes
        .iter()
        .map(|s| Stroke {
            x0: s.x0 + tx + rng.uniform(-0.7, 0.7),
            y0: s.y0 + ty + rng.uniform(-0.7, 0.7),
            x1: s.x1 + tx + rng.uniform(-0.7, 0.7),
            y1: s.y1 + ty + rng.uniform(-0.7, 0.7),
            width: s.width,
            intensity: s.intensity * rng.uniform(0.85, 1.05),
        })
        .collect();

    let mut img = vec![0.0f32; IMG_DIM];
    for (idx, px) in img.iter_mut().enumerate() {
        let x = (idx % IMG_SIDE) as f32;
        let y = (idx / IMG_SIDE) as f32;
        let mut v = 0.0f32;
        for s in &strokes {
            let d2 = point_segment_dist2(x, y, s);
            let sigma2 = s.width * s.width;
            if d2 < 9.0 * sigma2 {
                v += s.intensity * (-0.5 * d2 / sigma2).exp();
            }
        }
        // pixel noise
        v += rng.normal() * 0.05;
        *px = v.clamp(0.0, 1.0);
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn template_deterministic() {
        let a = ClassTemplate::new(1, 3);
        let b = ClassTemplate::new(1, 3);
        assert_eq!(a.strokes.len(), b.strokes.len());
        assert_eq!(a.strokes[0].x0, b.strokes[0].x0);
        let c = ClassTemplate::new(1, 4);
        assert!(
            a.strokes.len() != c.strokes.len() || a.strokes[0].x0 != c.strokes[0].x0
        );
    }

    #[test]
    fn render_has_signal() {
        let t = ClassTemplate::new(2, 0);
        let mut rng = Rng::new(1);
        let img = render_sample(&t, &mut rng);
        assert_eq!(img.len(), IMG_DIM);
        // stroke pixels should push the mean clearly above the noise floor
        let bright = img.iter().filter(|&&p| p > 0.5).count();
        assert!(bright > 10, "only {bright} bright pixels");
        assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn segment_distance() {
        let s = Stroke {
            x0: 0.0,
            y0: 0.0,
            x1: 10.0,
            y1: 0.0,
            width: 1.0,
            intensity: 1.0,
        };
        assert_eq!(point_segment_dist2(5.0, 0.0, &s), 0.0);
        assert_eq!(point_segment_dist2(5.0, 3.0, &s), 9.0);
        assert_eq!(point_segment_dist2(-4.0, 3.0, &s), 25.0); // clamps to endpoint
        // degenerate zero-length stroke
        let p = Stroke {
            x1: 0.0,
            y1: 0.0,
            ..s
        };
        assert_eq!(point_segment_dist2(3.0, 4.0, &p), 25.0);
    }
}
