//! Library error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate must
//! build offline with zero default dependencies.

use std::fmt;

/// Errors surfaced by the HCFL library.
#[derive(Debug)]
pub enum HcflError {
    /// Artifact directory / manifest problems.
    Manifest(String),

    /// JSON syntax or schema errors while reading the manifest.
    Json(String),

    /// A named executable is missing from the manifest.
    UnknownExecutable(String),

    /// Input tensors did not match the executable's recorded spec.
    SpecMismatch { exec: String, detail: String },

    /// The PJRT engine failed (compile or execute).
    Engine(String),

    /// The engine worker thread is gone.
    WorkerGone,

    /// Configuration problems (bad experiment parameters, etc.).
    Config(String),

    /// Dataset / shard construction problems.
    Data(String),

    /// A campaign snapshot file is corrupt, truncated, or belongs to a
    /// different experiment (`daemon::snapshot`).  Restore is
    /// all-or-nothing: this error means no state was touched.
    Snapshot(String),

    /// I/O wrapper.
    Io(std::io::Error),
}

impl fmt::Display for HcflError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HcflError::Manifest(s) => write!(f, "manifest error: {s}"),
            HcflError::Json(s) => write!(f, "json error: {s}"),
            HcflError::UnknownExecutable(s) => {
                write!(f, "unknown executable '{s}' (run `make artifacts`?)")
            }
            HcflError::SpecMismatch { exec, detail } => {
                write!(f, "spec mismatch for '{exec}': {detail}")
            }
            HcflError::Engine(s) => write!(f, "engine error: {s}"),
            HcflError::WorkerGone => write!(f, "engine worker disconnected"),
            HcflError::Config(s) => write!(f, "config error: {s}"),
            HcflError::Data(s) => write!(f, "data error: {s}"),
            HcflError::Snapshot(s) => write!(f, "snapshot error: {s}"),
            HcflError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for HcflError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            HcflError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for HcflError {
    fn from(e: std::io::Error) -> Self {
        HcflError::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for HcflError {
    fn from(e: xla::Error) -> Self {
        HcflError::Engine(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, HcflError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_old_thiserror_format() {
        assert_eq!(
            HcflError::Manifest("x".into()).to_string(),
            "manifest error: x"
        );
        assert_eq!(
            HcflError::UnknownExecutable("foo".into()).to_string(),
            "unknown executable 'foo' (run `make artifacts`?)"
        );
        assert_eq!(
            HcflError::SpecMismatch {
                exec: "e".into(),
                detail: "d".into()
            }
            .to_string(),
            "spec mismatch for 'e': d"
        );
        assert_eq!(HcflError::WorkerGone.to_string(), "engine worker disconnected");
    }

    #[test]
    fn io_conversion_and_source() {
        let err: HcflError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(err.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&err).is_some());
    }
}
