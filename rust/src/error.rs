//! Library error type.

use thiserror::Error;

/// Errors surfaced by the HCFL library.
#[derive(Debug, Error)]
pub enum HcflError {
    /// Artifact directory / manifest problems.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// JSON syntax or schema errors while reading the manifest.
    #[error("json error: {0}")]
    Json(String),

    /// A named executable is missing from the manifest.
    #[error("unknown executable '{0}' (run `make artifacts`?)")]
    UnknownExecutable(String),

    /// Input tensors did not match the executable's recorded spec.
    #[error("spec mismatch for '{exec}': {detail}")]
    SpecMismatch { exec: String, detail: String },

    /// The PJRT engine failed (compile or execute).
    #[error("engine error: {0}")]
    Engine(String),

    /// The engine worker thread is gone.
    #[error("engine worker disconnected")]
    WorkerGone,

    /// Configuration problems (bad experiment parameters, etc.).
    #[error("config error: {0}")]
    Config(String),

    /// Dataset / shard construction problems.
    #[error("data error: {0}")]
    Data(String),

    /// I/O wrapper.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for HcflError {
    fn from(e: xla::Error) -> Self {
        HcflError::Engine(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, HcflError>;
