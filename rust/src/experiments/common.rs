//! Shared plumbing for the experiment drivers.

use std::path::Path;

use crate::compression::Scheme;
use crate::config::ExperimentConfig;
use crate::coordinator::Simulation;
use crate::error::Result;
use crate::metrics::RunReport;
use crate::runtime::Engine;
use crate::util::cli::Args;

/// Scale knobs shared by all experiments: small defaults for a laptop
/// run; `--paper-scale` restores the paper's 100-round geometry.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub rounds: usize,
    pub epochs: usize,
    pub paper: bool,
}

impl Scale {
    pub fn from_args(args: &Args, default_rounds: usize, default_epochs: usize) -> Result<Scale> {
        let paper = args.flag("paper-scale");
        Ok(Scale {
            rounds: args.usize_or("rounds", if paper { 100 } else { default_rounds })?,
            epochs: args.usize_or("epochs", if paper { 5 } else { default_epochs })?,
            paper,
        })
    }
}

/// Run one configuration, stream per-round lines to stderr, and persist
/// the per-round CSV under `out_dir`.
pub fn run_and_save(
    engine: &Engine,
    mut cfg: ExperimentConfig,
    out_dir: &Path,
    tag: &str,
) -> Result<RunReport> {
    cfg.engine_workers = engine.n_workers();
    let mut sim = Simulation::new(engine, cfg)?;
    sim.verbose = true;
    let report = sim.run()?;
    std::fs::create_dir_all(out_dir)?;
    let file = out_dir.join(format!("{tag}.csv"));
    report.write_csv(&file)?;
    eprintln!("[saved] {}", file.display());
    Ok(report)
}

/// Slug for filenames: "HCFL 1:32" -> "hcfl_1_32".
pub fn slug(s: &str) -> String {
    s.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() {
                c.to_ascii_lowercase()
            } else {
                '_'
            }
        })
        .collect()
}

/// The compression schemes of the paper's Tables I/II.
pub fn table_schemes(ratios: &[usize]) -> Vec<Scheme> {
    let mut out = vec![Scheme::Fedavg, Scheme::Ternary];
    out.extend(ratios.iter().map(|&r| Scheme::Hcfl { ratio: r }));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slugging() {
        assert_eq!(slug("HCFL 1:32"), "hcfl_1_32");
        assert_eq!(slug("FedAvg"), "fedavg");
    }

    #[test]
    fn schemes_include_baselines() {
        let s = table_schemes(&[4, 32]);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0], Scheme::Fedavg);
        assert_eq!(s[1], Scheme::Ternary);
    }
}
