//! Figures 8-12 of the paper: accuracy/loss-vs-round series.
//!
//! Each driver prints a per-round series table (the figure's data) and
//! writes one CSV per curve under the results directory.

use crate::compression::Scheme;
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::experiments::common::{run_and_save, slug, Scale};
use crate::experiments::registry::ExperimentCtx;
use crate::metrics::{RunReport, Table};

fn print_series(title: &str, reports: &[(String, RunReport)], show_loss: bool) {
    println!("{title}");
    let rounds = reports
        .iter()
        .map(|(_, r)| r.rounds.len())
        .max()
        .unwrap_or(0);
    let mut headers: Vec<String> = vec!["round".into()];
    for (label, _) in reports {
        headers.push(label.clone());
        if show_loss {
            headers.push(format!("{label} loss"));
        }
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);
    for t in 0..rounds {
        let mut row = vec![format!("{}", t + 1)];
        for (_, rep) in reports {
            match rep.rounds.get(t) {
                Some(rec) => {
                    row.push(format!("{:.4}", rec.accuracy));
                    if show_loss {
                        row.push(format!("{:.4}", rec.loss));
                    }
                }
                None => {
                    row.push("-".into());
                    if show_loss {
                        row.push("-".into());
                    }
                }
            }
        }
        table.row(row);
    }
    println!("{}", table.render());
}

/// Fig. 8: MNIST accuracy per round at each compression ratio.
pub fn fig8(ctx: &ExperimentCtx) -> Result<()> {
    let scale = Scale::from_args(&ctx.args, 12, 2)?;
    let ratios = ctx.args.usize_list_or("ratios", &[4, 8, 16, 32])?;
    let mut reports = Vec::new();
    let mut schemes = vec![Scheme::Fedavg];
    schemes.extend(ratios.iter().map(|&r| Scheme::Hcfl { ratio: r }));
    for scheme in schemes {
        let mut cfg = ExperimentConfig::mnist(scheme, scale.rounds);
        cfg.local_epochs = scale.epochs;
        let rep = run_and_save(
            &ctx.engine,
            cfg,
            &ctx.out_dir,
            &format!("fig8_{}", slug(&scheme.label())),
        )?;
        reports.push((scheme.label(), rep));
    }
    print_series(
        "Fig. 8 — aggregation accuracy on MNIST per compression ratio",
        &reports,
        false,
    );
    Ok(())
}

/// Fig. 9: EMNIST accuracy per round at each compression ratio.
pub fn fig9(ctx: &ExperimentCtx) -> Result<()> {
    let scale = Scale::from_args(&ctx.args, 8, 2)?;
    let ratios = ctx.args.usize_list_or("ratios", &[4, 8, 16, 32])?;
    let mut reports = Vec::new();
    let mut schemes = vec![Scheme::Fedavg];
    schemes.extend(ratios.iter().map(|&r| Scheme::Hcfl { ratio: r }));
    for scheme in schemes {
        let mut cfg = ExperimentConfig::emnist(scheme, scale.rounds);
        cfg.local_epochs = scale.epochs;
        let rep = run_and_save(
            &ctx.engine,
            cfg,
            &ctx.out_dir,
            &format!("fig9_{}", slug(&scheme.label())),
        )?;
        reports.push((scheme.label(), rep));
    }
    print_series(
        "Fig. 9 — aggregation accuracy on EMNIST per compression ratio",
        &reports,
        false,
    );
    Ok(())
}

fn fig10(ctx: &ExperimentCtx, model: &str, title: &str) -> Result<()> {
    let scale = Scale::from_args(&ctx.args, 8, 2)?;
    let ks = ctx.args.usize_list_or("clients", &[10, 30, 100])?;
    let ratio = ctx.args.usize_or("ratio", 16)?;
    let mut reports = Vec::new();
    for &k in &ks {
        let mut cfg = if model == "lenet" {
            ExperimentConfig::mnist(Scheme::Hcfl { ratio }, scale.rounds)
        } else {
            ExperimentConfig::emnist(Scheme::Hcfl { ratio }, scale.rounds)
        };
        cfg.local_epochs = scale.epochs;
        cfg.n_clients = k;
        cfg.participation = 1.0; // all K participate: isolates the K effect
        cfg.data.n_clients = k;
        let rep = run_and_save(
            &ctx.engine,
            cfg,
            &ctx.out_dir,
            &format!("fig10_{model}_k{k}"),
        )?;
        // Theorem-1 framing: larger K => lower tail variance.
        eprintln!(
            "K={k}: final acc {:.4}, tail stddev {:.4}",
            rep.final_accuracy(),
            rep.accuracy_stddev_tail(5)
        );
        reports.push((format!("K={k}"), rep));
    }
    print_series(title, &reports, false);
    Ok(())
}

/// Fig. 10a: client-count sweep on MNIST.
pub fn fig10a(ctx: &ExperimentCtx) -> Result<()> {
    fig10(
        ctx,
        "lenet",
        "Fig. 10a — effect of client count K on MNIST accuracy (HCFL)",
    )
}

/// Fig. 10b: client-count sweep on EMNIST.
pub fn fig10b(ctx: &ExperimentCtx) -> Result<()> {
    fig10(
        ctx,
        "fivecnn",
        "Fig. 10b — effect of client count K on EMNIST accuracy (HCFL)",
    )
}

/// Fig. 11: local-epoch sweep (accuracy + loss).
pub fn fig11(ctx: &ExperimentCtx) -> Result<()> {
    let scale = Scale::from_args(&ctx.args, 10, 1)?;
    let epochs = ctx.args.usize_list_or("epoch-sweep", &[1, 5, 10, 20])?;
    let ratio = ctx.args.usize_or("ratio", 16)?;
    let mut reports = Vec::new();
    for &e in &epochs {
        let mut cfg = ExperimentConfig::mnist(Scheme::Hcfl { ratio }, scale.rounds);
        cfg.local_epochs = e;
        let rep = run_and_save(&ctx.engine, cfg, &ctx.out_dir, &format!("fig11_e{e}"))?;
        reports.push((format!("E={e}"), rep));
    }
    print_series(
        "Fig. 11 — effect of local epochs E on MNIST (HCFL), accuracy and loss",
        &reports,
        true,
    );
    Ok(())
}

/// Fig. 12: batch-size sweep (accuracy + loss).
pub fn fig12(ctx: &ExperimentCtx) -> Result<()> {
    let scale = Scale::from_args(&ctx.args, 10, 5)?;
    let batches = ctx.args.usize_list_or("batch-sweep", &[10, 64, 600])?;
    let ratio = ctx.args.usize_or("ratio", 16)?;
    let mut reports = Vec::new();
    for &b in &batches {
        let mut cfg = ExperimentConfig::mnist(Scheme::Hcfl { ratio }, scale.rounds);
        cfg.local_epochs = scale.epochs;
        cfg.batch = b;
        let rep = run_and_save(&ctx.engine, cfg, &ctx.out_dir, &format!("fig12_b{b}"))?;
        reports.push((format!("B={b}"), rep));
    }
    print_series(
        "Fig. 12 — effect of batch size B on MNIST (HCFL), accuracy and loss",
        &reports,
        true,
    );
    Ok(())
}
