//! Experiment harness: one module per table/figure of the paper's §VI.

pub mod common;
pub mod figures;
pub mod registry;
pub mod scenarios;
pub mod tables;
pub mod theorems;

pub use registry::{list, run_by_id, ExperimentCtx};
