//! Experiment registry: dispatch table from ids to drivers.

use crate::error::{HcflError, Result};
use crate::runtime::Engine;
use crate::util::cli::Args;

/// Shared context for experiment drivers.
pub struct ExperimentCtx {
    pub engine: Engine,
    pub args: Args,
    pub out_dir: std::path::PathBuf,
}

type Driver = fn(&ExperimentCtx) -> Result<()>;

fn drivers() -> Vec<(&'static str, &'static str, Driver)> {
    use crate::experiments::{figures, scenarios, tables, theorems};
    vec![
        (
            "table1",
            "Table I: LeNet-5/MNIST communication cost per scheme",
            tables::table1,
        ),
        (
            "table2",
            "Table II: 5-CNN/EMNIST communication cost per scheme",
            tables::table2,
        ),
        (
            "table3",
            "Table III: client/server computational delay per ratio",
            tables::table3,
        ),
        (
            "fig8",
            "Fig 8: MNIST accuracy vs round per compression ratio",
            figures::fig8,
        ),
        (
            "fig9",
            "Fig 9: EMNIST accuracy vs round per compression ratio",
            figures::fig9,
        ),
        (
            "fig10a",
            "Fig 10a: client-count sweep, MNIST",
            figures::fig10a,
        ),
        (
            "fig10b",
            "Fig 10b: client-count sweep, EMNIST",
            figures::fig10b,
        ),
        (
            "fig11",
            "Fig 11: local-epoch sweep, MNIST (acc + loss)",
            figures::fig11,
        ),
        (
            "fig12",
            "Fig 12: batch-size sweep, MNIST (acc + loss)",
            figures::fig12,
        ),
        (
            "scenarios",
            "Scenario sweep: straggler fleets under sync/deadline/fastest-m policies \
             + non-IID partitions x aggregators (--smoke for the engine-free CI run)",
            scenarios::scenarios,
        ),
        (
            "thm1",
            "Theorem 1: measured deviation probability vs bound",
            theorems::thm1,
        ),
        (
            "thm2",
            "Theorem 2: entropy-gap estimate vs measured MSE",
            theorems::thm2,
        ),
    ]
}

/// Known experiment ids with descriptions.
pub fn list() -> Vec<(&'static str, &'static str)> {
    drivers().into_iter().map(|(id, d, _)| (id, d)).collect()
}

/// Dispatch an experiment by id ("all" runs everything).
pub fn run_by_id(ctx: &ExperimentCtx, id: &str) -> Result<()> {
    if id == "all" {
        for (name, _, f) in drivers() {
            eprintln!("=== {name} ===");
            f(ctx)?;
        }
        return Ok(());
    }
    for (name, _, f) in drivers() {
        if name == id {
            return f(ctx);
        }
    }
    Err(HcflError::Config(format!(
        "unknown experiment '{id}' (try: {})",
        list()
            .iter()
            .map(|(n, _)| *n)
            .collect::<Vec<_>>()
            .join(", ")
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_table_and_figure() {
        let ids: Vec<&str> = list().iter().map(|(n, _)| *n).collect();
        for want in [
            "table1", "table2", "table3", "fig8", "fig9", "fig10a", "fig10b", "fig11",
            "fig12", "scenarios", "thm1", "thm2",
        ] {
            assert!(ids.contains(&want), "missing experiment {want}");
        }
    }
}
