//! Scenario sweep: HCFL vs FedAvg under straggler-heavy IoT fleets.
//!
//! Not a figure from the paper — it exercises the regime the paper's
//! title promises (very large scale IoT) but its synchronous simulator
//! could not show: heterogeneous devices, deadline / fastest-m round
//! policies, and the resulting participation and modelled-makespan
//! trade-off.  Compression and semi-synchrony compose: HCFL shrinks air
//! time, the round policy bounds compute stragglers.
//!
//! `repro experiment --id scenarios [--clients K] [--fracs-pct 10,30,50]
//!  [--slowdown 8] [--rounds N] [--ratio 32]`
//!
//! `--clients` scales to the ISSUE's K=100..10k sweep when the host can
//! afford it; the default stays laptop-sized.

use crate::compression::Scheme;
use crate::config::{ExperimentConfig, ScenarioConfig};
use crate::coordinator::clock::{calibrated_deadline, RoundPolicy};
use crate::coordinator::Simulation;
use crate::error::Result;
use crate::experiments::common::{slug, Scale};
use crate::experiments::registry::ExperimentCtx;
use crate::metrics::{RunReport, Table};
use crate::network::DevicePreset;

/// Run one config, calibrating the policy from a synchronous probe round.
///
/// Round 1 always runs synchronously; `make_policy` then maps the
/// fleet's reference arrival to the policy the remaining rounds use
/// (deadline / fastest-m need a time scale, which depends on the host's
/// measured compute).
fn run_with_policy(
    ctx: &ExperimentCtx,
    mut cfg: ExperimentConfig,
    rounds: usize,
    make_policy: impl Fn(f64) -> RoundPolicy,
    tag: &str,
) -> Result<RunReport> {
    cfg.engine_workers = ctx.engine.n_workers();
    let mut sim = Simulation::new(&ctx.engine, cfg)?;
    let probe = sim.run_round(1)?;
    sim.cfg.scenario.policy = make_policy(calibrated_deadline(&sim.cfg.link, &probe, 3.0));
    let mut records = vec![probe];
    for t in 2..=rounds {
        records.push(sim.run_round(t)?);
    }
    let report = RunReport {
        scheme: sim.compressor().name(),
        model: sim.cfg.model.clone(),
        rounds: records,
    };
    std::fs::create_dir_all(&ctx.out_dir)?;
    let file = ctx.out_dir.join(format!("{tag}.csv"));
    report.write_csv(&file)?;
    eprintln!("[saved] {}", file.display());
    Ok(report)
}

/// The `scenarios` experiment driver.
pub fn scenarios(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    let scale = Scale::from_args(args, 4, 1)?;
    let clients = args.usize_or("clients", 20)?;
    let fracs = args.usize_list_or("fracs-pct", &[10, 30, 50])?;
    let slowdown = args.f64_or("slowdown", 8.0)?;
    let ratio = args.usize_or("ratio", 32)?;

    println!(
        "Scenario sweep — K={clients}, {} rounds, stragglers {slowdown}x slower",
        scale.rounds
    );
    println!("(round 1 is a synchronous calibration round in every run)");
    let mut table = Table::new(&[
        "Scheme",
        "Stragglers",
        "Policy",
        "Final acc",
        "Participation",
        "Cut/Dropped",
        "Makespan (s)",
        "Upload (MB)",
    ]);

    for &pct in &fracs {
        let frac = pct as f64 / 100.0;
        for scheme in [Scheme::Fedavg, Scheme::Hcfl { ratio }] {
            let mut cfg = ExperimentConfig::mnist(scheme, scale.rounds);
            cfg.n_clients = clients;
            cfg.data.n_clients = clients;
            cfg.local_epochs = scale.epochs;
            cfg.scenario = ScenarioConfig {
                policy: RoundPolicy::Synchronous,
                devices: DevicePreset::Stragglers { frac, slowdown },
                ..ScenarioConfig::default()
            };

            // Synchronous baseline, calibrated deadline (keeps every
            // reference device, cuts anything slowed by more than 3x),
            // and fastest-m sized to the expected fast cohort.
            let m = cfg.m();
            let keep = ((m as f64) * (1.0 - frac)).ceil().max(1.0) as usize;
            let policies: [(&str, Box<dyn Fn(f64) -> RoundPolicy>); 3] = [
                ("sync", Box::new(|_| RoundPolicy::Synchronous)),
                (
                    "deadline",
                    Box::new(|t_max_s| RoundPolicy::Deadline { t_max_s }),
                ),
                (
                    "fastest-m",
                    Box::new(move |_| RoundPolicy::FastestM { m: keep }),
                ),
            ];

            // One Simulation per policy run: with the AE cache on (the
            // preset default) the HCFL compressor reloads rather than
            // retrains, so the rebuild only costs data generation.
            for (name, make_policy) in policies {
                let tag = format!(
                    "scenario_{}_{pct}pct_{name}",
                    slug(&scheme.label())
                );
                let report =
                    run_with_policy(ctx, cfg.clone(), scale.rounds, make_policy, &tag)?;
                table.row(vec![
                    report.scheme.clone(),
                    format!("{pct}%"),
                    name.to_string(),
                    format!("{:.4}", report.final_accuracy()),
                    format!("{:.2}", report.mean_participation()),
                    format!(
                        "{}/{}",
                        report.total_stragglers(),
                        report.total_dropped()
                    ),
                    format!("{:.2}", report.total_makespan()),
                    format!("{:.2}", report.total_up_bytes() as f64 / 1e6),
                ]);
            }
        }
    }
    println!("{}", table.render());
    Ok(())
}
