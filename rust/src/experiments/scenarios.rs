//! Scenario sweep: HCFL vs FedAvg under straggler-heavy IoT fleets and
//! non-IID client shards.
//!
//! Not a figure from the paper — it exercises the regime the paper's
//! title promises (very large scale IoT) but its synchronous simulator
//! could not show: heterogeneous devices, deadline / fastest-m round
//! policies, label-skewed shards, and the resulting participation /
//! makespan / aggregation-bias trade-offs.  Compression and
//! semi-synchrony compose: HCFL shrinks air time, the round policy
//! bounds compute stragglers, and `SampleWeighted` aggregation corrects
//! for the biased survivor sets that non-IID shards expose.
//!
//! `repro experiment --id scenarios [--clients K] [--client-threads N]
//!  [--fracs-pct 10,30,50] [--slowdown 8] [--rounds N] [--ratio 32]
//!  [--per-client N] [--alpha F] [--shards-per-client N] [--size-skew F]
//!  [--iid-only] [--smoke] [--sharded-100k] [--adaptive]`
//!
//! `--sharded-100k` replaces the sweep with the hierarchical-aggregation
//! arm (DESIGN.md §10): one engine-free fake-train round at K=100k
//! (override with `--clients`), folded flat and through E ∈ {4, 16}
//! edge shards — the run fails unless every arm lands on identical
//! global model bits, and the makespan/server-time table shows the
//! per-shard K/E scaling.
//!
//! `--adaptive` replaces the sweep with the control-plane arm
//! (DESIGN.md §11): static single-codec baselines vs per-client codec
//! policies over a heterogeneous IoT fleet, with a bytes/makespan
//! Pareto CSV.  The run fails unless the adaptive arm beats the static
//! FedAvg makespan by at least 20%.
//!
//! `--clients` scales to the paper's K=10k regime (m=1000 at the preset
//! C=0.1): shards generate lazily above K=512 so a 10k-client fleet
//! never materializes ~19 GB of pixels, and the worker-pool client stage
//! runs a round with zero per-client thread spawns.  `--smoke` shrinks
//! everything to a seconds-long engine-free run (fake training on the
//! synthetic manifest) so CI executes this driver on every PR.

use crate::compression::Scheme;
use crate::config::{ExperimentConfig, ScenarioConfig};
use crate::control::{CodecPolicy, ServerOptKind};
use crate::coordinator::clock::{calibrated_deadline, RoundPolicy};
use crate::coordinator::{CarryPolicy, Simulation};
use crate::data::Partition;
use crate::error::{HcflError, Result};
use crate::experiments::common::{slug, Scale};
use crate::experiments::registry::ExperimentCtx;
use crate::fl::AggregatorKind;
use crate::metrics::{RunReport, Table};
use crate::network::DevicePreset;

/// Shared sweep knobs resolved once from the CLI.
struct Knobs {
    clients: usize,
    rounds: usize,
    epochs: usize,
    client_threads: usize,
    per_client: Option<usize>,
    slowdown: f64,
    ratio: usize,
    smoke: bool,
}

impl Knobs {
    /// The two schemes every arm compares.  Smoke mode has no engine, so
    /// TopK stands in for HCFL as the "compressed" arm (both are pure
    /// Rust on the wire path).
    fn schemes(&self) -> [Scheme; 2] {
        if self.smoke {
            [Scheme::Fedavg, Scheme::TopK { keep: 0.1 }]
        } else {
            [Scheme::Fedavg, Scheme::Hcfl { ratio: self.ratio }]
        }
    }

    fn base_cfg(&self, scheme: Scheme) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mnist(scheme, self.rounds);
        cfg.n_clients = self.clients;
        cfg.data.n_clients = self.clients;
        cfg.local_epochs = self.epochs;
        cfg.client_threads = self.client_threads;
        // Lazy shard generation above laptop scale: eager MNIST-geometry
        // shards at K=10k would hold ~19 GB of pixels.
        cfg.data.lazy_shards = self.clients > 512;
        if self.smoke {
            cfg.model = "fake".into();
            cfg.fake_train = true;
            cfg.batch = 16;
            cfg.data.per_client = 64;
            cfg.data.test_n = 64;
            cfg.data.server_n = 16;
            cfg.use_ae_cache = false;
        }
        // --per-client wins over the smoke default
        if let Some(per_client) = self.per_client {
            cfg.data.per_client = per_client;
        }
        cfg
    }
}

/// Run one config, calibrating the policy from a synchronous probe round.
///
/// Round 1 always runs synchronously; `make_policy` then maps the
/// fleet's reference arrival to the policy the remaining rounds use
/// (deadline / fastest-m need a time scale, which depends on the host's
/// measured compute).
fn run_with_policy(
    ctx: &ExperimentCtx,
    mut cfg: ExperimentConfig,
    rounds: usize,
    make_policy: impl Fn(f64) -> RoundPolicy,
    tag: &str,
) -> Result<RunReport> {
    cfg.engine_workers = ctx.engine.n_workers();
    let mut sim = Simulation::new(&ctx.engine, cfg)?;
    let probe = sim.run_round(1)?;
    sim.cfg.scenario.policy = make_policy(calibrated_deadline(&sim.cfg.link, &probe, 3.0));
    let mut records = vec![probe];
    for t in 2..=rounds {
        records.push(sim.run_round(t)?);
    }
    let report = RunReport {
        scheme: sim.compressor().name(),
        model: sim.cfg.model.clone(),
        rounds: records,
    };
    std::fs::create_dir_all(&ctx.out_dir)?;
    let file = ctx.out_dir.join(format!("{tag}.csv"));
    report.write_csv(&file)?;
    eprintln!("[saved] {}", file.display());
    Ok(report)
}

/// The `--sharded-100k` arm: one engine-free fake-train round at very
/// large K, folded flat and through the two-level edge tier (DESIGN.md
/// §10).  Every arm must land on identical global model bits — this is
/// the CI-facing guard that hierarchical aggregation changes *where*
/// the adds run, never *what* they compute.
fn sharded_100k(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    let clients = args.usize_or("clients", 100_000)?;
    let client_threads = args.usize_or("client-threads", 8)?;
    let scheme = Scheme::TopK { keep: 0.1 };

    let mut cfg = ExperimentConfig::mnist(scheme, 1);
    cfg.model = "fake".into();
    cfg.fake_train = true;
    cfg.n_clients = clients;
    cfg.data.n_clients = clients;
    cfg.participation = 1.0;
    cfg.local_epochs = 1;
    cfg.batch = 16;
    cfg.data.per_client = 64;
    cfg.data.test_n = 64;
    cfg.data.server_n = 16;
    cfg.data.lazy_shards = true;
    cfg.use_ae_cache = false;
    // The exact sidecar clones K × d f32 — pointless at this scale.
    cfg.send_exact = false;
    cfg.client_threads = client_threads;
    cfg.engine_workers = ctx.engine.n_workers();

    println!(
        "Hierarchical aggregation — K={clients}, fake-train {}, 1 round, flat vs sharded",
        scheme.label()
    );
    let mut table = Table::new(&["Arm", "Folded", "Makespan (s)", "Server (s)", "Wall (s)"]);
    let mut reference: Option<Vec<u32>> = None;
    for edge in [0usize, 4, 16] {
        let mut cfg = cfg.clone();
        cfg.edge_shards = edge;
        let mut sim = Simulation::new(&ctx.engine, cfg)?;
        let rec = sim.run_round(1)?;
        let bits: Vec<u32> = sim.global().iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(flat) if *flat == bits => {}
            Some(_) => {
                return Err(HcflError::Engine(format!(
                    "E={edge} fold diverged from the flat global model bits"
                )))
            }
        }
        table.row(vec![
            if edge == 0 {
                "flat".into()
            } else {
                format!("E={edge}")
            },
            format!("{}", rec.completed),
            format!("{:.3}", rec.makespan_s),
            format!("{:.3}", rec.server_time_s),
            format!("{:.3}", rec.wall_time_s),
        ]);
    }
    println!("{}", table.render());
    println!("global model bits identical across all arms");
    Ok(())
}

/// The `--adaptive` arm: the per-client control plane (DESIGN.md §11)
/// against static single-codec baselines on a heterogeneous IoT fleet.
/// Every arm is engine-free fake training on the synthetic manifest, so
/// loss curves are flat by construction; the comparison (and the CI
/// gate) is the uplink-bytes / round-makespan Pareto front, written to
/// `adaptive_pareto.csv`.  The policies hand the slow-uplink tail the
/// ternary codec (the heaviest engine-free scheme — HCFL itself needs
/// the engine, DESIGN.md §11), and the adaptive arms install through
/// server FedAdam to exercise the optimizer path at scale.
fn adaptive(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    let smoke = args.flag("smoke");
    let clients = args.usize_or("clients", 1000)?;
    let rounds = args.usize_or("rounds", if smoke { 2 } else { 3 })?;
    let client_threads = args.usize_or("client-threads", 8)?;

    let base_cfg = |scheme: Scheme| {
        let mut cfg = ExperimentConfig::mnist(scheme, rounds);
        cfg.model = "fake".into();
        cfg.fake_train = true;
        cfg.n_clients = clients;
        cfg.data.n_clients = clients;
        cfg.participation = 1.0;
        cfg.local_epochs = 1;
        cfg.batch = 16;
        cfg.data.per_client = 64;
        cfg.data.test_n = 64;
        cfg.data.server_n = 16;
        cfg.data.lazy_shards = true;
        cfg.use_ae_cache = false;
        cfg.send_exact = false;
        cfg.client_threads = client_threads;
        cfg.engine_workers = ctx.engine.n_workers();
        cfg.scenario = ScenarioConfig {
            policy: RoundPolicy::Synchronous,
            devices: DevicePreset::Iot {
                sigma: 0.8,
                dropout_p: 0.0,
            },
            ..ScenarioConfig::default()
        };
        // The story here is the shared uplink; widen the downlink so
        // the model broadcast doesn't mask it.
        cfg.link.downlink_bps = 200e6;
        cfg
    };

    let arms: [(&str, Scheme, CodecPolicy, ServerOptKind); 5] = [
        (
            "static-fedavg",
            Scheme::Fedavg,
            CodecPolicy::Static,
            ServerOptKind::Sgd,
        ),
        (
            "static-topk",
            Scheme::TopK { keep: 0.1 },
            CodecPolicy::Static,
            ServerOptKind::Sgd,
        ),
        (
            "static-ternary",
            Scheme::Ternary,
            CodecPolicy::Static,
            ServerOptKind::Sgd,
        ),
        (
            "uplink-adaptive",
            Scheme::Fedavg,
            CodecPolicy::ThresholdByUplink {
                cutoff: 1.0,
                slow: Scheme::Ternary,
            },
            ServerOptKind::DEFAULT_ADAM,
        ),
        (
            "makespan-adaptive",
            Scheme::Fedavg,
            CodecPolicy::MakespanUnderDistortion {
                budget: 0.6,
                heavy: Scheme::Ternary,
            },
            ServerOptKind::DEFAULT_ADAM,
        ),
    ];

    println!(
        "Adaptive control plane — K={clients}, {rounds} rounds, IoT fleet (sigma 0.8), \
         static vs per-client codecs"
    );
    let mut table = Table::new(&[
        "Arm",
        "Base",
        "Policy",
        "Opt",
        "Makespan (s)",
        "Upload (MB)",
    ]);
    let mut csv = String::from("arm,scheme,policy,opt,up_bytes,makespan_s\n");
    let mut fedavg_makespan = 0.0f64;
    let mut adaptive_makespan = f64::INFINITY;
    for (name, scheme, policy, opt) in arms {
        let mut cfg = base_cfg(scheme);
        cfg.codec_policy = policy;
        cfg.server_opt = opt;
        let mut sim = Simulation::new(&ctx.engine, cfg)?;
        let mut records = Vec::with_capacity(rounds);
        for t in 1..=rounds {
            records.push(sim.run_round(t)?);
        }
        let report = RunReport {
            scheme: scheme.label(),
            model: "fake".into(),
            rounds: records,
        };
        let makespan = report.total_makespan();
        let up_bytes = report.total_up_bytes();
        if name == "static-fedavg" {
            fedavg_makespan = makespan;
        }
        if policy != CodecPolicy::Static {
            adaptive_makespan = adaptive_makespan.min(makespan);
        }
        table.row(vec![
            name.to_string(),
            scheme.label(),
            policy.label(),
            opt.label().to_string(),
            format!("{makespan:.3}"),
            format!("{:.3}", up_bytes as f64 / 1e6),
        ]);
        csv.push_str(&format!(
            "{name},{},{},{},{up_bytes},{makespan}\n",
            scheme.label(),
            policy.label(),
            opt.label()
        ));
    }
    println!("{}", table.render());
    std::fs::create_dir_all(&ctx.out_dir)?;
    let file = ctx.out_dir.join("adaptive_pareto.csv");
    std::fs::write(&file, csv)?;
    eprintln!("[saved] {}", file.display());

    // The CI gate: handing the slow-uplink tail a compact codec must
    // cut the round makespan well past the acceptance bar (20% under
    // the static FedAvg arm on this fleet).
    if adaptive_makespan > 0.8 * fedavg_makespan {
        return Err(HcflError::Engine(format!(
            "adaptive makespan {adaptive_makespan:.3}s did not beat static FedAvg \
             {fedavg_makespan:.3}s by at least 20%"
        )));
    }
    println!(
        "adaptive makespan {:.3}s vs static FedAvg {:.3}s ({:.0}% lower)",
        adaptive_makespan,
        fedavg_makespan,
        100.0 * (1.0 - adaptive_makespan / fedavg_makespan)
    );
    Ok(())
}

/// The `scenarios` experiment driver.
pub fn scenarios(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    if args.flag("sharded-100k") {
        return sharded_100k(ctx);
    }
    if args.flag("adaptive") {
        return adaptive(ctx);
    }
    let smoke = args.flag("smoke");
    let scale = Scale::from_args(args, if smoke { 2 } else { 4 }, 1)?;
    let knobs = Knobs {
        clients: args.usize_or("clients", if smoke { 24 } else { 20 })?,
        rounds: scale.rounds,
        epochs: scale.epochs,
        client_threads: args.usize_or("client-threads", 4)?,
        per_client: match args.str_opt("per-client") {
            Some(_) => Some(args.usize_or("per-client", 600)?),
            None => None,
        },
        slowdown: args.f64_or("slowdown", 8.0)?,
        ratio: args.usize_or("ratio", 32)?,
        smoke,
    };
    let default_fracs: &[usize] = if smoke { &[30] } else { &[10, 30, 50] };
    let fracs = args.usize_list_or("fracs-pct", default_fracs)?;

    println!(
        "Scenario sweep — K={}, {} rounds, stragglers {}x slower{}",
        knobs.clients,
        knobs.rounds,
        knobs.slowdown,
        if smoke { " [smoke: fake train]" } else { "" }
    );
    println!("(round 1 is a synchronous calibration round in every run)");
    let mut table = Table::new(&[
        "Scheme",
        "Stragglers",
        "Policy",
        "Final acc",
        "Participation",
        "Cut/Dropped",
        "Makespan (s)",
        "Upload (MB)",
    ]);

    for &pct in &fracs {
        let frac = pct as f64 / 100.0;
        for scheme in knobs.schemes() {
            let mut cfg = knobs.base_cfg(scheme);
            cfg.scenario = ScenarioConfig {
                policy: RoundPolicy::Synchronous,
                devices: DevicePreset::Stragglers {
                    frac,
                    slowdown: knobs.slowdown,
                },
                ..ScenarioConfig::default()
            };

            // Synchronous baseline, calibrated deadline (keeps every
            // reference device, cuts anything slowed by more than 3x),
            // and fastest-m sized to the expected fast cohort.
            let m = cfg.m();
            let keep = ((m as f64) * (1.0 - frac)).ceil().max(1.0) as usize;
            let policies: [(&str, Box<dyn Fn(f64) -> RoundPolicy>); 3] = [
                ("sync", Box::new(|_| RoundPolicy::Synchronous)),
                (
                    "deadline",
                    Box::new(|t_max_s| RoundPolicy::Deadline { t_max_s }),
                ),
                (
                    "fastest-m",
                    Box::new(move |_| RoundPolicy::FastestM { m: keep }),
                ),
            ];

            // One Simulation per policy run: with the AE cache on (the
            // preset default) the HCFL compressor reloads rather than
            // retrains, so the rebuild only costs data generation.
            for (name, make_policy) in policies {
                let tag = format!("scenario_{}_{pct}pct_{name}", slug(&scheme.label()));
                let report =
                    run_with_policy(ctx, cfg.clone(), knobs.rounds, make_policy, &tag)?;
                table.row(vec![
                    report.scheme.clone(),
                    format!("{pct}%"),
                    name.to_string(),
                    format!("{:.4}", report.final_accuracy()),
                    format!("{:.2}", report.mean_participation()),
                    format!(
                        "{}/{}",
                        report.total_stragglers(),
                        report.total_dropped()
                    ),
                    format!("{:.2}", report.total_makespan()),
                    format!("{:.2}", report.total_up_bytes() as f64 / 1e6),
                ]);
            }
        }
    }
    println!("{}", table.render());

    // ---- carry-over arms: scheme × carry on/off under a deadline -------
    // The session layer's cross-round carry-over: late uploads that a
    // Deadline round would discard are decoded, staleness-discounted and
    // folded into the round they finally reach.  Compare against the
    // discard baseline for both schemes — compression shrinks air time,
    // carry-over recovers the straggler compute the policy cut.
    let carry_lambda = args.f64_or("carry-lambda", 0.5)?;
    let carry_age = args.usize_or("carry-age", 2)?;
    println!(
        "Carry-over arms — calibrated deadline over a 30% x{} straggler fleet",
        knobs.slowdown
    );
    let mut ctable = Table::new(&[
        "Scheme",
        "Carry",
        "Final acc",
        "Participation",
        "Carried in/out",
        "Makespan (s)",
        "Upload (MB)",
    ]);
    for scheme in knobs.schemes() {
        for carry in [
            CarryPolicy::Discard,
            CarryPolicy::CarryDiscounted {
                lambda: carry_lambda,
                max_age_rounds: carry_age,
            },
        ] {
            let mut cfg = knobs.base_cfg(scheme);
            cfg.scenario = ScenarioConfig {
                policy: RoundPolicy::Synchronous,
                devices: DevicePreset::Stragglers {
                    frac: 0.3,
                    slowdown: knobs.slowdown,
                },
                carry: carry.clone(),
                ..ScenarioConfig::default()
            };
            let tag = format!(
                "scenario_carry_{}_{}",
                slug(&scheme.label()),
                if carry.carries() { "on" } else { "off" }
            );
            let report = run_with_policy(
                ctx,
                cfg,
                knobs.rounds,
                |t_max_s| RoundPolicy::Deadline { t_max_s },
                &tag,
            )?;
            ctable.row(vec![
                report.scheme.clone(),
                carry.label(),
                format!("{:.4}", report.final_accuracy()),
                format!("{:.2}", report.mean_participation()),
                format!(
                    "{}/{}",
                    report.total_carried_in(),
                    report.total_carried_out()
                ),
                format!("{:.2}", report.total_makespan()),
                format!("{:.2}", report.total_up_bytes() as f64 / 1e6),
            ]);
        }
    }
    println!("{}", ctable.render());

    // ---- non-IID arms: partition × scheme × aggregator -----------------
    // Calibrated-deadline rounds over a straggler fleet make the
    // surviving set biased; with label-skewed shards that bias reaches
    // the global model, which is what SampleWeighted aggregation exists
    // to correct.  Shard sizes are skewed too (`--size-skew`): with
    // equal shards n_k is constant and SampleWeighted degenerates to the
    // uniform mean.
    if args.flag("iid-only") {
        return Ok(());
    }
    let alpha = args.f64_or("alpha", 0.3)?;
    let spc = args.usize_or("shards-per-client", 2)?;
    let size_skew = args.f64_or("size-skew", 0.3)?;
    let partitions = [
        Partition::Dirichlet { alpha },
        Partition::LabelShards {
            shards_per_client: spc,
        },
    ];
    println!(
        "Non-IID arms — calibrated deadline over a 30% x{} straggler fleet",
        knobs.slowdown
    );
    let mut ntable = Table::new(&[
        "Scheme",
        "Partition",
        "Aggregator",
        "Final acc",
        "Participation",
        "Makespan (s)",
        "Upload (MB)",
    ]);
    for partition in &partitions {
        for scheme in knobs.schemes() {
            for agg in [AggregatorKind::UniformMean, AggregatorKind::SampleWeighted] {
                let mut cfg = knobs.base_cfg(scheme);
                cfg.data.partition = partition.clone();
                cfg.data.size_skew = size_skew;
                cfg.scenario = ScenarioConfig {
                    policy: RoundPolicy::Synchronous,
                    aggregator: agg.clone(),
                    devices: DevicePreset::Stragglers {
                        frac: 0.3,
                        slowdown: knobs.slowdown,
                    },
                    carry: CarryPolicy::Discard,
                };
                let tag = format!(
                    "scenario_noniid_{}_{}_{}",
                    slug(&scheme.label()),
                    slug(&partition.label()),
                    slug(&agg.label())
                );
                let report = run_with_policy(
                    ctx,
                    cfg,
                    knobs.rounds,
                    |t_max_s| RoundPolicy::Deadline { t_max_s },
                    &tag,
                )?;
                ntable.row(vec![
                    report.scheme.clone(),
                    partition.label(),
                    agg.label(),
                    format!("{:.4}", report.final_accuracy()),
                    format!("{:.2}", report.mean_participation()),
                    format!("{:.2}", report.total_makespan()),
                    format!("{:.2}", report.total_up_bytes() as f64 / 1e6),
                ]);
            }
        }
    }
    println!("{}", ntable.render());
    Ok(())
}
