//! Tables I, II and III of the paper.
//!
//! * Table I — LeNet-5 / MNIST communication cost per scheme.
//! * Table II — 5-CNN / EMNIST (8-way dense segmentation) ditto.
//! * Table III — client/server computational delay per compression ratio.
//!
//! The harness reports measured numbers at the configured scale and
//! extrapolates traffic to the paper's 100-round / m-clients-per-round
//! accounting so rows are directly comparable with the paper.

use crate::compression::Scheme;
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::experiments::common::{run_and_save, slug, table_schemes, Scale};
use crate::experiments::registry::ExperimentCtx;
use crate::metrics::Table;
use crate::network::true_ratio;

fn comm_table(ctx: &ExperimentCtx, model: &str, title: &str) -> Result<()> {
    let args = &ctx.args;
    let scale = Scale::from_args(args, 3, 2)?;
    let ratios = args.usize_list_or("ratios", &[4, 8, 16, 32])?;
    println!("{title}");
    println!(
        "(measured over {} rounds, E={}, traffic extrapolated to 100 rounds)",
        scale.rounds, scale.epochs
    );

    let mut table = Table::new(&[
        "Compress Method",
        "Reconstruction error",
        "Encoded Size Up/Down (MB, 100 rounds)",
        "True Compress Ratio",
    ]);

    let mut baseline_up: Option<u64> = None;
    for scheme in table_schemes(&ratios) {
        let mut cfg = if model == "lenet" {
            ExperimentConfig::mnist(scheme, scale.rounds)
        } else {
            ExperimentConfig::emnist(scheme, scale.rounds)
        };
        cfg.local_epochs = scale.epochs;
        // Paper Tables I/II count both directions encoded (§VI-B).
        cfg.compress_downlink = true;
        let report = run_and_save(
            &ctx.engine,
            cfg,
            &ctx.out_dir,
            &format!("{}_{}", model, slug(&scheme.label())),
        )?;

        let rounds = report.rounds.len().max(1) as u64;
        let up_100 = report.total_up_bytes() * 100 / rounds;
        let down_100 = report.total_down_bytes() * 100 / rounds;
        let base = *baseline_up.get_or_insert(up_100);
        table.row(vec![
            report.scheme.clone(),
            if matches!(scheme, Scheme::Fedavg) {
                "0.0".to_string()
            } else {
                format!("{:.4}", report.mean_recon_mse())
            },
            format!("{:.0}/{:.0}", up_100 as f64 / 1e6, down_100 as f64 / 1e6),
            format!("{:.3}", true_ratio(base, up_100)),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Table I: LeNet-5 on (synthetic) MNIST.
pub fn table1(ctx: &ExperimentCtx) -> Result<()> {
    comm_table(
        ctx,
        "lenet",
        "Table I — HCFL vs compression baselines, LeNet-5 / MNIST (C=0.1, K=100)",
    )
}

/// Table II: 5-CNN on (synthetic) EMNIST with 8-way dense segmentation.
pub fn table2(ctx: &ExperimentCtx) -> Result<()> {
    comm_table(
        ctx,
        "fivecnn",
        "Table II — HCFL vs compression baselines, 5-CNN / EMNIST (C=0.1, K=100, dense 8-way)",
    )
}

/// Table III: average client/server computational delay per ratio.
pub fn table3(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    let scale = Scale::from_args(args, 2, 1)?;
    let ratios = args.usize_list_or("ratios", &[4, 8, 16, 32])?;
    let models: Vec<&str> = if args.flag("full") {
        vec!["lenet", "fivecnn"]
    } else {
        vec![args.str_or("model", "lenet")]
    };

    for model in models {
        println!(
            "Table III — computational delay, {model} (averaged over {} rounds)",
            scale.rounds
        );
        let mut table = Table::new(&[
            "Compression Ratio",
            "client (s)",
            "server (s)",
        ]);
        let mut schemes = vec![Scheme::Fedavg];
        schemes.extend(ratios.iter().map(|&r| Scheme::Hcfl { ratio: r }));
        for scheme in schemes {
            let mut cfg = if model == "lenet" {
                ExperimentConfig::mnist(scheme, scale.rounds)
            } else {
                ExperimentConfig::emnist(scheme, scale.rounds)
            };
            cfg.local_epochs = scale.epochs;
            let report = run_and_save(
                &ctx.engine,
                cfg,
                &ctx.out_dir,
                &format!("table3_{}_{}", model, slug(&scheme.label())),
            )?;
            let label = match scheme {
                Scheme::Fedavg => "Baseline".to_string(),
                Scheme::Hcfl { ratio } => format!("1:{ratio}"),
                other => other.label(),
            };
            table.row(vec![
                label,
                format!("{:.3}", report.mean_client_time()),
                format!("{:.4}", report.mean_server_time()),
            ]);
        }
        println!("{}", table.render());
    }
    Ok(())
}
