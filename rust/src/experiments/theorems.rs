//! Theorem 1 / Theorem 2 verification experiments (paper §IV, §V).

use std::collections::BTreeMap;

use crate::config::ExperimentConfig;
use crate::compression::Scheme;
use crate::coordinator::session::build_compressor;
use crate::data::synthetic;
use crate::error::Result;
use crate::experiments::registry::ExperimentCtx;
use crate::fl::LocalTrainer;
use crate::hcfl::{chunk_dataset, premodel_snapshots};
use crate::metrics::Table;
use crate::model::{init_flat, merge_segment_ranges, split_dense};
use crate::theory::{empirical_deviation_prob, theorem1_bound, theorem2_estimate};
use crate::util::rng::Rng;

/// Theorem 1: measured `P(|w̃ − w| ≥ α)` vs the `2/(Kα)²·L(w)` bound.
///
/// We produce K independently-trained client models through the real
/// pipeline, compress/decompress each with HCFL, and compare the
/// aggregated deviation probability against the bound at several K.
pub fn thm1(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    let ratio = args.usize_or("ratio", 16)?;
    let ks = args.usize_list_or("clients", &[2, 5, 10, 25, 50])?;
    let alpha = args.f64_or("alpha", 0.002)?;
    let k_max = ks.iter().copied().max().unwrap_or(10);

    let mut cfg = ExperimentConfig::mnist(Scheme::Hcfl { ratio }, 1);
    cfg.n_clients = k_max;
    cfg.data.n_clients = k_max;
    let data = synthetic(&cfg.data, cfg.seed);
    let trainer = LocalTrainer::new(&ctx.engine, &cfg.model)?;
    let mut rng = Rng::new(cfg.seed);
    let global = init_flat(&trainer.model.layers, &mut rng);
    let compressor = build_compressor(&ctx.engine, &cfg, &data, &global)?;

    // K client models, exact and reconstructed.
    let mut clean = Vec::with_capacity(k_max);
    let mut noisy = Vec::with_capacity(k_max);
    let mut l_w_sum = 0.0;
    for k in 0..k_max {
        let out = trainer.train(&global, &data.shard(k), 1, cfg.batch, cfg.lr, &mut rng, 0)?;
        // Mirror the run pipeline: delta-encode against the broadcast.
        let delta: Vec<f32> = out.params.iter().zip(&global).map(|(w, g)| w - g).collect();
        let upd = compressor.compress(&delta, 0)?;
        let mut recon = compressor.decompress(upd, trainer.model.d, 0)?;
        for (v, g) in recon.iter_mut().zip(&global) {
            *v += g;
        }
        l_w_sum += out
            .params
            .iter()
            .zip(&recon)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / trainer.model.d as f64;
        clean.push(out.params);
        noisy.push(recon);
    }
    let l_w = l_w_sum / k_max as f64;

    println!(
        "Theorem 1 — aggregated deviation vs bound (HCFL 1:{ratio}, L(w)={l_w:.3e}, α={alpha})"
    );
    let mut table = Table::new(&["K", "bound 2/(Kα)²·L(w)", "measured P(|dev|≥α)"]);
    for &k in &ks {
        let bound = theorem1_bound(l_w, k, alpha);
        let measured = empirical_deviation_prob(&clean[..k], &noisy[..k], alpha);
        table.row(vec![
            format!("{k}"),
            format!("{bound:.4e}"),
            format!("{measured:.4e}"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper's worked example: K=10000, α=0.01, L=2.5 -> bound {:.4e}",
        crate::theory::paper_example()
    );
    Ok(())
}

/// Theorem 2: entropy-gap estimate of the reconstruction loss vs the
/// measured AE reconstruction MSE, per compression ratio.
pub fn thm2(ctx: &ExperimentCtx) -> Result<()> {
    let args = &ctx.args;
    let ratios = args.usize_list_or("ratios", &[4, 8, 16, 32])?;
    let bins = args.usize_or("bins", 64)?;
    let model_name = args.str_or("model", "lenet").to_string();

    let mut cfg = ExperimentConfig::mnist(Scheme::Fedavg, 1);
    cfg.model = model_name.clone();
    cfg.encode_deltas = false; // thm2 analyses the raw weight distribution
    let data = synthetic(&cfg.data, cfg.seed);
    let model = ctx.engine.manifest().model(&model_name)?.clone();
    let ranges = split_dense(&merge_segment_ranges(&model.layers), cfg.dense_parts);
    let chunk_of_segment: BTreeMap<String, usize> = ctx.engine.manifest().chunks.clone();

    // Weight-chunk dataset from the pre-model phase (the distribution the
    // AEs are trained on), starting from a reference init.
    let mut rng = Rng::new(cfg.seed);
    let init = init_flat(&model.layers, &mut rng);
    let snaps = premodel_snapshots(&ctx.engine, &model_name, &data.server, &cfg.ae, &init)?;
    let dense_chunk = chunk_of_segment["dense"];
    let rows = chunk_dataset(&snaps, &ranges, &chunk_of_segment, dense_chunk);

    println!(
        "Theorem 2 — entropy-gap estimate vs measured reconstruction MSE ({model_name}, dense c{dense_chunk})"
    );
    let mut table = Table::new(&["ratio", "H(W) bits", "H(C) bits", "est. L(w)", "measured MSE"]);
    for &ratio in &ratios {
        let mut hcfg = cfg.clone();
        hcfg.scheme = Scheme::Hcfl { ratio };
        let compressor = build_compressor(&ctx.engine, &hcfg, &data, &init)?;

        // H(W) over a sample of the weight-chunk distribution.
        let mut weights = Vec::new();
        for row in rows.iter().take(64) {
            weights.extend_from_slice(row);
        }
        let mut codes = Vec::new();
        let mut mse_sum = 0.0;
        let mut mse_n = 0usize;
        // Full-pipeline measurement on a snapshot row vector.
        let snap = &snaps[snaps.len() - 1];
        let upd = compressor.compress(snap, 0)?;
        // (snapshots here are raw-weight rows; the compressor was built
        // with the same convention via cfg.encode_deltas = false below)
        if let crate::compression::Payload::HcflCodes(rcs) = &upd.payload {
            for rc in rcs {
                codes.extend_from_slice(&rc.codes);
            }
        }
        let recon = compressor.decompress(upd, model.d, 0)?;
        mse_sum += snap
            .iter()
            .zip(&recon)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>();
        mse_n += model.d;

        let h_w = crate::util::stats::histogram_entropy(&weights, bins);
        let h_c = crate::util::stats::histogram_entropy(&codes, bins);
        let est = theorem2_estimate(&weights, &codes, dense_chunk, bins);
        table.row(vec![
            format!("1:{ratio}"),
            format!("{h_w:.3}"),
            format!("{h_c:.3}"),
            format!("{est:.3e}"),
            format!("{:.3e}", mse_sum / mse_n as f64),
        ]);
    }
    println!("{}", table.render());
    println!("expected shape: higher ratio -> lower H(C) -> larger entropy gap and larger measured MSE");
    Ok(())
}
