//! The aggregation layer: how decoded client updates fold into the next
//! global model.
//!
//! Algorithm 1 of the paper is the uniform streaming mean over arrivals
//! ([`UniformMean`], bit-identical to [`super::RunningAverage`]).  The
//! semi-synchronous policies of the clock layer motivate two more:
//! [`AggregatorKind::SampleWeighted`] (classic FedAvg `n_k / n`
//! weighting, which matters once deadline cuts make the surviving set
//! biased) and [`AggregatorKind::StalenessDiscounted`] (exponentially
//! down-weights late arrivals relative to the fastest, as in
//! adaptive/asynchronous FL for IoT).
//!
//! Two folds implement those rules:
//!
//! * the streaming [`Aggregator`]s below — the sequential reference
//!   (`acc += (x − acc)·w/W`), kept for the pre-refactor regression
//!   guarantee and single-threaded callers;
//! * the **reduction tree** ([`WeightedLeaf`] / [`combine_leaves`] /
//!   [`finish_tree`]) — the coordinator's hot path at K=10k.  Leaves are
//!   weight-scaled updates in modelled arrival order; interior nodes
//!   combine a fixed fan-in ([`TREE_FAN_IN`]) of consecutive children
//!   left-to-right.  The tree *shape* and every per-node summation order
//!   depend only on the leaf order, never on which pool thread computes
//!   a node, so the fold is bit-identical for any `client_threads`
//!   (`tests/pool_determinism.rs`).  The parallel driver lives in
//!   [`crate::coordinator::pool::reduce_tree`].

use crate::compression::simd;
use crate::error::{HcflError, Result};
use crate::fl::RunningAverage;

/// Per-update context the clock layer hands the aggregator.
#[derive(Debug, Clone)]
pub struct UpdateMeta {
    /// Global client id.
    pub client: usize,
    /// Samples on the client's shard (FedAvg `n_k`).
    pub n_samples: usize,
    /// Modelled arrival time of the upload (seconds after broadcast).
    pub arrival_s: f64,
}

/// Which aggregation rule a run uses.
#[derive(Debug, Clone, PartialEq)]
pub enum AggregatorKind {
    /// Algorithm 1's uniform running average over arrivals.
    UniformMean,
    /// Weight each update by its shard size `n_k`.
    SampleWeighted,
    /// Weight by `exp(-lambda * (arrival - fastest_arrival))`.
    StalenessDiscounted { lambda: f64 },
}

impl AggregatorKind {
    pub fn label(&self) -> String {
        match self {
            AggregatorKind::UniformMean => "uniform-mean".to_string(),
            AggregatorKind::SampleWeighted => "sample-weighted".to_string(),
            AggregatorKind::StalenessDiscounted { lambda } => {
                format!("staleness l={lambda:.2}")
            }
        }
    }

    /// Construct the aggregator for a `d`-dimensional model.
    pub fn build(&self, d: usize) -> Box<dyn Aggregator> {
        match self {
            AggregatorKind::UniformMean => Box::new(UniformMean::new(d)),
            AggregatorKind::SampleWeighted => Box::new(WeightedMean::sample_weighted(d)),
            AggregatorKind::StalenessDiscounted { lambda } => {
                Box::new(WeightedMean::staleness(d, *lambda))
            }
        }
    }

    /// One update's scalar weight under this rule.  `t0_arrival` is the
    /// fastest surviving arrival (the staleness reference); the uniform
    /// and sample rules ignore it.  Shared by the streaming fold and
    /// the reduction-tree leaves so both paths implement the exact same
    /// weighting.
    pub fn weight(&self, meta: &UpdateMeta, t0_arrival: f64) -> Result<f64> {
        match self {
            AggregatorKind::UniformMean => Ok(1.0),
            AggregatorKind::SampleWeighted => {
                if meta.n_samples == 0 {
                    return Err(HcflError::Config(format!(
                        "client {} has an empty shard; sample weighting undefined",
                        meta.client
                    )));
                }
                Ok(meta.n_samples as f64)
            }
            AggregatorKind::StalenessDiscounted { lambda } => {
                Ok((-lambda * (meta.arrival_s - t0_arrival).max(0.0)).exp())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reduction tree
// ---------------------------------------------------------------------------

/// Fan-in of the deterministic reduction tree.  Fixed — the tree shape
/// must be a pure function of the leaf count, never of the pool size.
pub const TREE_FAN_IN: usize = 8;

/// One reduction-tree node: the weighted sum `Σ wᵢ·xᵢ` of the leaves
/// under it (f32, elementwise) plus the exact total weight (f64).
pub struct WeightedLeaf {
    pub weight: f64,
    pub sum: Vec<f32>,
}

impl WeightedLeaf {
    /// Scale a decoded update into a leaf.  The multiply runs in f64 and
    /// rounds once per element, so a weight of exactly 1.0 (uniform
    /// mean) leaves the bits untouched.
    pub fn new(weight: f64, mut x: Vec<f32>) -> WeightedLeaf {
        if weight != 1.0 {
            simd::scale_f64(&mut x, weight);
        }
        WeightedLeaf { weight, sum: x }
    }
}

/// Combine a group of consecutive children into their parent node by
/// folding left-to-right into the first child's buffer (no allocation).
/// The group is always a contiguous arrival-order slice, so the
/// summation order is fixed by the leaf order alone.
pub fn combine_leaves(group: Vec<WeightedLeaf>) -> Result<WeightedLeaf> {
    let mut spent = Vec::new();
    combine_leaves_recycled(group, &mut spent)
}

/// [`combine_leaves`], handing the spent child buffers back to the
/// caller instead of dropping them — the pool's reduce jobs return them
/// to the per-worker arena so folds allocate nothing in steady state.
/// The arithmetic is exactly `combine_leaves`'s.
pub fn combine_leaves_recycled(
    group: Vec<WeightedLeaf>,
    spent: &mut Vec<Vec<f32>>,
) -> Result<WeightedLeaf> {
    let mut iter = group.into_iter();
    let mut acc = iter
        .next()
        .ok_or_else(|| HcflError::Config("combining an empty leaf group".into()))?;
    for leaf in iter {
        if leaf.sum.len() != acc.sum.len() {
            return Err(HcflError::Config(format!(
                "aggregation dim mismatch: {} vs {}",
                leaf.sum.len(),
                acc.sum.len()
            )));
        }
        acc.weight += leaf.weight;
        simd::add_assign(&mut acc.sum, &leaf.sum);
        spent.push(leaf.sum);
    }
    Ok(acc)
}

/// Normalize the root node into the aggregated model:
/// `out = (Σ wᵢ·xᵢ) / Σ wᵢ`, dividing in f64 per element — in place,
/// the root's own buffer becomes the model.
pub fn finish_tree(root: WeightedLeaf) -> Result<Vec<f32>> {
    if root.weight <= 0.0 || !root.weight.is_finite() {
        return Err(HcflError::Config(format!(
            "aggregating zero total weight ({})",
            root.weight
        )));
    }
    let mut out = root.sum;
    simd::div_f64(&mut out, root.weight);
    Ok(out)
}

/// Streaming fold of decoded updates (pushed in modelled arrival order).
pub trait Aggregator: Send {
    /// Fold one decoded client model into the aggregate.
    fn push(&mut self, w: &[f32], meta: &UpdateMeta) -> Result<()>;

    /// Updates folded so far.
    fn count(&self) -> usize;

    /// The aggregated model (error if nothing was pushed).
    fn finish(self: Box<Self>) -> Result<Vec<f32>>;
}

/// Algorithm 1's uniform mean; delegates to [`RunningAverage`] so the
/// arithmetic is bit-identical to the pre-refactor coordinator.
pub struct UniformMean {
    inner: RunningAverage,
}

impl UniformMean {
    pub fn new(d: usize) -> UniformMean {
        UniformMean {
            inner: RunningAverage::new(d),
        }
    }
}

impl Aggregator for UniformMean {
    fn push(&mut self, w: &[f32], _meta: &UpdateMeta) -> Result<()> {
        self.inner.push(w)
    }

    fn count(&self) -> usize {
        self.inner.count()
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.inner.finish()
    }
}

enum Weighting {
    Samples,
    Staleness { lambda: f64, t0: Option<f64> },
}

/// Streaming weighted mean: after each push the accumulator equals the
/// weighted mean of everything pushed (`acc += (w - acc) * wt/W_total`).
pub struct WeightedMean {
    acc: Vec<f32>,
    total_w: f64,
    count: usize,
    weighting: Weighting,
}

impl WeightedMean {
    pub fn sample_weighted(d: usize) -> WeightedMean {
        WeightedMean {
            acc: vec![0.0; d],
            total_w: 0.0,
            count: 0,
            weighting: Weighting::Samples,
        }
    }

    pub fn staleness(d: usize, lambda: f64) -> WeightedMean {
        WeightedMean {
            acc: vec![0.0; d],
            total_w: 0.0,
            count: 0,
            weighting: Weighting::Staleness { lambda, t0: None },
        }
    }

    fn weight_of(&mut self, meta: &UpdateMeta) -> Result<f64> {
        // Same rule as the reduction-tree leaves: delegate to
        // `AggregatorKind::weight` so the two folds can never drift.
        match &mut self.weighting {
            Weighting::Samples => AggregatorKind::SampleWeighted.weight(meta, 0.0),
            Weighting::Staleness { lambda, t0 } => {
                // Updates arrive in modelled arrival order, so the first
                // push fixes the freshness reference.
                let t0 = *t0.get_or_insert(meta.arrival_s);
                AggregatorKind::StalenessDiscounted { lambda: *lambda }.weight(meta, t0)
            }
        }
    }
}

impl Aggregator for WeightedMean {
    fn push(&mut self, w: &[f32], meta: &UpdateMeta) -> Result<()> {
        if w.len() != self.acc.len() {
            return Err(HcflError::Config(format!(
                "aggregation dim mismatch: {} vs {}",
                w.len(),
                self.acc.len()
            )));
        }
        let wt = self.weight_of(meta)?;
        self.total_w += wt;
        self.count += 1;
        let f = (wt / self.total_w) as f32;
        for (a, &x) in self.acc.iter_mut().zip(w) {
            *a += (x - *a) * f;
        }
        Ok(())
    }

    fn count(&self) -> usize {
        self.count
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        if self.count == 0 {
            return Err(HcflError::Config("aggregating zero updates".into()));
        }
        Ok(self.acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(client: usize, n_samples: usize, arrival_s: f64) -> UpdateMeta {
        UpdateMeta {
            client,
            n_samples,
            arrival_s,
        }
    }

    #[test]
    fn uniform_mean_is_bit_identical_to_running_average() {
        let mut rng = crate::util::rng::Rng::new(17);
        let updates: Vec<Vec<f32>> = (0..7)
            .map(|_| (0..33).map(|_| rng.normal() * 0.3).collect())
            .collect();
        let mut reference = RunningAverage::new(33);
        let mut agg: Box<dyn Aggregator> = AggregatorKind::UniformMean.build(33);
        for (i, u) in updates.iter().enumerate() {
            reference.push(u).unwrap();
            agg.push(u, &meta(i, 100, i as f64)).unwrap();
        }
        let a = reference.finish().unwrap();
        let b = agg.finish().unwrap();
        // exact f32 equality, not approximate: same fold, same bits
        assert_eq!(a, b);
    }

    #[test]
    fn sample_weighted_equals_uniform_for_equal_shards() {
        let updates = [vec![1.0f32, -2.0], vec![3.0, 0.5], vec![-1.0, 4.0]];
        let mut uni: Box<dyn Aggregator> = AggregatorKind::UniformMean.build(2);
        let mut wtd: Box<dyn Aggregator> = AggregatorKind::SampleWeighted.build(2);
        for (i, u) in updates.iter().enumerate() {
            uni.push(u, &meta(i, 600, 0.0)).unwrap();
            wtd.push(u, &meta(i, 600, 0.0)).unwrap();
        }
        assert_eq!(uni.finish().unwrap(), wtd.finish().unwrap());
    }

    #[test]
    fn sample_weighted_tracks_shard_sizes() {
        let mut agg: Box<dyn Aggregator> = AggregatorKind::SampleWeighted.build(1);
        agg.push(&[0.0], &meta(0, 300, 0.0)).unwrap();
        agg.push(&[1.0], &meta(1, 100, 0.0)).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 0.25).abs() < 1e-6, "got {}", out[0]);
    }

    #[test]
    fn sample_weighted_rejects_empty_shard() {
        let mut agg: Box<dyn Aggregator> = AggregatorKind::SampleWeighted.build(1);
        assert!(agg.push(&[1.0], &meta(0, 0, 0.0)).is_err());
    }

    #[test]
    fn staleness_downweights_late_arrivals() {
        let lambda = 1.0;
        let mut agg: Box<dyn Aggregator> =
            AggregatorKind::StalenessDiscounted { lambda }.build(1);
        // fastest at t=2 (reference), late at t=2+ln(3) with weight 1/3
        agg.push(&[0.0], &meta(0, 1, 2.0)).unwrap();
        agg.push(&[1.0], &meta(1, 1, 2.0 + 3.0f64.ln())).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 0.25).abs() < 1e-6, "got {}", out[0]);
    }

    #[test]
    fn staleness_with_zero_lambda_is_uniform() {
        let updates = [vec![2.0f32], vec![4.0], vec![9.0]];
        let mut uni: Box<dyn Aggregator> = AggregatorKind::UniformMean.build(1);
        let mut stale: Box<dyn Aggregator> =
            AggregatorKind::StalenessDiscounted { lambda: 0.0 }.build(1);
        for (i, u) in updates.iter().enumerate() {
            uni.push(u, &meta(i, 1, i as f64)).unwrap();
            stale.push(u, &meta(i, 1, i as f64)).unwrap();
        }
        let (a, b) = (uni.finish().unwrap(), stale.finish().unwrap());
        assert!((a[0] - b[0]).abs() < 1e-6);
    }

    #[test]
    fn dim_mismatch_and_empty_finish_error() {
        let mut agg: Box<dyn Aggregator> = AggregatorKind::SampleWeighted.build(2);
        assert!(agg.push(&[1.0], &meta(0, 1, 0.0)).is_err());
        assert!(AggregatorKind::SampleWeighted.build(2).finish().is_err());
        assert!(AggregatorKind::UniformMean.build(2).finish().is_err());
    }

    /// Sequential reference of the tree fold: combine fan-in-sized
    /// consecutive groups level by level (what `pool::reduce_tree`
    /// computes in parallel).
    fn tree_fold(mut nodes: Vec<WeightedLeaf>, fan_in: usize) -> WeightedLeaf {
        while nodes.len() > 1 {
            let mut next = Vec::with_capacity(nodes.len().div_ceil(fan_in));
            let mut iter = nodes.into_iter().peekable();
            while iter.peek().is_some() {
                let group: Vec<WeightedLeaf> = iter.by_ref().take(fan_in).collect();
                next.push(combine_leaves(group).unwrap());
            }
            nodes = next;
        }
        nodes.pop().unwrap()
    }

    #[test]
    fn tree_uniform_mean_equals_plain_mean() {
        let mut rng = crate::util::rng::Rng::new(7);
        let updates: Vec<Vec<f32>> = (0..23)
            .map(|_| (0..17).map(|_| rng.normal()).collect())
            .collect();
        let leaves: Vec<WeightedLeaf> = updates
            .iter()
            .map(|u| WeightedLeaf::new(1.0, u.clone()))
            .collect();
        let out = finish_tree(tree_fold(leaves, TREE_FAN_IN)).unwrap();
        for j in 0..17 {
            let mean: f64 =
                updates.iter().map(|u| u[j] as f64).sum::<f64>() / updates.len() as f64;
            assert!((out[j] as f64 - mean).abs() < 1e-5, "dim {j}");
        }
        // unit weight must not perturb the leaf bits
        let leaf = WeightedLeaf::new(1.0, updates[0].clone());
        assert_eq!(leaf.sum, updates[0]);
    }

    #[test]
    fn tree_matches_streaming_weighted_mean() {
        let mut rng = crate::util::rng::Rng::new(8);
        let updates: Vec<(Vec<f32>, usize)> = (0..19)
            .map(|i| {
                (
                    (0..9).map(|_| rng.normal() * 0.4).collect(),
                    100 + 37 * i,
                )
            })
            .collect();
        let mut streaming: Box<dyn Aggregator> = AggregatorKind::SampleWeighted.build(9);
        let mut leaves = Vec::new();
        for (i, (u, n)) in updates.iter().enumerate() {
            let m = meta(i, *n, i as f64);
            streaming.push(u, &m).unwrap();
            let w = AggregatorKind::SampleWeighted.weight(&m, 0.0).unwrap();
            leaves.push(WeightedLeaf::new(w, u.clone()));
        }
        let a = streaming.finish().unwrap();
        let b = finish_tree(tree_fold(leaves, TREE_FAN_IN)).unwrap();
        // different summation orders, same mean up to f32 rounding noise
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn tree_error_paths() {
        assert!(combine_leaves(Vec::new()).is_err());
        let bad = vec![
            WeightedLeaf::new(1.0, vec![1.0, 2.0]),
            WeightedLeaf::new(1.0, vec![1.0]),
        ];
        assert!(combine_leaves(bad).is_err());
        assert!(finish_tree(WeightedLeaf {
            weight: 0.0,
            sum: vec![1.0]
        })
        .is_err());
    }

    #[test]
    fn weight_rule_matches_streaming_semantics() {
        let kind = AggregatorKind::StalenessDiscounted { lambda: 1.0 };
        let w0 = kind.weight(&meta(0, 1, 2.0), 2.0).unwrap();
        let w1 = kind.weight(&meta(1, 1, 2.0 + 3.0f64.ln()), 2.0).unwrap();
        assert!((w0 - 1.0).abs() < 1e-12);
        assert!((w1 - 1.0 / 3.0).abs() < 1e-12);
        assert!(AggregatorKind::SampleWeighted
            .weight(&meta(0, 0, 0.0), 0.0)
            .is_err());
        assert_eq!(
            AggregatorKind::UniformMean.weight(&meta(0, 0, 9.0), 0.0).unwrap(),
            1.0
        );
    }

    #[test]
    fn labels() {
        assert_eq!(AggregatorKind::UniformMean.label(), "uniform-mean");
        assert_eq!(AggregatorKind::SampleWeighted.label(), "sample-weighted");
        assert!(AggregatorKind::StalenessDiscounted { lambda: 0.5 }
            .label()
            .contains("0.50"));
    }
}
