//! Client-side local training and evaluation through AOT executables.
//!
//! `ClientUpdates` of Algorithm 1: E epochs of mini-batch SGD on the
//! client's shard.  When the configured batch size matches the baked
//! `train_epoch` executable, a whole epoch runs in ONE dispatch
//! (`lax.scan` inside the graph); otherwise the per-batch `train_step_bN`
//! variant is looped.

use crate::data::Dataset;
use crate::error::{HcflError, Result};
use crate::runtime::{Engine, ModelMeta};
use crate::tensor::TensorValue;
use crate::util::rng::Rng;

/// Result of one local-training call.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    pub params: Vec<f32>,
    /// Mean training loss over the epochs.
    pub mean_loss: f64,
}

/// Runs a model's train/eval executables for one simulated client.
#[derive(Clone)]
pub struct LocalTrainer {
    engine: Engine,
    pub model: ModelMeta,
}

impl LocalTrainer {
    pub fn new(engine: &Engine, model_name: &str) -> Result<LocalTrainer> {
        let model = engine.manifest().model(model_name)?.clone();
        Ok(LocalTrainer {
            engine: engine.clone(),
            model,
        })
    }

    /// E epochs of local SGD (Algorithm 1 `ClientUpdates`).
    pub fn train(
        &self,
        params: &[f32],
        shard: &Dataset,
        epochs: usize,
        batch: usize,
        lr: f32,
        rng: &mut Rng,
        worker: usize,
    ) -> Result<LocalOutcome> {
        if params.len() != self.model.d {
            return Err(HcflError::Config(format!(
                "params len {} != model d {}",
                params.len(),
                self.model.d
            )));
        }
        let ep = &self.model.train_epoch;
        let use_epoch_exec = batch == ep.batch && shard.n >= ep.batch * ep.n_batches;

        let mut flat = params.to_vec();
        let mut losses = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            if use_epoch_exec {
                let (xs, ys) = shard.epoch_batches(ep.batch, ep.n_batches, rng)?;
                let outs = self.engine.call_on(
                    worker,
                    &ep.name,
                    vec![
                        TensorValue::vec_f32(flat),
                        TensorValue::f32(xs, vec![ep.n_batches, ep.batch, shard.dim])?,
                        TensorValue::i32(ys, vec![ep.n_batches, ep.batch])?,
                        TensorValue::scalar_f32(lr),
                    ],
                )?;
                let mut it = outs.into_iter();
                flat = it
                    .next()
                    .ok_or_else(|| HcflError::Engine("epoch exec returned nothing".into()))?
                    .into_f32()?;
                losses.push(it.next().map(|l| l.scalar()).transpose()?.unwrap_or(0.0) as f64);
            } else {
                let exec = self.model.train_step.get(&batch).ok_or_else(|| {
                    HcflError::Config(format!(
                        "no train_step executable for batch {batch} (baked: {:?})",
                        self.model.train_step.keys().collect::<Vec<_>>()
                    ))
                })?;
                let n_batches = shard.n / batch;
                if n_batches == 0 {
                    return Err(HcflError::Data(format!(
                        "shard of {} rows cannot form a batch of {batch}",
                        shard.n
                    )));
                }
                let mut idx: Vec<usize> = (0..shard.n).collect();
                rng.shuffle(&mut idx);
                let mut epoch_loss = 0.0f64;
                for b in 0..n_batches {
                    let rows = &idx[b * batch..(b + 1) * batch];
                    let (x, y) = shard.gather(rows);
                    let outs = self.engine.call_on(
                        worker,
                        exec,
                        vec![
                            TensorValue::vec_f32(flat),
                            TensorValue::f32(x, vec![batch, shard.dim])?,
                            TensorValue::i32(y, vec![batch])?,
                            TensorValue::scalar_f32(lr),
                        ],
                    )?;
                    let mut it = outs.into_iter();
                    flat = it
                        .next()
                        .ok_or_else(|| {
                            HcflError::Engine("train_step returned nothing".into())
                        })?
                        .into_f32()?;
                    epoch_loss +=
                        it.next().map(|l| l.scalar()).transpose()?.unwrap_or(0.0) as f64;
                }
                losses.push(epoch_loss / n_batches as f64);
            }
        }
        Ok(LocalOutcome {
            params: flat,
            mean_loss: crate::util::stats::mean(&losses),
        })
    }

    /// Accuracy + mean loss on a test set (batched through the eval
    /// executable; the set size must be a multiple of the eval batch).
    pub fn evaluate(&self, params: &[f32], test: &Dataset, worker: usize) -> Result<(f64, f64)> {
        let ev = &self.model.eval;
        if test.n % ev.batch != 0 || test.n == 0 {
            return Err(HcflError::Config(format!(
                "test set size {} must be a positive multiple of eval batch {}",
                test.n, ev.batch
            )));
        }
        let n_batches = test.n / ev.batch;
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        for b in 0..n_batches {
            let rows: Vec<usize> = (b * ev.batch..(b + 1) * ev.batch).collect();
            let (x, y) = test.gather(&rows);
            let outs = self.engine.call_on(
                worker,
                &ev.name,
                vec![
                    TensorValue::vec_f32(params.to_vec()),
                    TensorValue::f32(x, vec![ev.batch, test.dim])?,
                    TensorValue::i32(y, vec![ev.batch])?,
                ],
            )?;
            correct += outs[0].scalar()? as f64;
            loss_sum += outs[1].scalar()? as f64;
        }
        Ok((
            correct / test.n as f64,
            loss_sum / n_batches as f64,
        ))
    }
}
