//! The FedAvg substrate (paper §II-B, Algorithm 1): local training on
//! client shards, client selection, and the pluggable aggregation layer.

pub mod aggregate;
mod client;
mod server;

pub use aggregate::{Aggregator, AggregatorKind, UpdateMeta};
pub use client::{LocalOutcome, LocalTrainer};
pub use server::{select_clients, RunningAverage, Server};
