//! The FedAvg substrate (paper §II-B, Algorithm 1): local training on
//! client shards, client selection, and running-average aggregation.

mod client;
mod server;

pub use client::{LocalOutcome, LocalTrainer};
pub use server::{select_clients, RunningAverage, Server};
