//! The FedAvg substrate (paper §II-B, Algorithm 1): local training on
//! client shards, client selection, and the pluggable aggregation layer.

pub mod aggregate;
mod client;
mod server;

pub use aggregate::{
    combine_leaves, combine_leaves_recycled, finish_tree, Aggregator, AggregatorKind,
    UpdateMeta, WeightedLeaf,
    TREE_FAN_IN,
};
pub use client::{LocalOutcome, LocalTrainer};
pub use server::{select_clients, RunningAverage, Server};
