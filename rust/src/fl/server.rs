//! Server-side FedAvg: client selection and running-average aggregation.

use crate::error::{HcflError, Result};
use crate::model::ParamSet;
use crate::runtime::ModelMeta;
use crate::util::rng::Rng;

/// Select `m = max(1, K*C)` distinct clients for a round (Algorithm 1).
pub fn select_clients(k: usize, c: f64, rng: &mut Rng) -> Vec<usize> {
    let m = ((k as f64 * c).round() as usize).clamp(1, k);
    rng.choose(k, m)
}

/// Streaming mean over decoded client updates, in FIFO arrival order —
/// Algorithm 1's `w ← (k−1)/k · w + 1/k · w_k`.
#[derive(Debug, Clone)]
pub struct RunningAverage {
    acc: Vec<f32>,
    count: usize,
}

impl RunningAverage {
    pub fn new(d: usize) -> RunningAverage {
        RunningAverage {
            acc: vec![0.0; d],
            count: 0,
        }
    }

    /// Fold one decoded client model into the average.
    pub fn push(&mut self, w: &[f32]) -> Result<()> {
        if w.len() != self.acc.len() {
            return Err(HcflError::Config(format!(
                "aggregation dim mismatch: {} vs {}",
                w.len(),
                self.acc.len()
            )));
        }
        self.count += 1;
        let inv = 1.0 / self.count as f32;
        for (a, &x) in self.acc.iter_mut().zip(w) {
            *a += (x - *a) * inv;
        }
        Ok(())
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// The aggregated model (error if nothing was pushed).
    pub fn finish(self) -> Result<Vec<f32>> {
        if self.count == 0 {
            return Err(HcflError::Config("aggregating zero updates".into()));
        }
        Ok(self.acc)
    }
}

/// The FL server: owns the global model.
pub struct Server {
    pub global: ParamSet,
    pub model: ModelMeta,
}

impl Server {
    /// Fresh server with fan-in-initialized global parameters.
    pub fn new(model: &ModelMeta, rng: &mut Rng) -> Server {
        Server {
            global: ParamSet::init(model, rng),
            model: model.clone(),
        }
    }

    /// Replace the global model with an aggregated one.
    pub fn install(&mut self, aggregated: Vec<f32>) -> Result<()> {
        if aggregated.len() != self.model.d {
            return Err(HcflError::Config(format!(
                "aggregated dim {} != model d {}",
                aggregated.len(),
                self.model.d
            )));
        }
        self.global = ParamSet { flat: aggregated };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_size_and_uniqueness() {
        let mut rng = Rng::new(1);
        let sel = select_clients(100, 0.1, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        // C so small that m would be 0 -> clamped to 1
        assert_eq!(select_clients(5, 0.0, &mut rng).len(), 1);
        // full participation
        assert_eq!(select_clients(7, 1.0, &mut rng).len(), 7);
    }

    #[test]
    fn running_average_equals_mean() {
        let mut ra = RunningAverage::new(3);
        ra.push(&[1.0, 2.0, 3.0]).unwrap();
        ra.push(&[3.0, 2.0, 1.0]).unwrap();
        ra.push(&[2.0, 2.0, 2.0]).unwrap();
        let m = ra.finish().unwrap();
        for v in m {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn running_average_order_independent_mean() {
        // FIFO arrival order must not change the final mean.
        let updates = [
            vec![0.5f32, -1.0],
            vec![1.5, 2.0],
            vec![-0.5, 0.0],
            vec![2.5, 3.0],
        ];
        let mut a = RunningAverage::new(2);
        for u in &updates {
            a.push(u).unwrap();
        }
        let mut b = RunningAverage::new(2);
        for u in updates.iter().rev() {
            b.push(u).unwrap();
        }
        let (fa, fb) = (a.finish().unwrap(), b.finish().unwrap());
        for (x, y) in fa.iter().zip(&fb) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn errors() {
        let mut ra = RunningAverage::new(2);
        assert!(ra.push(&[1.0]).is_err());
        assert!(RunningAverage::new(2).finish().is_err());
    }
}
