//! On-disk cache of trained autoencoder parameters.
//!
//! Binary format: magic `HCFLAE1\n`, u64 little-endian length, f32 LE
//! payload.  Keyed by (model, AE key, seed, steps, premodel epochs) in
//! the filename so stale configurations never collide.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::error::{HcflError, Result};

use super::AeTrainConfig;

const MAGIC: &[u8; 8] = b"HCFLAE1\n";

fn cache_path(
    dir: &Path,
    model: &str,
    ae_key: &str,
    cfg: &AeTrainConfig,
    fingerprint: u64,
) -> PathBuf {
    dir.join(format!(
        "ae_{model}_{ae_key}_s{}_t{}_p{}_e{}_i{fingerprint:016x}.bin",
        cfg.seed, cfg.steps, cfg.premodel_epochs, cfg.premodel_local_epochs
    ))
}

/// Persist trained AE parameters.
pub fn store_ae_params(
    dir: &Path,
    model: &str,
    ae_key: &str,
    cfg: &AeTrainConfig,
    fingerprint: u64,
    params: &[f32],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = cache_path(dir, model, ae_key, cfg, fingerprint);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    let mut buf = Vec::with_capacity(params.len() * 4);
    for v in params {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Load cached AE parameters if present (None on miss; error only on a
/// corrupt file).
pub fn load_ae_params(
    dir: &Path,
    model: &str,
    ae_key: &str,
    cfg: &AeTrainConfig,
    fingerprint: u64,
) -> Result<Option<Vec<f32>>> {
    let path = cache_path(dir, model, ae_key, cfg, fingerprint);
    let mut f = match std::fs::File::open(&path) {
        Ok(f) => f,
        Err(_) => return Ok(None),
    };
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(HcflError::Manifest(format!(
            "corrupt AE cache file {}",
            path.display()
        )));
    }
    let mut len_bytes = [0u8; 8];
    f.read_exact(&mut len_bytes)?;
    let len = u64::from_le_bytes(len_bytes) as usize;
    let mut buf = vec![0u8; len * 4];
    f.read_exact(&mut buf)?;
    let params = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Some(params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_miss() {
        let dir = std::env::temp_dir().join("hcfl_ae_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = AeTrainConfig::default();
        assert!(load_ae_params(&dir, "lenet", "c256_r4", &cfg, 7)
            .unwrap()
            .is_none());
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        store_ae_params(&dir, "lenet", "c256_r4", &cfg, 7, &params).unwrap();
        let loaded = load_ae_params(&dir, "lenet", "c256_r4", &cfg, 7)
            .unwrap()
            .unwrap();
        assert_eq!(loaded, params);
        // different config key misses
        let mut cfg2 = cfg.clone();
        cfg2.seed += 1;
        assert!(load_ae_params(&dir, "lenet", "c256_r4", &cfg2, 7)
            .unwrap()
            .is_none());
        // different init fingerprint misses
        assert!(load_ae_params(&dir, "lenet", "c256_r4", &cfg, 8)
            .unwrap()
            .is_none());
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join("hcfl_ae_cache_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = AeTrainConfig::default();
        let path = cache_path(&dir, "m", "k", &cfg, 1);
        std::fs::write(&path, b"garbagegarbagegarbage").unwrap();
        assert!(load_ae_params(&dir, "m", "k", &cfg, 1).is_err());
    }
}
