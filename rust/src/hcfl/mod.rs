//! HCFL compressor lifecycle (paper §III-D "Proposed Training Phase").
//!
//! 1. **Pre-model training**: the server trains a predictor on its own
//!    small dataset, snapshotting the flat parameter vector after every
//!    epoch — the snapshots form the weight-chunk dataset ("we only fetch
//!    the pre-saturated client's predicting models ... at every learning
//!    state").
//! 2. **AE training**: one autoencoder per chunk size (conv 256 / dense
//!    1024) is trained on those chunks through the `ae_*_train`
//!    executable at the requested compression ratio.
//! 3. **Caching**: trained AE parameters are persisted under
//!    `<artifacts>/cache/` keyed by (model, AE, seed, steps) so repeated
//!    experiments skip retraining.

mod cache;

pub use cache::{load_ae_params, store_ae_params};

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::compression::hcfl::AeHandle;
use crate::data::Dataset;
use crate::error::{HcflError, Result};
use crate::fl::LocalTrainer;
use crate::model::{chunk_count, extract_chunk, init_flat, SegmentRange};
use crate::runtime::Engine;
use crate::tensor::TensorValue;
use crate::util::rng::Rng;

/// Hyper-parameters of the HCFL compressor training phase.
#[derive(Debug, Clone)]
pub struct AeTrainConfig {
    /// Pre-model rounds of the pseudo-federated snapshot phase.
    pub premodel_epochs: usize,
    /// Local epochs per pseudo-client per pre-round; the coordinator sets
    /// this to the run's E so delta magnitudes match.
    pub premodel_local_epochs: usize,
    pub premodel_lr: f32,
    /// AE SGD steps per autoencoder.
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for AeTrainConfig {
    fn default() -> Self {
        AeTrainConfig {
            // Pre-rounds of the pseudo-federated pre-model: covers the
            // weight trajectory well past the early FL rounds.
            premodel_epochs: 12,
            premodel_local_epochs: 1,
            premodel_lr: 0.05,
            // Measured on the LeNet dense-chunk distribution: ~2.5k steps
            // at lr 0.1 reach raw-space MSE in the paper's Table I range
            // (EXPERIMENTS.md).
            steps: 2500,
            lr: 0.1,
            seed: 17,
        }
    }
}

/// Train (or load from cache) the autoencoders needed to compress a model
/// split into `ranges`, at compression `ratio`.
///
/// Returns one [`AeHandle`] per distinct chunk size plus the final
/// training loss per AE (for the Theorem-2 experiment).
pub fn prepare_autoencoders(
    engine: &Engine,
    model_name: &str,
    server_data: &Dataset,
    ranges: &[SegmentRange],
    chunk_of_segment: &BTreeMap<String, usize>,
    ratio: usize,
    cfg: &AeTrainConfig,
    cache_dir: Option<&std::path::Path>,
    init_params: &[f32],
    deltas: bool,
) -> Result<Vec<AeHandle>> {
    // The AE must see the SAME distribution the FL run will produce: the
    // pre-model starts from the run's actual global init (otherwise the
    // compressor faces an unseen distribution from round 1), and trains
    // on update deltas when the run encodes deltas.
    let fingerprint = fnv1a(init_params) ^ if deltas { 0xDE17A } else { 0 };
    // Which chunk sizes do we actually need?
    let mut needed: Vec<usize> = ranges
        .iter()
        .map(|r| {
            chunk_of_segment.get(&r.segment).copied().ok_or_else(|| {
                HcflError::Config(format!("no chunk size for segment '{}'", r.segment))
            })
        })
        .collect::<Result<Vec<_>>>()?;
    needed.sort_unstable();
    needed.dedup();

    // Cache probe first: if every AE is cached we skip the pre-model.
    let mut handles: BTreeMap<usize, AeHandle> = BTreeMap::new();
    if let Some(dir) = cache_dir {
        for &chunk in &needed {
            let meta = engine.manifest().autoencoder(chunk, ratio)?.clone();
            if let Some(params) = load_ae_params(dir, model_name, &meta.key, cfg, fingerprint)? {
                if params.len() == meta.d {
                    handles.insert(
                        chunk,
                        AeHandle {
                            meta,
                            params: Arc::new(params),
                        },
                    );
                }
            }
        }
    }
    let missing: Vec<usize> = needed
        .iter()
        .copied()
        .filter(|c| !handles.contains_key(c))
        .collect();

    if !missing.is_empty() {
        // ---- pre-model phase: collect weight/delta snapshots ------------
        let snapshots =
            premodel_rows(engine, model_name, server_data, cfg, init_params, deltas)?;

        for &chunk in &missing {
            let meta = engine.manifest().autoencoder(chunk, ratio)?.clone();
            let rows = chunk_dataset(&snapshots, ranges, chunk_of_segment, chunk);
            let params = train_one_ae(engine, &meta, &rows, cfg)?;
            if let Some(dir) = cache_dir {
                store_ae_params(dir, model_name, &meta.key, cfg, fingerprint, &params)?;
            }
            handles.insert(
                chunk,
                AeHandle {
                    meta,
                    params: Arc::new(params),
                },
            );
        }
    }

    Ok(handles.into_values().collect())
}

/// FNV-1a fingerprint of a parameter vector (cache key component).
pub fn fnv1a(params: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in params.iter().take(4096) {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h ^ params.len() as u64
}

/// Collect weight snapshots along a simulated *federated* trajectory
/// starting from the FL run's own initial parameters (paper §III-D /
/// §III-C1: "the data prepared for this system is generated after each
/// epoch in each client ... at every learning state").
///
/// The server's small dataset is split into up to 4 pseudo-client shards; each
/// pre-round every pseudo-client trains from the current pre-global and
/// its post-epoch weights are snapshotted, then the pre-global is
/// FedAvg-aggregated — so the chunk dataset covers exactly the kind of
/// client weights the compressor will face, round after round.
pub fn premodel_snapshots(
    engine: &Engine,
    model_name: &str,
    server_data: &Dataset,
    cfg: &AeTrainConfig,
    init_params: &[f32],
) -> Result<Vec<Vec<f32>>> {
    premodel_rows(engine, model_name, server_data, cfg, init_params, false)
}

/// As [`premodel_snapshots`], but `deltas = true` snapshots the per-epoch
/// client *updates* `Δ = w_client − w_preglobal` instead of raw weights
/// (the distribution the delta-coding pipeline compresses).
pub fn premodel_rows(
    engine: &Engine,
    model_name: &str,
    server_data: &Dataset,
    cfg: &AeTrainConfig,
    init_params: &[f32],
    deltas: bool,
) -> Result<Vec<Vec<f32>>> {
    let trainer = LocalTrainer::new(engine, model_name)?;
    let mut rng = Rng::new(cfg.seed ^ 0x9E3779B9);
    let batch = trainer.model.train_epoch.batch;

    // Split the server dataset into pseudo-client shards; every shard
    // must still fill the baked batch size.
    let pseudo_clients = (server_data.n / batch).clamp(1, 4);
    let per = server_data.n / pseudo_clients;
    let shards: Vec<Dataset> = (0..pseudo_clients)
        .map(|c| {
            let rows: Vec<usize> = (c * per..(c + 1) * per).collect();
            let (x, y) = server_data.gather(&rows);
            Dataset {
                x,
                y,
                n: per,
                dim: server_data.dim,
                classes: server_data.classes,
            }
        })
        .collect();

    let mut global = init_params.to_vec();
    let mut snaps = Vec::new();
    if !deltas {
        snaps.push(global.clone()); // round-1 clients start here
    }
    for _ in 0..cfg.premodel_epochs {
        let mut agg = vec![0.0f32; global.len()];
        for shard in &shards {
            // E local epochs per pseudo-client, snapshot weights or Δ.
            let out = trainer.train(
                &global,
                shard,
                cfg.premodel_local_epochs.max(1),
                batch.min(per),
                cfg.premodel_lr,
                &mut rng,
                0,
            )?;
            if deltas {
                snaps.push(
                    out.params
                        .iter()
                        .zip(&global)
                        .map(|(w, g)| w - g)
                        .collect(),
                );
            } else {
                snaps.push(out.params.clone());
            }
            for (a, v) in agg.iter_mut().zip(&out.params) {
                *a += v / pseudo_clients as f32;
            }
        }
        global = agg;
        if !deltas {
            snaps.push(global.clone()); // aggregated state too
        }
    }
    Ok(snaps)
}

/// Assemble the weight-chunk training rows for one chunk size from the
/// pre-model snapshots.
pub fn chunk_dataset(
    snapshots: &[Vec<f32>],
    ranges: &[SegmentRange],
    chunk_of_segment: &BTreeMap<String, usize>,
    chunk: usize,
) -> Vec<Vec<f32>> {
    let mut rows = Vec::new();
    for snap in snapshots {
        for range in ranges {
            if chunk_of_segment.get(&range.segment) != Some(&chunk) {
                continue;
            }
            let values = &snap[range.offset..range.offset + range.len];
            for i in 0..chunk_count(range.len, chunk) {
                rows.push(extract_chunk(values, i, chunk));
            }
        }
    }
    rows
}

/// SGD over the `ae_*_train` executable; returns trained AE parameters.
fn train_one_ae(
    engine: &Engine,
    meta: &crate::runtime::AeMeta,
    rows: &[Vec<f32>],
    cfg: &AeTrainConfig,
) -> Result<Vec<f32>> {
    if rows.is_empty() {
        return Err(HcflError::Config(format!(
            "no training chunks for AE {}",
            meta.key
        )));
    }
    let mut rng = Rng::new(cfg.seed ^ (meta.chunk as u64) << 20 ^ meta.ratio as u64);
    let mut ae = init_flat(&meta.layers, &mut rng);
    let b = meta.train_batch;
    for _ in 0..cfg.steps {
        // Sample a batch of chunks with replacement; half the samples get
        // small Gaussian jitter (the paper's §III-D augmentation, which
        // widens the snapshot distribution the compressor generalizes to).
        let mut batch = Vec::with_capacity(b * meta.chunk);
        for _ in 0..b {
            let row = &rows[rng.below(rows.len())];
            if rng.next_f64() < 0.5 {
                let sigma = 0.02
                    * (row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32).sqrt();
                batch.extend(row.iter().map(|&v| v + rng.normal() * sigma));
            } else {
                batch.extend_from_slice(row);
            }
        }
        let outs = engine.call(
            &meta.train,
            vec![
                TensorValue::vec_f32(ae),
                TensorValue::f32(batch, vec![b, meta.chunk])?,
                TensorValue::scalar_f32(cfg.lr),
            ],
        )?;
        let mut it = outs.into_iter();
        ae = it
            .next()
            .ok_or_else(|| HcflError::Engine("ae_train returned nothing".into()))?
            .into_f32()?;
    }
    Ok(ae)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_dataset_covers_ranges() {
        let snapshots = vec![(0..100).map(|i| i as f32).collect::<Vec<f32>>()];
        let ranges = vec![
            SegmentRange {
                segment: "conv".into(),
                label: "conv".into(),
                offset: 0,
                len: 30,
            },
            SegmentRange {
                segment: "dense".into(),
                label: "dense".into(),
                offset: 30,
                len: 70,
            },
        ];
        let chunks: BTreeMap<String, usize> =
            [("conv".to_string(), 16), ("dense".to_string(), 32)]
                .into_iter()
                .collect();
        let conv_rows = chunk_dataset(&snapshots, &ranges, &chunks, 16);
        assert_eq!(conv_rows.len(), 2); // ceil(30/16)
        assert_eq!(conv_rows[0].len(), 16);
        assert_eq!(conv_rows[0][0], 0.0);
        let dense_rows = chunk_dataset(&snapshots, &ranges, &chunks, 32);
        assert_eq!(dense_rows.len(), 3); // ceil(70/32)
        assert_eq!(dense_rows[0][0], 30.0);
        // padding tail is zero
        assert_eq!(*dense_rows[2].last().unwrap(), 0.0);
    }
}
