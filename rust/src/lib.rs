//! # HCFL — High-Compression Federated Learning
//!
//! Reproduction of *"HCFL: A High Compression Approach for
//! Communication-Efficient Federated Learning in Very Large Scale IoT
//! Networks"* (Nguyen et al., 2022) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator:
//!   FedAvg server, simulated client fleet, the HCFL compressor lifecycle
//!   (pre-model training, autoencoder training, per-round encode/decode),
//!   baselines (T-FedAvg ternary quantization, Top-K sparsification), the
//!   link-cost model, theory calculators, metrics, and the experiment
//!   harness that regenerates every table and figure of the paper.
//! * **Layer 2 (python/compile, build time only)** — JAX graphs (LeNet-5,
//!   5-CNN, the HCFL autoencoders) AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels (tiled GEMM,
//!   fused FC block, ternary/scale elementwise) that the Layer-2 graphs
//!   call; they reach this crate inside the lowered HLO.
//!
//! Python never runs at request time: [`runtime::Engine`] loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and executes them from
//! the round loop.

pub mod compression;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod daemon;
pub mod data;
pub mod error;
pub mod experiments;
pub mod fl;
pub mod hcfl;
pub mod metrics;
pub mod model;
pub mod network;
pub mod runtime;
pub mod tensor;
pub mod theory;
pub mod transport;
pub mod util;

/// Convenient re-exports for examples and binaries.
pub mod prelude {
    pub use crate::compression::{Compressor, Scheme};
    pub use crate::config::{ExperimentConfig, ScenarioConfig};
    pub use crate::control::{CodecBank, CodecPolicy, ServerOptKind, ServerOptState};
    pub use crate::coordinator::clock::RoundPolicy;
    pub use crate::coordinator::session::{CarryOver, CarryPolicy, FlSession};
    pub use crate::coordinator::{EdgeAggregator, Simulation};
    pub use crate::daemon::{snapshot::CampaignSnapshot, Daemon, JobDriver, JobSpec};
    pub use crate::data::Dataset;
    pub use crate::error::HcflError;
    pub use crate::fl::{AggregatorKind, Server};
    pub use crate::metrics::RoundRecord;
    pub use crate::model::ParamSet;
    pub use crate::network::{DeviceFleet, DevicePreset, DeviceProfile};
    pub use crate::runtime::{Engine, Manifest};
    pub use crate::tensor::TensorValue;
    pub use crate::transport::{run_loopback, run_swarm, RoundServer};
}
