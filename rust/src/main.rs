//! `repro` — the HCFL leader binary.
//!
//! Subcommands:
//! * `run` — run one FL configuration (scheme/model/rounds/... via flags).
//! * `experiment --id <id>` — regenerate a paper table/figure.
//! * `list` — list available experiments.

use hcfl::compression::Scheme;
use hcfl::data::Partition;
use hcfl::error::{HcflError, Result};
use hcfl::prelude::*;
use hcfl::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: repro <command> [options]\n\
         commands:\n\
           run         run one FL configuration\n\
           experiment  regenerate a paper table/figure (--id table1|table2|table3|fig8|fig9|fig10a|fig10b|fig11|fig12|scenarios|thm1|thm2)\n\
           list        list available experiments\n\
         run options:\n\
           --model lenet|fivecnn   (default lenet)\n\
           --scheme fedavg|ternary|topk|hcfl   (default hcfl)\n\
           --ratio N               HCFL compression ratio (default 8)\n\
           --keep F                TopK keep fraction (default 0.15)\n\
           --rounds N --clients K --participation C --epochs E --batch B --lr F\n\
           --seed N --workers N --dense-parts N --ae-steps N --no-cache --quiet\n\
           --client-threads N      client-stage worker pool size (default: 4)\n\
           --partition iid|shards|dirichlet   shard label distribution\n\
           --shards-per-client N   labels per client for --partition shards (default 2)\n\
           --alpha F               Dirichlet concentration (default 0.3)\n\
           --size-skew F           shard-size heterogeneity in [0, 0.5] (default 0)\n\
           --lazy-shards           regenerate shards on demand (auto above K=512)\n\
           --csv PATH              write the per-round series\n\
         common options:\n\
           --artifacts DIR   artifact directory (default: artifacts)\n\
           --workers N       PJRT engine workers (default: 4)\n\
           --smoke           engine-free fake-train mode on the synthetic manifest\n\
                             (experiment command; used by CI)"
    );
    std::process::exit(2);
}

fn parse_scheme(args: &Args) -> Result<Scheme> {
    match args.str_or("scheme", "hcfl") {
        "fedavg" => Ok(Scheme::Fedavg),
        "ternary" => Ok(Scheme::Ternary),
        "topk" => Ok(Scheme::TopK {
            keep: args.f64_or("keep", 0.15)?,
        }),
        "hcfl" => Ok(Scheme::Hcfl {
            ratio: args.usize_or("ratio", 8)?,
        }),
        other => Err(HcflError::Config(format!("unknown scheme '{other}'"))),
    }
}

fn parse_partition(args: &Args) -> Result<Partition> {
    match args.str_or("partition", "iid") {
        "iid" => Ok(Partition::Iid),
        "shards" => Ok(Partition::LabelShards {
            shards_per_client: args.usize_or("shards-per-client", 2)?,
        }),
        "dirichlet" => Ok(Partition::Dirichlet {
            alpha: args.f64_or("alpha", 0.3)?,
        }),
        other => Err(HcflError::Config(format!(
            "unknown partition '{other}' (iid|shards|dirichlet)"
        ))),
    }
}

fn cmd_run(args: &Args, artifacts: &str) -> Result<()> {
    let workers = args.usize_or("workers", 4)?;
    let engine = Engine::from_artifacts(artifacts, workers)?;

    let scheme = parse_scheme(args)?;
    let model = args.str_or("model", "lenet").to_string();
    let rounds = args.usize_or("rounds", 10)?;
    let mut cfg = if model == "fivecnn" {
        ExperimentConfig::emnist(scheme, rounds)
    } else {
        ExperimentConfig::mnist(scheme, rounds)
    };
    cfg.model = model;
    cfg.n_clients = args.usize_or("clients", cfg.n_clients)?;
    cfg.participation = args.f64_or("participation", cfg.participation)?;
    cfg.local_epochs = args.usize_or("epochs", cfg.local_epochs)?;
    cfg.batch = args.usize_or("batch", cfg.batch)?;
    cfg.lr = args.f64_or("lr", cfg.lr as f64)? as f32;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.dense_parts = args.usize_or("dense-parts", cfg.dense_parts)?;
    cfg.ae.steps = args.usize_or("ae-steps", cfg.ae.steps)?;
    cfg.use_ae_cache = !args.flag("no-cache");
    cfg.engine_workers = workers;
    cfg.client_threads = args.usize_or("client-threads", cfg.client_threads)?;
    cfg.data.partition = parse_partition(args)?;
    cfg.data.size_skew = args.f64_or("size-skew", 0.0)?;
    cfg.data.lazy_shards = args.flag("lazy-shards") || cfg.n_clients > 512;
    cfg.data.n_clients = cfg.n_clients;

    let mut sim = Simulation::new(&engine, cfg)?;
    sim.verbose = !args.flag("quiet");
    let report = sim.run()?;
    println!(
        "{} on {}: final accuracy {:.4}, final loss {:.4}, mean recon {:.3e}, upload {:.2} MB",
        report.scheme,
        report.model,
        report.final_accuracy(),
        report.final_loss(),
        report.mean_recon_mse(),
        report.total_up_bytes() as f64 / 1e6
    );
    if let Some(path) = args.str_opt("csv") {
        report.write_csv(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional(0).map(|s| s.to_string());
    let artifacts = args.str_or("artifacts", "artifacts").to_string();
    match cmd.as_deref() {
        Some("run") => cmd_run(&args, &artifacts),
        Some("list") => {
            for (id, desc) in hcfl::experiments::list() {
                println!("{id:>8}  {desc}");
            }
            Ok(())
        }
        Some("experiment") => {
            let id = args
                .str_opt("id")
                .map(|s| s.to_string())
                .unwrap_or_else(|| usage());
            let workers = args.usize_or("workers", 4)?;
            // --smoke / --fake-train: run engine-free on the synthetic
            // manifest (no artifacts needed; drivers that honour the
            // flag swap in fake training).
            let engine = if args.flag("smoke") || args.flag("fake-train") {
                Engine::with_manifest(Manifest::synthetic(), workers)?
            } else {
                Engine::from_artifacts(&artifacts, workers)?
            };
            let ctx = hcfl::experiments::ExperimentCtx {
                engine,
                args: args.clone(),
                out_dir: std::path::PathBuf::from(args.str_or("out", "results")),
            };
            hcfl::experiments::run_by_id(&ctx, &id)
        }
        _ => usage(),
    }
}
