//! Run metrics: per-round records, aggregate reports, CSV export and
//! console tables.

use std::io::Write;
use std::path::Path;

use crate::error::Result;
use crate::util::stats;

/// Everything measured in one communication round.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Test accuracy of the aggregated global model after this round.
    pub accuracy: f64,
    /// Test loss of the global model.
    pub loss: f64,
    /// Mean reconstruction MSE of the decompressed client updates
    /// (0 for lossless schemes) — the paper's "Reconstruction error".
    pub recon_mse: f64,
    /// Bytes uploaded by all transmitting clients this round.
    pub up_bytes: u64,
    /// Bytes downloaded by all participating clients this round.
    pub down_bytes: u64,
    /// Clients selected for the round (m).
    pub selected: usize,
    /// Uploads the aggregator actually folded in.
    pub completed: usize,
    /// Selected devices that vanished before uploading (device dropout).
    pub dropped: usize,
    /// Alive clients cut by the round policy (deadline miss / not in the
    /// fastest m).
    pub stragglers: usize,
    /// Carried-over updates from earlier rounds folded into this round's
    /// aggregate (staleness-discounted; see `coordinator::session`).
    /// Not counted in `completed`, which attributes this round's own
    /// uploads.
    pub carried_in: usize,
    /// Late updates leaving this round for a future one (newly cut plus
    /// still-in-flight carry-over).
    pub carried_out: usize,
    /// Carried updates that exceeded `max_age_rounds` and expired
    /// unfolded on entry to this round.  Over a run,
    /// `total_carried_out = total_carried_in + total_carried_expired +
    /// carry still in flight when the run ends` (the driver's pending
    /// `CarryOver`, see `Simulation::carry_pending`).
    pub carried_expired: usize,
    /// Modelled round makespan: the slowest *surviving* client's arrival
    /// (or the full deadline when any selected upload went missing —
    /// see `coordinator::clock::resolve`), seconds.
    pub makespan_s: f64,
    /// Mean per-client compute time (local training + encode), seconds.
    pub client_time_s: f64,
    /// Server compute time (decode + aggregate), seconds.
    pub server_time_s: f64,
    /// Modelled air time of the round (paper eq. 13): the slowest
    /// transmission among all non-dropped clients — cut stragglers
    /// occupy the cell too — capped at the makespan, past which cut
    /// transmissions stop.
    pub comm_time_s: f64,
    /// Wall-clock of the whole round in the simulator.
    pub wall_time_s: f64,
}

/// A completed run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Scheme label, e.g. "HCFL 1:32".
    pub scheme: String,
    pub model: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunReport {
    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.accuracy).unwrap_or(0.0)
    }

    pub fn final_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.loss).unwrap_or(f64::NAN)
    }

    pub fn total_up_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.up_bytes).sum()
    }

    pub fn total_down_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.down_bytes).sum()
    }

    pub fn mean_recon_mse(&self) -> f64 {
        stats::mean(&self.rounds.iter().map(|r| r.recon_mse).collect::<Vec<_>>())
    }

    pub fn mean_client_time(&self) -> f64 {
        stats::mean(
            &self
                .rounds
                .iter()
                .map(|r| r.client_time_s)
                .collect::<Vec<_>>(),
        )
    }

    pub fn mean_server_time(&self) -> f64 {
        stats::mean(
            &self
                .rounds
                .iter()
                .map(|r| r.server_time_s)
                .collect::<Vec<_>>(),
        )
    }

    /// Selected-but-unaggregated clients over the whole run.
    pub fn total_dropped(&self) -> u64 {
        self.rounds.iter().map(|r| r.dropped as u64).sum()
    }

    pub fn total_stragglers(&self) -> u64 {
        self.rounds.iter().map(|r| r.stragglers as u64).sum()
    }

    /// Carried-over updates folded across the whole run.
    pub fn total_carried_in(&self) -> u64 {
        self.rounds.iter().map(|r| r.carried_in as u64).sum()
    }

    /// Late updates that left a round for a future one, summed over
    /// rounds (an update carried twice counts twice).
    pub fn total_carried_out(&self) -> u64 {
        self.rounds.iter().map(|r| r.carried_out as u64).sum()
    }

    /// Carried updates that aged out unfolded over the whole run.
    pub fn total_carried_expired(&self) -> u64 {
        self.rounds.iter().map(|r| r.carried_expired as u64).sum()
    }

    /// Mean fraction of selected clients whose update was aggregated.
    pub fn mean_participation(&self) -> f64 {
        stats::mean(
            &self
                .rounds
                .iter()
                .map(|r| r.completed as f64 / r.selected.max(1) as f64)
                .collect::<Vec<_>>(),
        )
    }

    /// Sum of modelled round makespans (the run's modelled duration).
    pub fn total_makespan(&self) -> f64 {
        self.rounds.iter().map(|r| r.makespan_s).sum()
    }

    /// First round whose accuracy reaches `target` (convergence round).
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.accuracy >= target)
            .map(|r| r.round)
    }

    /// Std-dev of the accuracy over the last `window` rounds (the paper's
    /// Fig. 10 stability metric).
    pub fn accuracy_stddev_tail(&self, window: usize) -> f64 {
        let tail: Vec<f64> = self
            .rounds
            .iter()
            .rev()
            .take(window)
            .map(|r| r.accuracy)
            .collect();
        stats::stddev(&tail)
    }

    /// Write the per-round series as CSV.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,accuracy,loss,recon_mse,up_bytes,down_bytes,selected,completed,dropped,stragglers,carried_in,carried_out,carried_expired,makespan_s,client_time_s,server_time_s,comm_time_s,wall_time_s"
        )?;
        for r in &self.rounds {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.8},{},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6}",
                r.round,
                r.accuracy,
                r.loss,
                r.recon_mse,
                r.up_bytes,
                r.down_bytes,
                r.selected,
                r.completed,
                r.dropped,
                r.stragglers,
                r.carried_in,
                r.carried_out,
                r.carried_expired,
                r.makespan_s,
                r.client_time_s,
                r.server_time_s,
                r.comm_time_s,
                r.wall_time_s
            )?;
        }
        Ok(())
    }
}

/// Fixed-width console table writer used by the experiment harness.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            accuracy: acc,
            loss: 1.0 - acc,
            recon_mse: 0.001,
            up_bytes: 100,
            down_bytes: 100,
            selected: 4,
            completed: 3,
            dropped: 1,
            stragglers: 0,
            carried_in: 1,
            carried_out: 2,
            carried_expired: 1,
            makespan_s: 0.5,
            client_time_s: 0.1,
            server_time_s: 0.01,
            comm_time_s: 0.2,
            wall_time_s: 0.3,
        }
    }

    #[test]
    fn report_aggregates() {
        let rep = RunReport {
            scheme: "FedAvg".into(),
            model: "lenet".into(),
            rounds: vec![record(1, 0.5), record(2, 0.8), record(3, 0.9)],
        };
        assert_eq!(rep.final_accuracy(), 0.9);
        assert_eq!(rep.total_up_bytes(), 300);
        assert_eq!(rep.rounds_to_accuracy(0.75), Some(2));
        assert_eq!(rep.rounds_to_accuracy(0.95), None);
        assert!(rep.accuracy_stddev_tail(2) > 0.0);
        assert_eq!(rep.total_dropped(), 3);
        assert_eq!(rep.total_stragglers(), 0);
        assert_eq!(rep.total_carried_in(), 3);
        assert_eq!(rep.total_carried_out(), 6);
        assert_eq!(rep.total_carried_expired(), 3);
        assert!((rep.mean_participation() - 0.75).abs() < 1e-12);
        assert!((rep.total_makespan() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let rep = RunReport {
            scheme: "x".into(),
            model: "lenet".into(),
            rounds: vec![record(1, 0.5)],
        };
        let dir = std::env::temp_dir().join("hcfl_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.csv");
        rep.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,accuracy"));
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "method"]);
        t.row(vec!["1".into(), "FedAvg".into()]);
        t.row(vec!["22".into(), "HCFL 1:32".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[3].contains("HCFL 1:32"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
