//! Fixed-size chunking of segment slices for the per-chunk compressors.
//!
//! The AE / ternary executables operate on fixed-length chunks (256 for
//! conv segments, 1024 for dense); the final chunk of a segment is
//! zero-padded on the wire and truncated on reassembly.

/// Number of chunks covering `len` values.
pub fn chunk_count(len: usize, chunk: usize) -> usize {
    assert!(chunk > 0);
    len.div_ceil(chunk)
}

/// Extract chunk `i` from a segment slice, zero-padded to `chunk` values.
pub fn extract_chunk(values: &[f32], i: usize, chunk: usize) -> Vec<f32> {
    let start = i * chunk;
    assert!(start < values.len(), "chunk index out of range");
    let end = (start + chunk).min(values.len());
    let mut out = values[start..end].to_vec();
    out.resize(chunk, 0.0);
    out
}

/// Write a reconstructed chunk back into a segment slice (padding tail is
/// dropped automatically).
pub fn write_chunk(dst: &mut [f32], i: usize, chunk_data: &[f32]) {
    let chunk = chunk_data.len();
    let start = i * chunk;
    assert!(start < dst.len(), "chunk index out of range");
    let end = (start + chunk).min(dst.len());
    dst[start..end].copy_from_slice(&chunk_data[..end - start]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(chunk_count(1024, 1024), 1);
        assert_eq!(chunk_count(1025, 1024), 2);
        assert_eq!(chunk_count(1, 1024), 1);
        assert_eq!(chunk_count(2048, 1024), 2);
    }

    #[test]
    fn roundtrip_with_padding() {
        let values: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let chunk = 256;
        let n = chunk_count(values.len(), chunk);
        assert_eq!(n, 2);

        let c0 = extract_chunk(&values, 0, chunk);
        let c1 = extract_chunk(&values, 1, chunk);
        assert_eq!(c0.len(), 256);
        assert_eq!(c1.len(), 256);
        // tail zero-padded
        assert!(c1[44..].iter().all(|&v| v == 0.0));

        let mut rebuilt = vec![0.0f32; values.len()];
        write_chunk(&mut rebuilt, 0, &c0);
        write_chunk(&mut rebuilt, 1, &c1);
        assert_eq!(rebuilt, values);
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        let values = [0.0f32; 10];
        extract_chunk(&values, 2, 10);
    }
}
