//! Parameter initialization from the manifest layer table.

use crate::runtime::LayerMeta;
use crate::util::rng::Rng;

/// Fan-in uniform init: rank>=2 tensors get U(-sqrt(6/fan_in),
/// +sqrt(6/fan_in)) (He-style bound), rank-1 biases get zero.  Matches
/// `python/compile/layout.py::Layout.init_flat` so pytest-trained and
/// rust-trained models start from the same distribution family.
pub fn init_flat(layers: &[LayerMeta], rng: &mut Rng) -> Vec<f32> {
    let total: usize = layers.iter().map(|l| l.size).sum();
    let mut flat = Vec::with_capacity(total);
    for layer in layers {
        if layer.shape.len() > 1 {
            let fan_in: usize = layer.shape[..layer.shape.len() - 1].iter().product();
            let limit = (6.0 / fan_in.max(1) as f32).sqrt();
            for _ in 0..layer.size {
                flat.push(rng.uniform(-limit, limit));
            }
        } else {
            flat.extend(std::iter::repeat(0.0).take(layer.size));
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let layers = vec![LayerMeta {
            name: "w".into(),
            shape: vec![10, 10],
            offset: 0,
            size: 100,
            segment: "dense".into(),
        }];
        let a = init_flat(&layers, &mut Rng::new(5));
        let b = init_flat(&layers, &mut Rng::new(5));
        let c = init_flat(&layers, &mut Rng::new(6));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn conv_fan_in_uses_all_but_last_dim() {
        // conv [5,5,1,6]: fan_in = 25, limit = sqrt(6/25) ≈ 0.49
        let layers = vec![LayerMeta {
            name: "conv".into(),
            shape: vec![5, 5, 1, 6],
            offset: 0,
            size: 150,
            segment: "conv".into(),
        }];
        let flat = init_flat(&layers, &mut Rng::new(1));
        let limit = (6.0f32 / 25.0).sqrt();
        assert!(flat.iter().all(|v| v.abs() <= limit));
        // spread should roughly fill the range
        let max = flat.iter().cloned().fold(0.0f32, |a, b| a.max(b.abs()));
        assert!(max > 0.5 * limit);
    }
}
