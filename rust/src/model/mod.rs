//! Model parameters on the coordinator side: initialization, segmentation
//! and chunking of the flat parameter vector.
//!
//! Executables exchange parameters as one flat `f32[D]` vector (DESIGN.md
//! §6); the manifest's layer table drives everything here.

mod chunking;
mod init;
mod segment;

pub use chunking::{chunk_count, extract_chunk, write_chunk};
pub use init::init_flat;
pub use segment::{merge_segment_ranges, split_dense, SegmentRange};

use crate::runtime::ModelMeta;
use crate::util::rng::Rng;

/// A model's flat parameter vector.
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub flat: Vec<f32>,
}

impl ParamSet {
    /// Fan-in-uniform initialization from the manifest layer table
    /// (mirrors `python/compile/layout.py::Layout.init_flat`).
    pub fn init(meta: &ModelMeta, rng: &mut Rng) -> ParamSet {
        ParamSet {
            flat: init_flat(&meta.layers, rng),
        }
    }

    pub fn zeros(d: usize) -> ParamSet {
        ParamSet {
            flat: vec![0.0; d],
        }
    }

    pub fn d(&self) -> usize {
        self.flat.len()
    }

    /// Mean squared error against another parameter vector (the
    /// reconstruction-error metric of the paper's Tables I/II).
    pub fn mse(&self, other: &[f32]) -> f64 {
        assert_eq!(self.flat.len(), other.len());
        if self.flat.is_empty() {
            return 0.0;
        }
        self.flat
            .iter()
            .zip(other)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.flat.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::LayerMeta;

    fn toy_layers() -> Vec<LayerMeta> {
        vec![
            LayerMeta {
                name: "w".into(),
                shape: vec![4, 3],
                offset: 0,
                size: 12,
                segment: "conv".into(),
            },
            LayerMeta {
                name: "b".into(),
                shape: vec![3],
                offset: 12,
                size: 3,
                segment: "conv".into(),
            },
        ]
    }

    #[test]
    fn mse_of_identical_is_zero() {
        let p = ParamSet {
            flat: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(p.mse(&[1.0, 2.0, 3.0]), 0.0);
        assert!((p.mse(&[2.0, 2.0, 3.0]) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn init_respects_layer_table() {
        let layers = toy_layers();
        let mut rng = Rng::new(0);
        let flat = init_flat(&layers, &mut rng);
        assert_eq!(flat.len(), 15);
        // bias slice is zero
        assert!(flat[12..].iter().all(|&v| v == 0.0));
        // weight slice is bounded by the fan-in limit sqrt(6/4)
        let limit = (6.0f32 / 4.0).sqrt();
        assert!(flat[..12].iter().all(|&v| v.abs() <= limit));
        // and is not all zeros
        assert!(flat[..12].iter().any(|&v| v != 0.0));
    }
}
