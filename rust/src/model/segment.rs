//! Weight segmentation (paper §III-C3 "Data segmentation").
//!
//! HCFL trains one compressor per weight segment whose values share a
//! distribution: convolution kernels vs dense weights (both models), and
//! for the 5-CNN the dense segment is additionally split 8 ways to reduce
//! per-part entropy (paper §VI-A).  Layers with the same segment tag are
//! contiguous in the flat vector, so a segment is a simple range.

use crate::runtime::LayerMeta;

/// A contiguous slice of the flat parameter vector compressed as a unit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentRange {
    /// Segment type: "conv" | "dense" (selects the chunk size / AE family).
    pub segment: String,
    /// Display label, e.g. "dense[3/8]".
    pub label: String,
    pub offset: usize,
    pub len: usize,
}

/// Merge the layer table into contiguous per-segment-type ranges.
pub fn merge_segment_ranges(layers: &[LayerMeta]) -> Vec<SegmentRange> {
    let mut out: Vec<SegmentRange> = Vec::new();
    for layer in layers {
        match out.last_mut() {
            Some(last)
                if last.segment == layer.segment
                    && last.offset + last.len == layer.offset =>
            {
                last.len += layer.size;
            }
            _ => out.push(SegmentRange {
                segment: layer.segment.clone(),
                label: layer.segment.clone(),
                offset: layer.offset,
                len: layer.size,
            }),
        }
    }
    out
}

/// Split every "dense" range into `parts` near-equal sub-ranges (the
/// paper's 8-way EMNIST segmentation).  `parts == 1` is the identity.
pub fn split_dense(ranges: &[SegmentRange], parts: usize) -> Vec<SegmentRange> {
    assert!(parts >= 1, "split_dense needs parts >= 1");
    let mut out = Vec::new();
    for r in ranges {
        if r.segment != "dense" || parts == 1 || r.len < parts {
            out.push(r.clone());
            continue;
        }
        let base = r.len / parts;
        let extra = r.len % parts;
        let mut off = r.offset;
        for p in 0..parts {
            let len = base + usize::from(p < extra);
            out.push(SegmentRange {
                segment: r.segment.clone(),
                label: format!("{}[{}/{}]", r.segment, p + 1, parts),
                offset: off,
                len,
            });
            off += len;
        }
        debug_assert_eq!(off, r.offset + r.len);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(name: &str, segment: &str, offset: usize, size: usize) -> LayerMeta {
        LayerMeta {
            name: name.into(),
            shape: vec![size],
            offset,
            size,
            segment: segment.into(),
        }
    }

    #[test]
    fn merges_contiguous_same_segment() {
        let layers = vec![
            layer("c1", "conv", 0, 10),
            layer("c2", "conv", 10, 20),
            layer("f1", "dense", 30, 40),
            layer("f2", "dense", 70, 5),
        ];
        let ranges = merge_segment_ranges(&layers);
        assert_eq!(ranges.len(), 2);
        assert_eq!(ranges[0].offset, 0);
        assert_eq!(ranges[0].len, 30);
        assert_eq!(ranges[1].offset, 30);
        assert_eq!(ranges[1].len, 45);
    }

    #[test]
    fn split_preserves_coverage() {
        let ranges = vec![
            SegmentRange {
                segment: "conv".into(),
                label: "conv".into(),
                offset: 0,
                len: 30,
            },
            SegmentRange {
                segment: "dense".into(),
                label: "dense".into(),
                offset: 30,
                len: 103,
            },
        ];
        let split = split_dense(&ranges, 8);
        // conv untouched
        assert_eq!(split[0], ranges[0]);
        // dense split into 8 contiguous parts covering [30, 133)
        let dense: Vec<_> = split.iter().filter(|r| r.segment == "dense").collect();
        assert_eq!(dense.len(), 8);
        let mut off = 30;
        let mut total = 0;
        for r in &dense {
            assert_eq!(r.offset, off);
            off += r.len;
            total += r.len;
            // near-equal: lens differ by at most 1
            assert!(r.len == 103 / 8 || r.len == 103 / 8 + 1);
        }
        assert_eq!(total, 103);
    }

    #[test]
    fn split_one_is_identity() {
        let ranges = vec![SegmentRange {
            segment: "dense".into(),
            label: "dense".into(),
            offset: 0,
            len: 10,
        }];
        assert_eq!(split_dense(&ranges, 1), ranges);
    }
}
