//! Per-client device heterogeneity (the "very large scale IoT" part of
//! the paper's title that a homogeneous simulator cannot exercise).
//!
//! A [`DeviceProfile`] describes one client relative to the reference
//! hardware the link model and the measured compute times assume:
//! multipliers on its share of the cell in each direction, a compute
//! slowdown, and a per-round dropout probability.  A [`DeviceFleet`] is
//! the whole population, sampled once per run from a [`DevicePreset`]
//! with its own seeded RNG stream so device assignment never perturbs
//! client selection or training randomness.

use super::LinkModel;
use crate::util::rng::Rng;

/// One client's hardware/connectivity profile, relative to the reference
/// device (all fields 1.0 / 0.0 for the homogeneous baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    /// Multiplier on the client's share of the cell uplink rate.
    pub uplink_mult: f64,
    /// Multiplier on the client's share of the cell downlink rate.
    pub downlink_mult: f64,
    /// Local-compute slowdown: modelled train+encode time is the round's
    /// reference compute time times this (>= 1.0 means slower).
    pub compute_mult: f64,
    /// Probability the device vanishes for a round after being selected
    /// (battery, duty cycle, radio loss).
    pub dropout_p: f64,
}

impl DeviceProfile {
    /// The reference device: full cell share, reference speed, always up.
    pub fn reference() -> DeviceProfile {
        DeviceProfile {
            uplink_mult: 1.0,
            downlink_mult: 1.0,
            compute_mult: 1.0,
            dropout_p: 0.0,
        }
    }

    /// The wall-clock delay a live transport client replays for this
    /// device: modelled broadcast receive + local compute + upload air
    /// time, exactly the arrival formula of
    /// [`crate::coordinator::clock::client_timing`] so a swarm worker
    /// sleeping this long reproduces the simulator's round timeline.
    /// `base_compute_s` is the reference-device train+encode time the
    /// replayer measured for itself; dropouts are not replayed (the
    /// server's seeded dropout stream decides them).
    pub fn replay_delay_s(
        &self,
        link: &LinkModel,
        up_bytes: usize,
        down_bytes: usize,
        base_compute_s: f64,
        selected: usize,
        transmitting: usize,
    ) -> f64 {
        link.downlink_time(down_bytes, selected) / self.downlink_mult.max(1e-9)
            + base_compute_s * self.compute_mult
            + link.uplink_time(up_bytes, transmitting) / self.uplink_mult.max(1e-9)
    }
}

/// How the fleet's profiles are distributed.
#[derive(Debug, Clone, PartialEq)]
pub enum DevicePreset {
    /// Every client is the reference device (the pre-refactor simulator).
    Homogeneous,
    /// A fixed fraction of clients is `slowdown`x slower in both compute
    /// and uplink — the classic straggler regime.
    Stragglers { frac: f64, slowdown: f64 },
    /// Log-normal rate/compute spread plus an IID per-round dropout
    /// probability — an unevenly-connected IoT population.
    Iot { sigma: f64, dropout_p: f64 },
}

/// The sampled population: one profile per client id.
#[derive(Debug, Clone)]
pub struct DeviceFleet {
    profiles: Vec<DeviceProfile>,
}

impl DeviceFleet {
    /// Sample `n` profiles from `preset`.  Deterministic in `seed`; the
    /// homogeneous preset draws nothing so it is seed-independent.
    pub fn sample(n: usize, preset: &DevicePreset, seed: u64) -> DeviceFleet {
        let mut rng = Rng::new(seed ^ 0xDE71_CE5A_11E7_F1E7);
        let profiles = (0..n)
            .map(|_| match preset {
                DevicePreset::Homogeneous => DeviceProfile::reference(),
                DevicePreset::Stragglers { frac, slowdown } => {
                    if rng.next_f64() < *frac {
                        DeviceProfile {
                            uplink_mult: 1.0 / slowdown.max(1.0),
                            downlink_mult: 1.0,
                            compute_mult: slowdown.max(1.0),
                            dropout_p: 0.0,
                        }
                    } else {
                        DeviceProfile::reference()
                    }
                }
                DevicePreset::Iot { sigma, dropout_p } => {
                    // Log-normal with median 1: exp(sigma * N(0,1)).
                    let spread = (sigma * rng.normal() as f64).exp();
                    DeviceProfile {
                        uplink_mult: 1.0 / spread,
                        downlink_mult: 1.0 / spread,
                        compute_mult: spread,
                        dropout_p: *dropout_p,
                    }
                }
            })
            .collect();
        DeviceFleet { profiles }
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// Profile of client `k`.
    pub fn profile(&self, k: usize) -> &DeviceProfile {
        &self.profiles[k]
    }

    /// Number of clients slower than the reference (compute_mult > 1).
    pub fn n_slow(&self) -> usize {
        self.profiles.iter().filter(|p| p.compute_mult > 1.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_is_reference_everywhere() {
        let fleet = DeviceFleet::sample(16, &DevicePreset::Homogeneous, 1);
        assert_eq!(fleet.len(), 16);
        for k in 0..16 {
            assert_eq!(*fleet.profile(k), DeviceProfile::reference());
        }
        // seed-independent
        let other = DeviceFleet::sample(16, &DevicePreset::Homogeneous, 99);
        for k in 0..16 {
            assert_eq!(fleet.profile(k), other.profile(k));
        }
    }

    #[test]
    fn straggler_fraction_is_respected() {
        let preset = DevicePreset::Stragglers {
            frac: 0.3,
            slowdown: 8.0,
        };
        let fleet = DeviceFleet::sample(2000, &preset, 7);
        let slow = fleet.n_slow();
        let frac = slow as f64 / 2000.0;
        assert!((frac - 0.3).abs() < 0.05, "straggler frac {frac}");
        // stragglers are slower on compute AND uplink
        for k in 0..2000 {
            let p = fleet.profile(k);
            if p.compute_mult > 1.0 {
                assert_eq!(p.compute_mult, 8.0);
                assert!((p.uplink_mult - 0.125).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn sampling_is_deterministic_in_seed() {
        let preset = DevicePreset::Iot {
            sigma: 0.5,
            dropout_p: 0.2,
        };
        let a = DeviceFleet::sample(64, &preset, 42);
        let b = DeviceFleet::sample(64, &preset, 42);
        let c = DeviceFleet::sample(64, &preset, 43);
        for k in 0..64 {
            assert_eq!(a.profile(k), b.profile(k));
        }
        assert!((0..64).any(|k| a.profile(k) != c.profile(k)));
    }

    #[test]
    fn replay_delay_matches_the_clock_formula() {
        let link = LinkModel::default();
        let slow = DeviceProfile {
            uplink_mult: 0.125,
            downlink_mult: 1.0,
            compute_mult: 8.0,
            dropout_p: 0.0,
        };
        let got = slow.replay_delay_s(&link, 1000, 4000, 0.01, 10, 8);
        let want = link.downlink_time(4000, 10) + 0.01 * 8.0 + link.uplink_time(1000, 8) / 0.125;
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
        // the reference device replays the unscaled sum
        let r = DeviceProfile::reference().replay_delay_s(&link, 1000, 4000, 0.01, 10, 8);
        let base = link.downlink_time(4000, 10) + 0.01 + link.uplink_time(1000, 8);
        assert!((r - base).abs() < 1e-12);
    }

    #[test]
    fn iot_preset_sets_dropout_and_spread() {
        let preset = DevicePreset::Iot {
            sigma: 0.5,
            dropout_p: 0.1,
        };
        let fleet = DeviceFleet::sample(500, &preset, 3);
        assert!(fleet.profiles.iter().all(|p| p.dropout_p == 0.1));
        // spread actually spreads: some devices slower, some faster
        assert!(fleet.n_slow() > 100);
        assert!(fleet.profiles.iter().any(|p| p.compute_mult < 1.0));
    }
}
