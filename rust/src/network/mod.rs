//! Link / communication-cost model (paper §VI-A, eq. 13) and per-client
//! device heterogeneity.
//!
//! The paper places HCFL at the presentation layer: HARQ corrects packet
//! errors below us, so the link is modelled as lossless and the only
//! communication metric is data volume and the transmission time
//! `T = s / R` with the cell bandwidth shared equally by the clients
//! active in a round.  [`device::DeviceProfile`] scales each client's
//! share of that cell; all round-level cost accounting lives in the
//! clock layer ([`crate::coordinator::clock`]), which folds exact
//! per-client byte counts and device profiles into modelled times.

mod device;

pub use device::{DeviceFleet, DevicePreset, DeviceProfile};

/// Shared-bandwidth link model.
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Total uplink cell capacity in bits/s shared by active clients.
    pub uplink_bps: f64,
    /// Total downlink capacity in bits/s.
    pub downlink_bps: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // A modest NB-IoT-ish cell: 10 Mbit/s up, 20 Mbit/s down.
        LinkModel {
            uplink_bps: 10e6,
            downlink_bps: 20e6,
        }
    }
}

impl LinkModel {
    /// Per-client uplink transmission time (seconds) when `active`
    /// clients share the cell (paper eq. 13 with R_k = R / active).
    pub fn uplink_time(&self, bytes: usize, active: usize) -> f64 {
        let rate = self.uplink_bps / active.max(1) as f64;
        bytes as f64 * 8.0 / rate
    }

    /// Per-client downlink transmission time (seconds).
    pub fn downlink_time(&self, bytes: usize, active: usize) -> f64 {
        let rate = self.downlink_bps / active.max(1) as f64;
        bytes as f64 * 8.0 / rate
    }
}

/// The "true compression ratio" of the paper's tables: baseline bytes
/// over compressed bytes.
pub fn true_ratio(baseline_bytes: u64, compressed_bytes: u64) -> f64 {
    if compressed_bytes == 0 {
        return f64::INFINITY;
    }
    baseline_bytes as f64 / compressed_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq13_transmission_time() {
        let link = LinkModel {
            uplink_bps: 8e6,
            downlink_bps: 8e6,
        };
        // 1 MB at 8 Mbit/s alone: 1 second
        assert!((link.uplink_time(1_000_000, 1) - 1.0).abs() < 1e-9);
        // shared by 10 clients: 10 seconds
        assert!((link.uplink_time(1_000_000, 10) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ratio() {
        assert_eq!(true_ratio(100, 25), 4.0);
        assert_eq!(true_ratio(100, 0), f64::INFINITY);
    }
}
