//! Link / communication-cost model (paper §VI-A, eq. 13).
//!
//! The paper places HCFL at the presentation layer: HARQ corrects packet
//! errors below us, so the link is modelled as lossless and the only
//! communication metric is data volume and the transmission time
//! `T = s / R` with the cell bandwidth shared equally by the clients
//! active in a round.

/// Shared-bandwidth link model.
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Total uplink cell capacity in bits/s shared by active clients.
    pub uplink_bps: f64,
    /// Total downlink capacity in bits/s.
    pub downlink_bps: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // A modest NB-IoT-ish cell: 10 Mbit/s up, 20 Mbit/s down.
        LinkModel {
            uplink_bps: 10e6,
            downlink_bps: 20e6,
        }
    }
}

impl LinkModel {
    /// Per-client uplink transmission time (seconds) when `active`
    /// clients share the cell (paper eq. 13 with R_k = R / active).
    pub fn uplink_time(&self, bytes: usize, active: usize) -> f64 {
        let rate = self.uplink_bps / active.max(1) as f64;
        bytes as f64 * 8.0 / rate
    }

    /// Per-client downlink transmission time (seconds).
    pub fn downlink_time(&self, bytes: usize, active: usize) -> f64 {
        let rate = self.downlink_bps / active.max(1) as f64;
        bytes as f64 * 8.0 / rate
    }
}

/// Accumulated traffic of a run (the paper's "Encoded Size Up/Download").
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    pub up_bytes: u64,
    pub down_bytes: u64,
    /// Modelled time spent on the air (seconds, sum over rounds of the
    /// slowest active client).
    pub comm_time_s: f64,
}

impl CostLedger {
    /// Record one round: `m` clients each upload `up` bytes and download
    /// `down` bytes over the shared link.
    pub fn record_round(&mut self, link: &LinkModel, m: usize, up: usize, down: usize) {
        self.up_bytes += (up * m) as u64;
        self.down_bytes += (down * m) as u64;
        // Synchronous round: the round's air time is one client's
        // transmission at the shared rate (all m transmit concurrently).
        self.comm_time_s += link.uplink_time(up, m) + link.downlink_time(down, m);
    }

    pub fn up_mb(&self) -> f64 {
        self.up_bytes as f64 / 1e6
    }

    pub fn down_mb(&self) -> f64 {
        self.down_bytes as f64 / 1e6
    }
}

/// The "true compression ratio" of the paper's tables: baseline bytes
/// over compressed bytes.
pub fn true_ratio(baseline_bytes: u64, compressed_bytes: u64) -> f64 {
    if compressed_bytes == 0 {
        return f64::INFINITY;
    }
    baseline_bytes as f64 / compressed_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq13_transmission_time() {
        let link = LinkModel {
            uplink_bps: 8e6,
            downlink_bps: 8e6,
        };
        // 1 MB at 8 Mbit/s alone: 1 second
        assert!((link.uplink_time(1_000_000, 1) - 1.0).abs() < 1e-9);
        // shared by 10 clients: 10 seconds
        assert!((link.uplink_time(1_000_000, 10) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ledger_accumulates() {
        let link = LinkModel::default();
        let mut ledger = CostLedger::default();
        ledger.record_round(&link, 10, 1000, 2000);
        ledger.record_round(&link, 10, 1000, 2000);
        assert_eq!(ledger.up_bytes, 20_000);
        assert_eq!(ledger.down_bytes, 40_000);
        assert!(ledger.comm_time_s > 0.0);
    }

    #[test]
    fn ratio() {
        assert_eq!(true_ratio(100, 25), 4.0);
        assert_eq!(true_ratio(100, 0), f64::INFINITY);
    }
}
