//! PJRT execution engine: an actor pool around the `xla` crate.
//!
//! The `xla` crate's `PjRtClient` / `PjRtLoadedExecutable` are `Rc`-based
//! and therefore `!Send`, so the engine spawns N worker threads that each
//! own a client plus a lazily-compiled executable cache, and callers talk
//! to them over channels with [`TensorValue`] payloads.  A cloneable
//! [`Engine`] handle round-robins calls across workers; `call_on` pins a
//! call to a specific worker (used to give each simulated client cache
//! affinity).
//!
//! Compilation is per-worker and lazy: the first call of executable X on
//! worker W compiles X's HLO text on W's client; subsequent calls reuse
//! the compiled binary.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{HcflError, Result};
use crate::runtime::manifest::{Manifest, TensorSpec};
use crate::tensor::TensorValue;

struct Job {
    exec: String,
    inputs: Vec<TensorValue>,
    reply: mpsc::Sender<Result<Vec<TensorValue>>>,
}

struct WorkerHandle {
    tx: mpsc::Sender<Job>,
    join: Option<JoinHandle<()>>,
}

struct EngineInner {
    workers: Vec<Mutex<WorkerHandle>>,
    next: AtomicUsize,
    calls: AtomicUsize,
    manifest: Manifest,
}

/// Cloneable handle to the engine actor pool.
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

/// Whether this build can actually execute HLO (the `pjrt` feature).
/// Engine-dependent tests and examples gate on this to skip gracefully
/// in offline builds.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

impl Engine {
    /// Load the manifest from `dir` and spawn `n_workers` PJRT worker
    /// threads (>= 1).
    pub fn from_artifacts<P: AsRef<std::path::Path>>(
        dir: P,
        n_workers: usize,
    ) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Engine::with_manifest(manifest, n_workers)
    }

    /// Spawn the pool over an already-loaded manifest.
    pub fn with_manifest(manifest: Manifest, n_workers: usize) -> Result<Engine> {
        let n_workers = n_workers.max(1);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = mpsc::channel::<Job>();
            let mani = manifest.clone();
            let join = std::thread::Builder::new()
                .name(format!("pjrt-worker-{w}"))
                .spawn(move || worker_loop(rx, mani))
                .map_err(|e| HcflError::Engine(format!("spawn failed: {e}")))?;
            workers.push(Mutex::new(WorkerHandle {
                tx,
                join: Some(join),
            }));
        }
        Ok(Engine {
            inner: Arc::new(EngineInner {
                workers,
                next: AtomicUsize::new(0),
                calls: AtomicUsize::new(0),
                manifest,
            }),
        })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    /// Number of worker threads.
    pub fn n_workers(&self) -> usize {
        self.inner.workers.len()
    }

    /// Total executable dispatches submitted so far (across all workers).
    /// The batched-codec tests use deltas of this counter to assert the
    /// hot path issues O(segments), not O(chunks), engine calls.
    pub fn dispatch_count(&self) -> usize {
        self.inner.calls.load(Ordering::Relaxed)
    }

    /// Execute `exec` with `inputs`, round-robin across workers.
    pub fn call(&self, exec: &str, inputs: Vec<TensorValue>) -> Result<Vec<TensorValue>> {
        let w = self.inner.next.fetch_add(1, Ordering::Relaxed) % self.n_workers();
        self.call_on(w, exec, inputs)
    }

    /// Execute `exec` on a specific worker (cache affinity).
    pub fn call_on(
        &self,
        worker: usize,
        exec: &str,
        inputs: Vec<TensorValue>,
    ) -> Result<Vec<TensorValue>> {
        let spec = self.inner.manifest.exec_spec(exec)?;
        validate_inputs(exec, &spec.inputs, &inputs)?;
        self.inner.calls.fetch_add(1, Ordering::Relaxed);

        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let handle = self.inner.workers[worker % self.n_workers()]
                .lock()
                .map_err(|_| HcflError::Engine("worker mutex poisoned".into()))?;
            handle
                .tx
                .send(Job {
                    exec: exec.to_string(),
                    inputs,
                    reply: reply_tx,
                })
                .map_err(|_| HcflError::WorkerGone)?;
        }
        reply_rx.recv().map_err(|_| HcflError::WorkerGone)?
    }
}

impl Drop for EngineInner {
    fn drop(&mut self) {
        // Closing the senders ends the worker loops; join to avoid leaks.
        for w in &self.workers {
            if let Ok(mut h) = w.lock() {
                let (dead_tx, _) = mpsc::channel();
                h.tx = dead_tx; // drop the real sender
                if let Some(join) = h.join.take() {
                    let _ = join.join();
                }
            }
        }
    }
}

fn validate_inputs(exec: &str, specs: &[TensorSpec], inputs: &[TensorValue]) -> Result<()> {
    if specs.len() != inputs.len() {
        return Err(HcflError::SpecMismatch {
            exec: exec.to_string(),
            detail: format!("expected {} inputs, got {}", specs.len(), inputs.len()),
        });
    }
    for (i, (spec, input)) in specs.iter().zip(inputs).enumerate() {
        if spec.dtype != input.dtype() || spec.shape != input.shape() {
            return Err(HcflError::SpecMismatch {
                exec: exec.to_string(),
                detail: format!(
                    "input {i}: expected {:?}{:?}, got {:?}{:?}",
                    spec.dtype,
                    spec.shape,
                    input.dtype(),
                    input.shape()
                ),
            });
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Worker thread: owns every !Send xla object.
//
// The real backend needs the `xla` crate (PJRT C API bindings), which is
// not fetchable offline; it is gated behind the `pjrt` feature.  Without
// the feature the engine still constructs (manifest loading, spec
// validation and every pure-Rust layer above it work), but execution
// jobs fail with an explanatory error.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
fn worker_loop(rx: mpsc::Receiver<Job>, _manifest: Manifest) {
    for job in rx {
        let _ = job.reply.send(Err(HcflError::Engine(format!(
            "cannot execute '{}': hcfl was built without the `pjrt` feature \
             (rebuild with `--features pjrt` and an `xla` dependency)",
            job.exec
        ))));
    }
}

#[cfg(feature = "pjrt")]
fn worker_loop(rx: mpsc::Receiver<Job>, manifest: Manifest) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every job with the construction error.
            let msg = format!("PjRtClient::cpu failed: {e}");
            for job in rx {
                let _ = job.reply.send(Err(HcflError::Engine(msg.clone())));
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    for job in rx {
        let result = run_job(&client, &mut cache, &manifest, &job);
        let _ = job.reply.send(result);
    }
}

#[cfg(feature = "pjrt")]
fn run_job(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    job: &Job,
) -> Result<Vec<TensorValue>> {
    if !cache.contains_key(&job.exec) {
        let path = manifest.hlo_path(&job.exec)?;
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        cache.insert(job.exec.clone(), exe);
    }
    let exe = cache.get(&job.exec).expect("just inserted");

    let literals: Vec<xla::Literal> = job
        .inputs
        .iter()
        .map(to_literal)
        .collect::<Result<Vec<_>>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: the single output is a tuple.
    let parts = result.to_tuple()?;
    parts.into_iter().map(from_literal).collect()
}

#[cfg(feature = "pjrt")]
fn to_literal(t: &TensorValue) -> Result<xla::Literal> {
    let lit = match t {
        TensorValue::F32 { data, shape } => {
            if shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
        TensorValue::SharedF32 { data, shape } => {
            if shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data.as_slice()).reshape(&dims)?
            }
        }
        TensorValue::I32 { data, shape } => {
            if shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
    };
    Ok(lit)
}

#[cfg(feature = "pjrt")]
fn from_literal(lit: xla::Literal) -> Result<TensorValue> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(TensorValue::F32 {
            data: lit.to_vec::<f32>()?,
            shape: dims,
        }),
        xla::ElementType::S32 => Ok(TensorValue::I32 {
            data: lit.to_vec::<i32>()?,
            shape: dims,
        }),
        other => Err(HcflError::Engine(format!(
            "unsupported output element type {other:?}"
        ))),
    }
}
