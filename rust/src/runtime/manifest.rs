//! `artifacts/manifest.json` loader: the contract between `aot.py` and
//! the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{HcflError, Result};
use crate::tensor::Dtype;
use crate::util::json::Value;

/// Shape + dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled executable.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One named parameter tensor inside a model's flat vector.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub segment: String, // "conv" | "dense"
}

/// Epoch-executable geometry.
#[derive(Debug, Clone)]
pub struct EpochMeta {
    pub batch: usize,
    pub n_batches: usize,
    pub name: String,
}

/// Eval-executable geometry.
#[derive(Debug, Clone)]
pub struct EvalMeta {
    pub batch: usize,
    pub name: String,
}

/// A predictor model (LeNet-5 / 5-CNN).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub d: usize,
    pub classes: usize,
    pub input_dim: usize,
    pub layers: Vec<LayerMeta>,
    /// batch size -> executable name
    pub train_step: BTreeMap<usize, String>,
    pub train_epoch: EpochMeta,
    pub eval: EvalMeta,
}

/// An HCFL autoencoder variant (one per chunk size x ratio).
#[derive(Debug, Clone)]
pub struct AeMeta {
    pub key: String,
    pub chunk: usize,
    pub ratio: usize,
    pub code: usize,
    pub d: usize,
    pub enc_dims: Vec<usize>,
    pub layers: Vec<LayerMeta>,
    pub encode: String,
    pub decode: String,
    /// Batched encode executables, keyed by batch size (chunks per
    /// call).  Optional: absent in pre-batching manifests, in which case
    /// the codec falls back to the per-chunk `encode`/`decode` path.
    pub encode_batch: BTreeMap<usize, String>,
    pub decode_batch: BTreeMap<usize, String>,
    pub train_batch: usize,
    pub train: String,
}

/// Parsed manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub executables: BTreeMap<String, ExecSpec>,
    pub models: BTreeMap<String, ModelMeta>,
    pub autoencoders: BTreeMap<String, AeMeta>,
    /// chunk-size key ("c256") -> ternary executable name
    pub ternary: BTreeMap<String, String>,
    /// chunk-size key ("c256") -> batch size -> batched ternary
    /// executable name (optional; same fallback rule as the AE maps)
    pub ternary_batch: BTreeMap<String, BTreeMap<usize, String>>,
    /// segment name -> chunk size ("conv" -> 256, "dense" -> 1024)
    pub chunks: BTreeMap<String, usize>,
}

fn parse_tensor_spec(v: &Value) -> Result<TensorSpec> {
    let dtype = Dtype::parse(v.get("dtype")?.as_str()?)?;
    let shape = v
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { dtype, shape })
}

/// Parse an optional `{"<batch>": "<exec>"}` map (absent -> empty).
/// Batch 1 is the per-chunk executable's job and is rejected so the
/// dispatch planner's fallback rule stays unambiguous.
fn parse_batch_map(v: Option<&Value>) -> Result<BTreeMap<usize, String>> {
    let Some(v) = v else {
        return Ok(BTreeMap::new());
    };
    let mut out = BTreeMap::new();
    for (b, exec) in v.as_obj()? {
        let batch = b.parse::<usize>().map_err(|_| {
            HcflError::Manifest(format!("bad batched-codec batch key '{b}'"))
        })?;
        if batch < 2 {
            return Err(HcflError::Manifest(format!(
                "batched-codec batch size must be >= 2, got {batch}"
            )));
        }
        out.insert(batch, exec.as_str()?.to_string());
    }
    Ok(out)
}

fn parse_layers(v: &Value) -> Result<Vec<LayerMeta>> {
    v.as_arr()?
        .iter()
        .map(|l| {
            Ok(LayerMeta {
                name: l.get("name")?.as_str()?.to_string(),
                shape: l
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<Vec<_>>>()?,
                offset: l.get("offset")?.as_usize()?,
                size: l.get("size")?.as_usize()?,
                segment: l.get("segment")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            HcflError::Manifest(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            ))
        })?;
        let root = Value::parse(&text)?;

        let mut executables = BTreeMap::new();
        for (name, spec) in root.get("executables")?.as_obj()? {
            executables.insert(
                name.clone(),
                ExecSpec {
                    file: spec.get("file")?.as_str()?.to_string(),
                    inputs: spec
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(parse_tensor_spec)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: spec
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(parse_tensor_spec)
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_obj()? {
            let mut train_step = BTreeMap::new();
            for (b, exec) in m.get("train_step")?.as_obj()? {
                let batch = b.parse::<usize>().map_err(|_| {
                    HcflError::Manifest(format!("bad train_step batch key '{b}'"))
                })?;
                train_step.insert(batch, exec.as_str()?.to_string());
            }
            let ep = m.get("train_epoch")?;
            let ev = m.get("eval")?;
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    d: m.get("d")?.as_usize()?,
                    classes: m.get("classes")?.as_usize()?,
                    input_dim: m.get("input_dim")?.as_usize()?,
                    layers: parse_layers(m.get("layers")?)?,
                    train_step,
                    train_epoch: EpochMeta {
                        batch: ep.get("batch")?.as_usize()?,
                        n_batches: ep.get("n_batches")?.as_usize()?,
                        name: ep.get("name")?.as_str()?.to_string(),
                    },
                    eval: EvalMeta {
                        batch: ev.get("batch")?.as_usize()?,
                        name: ev.get("name")?.as_str()?.to_string(),
                    },
                },
            );
        }

        let mut autoencoders = BTreeMap::new();
        for (key, a) in root.get("autoencoders")?.as_obj()? {
            let tr = a.get("train")?;
            autoencoders.insert(
                key.clone(),
                AeMeta {
                    key: key.clone(),
                    chunk: a.get("chunk")?.as_usize()?,
                    ratio: a.get("ratio")?.as_usize()?,
                    code: a.get("code")?.as_usize()?,
                    d: a.get("d")?.as_usize()?,
                    enc_dims: a
                        .get("enc_dims")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    layers: parse_layers(a.get("layers")?)?,
                    encode: a.get("encode")?.as_str()?.to_string(),
                    decode: a.get("decode")?.as_str()?.to_string(),
                    encode_batch: parse_batch_map(a.opt("encode_batch"))?,
                    decode_batch: parse_batch_map(a.opt("decode_batch"))?,
                    train_batch: tr.get("batch")?.as_usize()?,
                    train: tr.get("name")?.as_str()?.to_string(),
                },
            );
        }

        let mut ternary = BTreeMap::new();
        for (key, name) in root.get("ternary")?.as_obj()? {
            ternary.insert(key.clone(), name.as_str()?.to_string());
        }

        let mut ternary_batch = BTreeMap::new();
        if let Some(tb) = root.opt("ternary_batch") {
            for (key, sizes) in tb.as_obj()? {
                ternary_batch.insert(key.clone(), parse_batch_map(Some(sizes))?);
            }
        }

        let mut chunks = BTreeMap::new();
        for (seg, size) in root.get("chunks")?.as_obj()? {
            chunks.insert(seg.clone(), size.as_usize()?);
        }

        let manifest = Manifest {
            dir,
            executables,
            models,
            autoencoders,
            ternary,
            ternary_batch,
            chunks,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// A minimal in-memory manifest for engine-free runs: one "fake"
    /// model with a paper-shaped conv/dense layer split and stub
    /// executable entries, so config validation and every pure-Rust
    /// pipeline layer work without artifacts on disk.  Execution jobs
    /// against the stub entries still fail — `fake_train` mode never
    /// submits any.
    pub fn synthetic() -> Manifest {
        let stub_exec = ExecSpec {
            file: "unavailable".into(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        };
        let mut executables = BTreeMap::new();
        for name in ["fake_train_step_b16", "fake_train_epoch", "fake_eval"] {
            executables.insert(name.to_string(), stub_exec.clone());
        }
        let layers = vec![
            LayerMeta {
                name: "conv".into(),
                shape: vec![3, 3, 1, 8],
                offset: 0,
                size: 72,
                segment: "conv".into(),
            },
            LayerMeta {
                name: "dense_w".into(),
                shape: vec![72, 10],
                offset: 72,
                size: 720,
                segment: "dense".into(),
            },
            LayerMeta {
                name: "dense_b".into(),
                shape: vec![10],
                offset: 792,
                size: 10,
                segment: "dense".into(),
            },
        ];
        let mut train_step = BTreeMap::new();
        train_step.insert(16usize, "fake_train_step_b16".to_string());
        let mut models = BTreeMap::new();
        models.insert(
            "fake".to_string(),
            ModelMeta {
                name: "fake".into(),
                d: 802,
                classes: 10,
                input_dim: 784,
                layers,
                train_step,
                train_epoch: EpochMeta {
                    batch: 16,
                    n_batches: 2,
                    name: "fake_train_epoch".into(),
                },
                eval: EvalMeta {
                    batch: 16,
                    name: "fake_eval".into(),
                },
            },
        );
        let mut chunks = BTreeMap::new();
        chunks.insert("conv".to_string(), 256);
        chunks.insert("dense".to_string(), 1024);
        Manifest {
            dir: PathBuf::from("synthetic"),
            executables,
            models,
            autoencoders: BTreeMap::new(),
            ternary: BTreeMap::new(),
            ternary_batch: BTreeMap::new(),
            chunks,
        }
    }

    /// Cross-checks: every referenced executable exists, layer tables are
    /// gapless, AE keys match chunk/ratio.
    pub fn validate(&self) -> Result<()> {
        let check = |name: &str| -> Result<()> {
            if self.executables.contains_key(name) {
                Ok(())
            } else {
                Err(HcflError::UnknownExecutable(name.to_string()))
            }
        };
        for m in self.models.values() {
            for exec in m.train_step.values() {
                check(exec)?;
            }
            check(&m.train_epoch.name)?;
            check(&m.eval.name)?;
            let mut end = 0usize;
            for l in &m.layers {
                if l.offset != end {
                    return Err(HcflError::Manifest(format!(
                        "model {}: layer table gap at '{}'",
                        m.name, l.name
                    )));
                }
                end += l.size;
            }
            if end != m.d {
                return Err(HcflError::Manifest(format!(
                    "model {}: layer table covers {end} of {} params",
                    m.name, m.d
                )));
            }
        }
        for a in self.autoencoders.values() {
            check(&a.encode)?;
            check(&a.decode)?;
            check(&a.train)?;
            for exec in a.encode_batch.values().chain(a.decode_batch.values()) {
                check(exec)?;
            }
            if a.key != format!("c{}_r{}", a.chunk, a.ratio) {
                return Err(HcflError::Manifest(format!("bad AE key '{}'", a.key)));
            }
            if a.code != a.chunk / a.ratio {
                return Err(HcflError::Manifest(format!(
                    "AE {}: code {} != chunk/ratio",
                    a.key, a.code
                )));
            }
        }
        for name in self.ternary.values() {
            check(name)?;
        }
        for sizes in self.ternary_batch.values() {
            for name in sizes.values() {
                check(name)?;
            }
        }
        Ok(())
    }

    /// Absolute path of an executable's HLO text file.
    pub fn hlo_path(&self, exec: &str) -> Result<PathBuf> {
        let spec = self
            .executables
            .get(exec)
            .ok_or_else(|| HcflError::UnknownExecutable(exec.to_string()))?;
        Ok(self.dir.join(&spec.file))
    }

    pub fn exec_spec(&self, exec: &str) -> Result<&ExecSpec> {
        self.executables
            .get(exec)
            .ok_or_else(|| HcflError::UnknownExecutable(exec.to_string()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| HcflError::Manifest(format!("unknown model '{name}'")))
    }

    /// The AE for a given segment's chunk size and a ratio.
    pub fn autoencoder(&self, chunk: usize, ratio: usize) -> Result<&AeMeta> {
        let key = format!("c{chunk}_r{ratio}");
        self.autoencoders
            .get(&key)
            .ok_or_else(|| HcflError::Manifest(format!("no autoencoder '{key}'")))
    }

    /// Ternary executable for a chunk size.
    pub fn ternary_exec(&self, chunk: usize) -> Result<&str> {
        self.ternary
            .get(&format!("c{chunk}"))
            .map(|s| s.as_str())
            .ok_or_else(|| HcflError::Manifest(format!("no ternary kernel for c{chunk}")))
    }

    /// Batched ternary executables for a chunk size (empty when the
    /// manifest predates batched codecs — callers fall back per-chunk).
    pub fn ternary_batch_execs(&self, chunk: usize) -> BTreeMap<usize, String> {
        self.ternary_batch
            .get(&format!("c{chunk}"))
            .cloned()
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_maps_parse_and_reject_batch_one() {
        let v = Value::parse(r#"{"2": "x_n2", "8": "x_n8"}"#).unwrap();
        let m = parse_batch_map(Some(&v)).unwrap();
        assert_eq!(m.get(&2).unwrap(), "x_n2");
        assert_eq!(m.get(&8).unwrap(), "x_n8");
        assert_eq!(m.len(), 2);
        // absent map -> empty (pre-batching manifests stay loadable)
        assert!(parse_batch_map(None).unwrap().is_empty());
        // batch 1 belongs to the per-chunk executable
        let bad = Value::parse(r#"{"1": "x_n1"}"#).unwrap();
        assert!(parse_batch_map(Some(&bad)).is_err());
        let junk = Value::parse(r#"{"two": "x"}"#).unwrap();
        assert!(parse_batch_map(Some(&junk)).is_err());
    }

    #[test]
    fn synthetic_manifest_is_internally_consistent() {
        let m = Manifest::synthetic();
        m.validate().unwrap();
        let model = m.model("fake").unwrap();
        assert_eq!(model.d, 802);
        assert_eq!(model.eval.batch, 16);
        assert!(model.train_step.contains_key(&16));
    }
}
