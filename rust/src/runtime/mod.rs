//! Runtime layer: manifest loading + the PJRT execution engine.
//!
//! This is the only module that touches the `xla` crate.  Everything
//! above it (FL server, compression, experiments) exchanges plain
//! [`crate::tensor::TensorValue`]s with [`Engine`].

mod engine;
mod manifest;

pub use engine::{pjrt_enabled, Engine};
pub use manifest::{
    AeMeta, EpochMeta, EvalMeta, ExecSpec, LayerMeta, Manifest, ModelMeta, TensorSpec,
};
