//! Host-side tensor values exchanged with the PJRT engine.
//!
//! The engine worker threads own all `xla` types (they are `Rc`-based and
//! not `Send`); callers talk in [`TensorValue`]s, which are plain
//! `Vec`-backed and cross thread boundaries freely.

use std::sync::Arc;

use crate::error::{HcflError, Result};

/// Element type of a tensor (matches the manifest's `dtype` strings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(HcflError::Manifest(format!("unknown dtype '{other}'"))),
        }
    }
}

/// A shaped host tensor (row-major).
///
/// `SharedF32` carries an `Arc` to the payload so round-constant inputs
/// (the HCFL autoencoder parameters, ~megabytes per chunk size) cross
/// the engine channel by reference count instead of being cloned into
/// every call — the codec hot path sends the same parameter vector with
/// every encode/decode dispatch.
#[derive(Debug, Clone)]
pub enum TensorValue {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    SharedF32 { data: Arc<Vec<f32>>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl PartialEq for TensorValue {
    fn eq(&self, other: &Self) -> bool {
        // Semantic equality: an owned and a shared f32 tensor with the
        // same shape and bits are the same value.
        match (self, other) {
            (TensorValue::I32 { data: a, shape: sa }, TensorValue::I32 { data: b, shape: sb }) => {
                sa == sb && a == b
            }
            (TensorValue::I32 { .. }, _) | (_, TensorValue::I32 { .. }) => false,
            _ => {
                self.shape() == other.shape()
                    && self.as_f32().ok() == other.as_f32().ok()
            }
        }
    }
}

impl TensorValue {
    /// Rank-0 f32 scalar.
    pub fn scalar_f32(v: f32) -> TensorValue {
        TensorValue::F32 {
            data: vec![v],
            shape: vec![],
        }
    }

    /// 1-D f32 vector.
    pub fn vec_f32(data: Vec<f32>) -> TensorValue {
        let shape = vec![data.len()];
        TensorValue::F32 { data, shape }
    }

    /// 1-D f32 vector shared by reference count (no payload clone).
    pub fn shared_f32(data: Arc<Vec<f32>>) -> TensorValue {
        let shape = vec![data.len()];
        TensorValue::SharedF32 { data, shape }
    }

    /// f32 tensor with explicit shape (element count must match).
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Result<TensorValue> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            return Err(HcflError::Engine(format!(
                "shape {shape:?} wants {want} elements, got {}",
                data.len()
            )));
        }
        Ok(TensorValue::F32 { data, shape })
    }

    /// i32 tensor with explicit shape.
    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Result<TensorValue> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            return Err(HcflError::Engine(format!(
                "shape {shape:?} wants {want} elements, got {}",
                data.len()
            )));
        }
        Ok(TensorValue::I32 { data, shape })
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            TensorValue::F32 { .. } | TensorValue::SharedF32 { .. } => Dtype::F32,
            TensorValue::I32 { .. } => Dtype::I32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            TensorValue::F32 { shape, .. }
            | TensorValue::SharedF32 { shape, .. }
            | TensorValue::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorValue::F32 { data, .. } => data.len(),
            TensorValue::SharedF32 { data, .. } => data.len(),
            TensorValue::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (error if i32).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorValue::F32 { data, .. } => Ok(data),
            TensorValue::SharedF32 { data, .. } => Ok(data.as_slice()),
            _ => Err(HcflError::Engine("expected f32 tensor".into())),
        }
    }

    /// Consume into the f32 payload (a shared tensor clones only when
    /// other references are still alive).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            TensorValue::F32 { data, .. } => Ok(data),
            TensorValue::SharedF32 { data, .. } => {
                Ok(Arc::try_unwrap(data).unwrap_or_else(|a| a.as_ref().clone()))
            }
            _ => Err(HcflError::Engine("expected f32 tensor".into())),
        }
    }

    /// Extract a rank-0 (or single-element) f32 value.
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(HcflError::Engine(format!(
                "expected scalar, got {} elements",
                d.len()
            )));
        }
        Ok(d[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checking() {
        assert!(TensorValue::f32(vec![0.0; 6], vec![2, 3]).is_ok());
        assert!(TensorValue::f32(vec![0.0; 5], vec![2, 3]).is_err());
        assert!(TensorValue::i32(vec![1, 2], vec![2]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = TensorValue::scalar_f32(3.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.scalar().unwrap(), 3.5);
        assert!(TensorValue::vec_f32(vec![1.0, 2.0]).scalar().is_err());
    }

    #[test]
    fn shared_tensor_behaves_like_owned() {
        let data = Arc::new(vec![1.0f32, 2.0, 3.0]);
        let shared = TensorValue::shared_f32(Arc::clone(&data));
        let owned = TensorValue::vec_f32(vec![1.0, 2.0, 3.0]);
        assert_eq!(shared.dtype(), Dtype::F32);
        assert_eq!(shared.shape(), &[3]);
        assert_eq!(shared.as_f32().unwrap(), owned.as_f32().unwrap());
        // semantic equality across representations
        assert_eq!(shared, owned);
        // into_f32 clones only while another Arc is alive
        assert_eq!(shared.into_f32().unwrap(), vec![1.0, 2.0, 3.0]);
        let unique = TensorValue::shared_f32(Arc::new(vec![5.0f32]));
        assert_eq!(unique.into_f32().unwrap(), vec![5.0]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }
}
