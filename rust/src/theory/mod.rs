//! Calculators for the paper's two theorems.
//!
//! * Theorem 1 (eq. 10): `P(|w̃_t − w_t| ≥ α) ≤ 2/(Kα)² · L(w)` — the
//!   aggregation error induced by lossy compression vanishes
//!   quadratically in the number of clients K.
//! * Theorem 2 (eq. 11): `L(w) ≈ (H(W) − H(C)) / (N·log(2πe))` — the
//!   reconstruction loss tracks the entropy gap between the weight
//!   distribution and the code distribution.
//!
//! Both have an analytic side (the bound/estimate) and an empirical side
//! (measured from simulation data); the `thm1` / `thm2` experiments print
//! them side by side.

use crate::util::stats;

/// Theorem 1 upper bound on the deviation probability.
///
/// `l_w` is the compressor's reconstruction MSE, `k` the number of
/// aggregated clients, `alpha` the deviation threshold.  Probabilities
/// are clamped to [0, 1].
pub fn theorem1_bound(l_w: f64, k: usize, alpha: f64) -> f64 {
    if k == 0 || alpha <= 0.0 {
        return 1.0;
    }
    (2.0 * l_w / ((k as f64 * alpha) * (k as f64 * alpha))).min(1.0)
}

/// Empirical counterpart: fraction of coordinates where the average of
/// `noisy` (per-client reconstructed) deviates from the average of
/// `clean` (per-client exact) by at least `alpha`.
///
/// `clean`/`noisy` are K slices of equal length D.
pub fn empirical_deviation_prob(clean: &[Vec<f32>], noisy: &[Vec<f32>], alpha: f64) -> f64 {
    assert_eq!(clean.len(), noisy.len());
    let k = clean.len();
    if k == 0 {
        return 0.0;
    }
    let d = clean[0].len();
    let mut exceed = 0usize;
    for j in 0..d {
        let mut mc = 0.0f64;
        let mut mn = 0.0f64;
        for i in 0..k {
            mc += clean[i][j] as f64;
            mn += noisy[i][j] as f64;
        }
        if ((mn - mc) / k as f64).abs() >= alpha {
            exceed += 1;
        }
    }
    exceed as f64 / d as f64
}

/// Theorem 2 estimate of the reconstruction loss from entropies.
///
/// `weights` are samples of W (original parameters), `codes` samples of C
/// (compressed representation); `bins` is the histogram resolution.  The
/// `n` in eq. (11) is the chunk length N.
pub fn theorem2_estimate(weights: &[f32], codes: &[f32], n: usize, bins: usize) -> f64 {
    let h_w = stats::histogram_entropy(weights, bins);
    let h_c = stats::histogram_entropy(codes, bins);
    // eq. (11): L(w) ≈ (H(W) − H(C)) / (N log(2πe)); entropies in bits.
    let denom = n as f64 * (2.0 * std::f64::consts::PI * std::f64::consts::E).log2();
    ((h_w - h_c) / denom).max(0.0)
}

/// The worked example from the paper (§IV-A): L(w)=2.5, α=0.01, K=10000
/// gives a bound of 0.0005 (99.95 % certainty).
pub fn paper_example() -> f64 {
    theorem1_bound(2.5, 10_000, 0.01)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worked_example() {
        let p = paper_example();
        assert!((p - 0.0005).abs() < 1e-12, "bound {p}");
    }

    #[test]
    fn bound_shrinks_quadratically_in_k() {
        // alpha chosen so the K=10 bound is not clamped at 1.
        let p10 = theorem1_bound(1.0, 10, 1.0);
        let p100 = theorem1_bound(1.0, 100, 1.0);
        assert!((p10 / p100 - 100.0).abs() < 1e-9, "{p10} / {p100}");
    }

    #[test]
    fn bound_clamped() {
        assert_eq!(theorem1_bound(100.0, 1, 0.001), 1.0);
        assert_eq!(theorem1_bound(1.0, 0, 0.1), 1.0);
        assert_eq!(theorem1_bound(1.0, 10, 0.0), 1.0);
    }

    #[test]
    fn empirical_deviation() {
        // Two clients, noise +e and -e cancels in the mean -> prob 0.
        let clean = vec![vec![1.0, 2.0], vec![1.0, 2.0]];
        let noisy = vec![vec![1.1, 2.0], vec![0.9, 2.0]];
        assert_eq!(empirical_deviation_prob(&clean, &noisy, 0.01), 0.0);
        // Systematic +0.1 shift on coordinate 0 only -> prob 0.5.
        let noisy2 = vec![vec![1.1, 2.0], vec![1.1, 2.0]];
        assert_eq!(empirical_deviation_prob(&clean, &noisy2, 0.05), 0.5);
    }

    #[test]
    fn thm2_entropy_gap_positive_when_code_narrow() {
        // Wide weight distribution vs a collapsed code.
        let weights: Vec<f32> = (0..4096).map(|i| (i % 64) as f32 / 64.0).collect();
        let codes = vec![0.5f32; 4096];
        let est = theorem2_estimate(&weights, &codes, 1024, 64);
        assert!(est > 0.0);
        // Identical distributions -> ~0 estimated loss.
        let est0 = theorem2_estimate(&weights, &weights, 1024, 64);
        assert!(est0.abs() < 1e-9);
    }
}
