//! Real wire transport: blocking length-prefixed TCP framing for the
//! round protocol, plus the message payload codecs shared by the
//! [`server`] and [`swarm`] endpoints.
//!
//! Every message is one *frame*: the fixed 24-byte
//! [`FrameHeader`](crate::compression::wire::FrameHeader) envelope
//! (magic, version, message type, codec tag, flags, round id, client
//! id, payload length, CRC-32) followed by exactly `len` payload
//! bytes.  The envelope and every payload layout are specified
//! byte-for-byte in DESIGN.md §8; this module is the executable form
//! of that spec.  Everything is hand-rolled little-endian over
//! `std::net` — no serde, no async runtime, zero dependencies,
//! matching the rest of the crate.
//!
//! The protocol is a strict request/response round pump:
//!
//! ```text
//! swarm worker                      round server
//!   Hello(worker idx)       ──>       (validates codec tag)
//!                           <──     RoundOpen(params, assignments, global)
//!   Update(slot, wire, …)*  ──>       submit / mark_dropped
//!                           <──     RoundDone            (per round)
//!                           <──     Shutdown             (end of session)
//! ```
//!
//! The **frame boundary is the hardened surface**: a malformed frame
//! (bad magic/version/type, oversized declared length, checksum
//! mismatch, truncation) or a malformed message payload is rejected
//! without panicking, and the server merely retires that connection —
//! the round stays open and unfulfilled assignments are accounted as
//! device dropouts (`tests/transport_malformed.rs`).  Payload
//! *contents* past that boundary (the packed codec buffers) are
//! validated by the PR-6-hardened parsers in [`crate::compression`]
//! at decode time; the swarm is a trusted load generator, not an
//! adversary.

#![deny(missing_docs)]

pub mod server;
pub mod swarm;

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::Arc;

use crate::compression::wire::{crc32, FrameHeader, MsgType, FRAME_HEADER_LEN};
use crate::compression::{Compressor, Identity, RefTernaryCompressor, Scheme, TopKCompressor};
use crate::config::ExperimentConfig;
use crate::error::{HcflError, Result};
use crate::metrics::RoundRecord;
use crate::runtime::Manifest;

pub use self::server::{RoundServer, SwarmLink};
pub use self::swarm::{run_swarm, run_swarm_with, SwarmOptions, SwarmStats};

/// Default cap on a declared payload length (64 MiB).  The reader
/// rejects bigger declarations *before* allocating, so a forged header
/// cannot force an out-of-memory allocation.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

/// One decoded frame: the parsed envelope plus its verified payload.
#[derive(Debug, Clone)]
pub struct Frame {
    /// The parsed 24-byte envelope.
    pub header: FrameHeader,
    /// Payload bytes; length and CRC already verified against the
    /// header.
    pub payload: Vec<u8>,
}

/// Write one frame: the packed envelope (with computed length and
/// CRC-32) followed by the payload bytes.
pub fn write_frame<W: Write>(
    w: &mut W,
    msg_type: MsgType,
    codec: u8,
    flags: u8,
    round: u32,
    client: u32,
    payload: &[u8],
) -> Result<()> {
    let header = FrameHeader::for_payload(msg_type, codec, flags, round, client, payload);
    w.write_all(&header.pack())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, enforcing every envelope rule: exactly 24 header
/// bytes (a short read is an I/O error), valid magic/version/type, a
/// declared length within `max_frame` (checked before any allocation),
/// exactly `len` payload bytes, and a matching payload CRC-32.
pub fn read_frame<R: Read>(r: &mut R, max_frame: usize) -> Result<Frame> {
    let mut head = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut head)?;
    let header = FrameHeader::parse(&head)?;
    let len = header.len as usize;
    if len > max_frame {
        return Err(HcflError::Config(format!(
            "frame declares a {len}-byte payload, cap is {max_frame}"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let crc = crc32(&payload);
    if crc != header.crc {
        return Err(HcflError::Config(format!(
            "frame checksum mismatch: payload hashes to {crc:#010x}, header says {:#010x}",
            header.crc
        )));
    }
    Ok(Frame { header, payload })
}

// ---------------------------------------------------------------------------
// Payload cursor
// ---------------------------------------------------------------------------

/// Little-endian byte cursor over a message payload; every read is
/// bounds-checked so a truncated or overlong payload becomes a typed
/// error, never a panic or a silent misparse.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(HcflError::Config(format!(
                "message payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// `count` little-endian f32s, length-checked before allocating.
    fn f32_vec(&mut self, count: usize) -> Result<Vec<f32>> {
        let bytes = self.take(4 * count)?;
        let mut out = Vec::with_capacity(count);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    /// Reject trailing garbage: a valid message consumes its payload
    /// exactly.
    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(HcflError::Config(format!(
                "message payload has {} trailing bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    for v in vs {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

// ---------------------------------------------------------------------------
// RoundOpen
// ---------------------------------------------------------------------------

/// One unit of client work inside a [`RoundOpenMsg`]: which selection
/// slot it fills, which simulated client it impersonates, the client's
/// private RNG seed for the round, and the codec the control plane
/// assigned it — the same quadruple as
/// [`crate::coordinator::pool::WorkSpec`], so socket and in-process
/// rounds compute identical updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    /// Selection slot within the round.
    pub slot: u32,
    /// Global client id.
    pub client: u32,
    /// The client's private RNG seed (`round_seed ^ (client << 1)`).
    pub seed: u64,
    /// The codec tag this slot must upload with
    /// ([`Scheme::codec_tag`]) — the per-client control-plane decision.
    /// The server rejects an `Update` whose envelope codec disagrees.
    pub codec: u8,
}

/// The `RoundOpen` payload: round hyperparameters, this connection's
/// work assignments, the round's cell population, and the broadcast
/// global model (layout in DESIGN.md §8.3).
#[derive(Debug, Clone, PartialEq)]
pub struct RoundOpenMsg {
    /// Local epochs E.
    pub epochs: u32,
    /// Local mini-batch size B.
    pub batch: u32,
    /// Learning rate.
    pub lr: f32,
    /// Encode `Δ = w_local − w_broadcast` instead of raw weights.
    pub encode_deltas: bool,
    /// Clients must append their exact post-training parameters to each
    /// `Update` (server-side reconstruction-MSE instrumentation).
    pub send_exact: bool,
    /// Selected clients this round (m) — the downlink cell population.
    pub selected: u32,
    /// Clients that will transmit this round (m minus dropouts) — the
    /// uplink cell population for timing replay.
    pub transmitting: u32,
    /// This connection's share of the round's work.
    pub assignments: Vec<Assignment>,
    /// The broadcast global model, all `d` parameters.
    pub global: Vec<f32>,
}

impl RoundOpenMsg {
    /// Serialize to the §8.3 payload layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(32 + 17 * self.assignments.len() + 4 * self.global.len());
        put_u32(&mut out, self.epochs);
        put_u32(&mut out, self.batch);
        out.extend_from_slice(&self.lr.to_bits().to_le_bytes());
        out.push(self.encode_deltas as u8);
        out.push(self.send_exact as u8);
        out.extend_from_slice(&0u16.to_le_bytes()); // reserved
        put_u32(&mut out, self.selected);
        put_u32(&mut out, self.transmitting);
        put_u32(&mut out, self.assignments.len() as u32);
        for a in &self.assignments {
            put_u32(&mut out, a.slot);
            put_u32(&mut out, a.client);
            out.extend_from_slice(&a.seed.to_le_bytes());
            out.push(a.codec);
        }
        put_u32(&mut out, self.global.len() as u32);
        put_f32s(&mut out, &self.global);
        out
    }

    /// Parse a §8.3 payload, rejecting truncation, nonzero reserved
    /// bytes, non-boolean flag bytes and trailing garbage; counted
    /// sections are length-checked before any count-sized allocation.
    pub fn decode(payload: &[u8]) -> Result<RoundOpenMsg> {
        let mut r = Reader::new(payload);
        let epochs = r.u32()?;
        let batch = r.u32()?;
        let lr = r.f32()?;
        let encode_deltas = decode_bool(r.u8()?, "encode_deltas")?;
        let send_exact = decode_bool(r.u8()?, "send_exact")?;
        let reserved = r.u16()?;
        if reserved != 0 {
            return Err(HcflError::Config(format!(
                "RoundOpen reserved field must be 0, got {reserved}"
            )));
        }
        let selected = r.u32()?;
        let transmitting = r.u32()?;
        let n_assign = r.u32()? as usize;
        if r.remaining() < 17 * n_assign {
            return Err(HcflError::Config(format!(
                "RoundOpen declares {n_assign} assignments but only {} bytes follow",
                r.remaining()
            )));
        }
        let mut assignments = Vec::with_capacity(n_assign);
        for _ in 0..n_assign {
            assignments.push(Assignment {
                slot: r.u32()?,
                client: r.u32()?,
                seed: r.u64()?,
                codec: r.u8()?,
            });
        }
        let d = r.u32()? as usize;
        let global = r.f32_vec(d)?;
        r.finish()?;
        Ok(RoundOpenMsg {
            epochs,
            batch,
            lr,
            encode_deltas,
            send_exact,
            selected,
            transmitting,
            assignments,
            global,
        })
    }
}

fn decode_bool(b: u8, field: &str) -> Result<bool> {
    match b {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(HcflError::Config(format!(
            "{field} must be 0 or 1, got {other}"
        ))),
    }
}

// ---------------------------------------------------------------------------
// Update
// ---------------------------------------------------------------------------

/// The `Update` payload: one finished assignment — the packed codec
/// wire buffer plus the metadata the session layer needs (layout in
/// DESIGN.md §8.4).  The trailing exact-params block is present iff
/// the frame carries
/// [`FLAG_EXACT_PARAMS`](crate::compression::wire::FLAG_EXACT_PARAMS).
#[derive(Debug, Clone, PartialEq)]
pub struct UpdateMsg {
    /// Selection slot this update fulfils.
    pub slot: u32,
    /// Global client id of the (simulated) sender.
    pub client: u32,
    /// Samples on the sender's shard (FedAvg `n_k`).
    pub n_samples: u32,
    /// Measured train + encode wall time, seconds.
    pub train_s: f64,
    /// The packed codec wire buffer (`compression/wire.rs` layouts).
    pub wire: Vec<u8>,
    /// Exact post-training parameters (empty unless the frame's
    /// exact-params flag is set).
    pub exact: Vec<f32>,
}

impl UpdateMsg {
    /// Serialize to the §8.4 payload layout; the exact block is
    /// appended only when `self.exact` is non-empty (the frame's flag
    /// byte must agree).
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(24 + self.wire.len() + 4 * self.exact.len());
        put_u32(&mut out, self.slot);
        put_u32(&mut out, self.client);
        put_u32(&mut out, self.n_samples);
        out.extend_from_slice(&self.train_s.to_bits().to_le_bytes());
        put_u32(&mut out, self.wire.len() as u32);
        out.extend_from_slice(&self.wire);
        if !self.exact.is_empty() {
            put_u32(&mut out, self.exact.len() as u32);
            put_f32s(&mut out, &self.exact);
        }
        out
    }

    /// Parse a §8.4 payload.  `has_exact` is the frame's
    /// exact-params flag: when set, a trailing exact block is
    /// mandatory; when clear, its presence is trailing garbage.
    pub fn decode(payload: &[u8], has_exact: bool) -> Result<UpdateMsg> {
        let mut r = Reader::new(payload);
        let slot = r.u32()?;
        let client = r.u32()?;
        let n_samples = r.u32()?;
        let train_s = r.f64()?;
        let wire_len = r.u32()? as usize;
        let wire = r.take(wire_len)?.to_vec();
        let exact = if has_exact {
            let n = r.u32()? as usize;
            r.f32_vec(n)?
        } else {
            Vec::new()
        };
        r.finish()?;
        Ok(UpdateMsg {
            slot,
            client,
            n_samples,
            train_s,
            wire,
            exact,
        })
    }
}

// ---------------------------------------------------------------------------
// Shared endpoint helpers
// ---------------------------------------------------------------------------

/// Build the codec both endpoints run.  The transport layer is
/// engine-free (no PJRT artifacts on either side of the socket), so
/// only the engine-free schemes serve; HCFL needs the engine
/// and go through the in-process [`crate::coordinator::Simulation`].
pub fn engine_free_compressor(scheme: &Scheme) -> Result<Arc<dyn Compressor>> {
    match scheme {
        Scheme::Fedavg => Ok(Arc::new(Identity)),
        Scheme::TopK { keep } => Ok(Arc::new(TopKCompressor::new(*keep)?)),
        Scheme::Ternary => Ok(Arc::new(RefTernaryCompressor::new())),
        other => Err(HcflError::Config(format!(
            "transport serving supports engine-free schemes (fedavg/topk/ternary), got {}",
            other.label()
        ))),
    }
}

/// The shared server/swarm demo configuration: the engine-free
/// fake-train setup both binaries must agree on byte-for-byte (same
/// seed → same selection, fleet, shard sizes and work seeds on both
/// ends of the socket).  Mirrors the K=10k acceptance configuration of
/// `tests/round10k.rs`, scaled by `n_clients`.
pub fn demo_config(scheme: Scheme, n_clients: usize, rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist(scheme, rounds);
    cfg.model = "fake".into();
    cfg.fake_train = true;
    cfg.n_clients = n_clients;
    cfg.data.n_clients = n_clients;
    cfg.participation = 1.0;
    cfg.batch = 16;
    cfg.data.per_client = 64;
    cfg.data.test_n = 16;
    cfg.data.server_n = 8;
    cfg.data.lazy_shards = true;
    cfg.client_threads = 4;
    cfg.engine_workers = 2;
    cfg.seed = seed;
    // Over a real wire the exact-params sidecar defeats the codec (it
    // ships the raw f32s next to every compressed payload), so the demo
    // transport path leaves reconstruction-MSE instrumentation off.
    cfg.send_exact = false;
    cfg
}

/// Everything a loopback session produced: the per-round records and
/// final global model from the server side, and the swarm's traffic
/// stats from the client side.
#[derive(Debug)]
pub struct LoopbackRun {
    /// One record per completed round, server-side.
    pub records: Vec<RoundRecord>,
    /// The final global model after the last round.
    pub global: Vec<f32>,
    /// Aggregated swarm-side traffic counters.
    pub swarm: SwarmStats,
}

/// Run a full server + swarm session over real TCP connections on
/// localhost: bind an ephemeral port, serve `cfg.rounds` rounds to
/// `workers` swarm connections, and return both sides' outputs.  With
/// `time_scale` 0 the swarm skips its timing-replay sleeps (tests and
/// benches); 1.0 replays the modelled device delays in real time.
pub fn run_loopback(
    manifest: &Manifest,
    cfg: &ExperimentConfig,
    workers: usize,
    time_scale: f64,
) -> Result<LoopbackRun> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let mut server = RoundServer::new(manifest, cfg.clone())?;
    let rounds = cfg.rounds;
    let swarm_cfg = cfg.clone();
    let swarm = std::thread::Builder::new()
        .name("hcfl-swarm".into())
        .spawn(move || run_swarm(&addr, &swarm_cfg, workers, time_scale))
        .map_err(|e| HcflError::Engine(format!("swarm spawn failed: {e}")))?;
    let served = server.serve(&listener, workers, rounds);
    let stats = swarm
        .join()
        .map_err(|_| HcflError::Engine("swarm thread panicked".into()))?;
    Ok(LoopbackRun {
        records: served?,
        global: server.into_global(),
        swarm: stats?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_over_a_cursor() {
        let mut buf = Vec::new();
        write_frame(&mut buf, MsgType::Update, 3, 1, 7, 42, b"payload").unwrap();
        assert_eq!(buf.len(), FRAME_HEADER_LEN + 7);
        let frame = read_frame(&mut Cursor::new(&buf), DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(frame.header.msg_type, MsgType::Update);
        assert_eq!(frame.header.codec, 3);
        assert_eq!(frame.header.flags, 1);
        assert_eq!(frame.header.round, 7);
        assert_eq!(frame.header.client, 42);
        assert_eq!(frame.payload, b"payload");
    }

    #[test]
    fn round_open_roundtrip() {
        let msg = RoundOpenMsg {
            epochs: 5,
            batch: 16,
            lr: 0.05,
            encode_deltas: true,
            send_exact: true,
            selected: 10,
            transmitting: 9,
            assignments: vec![
                Assignment {
                    slot: 0,
                    client: 3,
                    seed: 0xDEAD_BEEF_0BAD_F00D,
                    codec: 1,
                },
                Assignment {
                    slot: 4,
                    client: 7,
                    seed: 1,
                    codec: 3,
                },
            ],
            global: vec![1.0, -2.5, 0.0],
        };
        let bytes = msg.encode();
        assert_eq!(bytes.len(), 32 + 2 * 17 + 3 * 4);
        assert_eq!(RoundOpenMsg::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn update_roundtrip_with_and_without_exact() {
        let with = UpdateMsg {
            slot: 2,
            client: 9,
            n_samples: 64,
            train_s: 0.125,
            wire: vec![1, 2, 3, 4, 5],
            exact: vec![0.5, -0.5],
        };
        let bytes = with.encode();
        assert_eq!(UpdateMsg::decode(&bytes, true).unwrap(), with);
        let without = UpdateMsg {
            exact: Vec::new(),
            ..with.clone()
        };
        let bytes = without.encode();
        assert_eq!(UpdateMsg::decode(&bytes, false).unwrap(), without);
        // flag says exact but the block is missing -> truncation error
        assert!(UpdateMsg::decode(&bytes, true).is_err());
        // no flag but an exact block present -> trailing garbage
        assert!(UpdateMsg::decode(&with.encode(), false).is_err());
    }

    #[test]
    fn decoders_reject_malformed_payloads() {
        let msg = RoundOpenMsg {
            epochs: 1,
            batch: 16,
            lr: 0.1,
            encode_deltas: false,
            send_exact: false,
            selected: 2,
            transmitting: 2,
            assignments: vec![Assignment {
                slot: 0,
                client: 0,
                seed: 0,
                codec: 0,
            }],
            global: vec![1.0, 2.0],
        };
        let good = msg.encode();
        // truncation at every prefix must error, never panic
        for cut in 0..good.len() {
            assert!(RoundOpenMsg::decode(&good[..cut]).is_err(), "cut={cut}");
        }
        // trailing garbage
        let mut long = good.clone();
        long.push(0);
        assert!(RoundOpenMsg::decode(&long).is_err());
        // forged assignment count with no bytes behind it (n_assign
        // lives at offset 24, after `selected` and `transmitting`)
        let mut forged = good.clone();
        forged[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(RoundOpenMsg::decode(&forged).is_err());
        // non-boolean flag byte
        let mut flag = good.clone();
        flag[12] = 2;
        assert!(RoundOpenMsg::decode(&flag).is_err());
        // nonzero reserved bytes
        let mut reserved = good;
        reserved[14] = 1;
        assert!(RoundOpenMsg::decode(&reserved).is_err());
    }

    #[test]
    fn engine_free_compressor_gates_schemes() {
        assert!(engine_free_compressor(&Scheme::Fedavg).is_ok());
        assert!(engine_free_compressor(&Scheme::TopK { keep: 0.1 }).is_ok());
        assert!(engine_free_compressor(&Scheme::Ternary).is_ok());
        assert!(engine_free_compressor(&Scheme::Hcfl { ratio: 8 }).is_err());
    }
}
