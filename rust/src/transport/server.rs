//! The round server: owns an [`FlSession`] and pumps its
//! `begin_round → submit/mark_dropped → resolve → finalize` lifecycle
//! from real TCP connections instead of the in-process pool channel.
//!
//! The server is the deterministic side of the wire: it runs the exact
//! driver recipe of [`crate::coordinator::Simulation::run_round`] —
//! same selection stream, same per-round dropout stream, same work
//! seeds, same timing model — so a loopback round is bit-identical to
//! the in-process path (modulo measured wall-clock fields).  The swarm
//! on the other side of the socket is untrusted at the frame boundary:
//! any malformed frame or protocol violation retires that connection
//! (its unfulfilled assignments become device losses) and the round
//! still completes.

use std::io::Read;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{
    engine_free_compressor, read_frame, write_frame, Assignment, Frame, RoundOpenMsg, UpdateMsg,
    DEFAULT_MAX_FRAME,
};
use crate::compression::wire::{MsgType, FLAG_EXACT_PARAMS};
use crate::compression::WireUpdate;
use crate::config::ExperimentConfig;
use crate::control::{self, CodecBank, ServerOptState};
use crate::coordinator::clock::client_timing;
use crate::coordinator::pool::{WorkSpec, WorkerPool};
use crate::coordinator::session::ClientUpdate;
use crate::coordinator::{round_seed, CarryOver, EdgeAggregator, FlSession};
use crate::error::{HcflError, Result};
use crate::fl::{select_clients, Server};
use crate::metrics::RoundRecord;
use crate::network::DeviceFleet;
use crate::runtime::Manifest;
use crate::util::rng::Rng;
use crate::util::stats;

/// One accepted swarm connection.
struct Conn {
    stream: TcpStream,
    alive: bool,
    /// Assignments sent this round and not yet fulfilled.
    pending: usize,
}

impl Conn {
    /// Retire the connection: half of the socket teardown is enough to
    /// unblock its reader thread; repeated kills are idempotent.
    fn kill(&mut self) {
        if self.alive {
            self.alive = false;
            let _ = self.stream.shutdown(Shutdown::Both);
        }
        self.pending = 0;
    }
}

/// An accepted swarm: the connections, their reader threads, and the
/// event channel the readers pump.  Produced by
/// [`RoundServer::accept_swarm`], driven round by round through
/// [`RoundServer::serve_round`], closed by [`RoundServer::finish`].
/// Dropping a link without `finish` abandons the sockets mid-session —
/// exactly the crash a resumed daemon recovers from (DESIGN.md §9).
pub struct SwarmLink {
    conns: Vec<Conn>,
    readers: Vec<JoinHandle<()>>,
    rx: mpsc::Receiver<(usize, Result<Frame>)>,
}

impl SwarmLink {
    /// Connections still in the round-robin rotation.
    pub fn live(&self) -> usize {
        self.conns.iter().filter(|c| c.alive).count()
    }

    /// Tear every socket down with no goodbye frame — the in-process
    /// stand-in for the owning process being killed (a real `SIGKILL`
    /// closes the descriptors exactly like this).  The far end observes
    /// a bare EOF mid-session, which is what sends a re-dialing swarm
    /// worker back to `connect` (`crate::transport::SwarmOptions`).
    pub fn sever(mut self) {
        for conn in self.conns.iter_mut() {
            conn.kill();
        }
        for join in self.readers.drain(..) {
            let _ = join.join();
        }
    }
}

/// A socket-driven FL round server, bit-identical to the in-process
/// [`crate::coordinator::Simulation`] driver for the engine-free
/// schemes.
pub struct RoundServer {
    cfg: ExperimentConfig,
    session: FlSession,
    carry: CarryOver,
    fleet: DeviceFleet,
    pool: WorkerPool,
    /// `Some` when `cfg.edge_shards > 0`: the in-process edge shards the
    /// round's decode + fold partitions across (DESIGN.md §10).
    edge: Option<EdgeAggregator>,
    rng: Rng,
    /// How long [`Self::accept_swarm`] waits for a connection's `Hello`
    /// before retiring it; `None` waits forever (the pre-deadline
    /// behavior, vulnerable to a stalled client).
    handshake_timeout: Option<Duration>,
    /// Wall-clock budget for one round's collection phase; on expiry
    /// every connection still owing updates is retired and the round
    /// closes with what arrived.  `None` waits forever.
    round_deadline: Option<Duration>,
}

impl RoundServer {
    /// Build the server side: validate the config, initialize the
    /// global model from the config seed (the same stream order as
    /// `Simulation::new`), sample the device fleet, and spin up the
    /// aggregation worker pool.  Requires `fake_train` (the transport
    /// layer ships no engine) and an engine-free scheme.
    pub fn new(manifest: &Manifest, cfg: ExperimentConfig) -> Result<RoundServer> {
        cfg.validate(manifest)?;
        if !cfg.fake_train {
            return Err(HcflError::Config(
                "transport serving requires fake_train (no engine crosses the socket)".into(),
            ));
        }
        let model = manifest.model(&cfg.model)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let server = Server::new(&model, &mut rng);
        let fleet = DeviceFleet::sample(cfg.n_clients, &cfg.scenario.devices, cfg.seed);
        let compressor = engine_free_compressor(&cfg.scheme)?;
        // Every scheme the policy can hand out must be servable without
        // an engine — the bank is the socket-path twin of
        // `crate::coordinator::session::build_codec_bank`.
        let mut bank = CodecBank::single(Arc::clone(&compressor));
        for scheme in cfg.codec_policy.menu(cfg.scheme) {
            if scheme.codec_tag() != bank.base_tag() {
                bank.insert(engine_free_compressor(&scheme)?);
            }
        }
        let mut session = FlSession::new(
            server,
            compressor,
            cfg.scenario.aggregator.clone(),
            cfg.scenario.carry.clone(),
            cfg.encode_deltas,
            cfg.compress_downlink,
        );
        session.set_codec_bank(bank);
        session.set_server_opt(cfg.server_opt);
        let pool = WorkerPool::new(cfg.client_threads, cfg.engine_workers)?;
        let edge = match cfg.edge_shards {
            0 => None,
            e => Some(EdgeAggregator::new(
                e,
                cfg.client_threads,
                cfg.engine_workers,
            )?),
        };
        Ok(RoundServer {
            cfg,
            session,
            carry: CarryOver::empty(),
            fleet,
            pool,
            edge,
            rng,
            handshake_timeout: Some(Duration::from_secs(30)),
            round_deadline: None,
        })
    }

    /// Bound the wait for each connection's `Hello` in
    /// [`Self::accept_swarm`] (`None` waits forever).  Default: 30 s.
    pub fn set_handshake_timeout(&mut self, timeout: Option<Duration>) {
        self.handshake_timeout = timeout;
    }

    /// Bound one round's collection phase (`None` waits forever, the
    /// default).  Enforced on the server's event channel, so a healthy
    /// connection idling *between* rounds is never at risk — only one
    /// that owes updates past the deadline is retired.
    pub fn set_round_deadline(&mut self, deadline: Option<Duration>) {
        self.round_deadline = deadline;
    }

    /// Current global model.
    pub fn global(&self) -> &[f32] {
        self.session.global()
    }

    /// Consume the server and take the final global model.
    pub fn into_global(self) -> Vec<f32> {
        self.session.global().to_vec()
    }

    /// Late updates currently carried toward a future round.
    pub fn carry_pending(&self) -> usize {
        self.carry.len()
    }

    /// The in-flight carry-over, for snapshotting between rounds.
    pub fn carry(&self) -> &CarryOver {
        &self.carry
    }

    /// The selection-RNG cursor — with the global model and the
    /// carry-over, the only state that crosses rounds
    /// (`crate::daemon::snapshot`).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Rewind onto a snapshot taken after some round's `finalize`, so
    /// the next [`Self::serve_round`] continues the interrupted campaign
    /// bit-identically — the socket-path twin of
    /// `Simulation::restore` (DESIGN.md §9).
    pub fn restore(
        &mut self,
        global: Vec<f32>,
        carry: CarryOver,
        rng_state: [u64; 4],
        opt_state: ServerOptState,
    ) -> Result<()> {
        self.session.restore_global(global)?;
        self.carry = carry;
        self.rng = Rng::from_state(rng_state);
        self.session.restore_opt_state(opt_state);
        Ok(())
    }

    /// The server optimizer's moment state, for snapshotting between
    /// rounds (`crate::daemon::snapshot`, DESIGN.md §9.2 v2).
    pub fn opt_state(&self) -> &ServerOptState {
        self.session.opt_state()
    }

    /// Accept `n_conns` swarm connections on `listener`, serve `rounds`
    /// rounds over them, and return one [`RoundRecord`] per round.
    ///
    /// The listener is borrowed so a caller (benches) can serve several
    /// sessions on one port.  Each connection must open with a `Hello`
    /// frame carrying the session's codec tag; a connection that fails
    /// the handshake, sends a malformed frame, or violates the protocol
    /// mid-round is retired — its outstanding assignments are accounted
    /// as device losses and every round still completes, even with zero
    /// live connections left.
    pub fn serve(
        &mut self,
        listener: &TcpListener,
        n_conns: usize,
        rounds: usize,
    ) -> Result<Vec<RoundRecord>> {
        let mut link = self.accept_swarm(listener, n_conns)?;
        let mut records = Vec::with_capacity(rounds);
        for t in 1..=rounds {
            records.push(self.serve_round(&mut link, t)?);
        }
        self.finish(link, rounds);
        Ok(records)
    }

    /// Accept `n_conns` swarm connections and run their handshakes,
    /// returning the live [`SwarmLink`].  A connection that fails the
    /// handshake — or stalls past the handshake timeout before sending
    /// `Hello` — is retired on the spot; it can never wedge the accept
    /// loop for the swarm queued behind it.
    pub fn accept_swarm(&self, listener: &TcpListener, n_conns: usize) -> Result<SwarmLink> {
        let codec = self.cfg.scheme.codec_tag();
        let (tx, rx) = mpsc::channel::<(usize, Result<Frame>)>();
        let mut conns: Vec<Conn> = Vec::with_capacity(n_conns);
        let mut readers: Vec<JoinHandle<()>> = Vec::with_capacity(n_conns);
        for idx in 0..n_conns {
            let (stream, _) = listener.accept()?;
            let _ = stream.set_nodelay(true);
            let mut conn = Conn {
                stream,
                alive: true,
                pending: 0,
            };
            // Handshake: exactly one well-formed Hello with our codec,
            // inside the handshake deadline.
            let _ = conn.stream.set_read_timeout(self.handshake_timeout);
            match read_frame(&mut conn.stream, DEFAULT_MAX_FRAME) {
                Ok(f) if f.header.msg_type == MsgType::Hello && f.header.codec == codec => {}
                _ => conn.kill(),
            }
            // The reader clone shares the socket's timeout option —
            // clear it so a healthy connection idling between rounds is
            // never retired; the per-round deadline is enforced on the
            // event channel in `serve_round` instead.
            let _ = conn.stream.set_read_timeout(None);
            if conn.alive {
                let mut reader = conn.stream.try_clone()?;
                let tx = tx.clone();
                let join = std::thread::Builder::new()
                    .name(format!("hcfl-conn-{idx}"))
                    .spawn(move || reader_loop(idx, &mut reader, &tx))
                    .map_err(|e| HcflError::Engine(format!("reader spawn failed: {e}")))?;
                readers.push(join);
            }
            conns.push(conn);
        }
        drop(tx);
        Ok(SwarmLink { conns, readers, rx })
    }

    /// Close the session: `Shutdown` every live connection, tear the
    /// sockets down, and join the reader threads.  `rounds` is echoed in
    /// the goodbye frame's round field so the swarm can report how far
    /// the session got.
    pub fn finish(&mut self, link: SwarmLink, rounds: usize) {
        let codec = self.cfg.scheme.codec_tag();
        let SwarmLink {
            mut conns,
            readers,
            rx,
        } = link;
        drop(rx);
        for conn in conns.iter_mut() {
            if conn.alive {
                let _ = write_frame(
                    &mut conn.stream,
                    MsgType::Shutdown,
                    codec,
                    0,
                    rounds as u32,
                    0,
                    &[],
                );
            }
            let _ = conn.stream.shutdown(Shutdown::Both);
        }
        for join in readers {
            let _ = join.join();
        }
    }

    /// One socket-driven round: the `Simulation::run_round` recipe with
    /// the client stage running on the far side of the wire.  Public so
    /// a resident driver (`crate::daemon`) can snapshot between rounds;
    /// rounds must be served in order starting from `t = 1` (or from
    /// `rounds_done + 1` after [`Self::restore`]).
    pub fn serve_round(&mut self, link: &mut SwarmLink, t: usize) -> Result<RoundRecord> {
        let conns: &mut [Conn] = &mut link.conns;
        let rx = &link.rx;
        let codec = self.cfg.scheme.codec_tag();
        let selected = select_clients(self.cfg.n_clients, self.cfg.participation, &mut self.rng);
        let m = selected.len();

        // Control plane: the same pure decision function as the
        // in-process driver, taken before the dropout realization.
        let codecs = control::assign_codecs(
            &self.cfg.codec_policy,
            self.cfg.scheme,
            &self.fleet,
            &selected,
            self.session.d(),
            &self.cfg.link,
        );

        self.session.set_scenario(
            self.cfg.scenario.aggregator.clone(),
            self.cfg.scenario.carry.clone(),
        );
        let carry = std::mem::take(&mut self.carry);
        let mut round = self.session.begin_round(t, carry)?;

        // Device layer: the same per-round dropout stream as the
        // in-process driver.  Dropped clients are simply never
        // assigned; the swarm does not replay dropouts itself.
        let seed = round_seed(self.cfg.seed, t);
        let mut drop_rng = Rng::new(seed ^ 0x0D10_D0A7_5EED_0001);
        let dropped: Vec<bool> = selected
            .iter()
            .map(|&k| drop_rng.next_f64() < self.fleet.profile(k).dropout_p)
            .collect();
        let specs: Vec<WorkSpec> = selected
            .iter()
            .enumerate()
            .filter(|&(slot, _)| !dropped[slot])
            .map(|(slot, &k)| WorkSpec {
                slot,
                client: k,
                seed: seed ^ ((k as u64) << 1),
                codec: codecs[slot].codec_tag(),
            })
            .collect();
        // The pacing forecast broadcast in `RoundOpenMsg`: how many
        // uploads hit the air if every connection survives the round.
        // It is sent before collection, so it cannot know about
        // connection deaths — the *timing* model below uses the realized
        // arrival count instead (DESIGN.md §8.6).
        let forecast = specs.len();

        // Round-robin the work over live connections, then open the
        // round on each of them.
        let mut slot_conn: Vec<Option<usize>> = vec![None; m];
        let mut slot_client: Vec<u32> = vec![0; m];
        let slot_codec: Vec<u8> = codecs.iter().map(|s| s.codec_tag()).collect();
        let live: Vec<usize> = (0..conns.len()).filter(|&i| conns[i].alive).collect();
        let mut shares: Vec<Vec<Assignment>> = vec![Vec::new(); conns.len()];
        if !live.is_empty() {
            for (i, spec) in specs.iter().enumerate() {
                let c = live[i % live.len()];
                slot_conn[spec.slot] = Some(c);
                slot_client[spec.slot] = spec.client as u32;
                shares[c].push(Assignment {
                    slot: spec.slot as u32,
                    client: spec.client as u32,
                    seed: spec.seed,
                    codec: slot_codec[spec.slot],
                });
            }
        }
        let global: Vec<f32> = round.global().as_ref().clone();
        let mut total_pending = 0usize;
        for (idx, conn) in conns.iter_mut().enumerate() {
            if !conn.alive {
                continue;
            }
            let share = std::mem::take(&mut shares[idx]);
            conn.pending = share.len();
            let msg = RoundOpenMsg {
                epochs: self.cfg.local_epochs as u32,
                batch: self.cfg.batch as u32,
                lr: self.cfg.lr,
                encode_deltas: self.cfg.encode_deltas,
                send_exact: self.cfg.send_exact,
                selected: m as u32,
                transmitting: forecast as u32,
                assignments: share,
                global: global.clone(),
            };
            let sent = write_frame(
                &mut conn.stream,
                MsgType::RoundOpen,
                codec,
                0,
                t as u32,
                idx as u32,
                &msg.encode(),
            );
            if sent.is_err() {
                conn.kill();
                continue;
            }
            total_pending += conn.pending;
        }

        // Collect updates until every live assignment is fulfilled, its
        // connection died, or the round deadline expired.  A protocol
        // violation retires the offending connection, never the round.
        let deadline = self.round_deadline.map(|d| Instant::now() + d);
        let mut results: Vec<Option<UpdateMsg>> = Vec::with_capacity(m);
        results.resize_with(m, || None);
        while total_pending > 0 {
            let next = match deadline {
                None => rx.recv().ok(),
                Some(dl) => {
                    match rx.recv_timeout(dl.saturating_duration_since(Instant::now())) {
                        Ok(ev) => Some(ev),
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            // Deadline expired: retire every connection
                            // still owing updates — exactly like a
                            // malformed frame — and close the round with
                            // what arrived.
                            for conn in conns.iter_mut() {
                                if conn.alive && conn.pending > 0 {
                                    conn.kill();
                                }
                            }
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => None,
                    }
                }
            };
            let (idx, event) = match next {
                Some(ev) => ev,
                None => break, // every reader gone
            };
            if !conns[idx].alive {
                continue;
            }
            let frame = match event {
                Ok(f) => f,
                Err(_) => {
                    total_pending -= conns[idx].pending;
                    conns[idx].kill();
                    continue;
                }
            };
            match self.accept_update(
                frame,
                t,
                idx,
                &slot_conn,
                &slot_client,
                &slot_codec,
                &mut results,
            ) {
                Ok(()) => {
                    conns[idx].pending -= 1;
                    total_pending -= 1;
                }
                Err(_) => {
                    total_pending -= conns[idx].pending;
                    conns[idx].kill();
                }
            }
        }

        // Timing + session pump: identical to the in-process driver.
        // `dropped` here means "nothing arrived" — the rng dropout
        // stream and dead-connection losses land in the same bucket.
        // `transmitting` is therefore the count of *realized* arrivals,
        // exactly what the in-process driver feeds `client_timing`: an
        // assignment lost to a dead connection never occupied the
        // shared uplink, and counting it would mistime every survivor.
        let send_exact = self.cfg.send_exact;
        let measured: Vec<f64> = results
            .iter()
            .flatten()
            .map(|msg| msg.train_s)
            .collect();
        let reference_compute_s = stats::mean(&measured);
        let transmitting = measured.len();
        let down_bytes = round.down_bytes();
        for (slot, &k) in selected.iter().enumerate() {
            // The exact-params sidecar rides the same uplink as the
            // payload when enabled: a 4-byte length plus raw f32s
            // (DESIGN.md §8.4).
            let extra = results[slot]
                .as_ref()
                .map(|msg| if send_exact { 4 + 4 * msg.exact.len() } else { 0 })
                .unwrap_or(0);
            let up = results[slot]
                .as_ref()
                .map(|msg| msg.wire.len())
                .unwrap_or(0)
                + extra;
            let timing = client_timing(
                &self.cfg.link,
                self.fleet.profile(k),
                k,
                slot,
                up,
                down_bytes,
                reference_compute_s,
                m,
                transmitting,
                results[slot].is_none(),
            );
            match results[slot].take() {
                Some(msg) => round.submit(ClientUpdate {
                    payload: WireUpdate { bytes: msg.wire },
                    n_samples: msg.n_samples as usize,
                    timing,
                    exact: msg.exact,
                    extra_up_bytes: extra,
                    train_s: msg.train_s,
                    codec: slot_codec[slot],
                }),
                None => round.mark_dropped(timing),
            }
        }

        let resolved = round.resolve(&self.cfg.scenario.policy);
        let (rec, carry) = match &self.edge {
            Some(edge) => resolved.finalize_sharded(edge)?,
            None => resolved.finalize(&self.pool)?,
        };
        self.carry = carry;

        for (idx, conn) in conns.iter_mut().enumerate() {
            if !conn.alive {
                continue;
            }
            let done = write_frame(
                &mut conn.stream,
                MsgType::RoundDone,
                codec,
                0,
                t as u32,
                idx as u32,
                &[],
            );
            if done.is_err() {
                conn.kill();
            }
        }
        Ok(rec)
    }

    /// Validate one incoming frame as this round's next update.  Any
    /// error verdict retires the sending connection.
    #[allow(clippy::too_many_arguments)]
    fn accept_update(
        &self,
        frame: Frame,
        t: usize,
        idx: usize,
        slot_conn: &[Option<usize>],
        slot_client: &[u32],
        slot_codec: &[u8],
        results: &mut [Option<UpdateMsg>],
    ) -> Result<()> {
        let h = &frame.header;
        if h.msg_type != MsgType::Update {
            return Err(HcflError::Config(format!(
                "expected Update, got {:?}",
                h.msg_type
            )));
        }
        let want_flags = if self.cfg.send_exact {
            FLAG_EXACT_PARAMS
        } else {
            0
        };
        if h.round != t as u32 || h.flags != want_flags {
            return Err(HcflError::Config(format!(
                "update envelope mismatch: round {} flags {:#04x}",
                h.round, h.flags
            )));
        }
        let msg = UpdateMsg::decode(&frame.payload, self.cfg.send_exact)?;
        let slot = msg.slot as usize;
        if slot >= slot_conn.len()
            || slot_conn[slot] != Some(idx)
            || slot_client[slot] != msg.client
            || results[slot].is_some()
        {
            return Err(HcflError::Config(format!(
                "update for slot {slot} is unassigned, duplicated or misattributed"
            )));
        }
        // The envelope codec is per-slot: the control plane told this
        // slot what to upload with, and anything else is a forgery.
        if h.codec != slot_codec[slot] {
            return Err(HcflError::Config(format!(
                "update for slot {slot} uses codec {} but was assigned {}",
                h.codec, slot_codec[slot]
            )));
        }
        results[slot] = Some(msg);
        Ok(())
    }
}

/// Per-connection reader: pump frames (or the first error) into the
/// server's event channel until the socket dies or the server hangs up.
fn reader_loop(idx: usize, stream: &mut impl Read, tx: &mpsc::Sender<(usize, Result<Frame>)>) {
    loop {
        let event = read_frame(stream, DEFAULT_MAX_FRAME);
        let failed = event.is_err();
        if tx.send((idx, event)).is_err() || failed {
            return;
        }
    }
}
