//! The swarm client: a worker pool of real TCP connections that
//! impersonates a device fleet against a [`super::RoundServer`].
//!
//! Each worker owns one connection and executes every assignment the
//! server hands it: seeded fake training (the exact
//! [`crate::coordinator::pool::FakeTrainRunner`] computation, so the
//! server aggregates bit-identical updates), codec encode + wire pack,
//! and optionally a real-time replay of the device's modelled delay
//! ([`crate::network::DeviceProfile::replay_delay_s`] scaled by
//! `time_scale`).  Dropouts are *not* replayed here — the server's
//! seeded dropout stream decides them and simply never assigns the
//! dropped slots, keeping the swarm stateless across rounds.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{engine_free_compressor, read_frame, write_frame, RoundOpenMsg, UpdateMsg};
use crate::compression::wire::{MsgType, FLAG_EXACT_PARAMS, FRAME_HEADER_LEN};
use crate::compression::WireScratch;
use crate::config::ExperimentConfig;
use crate::control::CodecBank;
use crate::data::{synthetic, FlData};
use crate::error::{HcflError, Result};
use crate::network::{DeviceFleet, LinkModel};
use crate::runtime::Manifest;
use crate::util::rng::Rng;

/// Swarm-side traffic counters, merged across workers.
#[derive(Debug, Clone, Default)]
pub struct SwarmStats {
    /// Rounds this swarm saw complete (`RoundDone` frames).
    pub rounds: usize,
    /// Update frames sent.
    pub updates_sent: usize,
    /// Total bytes written to the wire (frame headers included).
    pub bytes_sent: usize,
}

impl SwarmStats {
    fn merge(&mut self, other: &SwarmStats) {
        self.rounds = self.rounds.max(other.rounds);
        self.updates_sent += other.updates_sent;
        self.bytes_sent += other.bytes_sent;
    }
}

/// Read-only state every worker shares.
struct SwarmShared {
    fleet: DeviceFleet,
    data: Arc<FlData>,
    /// Every codec the server's policy can assign, keyed by tag — each
    /// assignment carries the tag the control plane picked for it.
    bank: CodecBank,
    link: LinkModel,
    /// The base scheme's tag, used for the `Hello` handshake.
    codec: u8,
    time_scale: f64,
}

/// Swarm resilience knobs.  A crash-tolerant campaign restarts its
/// server between rounds (DESIGN.md §9); workers given a re-dial
/// budget survive the gap and resume serving assignments against the
/// resumed session.
#[derive(Debug, Clone)]
pub struct SwarmOptions {
    /// How many times a worker re-dials after a failed connect or a
    /// dropped connection before giving up.  0 (the default)
    /// reproduces the fail-fast single-session behavior.
    pub redial_attempts: usize,
    /// Pause between re-dial attempts.
    pub redial_wait: Duration,
}

impl Default for SwarmOptions {
    fn default() -> SwarmOptions {
        SwarmOptions {
            redial_attempts: 0,
            redial_wait: Duration::from_millis(20),
        }
    }
}

/// Connect `workers` swarm connections to the server at `addr` and
/// replay the fleet described by `cfg` until the server says
/// `Shutdown`.
///
/// `cfg` must be byte-identical to the server's configuration: the
/// fleet sample, shard sizes and codec are all rebuilt here from the
/// same seed, which is what lets the wire carry only seeds and slots.
/// `time_scale` scales the modelled device delays replayed before each
/// upload — 0 disables the sleeps (tests, benches, throughput runs),
/// 1.0 replays stragglers in real time.  Note the replay is
/// per-connection sequential: a worker serving several assignments
/// sleeps them back to back, so small swarms compress a round's wall
/// clock relative to K independent radios.
pub fn run_swarm(
    addr: &str,
    cfg: &ExperimentConfig,
    workers: usize,
    time_scale: f64,
) -> Result<SwarmStats> {
    run_swarm_with(addr, cfg, workers, time_scale, &SwarmOptions::default())
}

/// [`run_swarm`] with explicit [`SwarmOptions`] — the entry point for
/// crash-tolerant campaigns whose workers must re-dial a restarted
/// server.
pub fn run_swarm_with(
    addr: &str,
    cfg: &ExperimentConfig,
    workers: usize,
    time_scale: f64,
    opts: &SwarmOptions,
) -> Result<SwarmStats> {
    let mut data_spec = cfg.data.clone();
    data_spec.n_clients = cfg.n_clients;
    let mut bank = CodecBank::single(engine_free_compressor(&cfg.scheme)?);
    for scheme in cfg.codec_policy.menu(cfg.scheme) {
        if scheme.codec_tag() != bank.base_tag() {
            bank.insert(engine_free_compressor(&scheme)?);
        }
    }
    let shared = Arc::new(SwarmShared {
        fleet: DeviceFleet::sample(cfg.n_clients, &cfg.scenario.devices, cfg.seed),
        data: Arc::new(synthetic(&data_spec, cfg.seed)),
        bank,
        link: cfg.link.clone(),
        codec: cfg.scheme.codec_tag(),
        time_scale,
    });
    let workers = workers.max(1);
    let mut joins = Vec::with_capacity(workers);
    for w in 0..workers {
        let shared = Arc::clone(&shared);
        let addr = addr.to_string();
        let opts = opts.clone();
        let join = std::thread::Builder::new()
            .name(format!("hcfl-swarm-{w}"))
            .spawn(move || worker_loop(&addr, w, &shared, &opts))
            .map_err(|e| HcflError::Engine(format!("swarm worker spawn failed: {e}")))?;
        joins.push(join);
    }
    let mut stats = SwarmStats::default();
    let mut first_err = None;
    for join in joins {
        match join
            .join()
            .map_err(|_| HcflError::Engine("swarm worker panicked".into()))?
        {
            Ok(s) => stats.merge(&s),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

/// One worker: serve sessions until a clean `Shutdown`, re-dialing
/// through `opts.redial_attempts` connection failures along the way.
fn worker_loop(addr: &str, w: usize, shared: &SwarmShared, opts: &SwarmOptions) -> Result<SwarmStats> {
    let mut stats = SwarmStats::default();
    let mut attempts_left = opts.redial_attempts;
    loop {
        match worker_session(addr, w, shared, &mut stats) {
            Ok(()) => return Ok(stats),
            Err(e) => {
                if attempts_left == 0 {
                    return Err(e);
                }
                attempts_left -= 1;
                std::thread::sleep(opts.redial_wait);
            }
        }
    }
}

/// One connected session: handshake, then serve assignments until
/// `Shutdown`.  Counters accumulate into `stats`, so a re-dialing
/// worker's totals span every session it survived.
fn worker_session(
    addr: &str,
    w: usize,
    shared: &SwarmShared,
    stats: &mut SwarmStats,
) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    write_frame(
        &mut stream,
        MsgType::Hello,
        shared.codec,
        0,
        0,
        w as u32,
        &[],
    )?;
    stats.bytes_sent += FRAME_HEADER_LEN;
    let mut scratch = WireScratch::new();
    loop {
        let frame = read_frame(&mut stream, super::DEFAULT_MAX_FRAME)?;
        match frame.header.msg_type {
            MsgType::RoundOpen => {
                let round = frame.header.round;
                let open = RoundOpenMsg::decode(&frame.payload)?;
                run_assignments(&mut stream, &open, round, w, shared, &mut scratch, stats)?;
            }
            MsgType::RoundDone => stats.rounds += 1,
            MsgType::Shutdown => return Ok(()),
            other => {
                return Err(HcflError::Config(format!(
                    "swarm expected RoundOpen/RoundDone/Shutdown, got {other:?}"
                )))
            }
        }
    }
}

/// Execute one `RoundOpen`'s assignments in order: fake-train, encode,
/// optionally replay the modelled delay, upload.
fn run_assignments(
    stream: &mut TcpStream,
    open: &RoundOpenMsg,
    round: u32,
    w: usize,
    shared: &SwarmShared,
    scratch: &mut WireScratch,
    stats: &mut SwarmStats,
) -> Result<()> {
    let down_bytes = 4 * open.global.len();
    for a in &open.assignments {
        // The exact FakeTrainRunner computation, seeded by the wire.
        let compressor = shared.bank.get(a.codec)?;
        let mut crng = Rng::new(a.seed);
        let started = Instant::now();
        let scale = open.lr * (open.epochs.max(1) as f32).sqrt() * 0.1;
        let params: Vec<f32> = open
            .global
            .iter()
            .map(|g| g + scale * crng.normal())
            .collect();
        let payload = compressor.encode_payload(&params, &open.global, open.encode_deltas);
        let update = compressor.compress(&payload, 0)?;
        let wire = scratch.pack_update(&update.payload)?;
        let train_s = started.elapsed().as_secs_f64();

        if shared.time_scale > 0.0 {
            let client = a.client as usize;
            let delay_s = shared.time_scale
                * shared.fleet.profile(client).replay_delay_s(
                    &shared.link,
                    wire.bytes.len(),
                    down_bytes,
                    train_s,
                    open.selected as usize,
                    open.transmitting as usize,
                );
            if delay_s > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(delay_s));
            }
        }

        let msg = UpdateMsg {
            slot: a.slot,
            client: a.client,
            n_samples: shared.data.shard_rows(a.client as usize) as u32,
            train_s,
            wire: wire.bytes,
            exact: if open.send_exact { params } else { Vec::new() },
        };
        let flags = if open.send_exact { FLAG_EXACT_PARAMS } else { 0 };
        let body = msg.encode();
        write_frame(
            stream,
            MsgType::Update,
            a.codec,
            flags,
            round,
            w as u32,
            &body,
        )?;
        stats.updates_sent += 1;
        stats.bytes_sent += FRAME_HEADER_LEN + body.len();
        scratch.put_bytes(msg.wire);
    }
    Ok(())
}

/// Convenience used by the `hcfl-swarm` binary: validate the config
/// against a manifest before dialing out (the server does the same, so
/// mismatches fail fast on both ends).
pub fn validated_swarm(
    manifest: &Manifest,
    addr: &str,
    cfg: &ExperimentConfig,
    workers: usize,
    time_scale: f64,
) -> Result<SwarmStats> {
    validated_swarm_with(
        manifest,
        addr,
        cfg,
        workers,
        time_scale,
        &SwarmOptions::default(),
    )
}

/// [`validated_swarm`] with explicit [`SwarmOptions`] (re-dial budget
/// for crash-tolerant campaigns).
pub fn validated_swarm_with(
    manifest: &Manifest,
    addr: &str,
    cfg: &ExperimentConfig,
    workers: usize,
    time_scale: f64,
    opts: &SwarmOptions,
) -> Result<SwarmStats> {
    cfg.validate(manifest)?;
    run_swarm_with(addr, cfg, workers, time_scale, opts)
}
