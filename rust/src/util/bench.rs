//! Minimal benchmark harness (criterion is not available offline).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (declared with
//! `harness = false`); they use this module for warmup, adaptive
//! iteration and robust summary statistics.  Drivers can collect their
//! [`BenchResult`]s and emit a machine-readable JSON report
//! ([`write_json`]) so the perf trajectory is trackable across PRs (CI
//! uploads `BENCH_round.json` as an artifact).

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    /// Work items processed per iteration (clients, updates, bytes …);
    /// 0 when the case has no natural unit.  JSON reports derive
    /// `throughput_per_s = items / p50_s` from it.
    pub items: usize,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>11}  p50 {:>11}  p95 {:>11}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
        )
    }

    /// Items per second at the median, if the case declared items.
    pub fn throughput_per_s(&self) -> Option<f64> {
        if self.items > 0 && self.p50_s > 0.0 {
            Some(self.items as f64 / self.p50_s)
        } else {
            None
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize results as a machine-readable report (per-case median
/// nanoseconds + throughput), e.g. `BENCH_round.json`.
pub fn to_json(bench: &str, results: &[BenchResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{{\n \"bench\": \"{}\",\n \"results\": [", json_escape(bench)));
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let throughput = match r.throughput_per_s() {
            Some(t) => format!("{t:.3}"),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "\n  {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.0}, \
             \"p50_ns\": {:.0}, \"p95_ns\": {:.0}, \"items\": {}, \
             \"throughput_per_s\": {}}}",
            json_escape(&r.name),
            r.iters,
            r.mean_s * 1e9,
            r.p50_s * 1e9,
            r.p95_s * 1e9,
            r.items,
            throughput,
        ));
    }
    out.push_str("\n ]\n}\n");
    out
}

/// Write the JSON report to `path`.
pub fn write_json(
    path: &std::path::Path,
    bench: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    std::fs::write(path, to_json(bench, results))
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Run `f` with 2 warmup calls, then until `budget_s` seconds or
/// `max_iters`, whichever first (at least 3 timed iterations).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, max_iters: usize, f: F) -> BenchResult {
    bench_items(name, budget_s, max_iters, 0, f)
}

/// [`bench`] with a work-item count per iteration, so the JSON report
/// can derive throughput.
pub fn bench_items<F: FnMut()>(
    name: &str,
    budget_s: f64,
    max_iters: usize,
    items: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 3)
        || (start.elapsed().as_secs_f64() < budget_s && samples.len() < max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: stats::mean(&samples),
        p50_s: stats::percentile(&samples, 0.5),
        p95_s: stats::percentile(&samples, 0.95),
        items,
    };
    println!("{}", res.line());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_minimum_iterations() {
        let mut count = 0;
        let res = bench("noop", 0.0, 10, || count += 1);
        assert!(res.iters >= 3);
        assert!(count >= res.iters);
        assert!(res.p95_s >= res.p50_s);
    }

    #[test]
    fn formats() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(0.002).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
    }

    #[test]
    fn json_report_is_machine_readable() {
        let results = vec![
            BenchResult {
                name: "case \"a\"".into(),
                iters: 5,
                mean_s: 1.5e-3,
                p50_s: 1.0e-3,
                p95_s: 2.0e-3,
                items: 1000,
            },
            BenchResult {
                name: "case-b".into(),
                iters: 3,
                mean_s: 2.0,
                p50_s: 2.0,
                p95_s: 2.0,
                items: 0,
            },
        ];
        let text = to_json("round", &results);
        // parseable by our own strict JSON parser
        let v = crate::util::json::Value::parse(&text).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "round");
        let arr = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "case \"a\"");
        assert_eq!(arr[0].get("p50_ns").unwrap().as_usize().unwrap(), 1_000_000);
        // 1000 items at 1 ms median -> 1e6 items/s
        let tput = arr[0].get("throughput_per_s").unwrap().as_f64().unwrap();
        assert!((tput - 1e6).abs() < 1.0);
        assert_eq!(
            *arr[1].get("throughput_per_s").unwrap(),
            crate::util::json::Value::Null
        );
        // itemless cases report no throughput
        assert!(results[1].throughput_per_s().is_none());
    }
}
