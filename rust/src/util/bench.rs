//! Minimal benchmark harness (criterion is not available offline).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (declared with
//! `harness = false`); they use this module for warmup, adaptive
//! iteration and robust summary statistics.

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>11}  p50 {:>11}  p95 {:>11}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Run `f` with 2 warmup calls, then until `budget_s` seconds or
/// `max_iters`, whichever first (at least 3 timed iterations).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, max_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 3)
        || (start.elapsed().as_secs_f64() < budget_s && samples.len() < max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: stats::mean(&samples),
        p50_s: stats::percentile(&samples, 0.5),
        p95_s: stats::percentile(&samples, 0.95),
    };
    println!("{}", res.line());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_minimum_iterations() {
        let mut count = 0;
        let res = bench("noop", 0.0, 10, || count += 1);
        assert!(res.iters >= 3);
        assert!(count >= res.iters);
        assert!(res.p95_s >= res.p50_s);
    }

    #[test]
    fn formats() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(0.002).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
    }
}
