//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; typed getters with defaults.  Used by the `repro` binary
//! and the example/bench drivers.

use std::collections::BTreeMap;

use crate::error::{HcflError, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .options
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.str_opt(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HcflError::Config(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HcflError::Config(format!("--{name} expects a number, got '{v}'"))
            }),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HcflError::Config(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    /// Comma-separated usize list (`--ratios 4,8,16,32`).
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse::<usize>().map_err(|_| {
                        HcflError::Config(format!("--{name}: bad entry '{p}'"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["experiment", "--id", "table1", "--rounds=30", "--verbose"]);
        assert_eq!(a.positional(0), Some("experiment"));
        assert_eq!(a.str_opt("id"), Some("table1"));
        assert_eq!(a.usize_or("rounds", 0).unwrap(), 30);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--rounds", "abc"]);
        assert!(a.usize_or("rounds", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("k", 17).unwrap(), 17);
        assert_eq!(a.str_or("model", "lenet"), "lenet");
        assert_eq!(a.f64_or("lr", 0.01).unwrap(), 0.01);
    }

    #[test]
    fn lists() {
        let a = parse(&["--ratios", "4,8,16"]);
        assert_eq!(a.usize_list_or("ratios", &[]).unwrap(), vec![4, 8, 16]);
        let b = parse(&[]);
        assert_eq!(b.usize_list_or("ratios", &[32]).unwrap(), vec![32]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--cache", "--paper-scale"]);
        assert!(a.flag("cache"));
        assert!(a.flag("paper-scale"));
    }
}
