//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! serde is not available offline, and the manifest is machine-generated
//! by our own `aot.py`, so a small recursive-descent parser over the full
//! JSON grammar (RFC 8259) is all we need.  It is strict: trailing
//! garbage, unterminated strings, bad escapes and malformed numbers are
//! errors, not best-effort.

use std::collections::BTreeMap;

use crate::error::{HcflError, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors (schema errors become HcflError::Json) ----

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| HcflError::Json(format!("missing key '{key}'"))),
            _ => Err(HcflError::Json(format!("'{key}': not an object"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            other => Err(HcflError::Json(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            other => Err(HcflError::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(HcflError::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => Err(HcflError::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > usize::MAX as f64 {
            return Err(HcflError::Json(format!("expected usize, got {n}")));
        }
        Ok(n as usize)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> HcflError {
        HcflError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                // Raw UTF-8 passthrough: collect continuation bytes.
                b if b < 0x20 => return Err(self.err("control character in string")),
                b => {
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        // Re-decode the multi-byte sequence from the source.
                        let start = self.pos - 1;
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"hi\\nthere\"").unwrap(),
            Value::Str("hi\nthere".into())
        );
    }

    #[test]
    fn nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Value::parse(r#""é😀""#).unwrap(),
            Value::Str("é😀".into())
        );
        // raw multibyte passthrough
        assert_eq!(Value::parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("\"abc").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessor_errors() {
        let v = Value::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "version": 1,
          "executables": {
            "x": {"file": "x.hlo.txt",
                   "inputs": [{"dtype": "f32", "shape": [44426]}],
                   "outputs": [{"dtype": "f32", "shape": []}]}
          }
        }"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        let ex = v.get("executables").unwrap().get("x").unwrap();
        let shape = ex.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 44426);
    }
}
