//! Small self-contained substrates the offline environment forces us to
//! own: deterministic PRNG, a minimal JSON parser (manifest.json), a CLI
//! argument parser, and summary statistics.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
