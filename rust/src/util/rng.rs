//! Deterministic PRNG (SplitMix64 seeding + xoshiro256++ core).
//!
//! Every stochastic piece of the simulation (data generation, client
//! selection, parameter init, shard shuffling) derives its stream from an
//! explicit seed so experiment runs are exactly reproducible.  `rand` is
//! not available offline; this is the standard xoshiro256++ construction.

/// xoshiro256++ PRNG with SplitMix64 seed expansion.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        Rng {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
        }
    }

    /// Derive an independent child stream (for per-client / per-shard RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw xoshiro256++ state — the stream cursor.  Captured into
    /// campaign snapshots so a resumed run continues the exact sequence
    /// (`daemon::snapshot`).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position.  `s` must come
    /// from [`Rng::state`]: arbitrary words (in particular all zeros,
    /// xoshiro's one forbidden state) are not a valid cursor.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze rejection, with the
    /// `G(a) = G(a+1) · U^(1/a)` boost for shape < 1.  Feeds the
    /// Dirichlet shard partitioner (`data::Partition::Dirichlet`).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(
            shape.is_finite() && shape > 0.0,
            "Rng::gamma needs a positive finite shape, got {shape}"
        );
        if shape < 1.0 {
            let u = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "choose({m}) from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.uniform(-0.5, 0.5);
            assert!((-0.5..0.5).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(a, 1) has mean a and variance a in both sampler branches.
        for a in [0.5f64, 2.5] {
            let mut r = Rng::new(13);
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| r.gamma(a)).collect();
            assert!(xs.iter().all(|&x| x >= 0.0));
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!((mean - a).abs() < 0.05 * a.max(1.0), "shape {a}: mean {mean}");
            assert!((var - a).abs() < 0.15 * a.max(1.0), "shape {a}: var {var}");
        }
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(5);
        let picked = r.choose(100, 10);
        assert_eq!(picked.len(), 10);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(picked.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trips_mid_stream() {
        // Capture after a mixed draw history, then the original and the
        // restored generator must produce identical continuations.
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        a.next_f64();
        a.normal();
        let saved = a.state();
        let mut b = Rng::from_state(saved);
        assert_eq!(b.state(), saved);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // and the capture itself does not advance the stream
        let mut c = Rng::new(7);
        let s0 = c.state();
        assert_eq!(c.state(), s0);
        let first = c.next_u64();
        assert_eq!(Rng::from_state(s0).next_u64(), first);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
