//! Summary statistics shared by the metrics recorder, the theory
//! calculators, and the bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile; `q` in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Shannon entropy (bits) of a histogram over `bins` equal-width buckets.
///
/// Used by the Theorem-2 calculator to estimate H(W) and H(C) from
/// empirical weight/code samples (paper eq. 11).
pub fn histogram_entropy(xs: &[f32], bins: usize) -> f64 {
    if xs.is_empty() || bins == 0 {
        return 0.0;
    }
    let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    if !(hi > lo) {
        return 0.0; // constant data carries no entropy
    }
    let width = (hi - lo) / bins as f64;
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let idx = (((x as f64 - lo) / width) as usize).min(bins - 1);
        counts[idx] += 1;
    }
    let n = xs.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(histogram_entropy(&[], 10), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.25), 2.0);
    }

    #[test]
    fn entropy_uniform_vs_constant() {
        // constant => 0 bits
        assert_eq!(histogram_entropy(&[1.0; 100], 16), 0.0);
        // uniform over 16 bins => ~4 bits
        let xs: Vec<f32> = (0..1600).map(|i| i as f32 / 100.0).collect();
        let h = histogram_entropy(&xs, 16);
        assert!((h - 4.0).abs() < 0.05, "h={h}");
        // concentrated distribution has lower entropy than uniform
        let mut peaked = vec![0.0f32; 1500];
        peaked.extend((0..100).map(|i| i as f32 / 100.0));
        assert!(histogram_entropy(&peaked, 16) < h);
    }
}
