//! Engine-free property tests over the pure-Rust codec paths.
//!
//! The engine-backed integration tests in `compression_pipeline.rs` skip
//! themselves without the `pjrt` feature + generated artifacts, so CI
//! used to exercise none of the codec properties.  Everything here runs
//! under plain `cargo test -q` on every build: the properties cover the
//! reference quantizer (`TernaryCompressor::quantize_ref`, which the
//! engine kernel is itself tested against), the wire-size accounting,
//! and the pure sparsification/identity codecs.
//!
//! proptest is not available offline; these use the same
//! seeded-random-case sweep pattern (many generated cases per property,
//! deterministic seeds).

use hcfl::compression::{Compressor, Identity, TernaryChunk, TernaryCompressor, TopKCompressor};
use hcfl::util::rng::Rng;

fn random_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Pure-Rust mirror of the compressor's chunking: quantize each
/// 1024-slice (including the partial tail) with the reference TWN math.
fn quantize_chunked(v: &[f32], chunk: usize) -> Vec<TernaryChunk> {
    v.chunks(chunk).map(TernaryCompressor::quantize_ref).collect()
}

#[test]
fn identity_property_lossless_any_length() {
    let c = Identity;
    let mut rng = Rng::new(11);
    for case in 0..50 {
        let n = 1 + rng.below(5000);
        let v = random_vec(&mut rng, n, 0.5);
        let upd = c.compress(&v, 0).unwrap();
        assert_eq!(upd.wire_bytes, 4 * n, "case {case}");
        assert_eq!(c.decompress(upd, n, 0).unwrap(), v);
    }
}

#[test]
fn ternary_property_roundtrip_is_scaled_sign() {
    let chunk = 1024;
    let mut rng = Rng::new(22);
    for case in 0..12 {
        // lengths around the chunk boundary exercise the tail path
        let n = [512, 1024, 1025, 2048, 3000, 4096][case % 6];
        let v = random_vec(&mut rng, n, 0.2);
        let chunks = quantize_chunked(&v, chunk);
        let back = TernaryCompressor::decode_chunks(&chunks, n).unwrap();
        assert_eq!(back.len(), n);
        // every reconstructed value is 0 or ±alpha of its chunk, with
        // the sign of the original
        for (i, (orig, rec)) in v.iter().zip(&back).enumerate() {
            if *rec != 0.0 {
                assert_eq!(rec.signum(), orig.signum(), "case {case}");
                let alpha = chunks[i / chunk].alpha;
                assert!(
                    (rec.abs() - alpha).abs() < 1e-6,
                    "case {case}: |rec| {} != alpha {alpha}",
                    rec.abs()
                );
            }
        }
        // wire size: ~2 bits per weight
        let wire = TernaryCompressor::wire_bytes_for(n, chunk);
        assert!(wire < n, "case {case}: {wire} bytes for {n} weights");
    }
}

#[test]
fn ternary_property_alpha_is_mean_of_kept_magnitudes() {
    let mut rng = Rng::new(33);
    for case in 0..30 {
        let n = 8 + rng.below(2000);
        let v = random_vec(&mut rng, n, 0.5);
        let t = TernaryCompressor::quantize_ref(&v);
        assert_eq!(t.q.len(), n, "case {case}");
        let kept: Vec<f32> = v
            .iter()
            .zip(&t.q)
            .filter(|(_, &q)| q != 0)
            .map(|(x, _)| x.abs())
            .collect();
        if kept.is_empty() {
            assert_eq!(t.alpha, 0.0, "case {case}");
        } else {
            let mean = kept.iter().sum::<f32>() / kept.len() as f32;
            assert!((t.alpha - mean).abs() < 1e-4, "case {case}");
        }
        // the threshold keeps exactly the weights above 0.7 * mean|w|
        // (same association order as quantize_ref, so f32-exact)
        let mean_abs = v.iter().map(|x| x.abs()).sum::<f32>() / n as f32;
        let delta = 0.7 * mean_abs;
        for (x, &q) in v.iter().zip(&t.q) {
            assert_eq!(q != 0, x.abs() > delta, "case {case}");
        }
    }
}

#[test]
fn ternary_wire_size_property() {
    let mut rng = Rng::new(44);
    for _ in 0..50 {
        let d = 1 + rng.below(100_000);
        let chunk = 1024;
        let wire = TernaryCompressor::wire_bytes_for(d, chunk);
        // 2 bits per weight packed four-per-byte + one f32 scale per chunk
        assert_eq!(wire, d.div_ceil(4) + 4 * d.div_ceil(chunk));
        // compression vs 4 B/weight approaches 16x for large d
        if d >= 16 * chunk {
            let ratio = (4 * d) as f64 / wire as f64;
            assert!(ratio > 15.0 && ratio < 16.1, "d={d}: ratio {ratio}");
        }
    }
}

#[test]
fn topk_property_preserves_top_magnitudes() {
    let mut rng = Rng::new(55);
    for _ in 0..30 {
        let n = 10 + rng.below(3000);
        let keep = 0.05 + rng.next_f64() * 0.9;
        let c = TopKCompressor::new(keep).unwrap();
        let v = random_vec(&mut rng, n, 1.0);
        let upd = c.compress(&v, 0).unwrap();
        let k = c.k_for(n);
        assert_eq!(upd.wire_bytes, 8 * k);
        let back = c.decompress(upd, n, 0).unwrap();
        // kept entries equal original; dropped are zero
        let kept = back.iter().filter(|x| **x != 0.0).count();
        assert!(kept <= k);
        let mut mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let threshold = mags[k - 1];
        for (orig, rec) in v.iter().zip(&back) {
            if orig.abs() > threshold {
                assert_eq!(orig, rec);
            }
        }
    }
}
