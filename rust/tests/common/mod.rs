//! Shared plumbing for engine-backed integration tests.

use hcfl::prelude::*;

/// Build the PJRT engine when this build can actually run it: requires
/// both the `pjrt` feature and generated artifacts.  Returns `None`
/// (with a note on stderr) otherwise, so engine tests skip rather than
/// fail in offline builds while still running fully where the real
/// backend is available.
pub fn engine(workers: usize) -> Option<Engine> {
    if !hcfl::runtime::pjrt_enabled() {
        eprintln!("skipping engine test: built without the `pjrt` feature");
        return None;
    }
    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if !dir.join("manifest.json").is_file() {
        eprintln!("skipping engine test: no artifacts (run `make artifacts` first)");
        return None;
    }
    Some(Engine::from_artifacts(dir, workers).expect("artifacts load"))
}
