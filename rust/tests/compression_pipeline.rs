//! Integration tests over the compression schemes against the real
//! engine and artifacts (they skip without `pjrt` + artifacts).
//!
//! The pure-Rust codec properties — reference quantizer round-trips,
//! wire-size accounting, sparsification/identity codecs — live in
//! `codec_properties.rs`, which always runs; this file keeps only what
//! genuinely needs the engine (kernel-vs-reference equivalence and the
//! HCFL autoencoder pipeline).

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use hcfl::compression::hcfl::{hcfl_wire_bytes, AeHandle};
use hcfl::compression::{Compressor, HcflCompressor, TernaryCompressor};
use hcfl::model::{merge_segment_ranges, split_dense};
use hcfl::prelude::*;
use hcfl::util::rng::Rng;

fn random_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[test]
fn ternary_engine_matches_rust_reference() {
    let Some(eng) = common::engine(1) else { return };
    let c = TernaryCompressor::new(eng, 1024).unwrap();
    let mut rng = Rng::new(33);
    let v = random_vec(&mut rng, 1024, 0.3);
    let upd = c.compress(&v, 0).unwrap();
    let back = c.decompress(&upd, 1024, 0).unwrap();
    let r = TernaryCompressor::quantize_ref(&v);
    let expect: Vec<f32> = r.q.iter().map(|&q| q as f32 * r.alpha).collect();
    for (a, b) in back.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-5);
    }
}

fn make_hcfl(eng: &Engine, ratio: usize) -> HcflCompressor {
    // Untrained (random) AE params are fine for pipeline-shape tests.
    let mut rng = Rng::new(7);
    let chunk_of_segment: BTreeMap<String, usize> = eng.manifest().chunks.clone();
    let model = eng.manifest().model("lenet").unwrap();
    let ranges = split_dense(&merge_segment_ranges(&model.layers), 1);
    let chunks: std::collections::BTreeSet<usize> =
        chunk_of_segment.values().copied().collect();
    let aes: Vec<AeHandle> = chunks
        .into_iter()
        .map(|chunk| {
            let meta = eng.manifest().autoencoder(chunk, ratio).unwrap().clone();
            let params = (0..meta.d).map(|_| rng.normal() * 0.05).collect();
            AeHandle {
                meta,
                params: Arc::new(params),
            }
        })
        .collect();
    HcflCompressor::new(eng.clone(), ratio, ranges, aes, chunk_of_segment).unwrap()
}

#[test]
fn hcfl_pipeline_shape_and_wire_size() {
    let Some(eng) = common::engine(1) else { return };
    let model_d = eng.manifest().model("lenet").unwrap().d;
    for ratio in [4usize, 32] {
        let c = make_hcfl(&eng, ratio);
        let mut rng = Rng::new(55);
        let v = random_vec(&mut rng, model_d, 0.1);
        let upd = c.compress(&v, 0).unwrap();
        // wire matches the closed-form accounting
        let expect = hcfl_wire_bytes(c.ranges(), &eng.manifest().chunks, ratio);
        assert_eq!(upd.wire_bytes, expect);
        // decompression reproduces the right shape and is finite
        let back = c.decompress(&upd, model_d, 0).unwrap();
        assert_eq!(back.len(), model_d);
        assert!(back.iter().all(|x| x.is_finite()));
        // true ratio is in the right ballpark (below nominal due to side
        // info + padding, same effect as the paper's Tables I/II)
        let true_ratio = (4 * model_d) as f64 / upd.wire_bytes as f64;
        assert!(
            true_ratio > ratio as f64 * 0.5 && true_ratio < ratio as f64 * 1.05,
            "ratio {ratio}: true {true_ratio}"
        );
    }
}

#[test]
fn hcfl_variance_preserving_decode() {
    // Even with an untrained AE the reconstructed chunks must carry the
    // original per-chunk energy (the moment side-info guarantees it).
    let Some(eng) = common::engine(1) else { return };
    let c = make_hcfl(&eng, 8);
    let model_d = eng.manifest().model("lenet").unwrap().d;
    let mut rng = Rng::new(66);
    let v = random_vec(&mut rng, model_d, 0.05);
    let upd = c.compress(&v, 0).unwrap();
    let back = c.decompress(&upd, model_d, 0).unwrap();
    let var_orig: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / v.len() as f64;
    let var_back: f64 =
        back.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / back.len() as f64;
    assert!(
        (var_back / var_orig) > 0.5 && (var_back / var_orig) < 2.0,
        "energy ratio {}",
        var_back / var_orig
    );
}
