//! Integration tests over the compression schemes against the real
//! engine and artifacts (they skip without `pjrt` + artifacts).
//!
//! The pure-Rust codec properties — reference quantizer round-trips,
//! wire-size accounting, sparsification/identity codecs — live in
//! `codec_properties.rs`, which always runs; this file keeps only what
//! genuinely needs the engine (kernel-vs-reference equivalence and the
//! HCFL autoencoder pipeline).

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use hcfl::compression::hcfl::{hcfl_wire_bytes, AeHandle};
use hcfl::compression::{
    plan_batches, wire, Compressor, HcflCompressor, Payload, TernaryCompressor,
};
use hcfl::model::{chunk_count, merge_segment_ranges, split_dense};
use hcfl::prelude::*;
use hcfl::util::rng::Rng;

fn random_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Canonical byte image of a payload (bit-level comparison helper).
fn packed(p: &Payload) -> Vec<u8> {
    let mut out = Vec::new();
    wire::pack_payload(p, &mut out).unwrap();
    out
}

#[test]
fn ternary_engine_matches_rust_reference() {
    let Some(eng) = common::engine(1) else { return };
    let c = TernaryCompressor::new(eng, 1024).unwrap();
    let mut rng = Rng::new(33);
    let v = random_vec(&mut rng, 1024, 0.3);
    let upd = c.compress(&v, 0).unwrap();
    let back = c.decompress(upd, 1024, 0).unwrap();
    let r = TernaryCompressor::quantize_ref(&v);
    let expect: Vec<f32> = r.q.iter().map(|&q| q as f32 * r.alpha).collect();
    for (a, b) in back.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-5);
    }
}

fn make_hcfl(eng: &Engine, ratio: usize) -> HcflCompressor {
    // Untrained (random) AE params are fine for pipeline-shape tests.
    let mut rng = Rng::new(7);
    let chunk_of_segment: BTreeMap<String, usize> = eng.manifest().chunks.clone();
    let model = eng.manifest().model("lenet").unwrap();
    let ranges = split_dense(&merge_segment_ranges(&model.layers), 1);
    let chunks: std::collections::BTreeSet<usize> =
        chunk_of_segment.values().copied().collect();
    let aes: Vec<AeHandle> = chunks
        .into_iter()
        .map(|chunk| {
            let meta = eng.manifest().autoencoder(chunk, ratio).unwrap().clone();
            let params = (0..meta.d).map(|_| rng.normal() * 0.05).collect();
            AeHandle {
                meta,
                params: Arc::new(params),
            }
        })
        .collect();
    HcflCompressor::new(eng.clone(), ratio, ranges, aes, chunk_of_segment).unwrap()
}

#[test]
fn hcfl_pipeline_shape_and_wire_size() {
    let Some(eng) = common::engine(1) else { return };
    let model_d = eng.manifest().model("lenet").unwrap().d;
    for ratio in [4usize, 32] {
        let c = make_hcfl(&eng, ratio);
        let mut rng = Rng::new(55);
        let v = random_vec(&mut rng, model_d, 0.1);
        let upd = c.compress(&v, 0).unwrap();
        // wire matches the closed-form accounting
        let expect = hcfl_wire_bytes(c.ranges(), &eng.manifest().chunks, ratio);
        assert_eq!(upd.wire_bytes, expect);
        let wire_bytes = upd.wire_bytes;
        // decompression reproduces the right shape and is finite
        let back = c.decompress(upd, model_d, 0).unwrap();
        assert_eq!(back.len(), model_d);
        assert!(back.iter().all(|x| x.is_finite()));
        // true ratio is in the right ballpark (below nominal due to side
        // info + padding, same effect as the paper's Tables I/II)
        let true_ratio = (4 * model_d) as f64 / wire_bytes as f64;
        assert!(
            true_ratio > ratio as f64 * 0.5 && true_ratio < ratio as f64 * 1.05,
            "ratio {ratio}: true {true_ratio}"
        );
    }
}

/// Tentpole acceptance: the batched dispatch must produce bit-identical
/// payloads and reconstructions to the per-chunk path while issuing
/// O(segments) engine calls instead of O(chunks).
#[test]
fn hcfl_batched_dispatch_is_bit_identical_and_o_segments() {
    let Some(eng) = common::engine(1) else { return };
    let ratio = 8usize;
    let batched = make_hcfl(&eng, ratio);
    if eng
        .manifest()
        .autoencoder(1024, ratio)
        .map(|ae| ae.encode_batch.is_empty())
        .unwrap_or(true)
    {
        eprintln!("skipping: artifacts predate batched codec executables");
        return;
    }
    let mut per_chunk = make_hcfl(&eng, ratio);
    per_chunk.disable_batched();

    let model_d = eng.manifest().model("lenet").unwrap().d;
    let mut rng = Rng::new(77);
    let v = random_vec(&mut rng, model_d, 0.1);

    let before = eng.dispatch_count();
    let upd_b = batched.compress(&v, 0).unwrap();
    let batched_calls = eng.dispatch_count() - before;
    let before = eng.dispatch_count();
    let upd_p = per_chunk.compress(&v, 0).unwrap();
    let per_chunk_calls = eng.dispatch_count() - before;

    // call counts: per-chunk = total chunks, batched = the planned
    // number of tiles per segment range
    let mut total_chunks = 0usize;
    let mut planned = 0usize;
    for r in batched.ranges() {
        let chunk = eng.manifest().chunks[&r.segment];
        let n = chunk_count(r.len, chunk);
        let sizes: Vec<usize> = eng
            .manifest()
            .autoencoder(chunk, ratio)
            .unwrap()
            .encode_batch
            .keys()
            .copied()
            .collect();
        total_chunks += n;
        planned += plan_batches(n, &sizes).len();
    }
    assert_eq!(per_chunk_calls, total_chunks);
    assert_eq!(batched_calls, planned);
    assert!(
        batched_calls * 4 <= total_chunks,
        "batched path made {batched_calls} calls for {total_chunks} chunks"
    );

    // payloads are bit-identical (canonical packed form)
    assert_eq!(upd_b.wire_bytes, upd_p.wire_bytes);
    assert_eq!(packed(&upd_b.payload), packed(&upd_p.payload));

    // reconstructions are bit-identical too, and batched decode also
    // collapses the call count
    let before = eng.dispatch_count();
    let back_b = batched.decompress(upd_b, model_d, 0).unwrap();
    let batched_dec = eng.dispatch_count() - before;
    let before = eng.dispatch_count();
    let back_p = per_chunk.decompress(upd_p, model_d, 0).unwrap();
    let per_chunk_dec = eng.dispatch_count() - before;
    assert_eq!(back_b, back_p);
    assert!(batched_dec * 4 <= per_chunk_dec);
}

#[test]
fn ternary_batched_dispatch_is_bit_identical() {
    let Some(eng) = common::engine(1) else { return };
    let batched = TernaryCompressor::new(eng.clone(), 1024).unwrap();
    if eng.manifest().ternary_batch_execs(1024).is_empty() {
        eprintln!("skipping: artifacts predate batched codec executables");
        return;
    }
    let mut per_chunk = TernaryCompressor::new(eng.clone(), 1024).unwrap();
    per_chunk.disable_batched();

    // 43 full chunks + a partial tail
    let d = 43 * 1024 + 700;
    let mut rng = Rng::new(88);
    let v = random_vec(&mut rng, d, 0.2);

    let before = eng.dispatch_count();
    let upd_b = batched.compress(&v, 0).unwrap();
    let batched_calls = eng.dispatch_count() - before;
    let before = eng.dispatch_count();
    let upd_p = per_chunk.compress(&v, 0).unwrap();
    let per_chunk_calls = eng.dispatch_count() - before;

    assert_eq!(per_chunk_calls, 43);
    assert!(
        batched_calls * 4 <= per_chunk_calls,
        "batched ternary made {batched_calls} calls"
    );
    assert_eq!(upd_b.wire_bytes, upd_p.wire_bytes);
    assert_eq!(packed(&upd_b.payload), packed(&upd_p.payload));
    assert_eq!(
        batched.decompress(upd_b, d, 0).unwrap(),
        per_chunk.decompress(upd_p, d, 0).unwrap()
    );
}

#[test]
fn hcfl_variance_preserving_decode() {
    // Even with an untrained AE the reconstructed chunks must carry the
    // original per-chunk energy (the moment side-info guarantees it).
    let Some(eng) = common::engine(1) else { return };
    let c = make_hcfl(&eng, 8);
    let model_d = eng.manifest().model("lenet").unwrap().d;
    let mut rng = Rng::new(66);
    let v = random_vec(&mut rng, model_d, 0.05);
    let upd = c.compress(&v, 0).unwrap();
    let back = c.decompress(upd, model_d, 0).unwrap();
    let var_orig: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / v.len() as f64;
    let var_back: f64 =
        back.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / back.len() as f64;
    assert!(
        (var_back / var_orig) > 0.5 && (var_back / var_orig) < 2.0,
        "energy ratio {}",
        var_back / var_orig
    );
}
