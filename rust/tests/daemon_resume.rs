//! Crash-tolerance acceptance (DESIGN.md §9): a campaign interrupted
//! after any round's snapshot and resumed by a fresh process-equivalent
//! (new driver, restored state) must finish bit-identical to a run
//! that was never interrupted — same remaining `RoundRecord`s, same
//! final global model bits.  Covered here for both drivers:
//!
//! * the in-process `Simulation` path, through the serialized snapshot
//!   (encode → atomic file → load → restore);
//! * the TCP path, where the server is severed mid-campaign (no
//!   `Shutdown` frames — the library stand-in for `SIGKILL`), rebinds
//!   the same port, restores, and the swarm's re-dial budget carries
//!   its workers across the gap;
//! * the `Daemon` scheduler end-to-end: resuming a half-done job from
//!   its `.snap`, skipping completed jobs, and refusing corrupt
//!   snapshots with a typed error.
//!
//! The carry-heavy scenario (FastestM + stragglers + discounted carry)
//! is deliberate: the snapshot must round-trip non-trivial `CarryOver`
//! entries, not just the model vector.

use std::net::TcpListener;
use std::path::PathBuf;
use std::time::Duration;

use hcfl::compression::Scheme;
use hcfl::coordinator::session::CarryPolicy;
use hcfl::error::HcflError;
use hcfl::metrics::RoundRecord;
use hcfl::prelude::*;
use hcfl::transport::{demo_config, run_loopback, run_swarm_with, SwarmOptions};

/// The deterministic RoundRecord fields; measured timing fields are
/// excluded by design (see `tests/transport_loopback.rs`).
fn assert_record_eq(a: &RoundRecord, b: &RoundRecord) {
    let t = a.round;
    assert_eq!(a.round, b.round);
    assert_eq!(a.up_bytes, b.up_bytes, "up_bytes diverged in round {t}");
    assert_eq!(a.down_bytes, b.down_bytes, "down_bytes diverged in round {t}");
    assert_eq!(a.selected, b.selected, "selected diverged in round {t}");
    assert_eq!(a.completed, b.completed, "completed diverged in round {t}");
    assert_eq!(a.dropped, b.dropped, "dropped diverged in round {t}");
    assert_eq!(a.stragglers, b.stragglers, "stragglers diverged in round {t}");
    assert_eq!(a.carried_in, b.carried_in, "carried_in diverged in round {t}");
    assert_eq!(a.carried_out, b.carried_out, "carried_out diverged in round {t}");
    assert_eq!(
        a.carried_expired, b.carried_expired,
        "carried_expired diverged in round {t}"
    );
    assert_eq!(a.recon_mse, b.recon_mse, "recon_mse diverged in round {t}");
}

/// The carry-heavy campaign both resume arms replay: FastestM cuts half
/// the fleet every round, so the snapshot taken mid-campaign must carry
/// live `CarryOver` entries across the crash.
fn carry_campaign(rounds: usize) -> ExperimentConfig {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.2 }, 32, rounds, 42);
    cfg.data.size_skew = 0.25;
    cfg.scenario.policy = RoundPolicy::FastestM { m: 16 };
    cfg.scenario.devices = DevicePreset::Stragglers {
        frac: 0.25,
        slowdown: 8.0,
    };
    cfg.scenario.carry = CarryPolicy::CarryDiscounted {
        lambda: 0.5,
        max_age_rounds: 3,
    };
    cfg.scenario.aggregator = AggregatorKind::SampleWeighted;
    cfg
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hcfl-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// In-process kill-and-resume: freeze after round 3 of 6, push the
/// state through the full serialization path (encode → atomic write →
/// load), rebuild the driver from scratch and finish — every remaining
/// record and the final model bits must match the uninterrupted run.
#[test]
fn inprocess_resume_is_bit_identical() {
    let cfg = carry_campaign(6);
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();

    // The uninterrupted reference.
    let mut reference = Simulation::new(&engine, cfg.clone()).unwrap();
    let ref_records: Vec<RoundRecord> =
        (1..=6).map(|t| reference.run_round(t).unwrap()).collect();
    let ref_global = reference.global().to_vec();

    // The interrupted run: three rounds, then freeze and "die".
    let mut victim = Simulation::new(&engine, cfg.clone()).unwrap();
    for t in 1..=3 {
        victim.run_round(t).unwrap();
    }
    let snap = CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: victim.global().len() as u64,
        rounds_done: 3,
        rng: victim.rng_state(),
        global: victim.global().to_vec(),
        carry: victim.carry().clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: victim.opt_state().m.clone(),
        opt_v: victim.opt_state().v.clone(),
    };
    assert!(
        !snap.carry.is_empty(),
        "the carry campaign must snapshot live carry-over entries"
    );
    let dir = scratch_dir("resume-inproc");
    let path = dir.join("campaign.snap");
    snap.write_atomic(&path).unwrap();
    drop(victim);

    // A fresh process-equivalent: reload, fingerprint-check, restore.
    let snap = CampaignSnapshot::load(&path).unwrap();
    let mut resumed = Simulation::new(&engine, cfg.clone()).unwrap();
    snap.check(&cfg, resumed.global().len()).unwrap();
    assert_eq!(snap.rounds_done, 3);
    let opt = ServerOptState {
        m: snap.opt_m,
        v: snap.opt_v,
    };
    resumed
        .restore(snap.global, snap.carry, snap.rng, opt)
        .unwrap();
    for t in 4..=6 {
        let rec = resumed.run_round(t).unwrap();
        assert_record_eq(&ref_records[t - 1], &rec);
    }
    assert_eq!(
        resumed.global(),
        &ref_global[..],
        "resumed final model bits diverged"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// FedAdam moment vectors across the crash (DESIGN.md §9.2 snapshot v2
/// + §11): a campaign running the adaptive control plane and the
/// FedAdam server optimizer is frozen mid-flight, so the snapshot must
/// round-trip the nonzero first/second-moment state — resuming into a
/// zeroed optimizer would diverge on the very next install.
#[test]
fn fedadam_resume_is_bit_identical() {
    let mut cfg = carry_campaign(6);
    // Heterogeneous uplinks so the policy genuinely splits the fleet
    // between the TopK base codec and the ternary reference codec.
    cfg.scenario.devices = DevicePreset::Iot {
        sigma: 0.8,
        dropout_p: 0.0,
    };
    cfg.codec_policy = CodecPolicy::ThresholdByUplink {
        cutoff: 1.0,
        slow: Scheme::Ternary,
    };
    cfg.server_opt = ServerOptKind::DEFAULT_ADAM;
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();

    // The uninterrupted reference.
    let mut reference = Simulation::new(&engine, cfg.clone()).unwrap();
    let ref_records: Vec<RoundRecord> =
        (1..=6).map(|t| reference.run_round(t).unwrap()).collect();
    let ref_global = reference.global().to_vec();

    // Three rounds, then freeze: by now both Adam moments are live.
    let mut victim = Simulation::new(&engine, cfg.clone()).unwrap();
    for t in 1..=3 {
        victim.run_round(t).unwrap();
    }
    let snap = CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: victim.global().len() as u64,
        rounds_done: 3,
        rng: victim.rng_state(),
        global: victim.global().to_vec(),
        carry: victim.carry().clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: victim.opt_state().m.clone(),
        opt_v: victim.opt_state().v.clone(),
    };
    assert_eq!(snap.opt_m.len(), snap.d as usize);
    assert!(
        snap.opt_m.iter().any(|x| *x != 0.0) && snap.opt_v.iter().any(|x| *x != 0.0),
        "three FedAdam rounds must leave nonzero moment state to snapshot"
    );
    let dir = scratch_dir("resume-fedadam");
    let path = dir.join("campaign.snap");
    snap.write_atomic(&path).unwrap();
    drop(victim);

    let snap = CampaignSnapshot::load(&path).unwrap();
    let mut resumed = Simulation::new(&engine, cfg.clone()).unwrap();
    snap.check(&cfg, resumed.global().len()).unwrap();
    let opt = ServerOptState {
        m: snap.opt_m,
        v: snap.opt_v,
    };
    resumed
        .restore(snap.global, snap.carry, snap.rng, opt)
        .unwrap();
    for t in 4..=6 {
        let rec = resumed.run_round(t).unwrap();
        assert_record_eq(&ref_records[t - 1], &rec);
    }
    assert_eq!(
        resumed.global(),
        &ref_global[..],
        "FedAdam-resumed final model bits diverged"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// TCP kill-and-resume: the server is severed after round 2 of 4 with
/// no goodbye (the `SIGKILL` stand-in), a fresh server rebinds the same
/// port and restores the snapshot, and the swarm's re-dial budget
/// carries its connections across the restart.  Remaining records and
/// the final global model must match an uninterrupted loopback run.
#[test]
fn tcp_resume_with_redialing_swarm_is_bit_identical() {
    let cfg = carry_campaign(4);
    let manifest = Manifest::synthetic();
    let reference = run_loopback(&manifest, &cfg, 2, 0.0).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = SwarmOptions {
        redial_attempts: 600,
        redial_wait: Duration::from_millis(20),
    };
    let swarm_cfg = cfg.clone();
    let swarm_addr = addr.clone();
    let swarm = std::thread::spawn(move || {
        run_swarm_with(&swarm_addr, &swarm_cfg, 2, 0.0, &opts).unwrap()
    });

    // Rounds 1–2, snapshot, then the "crash": listener gone, sockets
    // severed mid-session, server dropped without `finish`.
    let mut server = RoundServer::new(&manifest, cfg.clone()).unwrap();
    let mut link = server.accept_swarm(&listener, 2).unwrap();
    let mut records = Vec::new();
    for t in 1..=2 {
        records.push(server.serve_round(&mut link, t).unwrap());
    }
    let snap = CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: server.global().len() as u64,
        rounds_done: 2,
        rng: server.rng_state(),
        global: server.global().to_vec(),
        carry: server.carry().clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: server.opt_state().m.clone(),
        opt_v: server.opt_state().v.clone(),
    };
    assert!(!snap.carry.is_empty(), "snapshot must carry live entries");
    let frozen = snap.encode();
    drop(listener);
    link.sever();
    drop(server);

    // The restarted daemon: same port, fresh server, restored state.
    let snap = CampaignSnapshot::decode(&frozen).unwrap();
    let listener = TcpListener::bind(&addr).unwrap();
    let mut server = RoundServer::new(&manifest, cfg.clone()).unwrap();
    snap.check(&cfg, server.global().len()).unwrap();
    let opt = ServerOptState {
        m: snap.opt_m,
        v: snap.opt_v,
    };
    server
        .restore(snap.global, snap.carry, snap.rng, opt)
        .unwrap();
    let mut link = server.accept_swarm(&listener, 2).unwrap();
    for t in 3..=4 {
        records.push(server.serve_round(&mut link, t).unwrap());
    }
    server.finish(link, 4);
    let stats = swarm.join().unwrap();

    assert_eq!(reference.records.len(), records.len());
    for (a, b) in reference.records.iter().zip(&records) {
        assert_record_eq(a, b);
    }
    assert_eq!(
        server.global(),
        &reference.global[..],
        "final model bits diverged across the crash"
    );
    assert_eq!(stats.rounds, 4, "the swarm must see every round complete");
    let carried: usize = records.iter().map(|r| r.carried_in).sum();
    assert!(carried > 0, "the campaign never exercised carry-over");
}

/// The scheduler end-to-end: a half-done job (snapshot on disk, no
/// model) resumes through `Daemon::run_job` and produces the exact
/// final model of an uninterrupted run; a finished job is skipped
/// idempotently.
#[test]
fn daemon_resumes_a_half_done_job_to_the_exact_model() {
    let job = JobSpec {
        name: "resume-e2e".into(),
        scheme: Scheme::TopK { keep: 0.2 },
        n_clients: 16,
        rounds: 5,
        seed: 9,
        driver: JobDriver::InProcess,
        edge_shards: 0,
        policy: CodecPolicy::Static,
        server_opt: ServerOptKind::Sgd,
    };
    let cfg = job.config();
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();

    // The uninterrupted reference model.
    let mut reference = Simulation::new(&engine, cfg.clone()).unwrap();
    for t in 1..=5 {
        reference.run_round(t).unwrap();
    }
    let ref_global = reference.global().to_vec();

    // A victim drives three rounds and leaves only its snapshot behind.
    let dir = scratch_dir("daemon-resume");
    let mut victim = Simulation::new(&engine, cfg.clone()).unwrap();
    for t in 1..=3 {
        victim.run_round(t).unwrap();
    }
    let snap = CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: victim.global().len() as u64,
        rounds_done: 3,
        rng: victim.rng_state(),
        global: victim.global().to_vec(),
        carry: victim.carry().clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: victim.opt_state().m.clone(),
        opt_v: victim.opt_state().v.clone(),
    };
    snap.write_atomic(&dir.join("resume-e2e.snap")).unwrap();
    drop(victim);

    // The daemon picks the job up mid-campaign and completes it.
    let daemon = Daemon::new(&dir);
    daemon.run_job(&job).unwrap();
    let model_path = dir.join("resume-e2e.model");
    let bytes = std::fs::read(&model_path).unwrap();
    let model: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
        .collect();
    assert_eq!(
        model.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        ref_global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "daemon-resumed model must be bit-identical to the uninterrupted run"
    );
    assert!(
        !dir.join("resume-e2e.snap").exists(),
        "a completed job's snapshot is retired"
    );
    assert!(dir.join("resume-e2e.csv").exists());

    // Idempotent restart: the model exists, so the job is skipped and
    // the output is untouched.
    daemon.run_job(&job).unwrap();
    assert_eq!(std::fs::read(&model_path).unwrap(), bytes);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A corrupt snapshot must fail the resume with a typed error and stay
/// on disk for inspection — never silently restart the campaign from
/// round 1.
#[test]
fn daemon_refuses_a_corrupt_snapshot() {
    let job = JobSpec {
        name: "corrupt".into(),
        scheme: Scheme::Fedavg,
        n_clients: 8,
        rounds: 3,
        seed: 5,
        driver: JobDriver::InProcess,
        edge_shards: 0,
        policy: CodecPolicy::Static,
        server_opt: ServerOptKind::Sgd,
    };
    let cfg = job.config();
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();
    let mut victim = Simulation::new(&engine, cfg.clone()).unwrap();
    victim.run_round(1).unwrap();
    let snap = CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: victim.global().len() as u64,
        rounds_done: 1,
        rng: victim.rng_state(),
        global: victim.global().to_vec(),
        carry: victim.carry().clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: victim.opt_state().m.clone(),
        opt_v: victim.opt_state().v.clone(),
    };
    let mut bytes = snap.encode();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    let dir = scratch_dir("daemon-corrupt");
    let snap_path = dir.join("corrupt.snap");
    std::fs::write(&snap_path, &bytes).unwrap();

    let daemon = Daemon::new(&dir);
    let err = daemon.run_job(&job).unwrap_err();
    assert!(
        matches!(err, HcflError::Snapshot(_)),
        "wanted a typed snapshot error, got: {err}"
    );
    assert!(
        snap_path.exists(),
        "the corrupt snapshot must survive for inspection"
    );
    assert!(!dir.join("corrupt.model").exists());
    std::fs::remove_dir_all(&dir).unwrap();
}
