//! The hierarchical-aggregation acceptance pin (DESIGN.md §10): a round
//! folded through `E` edge shards must be **bit-identical** to the flat
//! single-session fold — same global model bits, same deterministic
//! `RoundRecord` fields, same `CarryOver` entries — for E ∈ {1, 4, 16},
//! across `client_threads`, and across a daemon-style kill-and-resume
//! with sharding enabled.
//!
//! The campaign is deliberately carry-heavy (FastestM + stragglers +
//! discounted carry + sample weighting): carried leaves enter the tree
//! ahead of fresh survivors, so the shard partition must respect the
//! full leaf order, not just the survivor slice.

use hcfl::compression::Scheme;
use hcfl::coordinator::session::CarryPolicy;
use hcfl::metrics::RoundRecord;
use hcfl::prelude::*;
use hcfl::transport::demo_config;

/// The deterministic RoundRecord fields; measured timing fields are
/// excluded by design (see `tests/transport_loopback.rs`).
fn assert_record_eq(a: &RoundRecord, b: &RoundRecord) {
    let t = a.round;
    assert_eq!(a.round, b.round);
    assert_eq!(a.up_bytes, b.up_bytes, "up_bytes diverged in round {t}");
    assert_eq!(a.down_bytes, b.down_bytes, "down_bytes diverged in round {t}");
    assert_eq!(a.selected, b.selected, "selected diverged in round {t}");
    assert_eq!(a.completed, b.completed, "completed diverged in round {t}");
    assert_eq!(a.dropped, b.dropped, "dropped diverged in round {t}");
    assert_eq!(a.stragglers, b.stragglers, "stragglers diverged in round {t}");
    assert_eq!(a.carried_in, b.carried_in, "carried_in diverged in round {t}");
    assert_eq!(a.carried_out, b.carried_out, "carried_out diverged in round {t}");
    assert_eq!(
        a.carried_expired, b.carried_expired,
        "carried_expired diverged in round {t}"
    );
    assert_eq!(a.recon_mse, b.recon_mse, "recon_mse diverged in round {t}");
}

/// Carry-over entries are part of the round contract: compare them
/// field-wise, decoded parameters at bit level.
fn assert_carry_eq(a: &CarryOver, b: &CarryOver) {
    assert_eq!(a.len(), b.len(), "carry-over length diverged");
    for (x, y) in a.updates.iter().zip(&b.updates) {
        assert_eq!(x.client, y.client);
        assert_eq!(x.n_samples, y.n_samples);
        assert_eq!(x.born_round, y.born_round);
        assert_eq!(x.base_weight.to_bits(), y.base_weight.to_bits());
        assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        assert_eq!(
            x.decoded.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.decoded.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "carried decoded bits diverged for client {}",
            x.client
        );
    }
}

/// The carry-heavy campaign every arm replays.
fn carry_campaign(rounds: usize, client_threads: usize, edge_shards: usize) -> ExperimentConfig {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.2 }, 40, rounds, 42);
    cfg.client_threads = client_threads;
    cfg.edge_shards = edge_shards;
    cfg.data.size_skew = 0.25;
    cfg.scenario.policy = RoundPolicy::FastestM { m: 16 };
    cfg.scenario.devices = DevicePreset::Stragglers {
        frac: 0.25,
        slowdown: 8.0,
    };
    cfg.scenario.carry = CarryPolicy::CarryDiscounted {
        lambda: 0.5,
        max_age_rounds: 3,
    };
    cfg.scenario.aggregator = AggregatorKind::SampleWeighted;
    cfg
}

fn run_campaign(cfg: &ExperimentConfig, rounds: usize) -> (Vec<RoundRecord>, Vec<f32>, CarryOver) {
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();
    let mut sim = Simulation::new(&engine, cfg.clone()).unwrap();
    let records = (1..=rounds).map(|t| sim.run_round(t).unwrap()).collect();
    let global = sim.global().to_vec();
    let carry = sim.carry().clone();
    (records, global, carry)
}

/// The headline pin: flat vs sharded across E ∈ {1, 4, 16} and two pool
/// widths — global bits, every deterministic record field, and the
/// final in-flight carry-over must all match.
#[test]
fn sharded_rounds_are_bit_identical_to_flat() {
    const ROUNDS: usize = 5;
    let (flat_records, flat_global, flat_carry) =
        run_campaign(&carry_campaign(ROUNDS, 4, 0), ROUNDS);
    let carried: usize = flat_records.iter().map(|r| r.carried_in).sum();
    assert!(carried > 0, "the campaign never exercised carry-over");

    for client_threads in [1usize, 4] {
        for edge in [1usize, 4, 16] {
            let cfg = carry_campaign(ROUNDS, client_threads, edge);
            let (records, global, carry) = run_campaign(&cfg, ROUNDS);
            for (a, b) in flat_records.iter().zip(&records) {
                assert_record_eq(a, b);
            }
            assert_eq!(
                flat_global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "global model bits diverged (threads={client_threads}, E={edge})"
            );
            assert_carry_eq(&flat_carry, &carry);
        }
    }
}

/// Kill-and-resume with sharding on: freeze a sharded campaign after
/// round 3, round-trip the snapshot through the serialized form, and
/// finish in a fresh sharded driver — bit-identical to the flat
/// uninterrupted run.  Also proves snapshot E-compatibility: the same
/// frozen state resumes under a *different* E (the fold is E-invariant,
/// so the fingerprint deliberately excludes it).
#[test]
fn sharded_kill_and_resume_matches_flat_reference() {
    const ROUNDS: usize = 6;
    let (flat_records, flat_global, _) = run_campaign(&carry_campaign(ROUNDS, 4, 0), ROUNDS);

    let cfg = carry_campaign(ROUNDS, 4, 4);
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();
    let mut victim = Simulation::new(&engine, cfg.clone()).unwrap();
    assert_eq!(victim.edge_shards(), 4);
    for t in 1..=3 {
        victim.run_round(t).unwrap();
    }
    let snap = CampaignSnapshot {
        seed: cfg.seed,
        codec: cfg.scheme.codec_tag(),
        n_clients: cfg.n_clients as u64,
        d: victim.global().len() as u64,
        rounds_done: 3,
        rng: victim.rng_state(),
        global: victim.global().to_vec(),
        carry: victim.carry().clone(),
        opt_tag: cfg.server_opt.tag(),
        opt_m: victim.opt_state().m.clone(),
        opt_v: victim.opt_state().v.clone(),
    };
    assert!(
        !snap.carry.is_empty(),
        "the carry campaign must snapshot live carry-over entries"
    );
    // Full serialization path, as the daemon would take it.
    let bytes = snap.encode();
    drop(victim);

    // Resume under E=4 (the crashed job's own shape) and under E=16
    // (a re-provisioned edge tier): both must finish on the flat bits.
    for resume_edge in [4usize, 16] {
        let snap = CampaignSnapshot::decode(&bytes).unwrap();
        let mut cfg = cfg.clone();
        cfg.edge_shards = resume_edge;
        let mut resumed = Simulation::new(&engine, cfg.clone()).unwrap();
        snap.check(&cfg, resumed.global().len()).unwrap();
        let opt = ServerOptState {
            m: snap.opt_m,
            v: snap.opt_v,
        };
        resumed
            .restore(snap.global, snap.carry, snap.rng, opt)
            .unwrap();
        for t in 4..=ROUNDS {
            let rec = resumed.run_round(t).unwrap();
            assert_record_eq(&flat_records[t - 1], &rec);
        }
        assert_eq!(
            resumed
                .global()
                .iter()
                .map(|v| v.to_bits())
                .collect::<Vec<_>>(),
            flat_global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "resumed sharded model diverged from the flat reference (E={resume_edge})"
        );
    }
}

/// Degenerate shard shapes at the driver level: a fleet so small that
/// E exceeds every round's survivor count (single-leaf and empty
/// shards), and a policy keeping exactly one survivor per round.
#[test]
fn oversharded_small_rounds_match_flat() {
    for m in [1usize, 3] {
        let mut flat_cfg = carry_campaign(4, 2, 0);
        flat_cfg.n_clients = 8;
        flat_cfg.data.n_clients = 8;
        flat_cfg.scenario.policy = RoundPolicy::FastestM { m };
        let (flat_records, flat_global, flat_carry) = run_campaign(&flat_cfg, 4);

        let mut sharded_cfg = flat_cfg.clone();
        sharded_cfg.edge_shards = 16;
        let (records, global, carry) = run_campaign(&sharded_cfg, 4);
        for (a, b) in flat_records.iter().zip(&records) {
            assert_record_eq(a, b);
        }
        assert_eq!(
            flat_global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "global bits diverged with E=16 over m={m} survivors"
        );
        assert_carry_eq(&flat_carry, &carry);
    }
}
