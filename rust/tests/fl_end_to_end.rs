//! End-to-end FL integration: full rounds through the real engine.

mod common;

use hcfl::compression::Scheme;
use hcfl::config::ExperimentConfig;
use hcfl::coordinator::Simulation;
use hcfl::data::{DataSpec, Partition};
use hcfl::prelude::*;

fn tiny_cfg(scheme: Scheme) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.scheme = scheme;
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.data = DataSpec {
        classes: 10,
        n_clients: 4,
        per_client: 128,
        test_n: 512,
        server_n: 128,
        partition: Partition::Iid,
        size_skew: 0.0,
        lazy_shards: false,
    };
    // keep the AE phase cheap in CI
    cfg.ae.steps = 30;
    cfg.ae.premodel_epochs = 2;
    cfg.use_ae_cache = false;
    cfg
}

#[test]
fn fedavg_learns_on_tiny_run() {
    let Some(eng) = common::engine(2) else { return };
    let mut cfg = tiny_cfg(Scheme::Fedavg);
    cfg.rounds = 3;
    let mut sim = Simulation::new(&eng, cfg).unwrap();
    let report = sim.run().unwrap();
    assert_eq!(report.rounds.len(), 3);
    // lossless scheme: reconstruction error at f32 round-off only (delta
    // coding subtracts and re-adds the broadcast in f32)
    assert!(report.mean_recon_mse() < 1e-12);
    // the synthetic task is easy: accuracy must clearly beat chance
    assert!(
        report.final_accuracy() > 0.3,
        "accuracy {}",
        report.final_accuracy()
    );
    // losses decrease
    assert!(report.rounds.last().unwrap().loss < report.rounds[0].loss * 1.5);
}

#[test]
fn hcfl_round_runs_and_accounts_traffic() {
    let Some(eng) = common::engine(2) else { return };
    let cfg = tiny_cfg(Scheme::Hcfl { ratio: 8 });
    let m = cfg.m();
    let mut sim = Simulation::new(&eng, cfg).unwrap();
    let report = sim.run().unwrap();
    let rec = &report.rounds[0];
    // reconstruction error is nonzero but finite for a lossy scheme
    assert!(rec.recon_mse > 0.0 && rec.recon_mse.is_finite());
    // uplink is compressed vs the 4*d baseline
    let d = eng.manifest().model("lenet").unwrap().d;
    assert!(rec.up_bytes < (4 * d * m) as u64);
    // downlink is uncompressed by default (paper Fig. 3 deployment)
    assert_eq!(rec.down_bytes, (4 * d * m) as u64);
    assert!(rec.client_time_s > 0.0);
    assert!(rec.server_time_s > 0.0);
    assert!(rec.comm_time_s > 0.0);
    // default scenario: everyone selected is aggregated, nobody is cut
    assert_eq!(rec.selected, m);
    assert_eq!(rec.completed, m);
    assert_eq!(rec.dropped, 0);
    assert_eq!(rec.stragglers, 0);
    // makespan covers the full path: broadcast + compute + upload
    assert!(rec.makespan_s >= rec.comm_time_s);
}

#[test]
fn ternary_and_topk_rounds_run() {
    let Some(eng) = common::engine(2) else { return };
    for scheme in [Scheme::Ternary, Scheme::TopK { keep: 0.15 }] {
        let cfg = tiny_cfg(scheme);
        let mut sim = Simulation::new(&eng, cfg).unwrap();
        let report = sim.run().unwrap();
        assert_eq!(report.rounds.len(), 2);
        assert!(report.rounds[0].up_bytes > 0);
        assert!(report.final_accuracy() > 0.05);
    }
}

#[test]
fn runs_are_reproducible() {
    let Some(eng) = common::engine(2) else { return };
    let r1 = Simulation::new(&eng, tiny_cfg(Scheme::Fedavg))
        .unwrap()
        .run()
        .unwrap();
    let r2 = Simulation::new(&eng, tiny_cfg(Scheme::Fedavg))
        .unwrap()
        .run()
        .unwrap();
    for (a, b) in r1.rounds.iter().zip(&r2.rounds) {
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.up_bytes, b.up_bytes);
        assert_eq!(a.completed, b.completed);
    }
}

#[test]
fn pool_size_never_changes_results_end_to_end() {
    // Engine-backed twin of tests/pool_determinism.rs: real local
    // training through PJRT must also be bit-identical for any
    // client-pool size.
    let Some(eng) = common::engine(2) else { return };
    let run = |client_threads: usize| {
        let mut cfg = tiny_cfg(Scheme::Fedavg);
        cfg.client_threads = client_threads;
        let mut sim = Simulation::new(&eng, cfg).unwrap();
        let report = sim.run().unwrap();
        (sim.global().to_vec(), report)
    };
    let (g1, r1) = run(1);
    for client_threads in [4usize, 16] {
        let (g, r) = run(client_threads);
        assert_eq!(
            g1, g,
            "global model diverged at client_threads={client_threads}"
        );
        for (a, b) in r1.rounds.iter().zip(&r.rounds) {
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.recon_mse, b.recon_mse);
            assert_eq!(a.up_bytes, b.up_bytes);
            assert_eq!(a.completed, b.completed);
        }
    }
}

#[test]
fn noniid_partitions_run_end_to_end() {
    // Dirichlet and LabelShards shards must reach the aggregator through
    // the real engine path.
    let Some(eng) = common::engine(2) else { return };
    for partition in [
        Partition::Dirichlet { alpha: 0.3 },
        Partition::LabelShards {
            shards_per_client: 2,
        },
    ] {
        let mut cfg = tiny_cfg(Scheme::Fedavg);
        cfg.data.partition = partition.clone();
        cfg.scenario.aggregator = AggregatorKind::SampleWeighted;
        let mut sim = Simulation::new(&eng, cfg).unwrap();
        let report = sim.run().unwrap();
        assert_eq!(report.rounds.len(), 2, "{partition:?}");
        assert!(report.rounds[0].completed > 0, "{partition:?}");
        assert!(report.rounds[0].up_bytes > 0, "{partition:?}");
    }
}

#[test]
fn deadline_policy_cuts_stragglers_end_to_end() {
    let Some(eng) = common::engine(2) else { return };
    // Two reference devices + two 1000x stragglers under a tight
    // deadline: the stragglers must be cut every round, and the run must
    // still learn from the surviving updates.
    let mut cfg = tiny_cfg(Scheme::Fedavg);
    cfg.rounds = 2;
    cfg.participation = 1.0; // select the whole fleet so stragglers appear
    cfg.scenario = ScenarioConfig {
        policy: RoundPolicy::Deadline { t_max_s: 1e6 },
        aggregator: AggregatorKind::UniformMean,
        devices: DevicePreset::Stragglers {
            frac: 0.5,
            slowdown: 1000.0,
        },
        ..ScenarioConfig::default()
    };
    // The fleet is sampled from the run seed; pick one whose 4-device
    // fleet is mixed (some but not all stragglers) so the cut is visible.
    let mut sim = (0..20)
        .find_map(|seed| {
            let mut c = cfg.clone();
            c.seed = seed;
            let s = Simulation::new(&eng, c).unwrap();
            (1..=3).contains(&s.fleet().n_slow()).then_some(s)
        })
        .expect("some seed yields a mixed fleet");
    let n_slow = sim.fleet().n_slow();
    // Calibrate the deadline from round 1's makespan under a generous
    // cutoff, then tighten it: anything 1000x slower than the reference
    // client cannot make a deadline sized for the reference arrival.
    let probe = sim.run_round(1).unwrap();
    assert_eq!(probe.stragglers, 0);
    let t_max = probe.makespan_s / 10.0; // far below slowest, above fastest
    sim.cfg.scenario.policy = RoundPolicy::Deadline { t_max_s: t_max };
    let rec = sim.run_round(2).unwrap();
    assert_eq!(rec.selected, 4);
    assert_eq!(rec.stragglers, n_slow, "stragglers must miss the deadline");
    assert_eq!(rec.completed, 4 - n_slow);
    assert_eq!(rec.makespan_s, t_max);
}

#[test]
fn invalid_configs_rejected() {
    let Some(eng) = common::engine(1) else { return };
    let mut cfg = tiny_cfg(Scheme::Fedavg);
    cfg.batch = 77; // not baked
    assert!(Simulation::new(&eng, cfg).is_err());

    let mut cfg = tiny_cfg(Scheme::Fedavg);
    cfg.rounds = 0;
    assert!(Simulation::new(&eng, cfg).is_err());

    let mut cfg = tiny_cfg(Scheme::Fedavg);
    cfg.model = "nope".into();
    assert!(Simulation::new(&eng, cfg).is_err());

    let mut cfg = tiny_cfg(Scheme::Fedavg);
    cfg.scenario.policy = RoundPolicy::Deadline { t_max_s: -1.0 };
    assert!(Simulation::new(&eng, cfg).is_err());
}
