//! Partition-scheme invariants, engine-free (always run in CI):
//! exact row conservation, per-seed determinism, label-skew behavior of
//! `Dirichlet{alpha}`, the `LabelShards` distinct-label guarantee, and
//! lazy/eager shard-source equivalence.

use hcfl::data::{label_entropy, synthetic, DataSpec, Partition, IMG_DIM};

fn spec(partition: Partition, n_clients: usize, per_client: usize, classes: usize) -> DataSpec {
    DataSpec {
        classes,
        n_clients,
        per_client,
        test_n: 16,
        server_n: 8,
        partition,
        size_skew: 0.0,
        lazy_shards: false,
    }
}

fn all_partitions() -> [Partition; 3] {
    [
        Partition::Iid,
        Partition::LabelShards {
            shards_per_client: 3,
        },
        Partition::Dirichlet { alpha: 0.3 },
    ]
}

#[test]
fn every_partition_conserves_rows_exactly() {
    for p in all_partitions() {
        let s = spec(p.clone(), 7, 50, 10);
        let data = synthetic(&s, 11);
        for k in 0..7 {
            let shard = data.shard(k);
            assert_eq!(shard.n, 50, "{p:?}");
            assert_eq!(shard.y.len(), 50, "{p:?}");
            assert_eq!(shard.x.len(), 50 * IMG_DIM, "{p:?}");
            assert!(shard.y.iter().all(|&c| (0..10).contains(&c)), "{p:?}");
        }
    }
}

#[test]
fn every_partition_is_deterministic_per_seed() {
    for p in all_partitions() {
        let s = spec(p.clone(), 4, 40, 10);
        let a = synthetic(&s, 9);
        let b = synthetic(&s, 9);
        let c = synthetic(&s, 10);
        for k in 0..4 {
            assert_eq!(a.shard(k).x, b.shard(k).x, "{p:?}");
            assert_eq!(a.shard(k).y, b.shard(k).y, "{p:?}");
        }
        // a different seed moves at least the pixel streams
        assert_ne!(a.shard(0).x, c.shard(0).x, "{p:?}");
    }
}

#[test]
fn label_shards_gives_exactly_that_many_distinct_labels() {
    for spc in [1usize, 2, 4] {
        let s = spec(
            Partition::LabelShards {
                shards_per_client: spc,
            },
            10,
            60,
            10,
        );
        let data = synthetic(&s, 5);
        for k in 0..10 {
            let shard = data.shard(k);
            let mut labels = shard.y.clone();
            labels.sort_unstable();
            labels.dedup();
            assert_eq!(labels.len(), spc, "client {k} at spc={spc}");
            // near-equal label proportions: counts differ by at most 1
            let counts: Vec<usize> = labels
                .iter()
                .map(|&l| shard.y.iter().filter(|&&c| c == l).count())
                .collect();
            let (min, max) = (
                counts.iter().min().unwrap(),
                counts.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "client {k}: counts {counts:?}");
        }
    }
}

#[test]
fn dirichlet_alpha_controls_label_entropy() {
    let classes = 10;
    let mean_entropy = |partition: Partition| -> f64 {
        let s = spec(partition, 20, 200, classes);
        let data = synthetic(&s, 3);
        let ents: Vec<f64> = (0..20)
            .map(|k| label_entropy(&data.shard(k).y, classes))
            .collect();
        ents.iter().sum::<f64>() / ents.len() as f64
    };
    let concentrated = mean_entropy(Partition::Dirichlet { alpha: 0.05 });
    let spread = mean_entropy(Partition::Dirichlet { alpha: 1000.0 });
    let iid = mean_entropy(Partition::Iid);

    // small alpha concentrates labels: entropy well below the IID level
    assert!(
        concentrated < spread - 0.5,
        "alpha=0.05 entropy {concentrated} not below alpha=1000 entropy {spread}"
    );
    // alpha -> infinity approaches the IID class balance
    assert!(
        (spread - iid).abs() < 0.15,
        "alpha=1000 entropy {spread} vs iid {iid}"
    );
    assert!(
        spread > (classes as f64).ln() - 0.2,
        "alpha=1000 entropy {spread} far from uniform bound"
    );
}

#[test]
fn size_skew_varies_n_k_but_conserves_the_total() {
    for p in all_partitions() {
        let mut s = spec(p.clone(), 12, 80, 10);
        s.size_skew = 0.4;
        let data = synthetic(&s, 13);
        let sizes: Vec<usize> = (0..12).map(|k| data.shard_rows(k)).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 12 * 80, "{p:?}");
        assert!(sizes.iter().any(|&n| n != 80), "{p:?}: no size variation");
        for k in 0..12 {
            let shard = data.shard(k);
            assert_eq!(shard.n, sizes[k], "{p:?}");
            assert_eq!(shard.y.len(), sizes[k], "{p:?}");
        }
    }
}

#[test]
fn lazy_shards_match_eager_for_every_partition() {
    for p in all_partitions() {
        let mut s = spec(p.clone(), 6, 32, 10);
        s.size_skew = 0.25;
        let eager = synthetic(&s, 21);
        s.lazy_shards = true;
        let lazy = synthetic(&s, 21);
        assert!(lazy.is_lazy() && !eager.is_lazy());
        // access out of order: lazy shards must not depend on generation
        // order
        for k in [5usize, 0, 3, 1, 4, 2] {
            assert_eq!(eager.shard(k).x, lazy.shard(k).x, "{p:?} shard {k}");
            assert_eq!(eager.shard(k).y, lazy.shard(k).y, "{p:?} shard {k}");
        }
        assert_eq!(eager.test.x, lazy.test.x, "{p:?}");
        assert_eq!(eager.server.x, lazy.server.x, "{p:?}");
    }
}

#[test]
fn partition_validation_is_enforced() {
    assert!(Partition::LabelShards {
        shards_per_client: 11
    }
    .validate(10)
    .is_err());
    assert!(Partition::Dirichlet { alpha: -1.0 }.validate(10).is_err());
    assert!(Partition::Dirichlet { alpha: 0.5 }.validate(10).is_ok());
}
