//! The worker-pool client stage must be invisible in the results: the
//! same seed + the same scenario produce a bit-identical global model
//! and identical deterministic round-record fields for any
//! `client_threads`.  Runs the full pipeline in fake-train mode on the
//! synthetic manifest, so it needs no PJRT artifacts and always runs in
//! CI (an engine-backed twin lives in `fl_end_to_end.rs`).

use std::sync::Arc;

use hcfl::compression::{Compressor, Identity, Scheme};
use hcfl::coordinator::pool::{
    reduce_tree, ClientMsg, ClientPool, ClientRunner, FakeTrainRunner, RoundInputs,
    WorkSpec, WorkerCtx, WorkerPool,
};
use hcfl::data::{synthetic, DataSpec, FlData, Partition};
use hcfl::error::{HcflError, Result};
use hcfl::fl::{finish_tree, AggregatorKind, WeightedLeaf, TREE_FAN_IN};
use hcfl::util::rng::Rng;
use hcfl::metrics::RoundRecord;
use hcfl::network::DevicePreset;
use hcfl::prelude::*;

/// A lazy fleet the fake runner can read `n_k` from without rendering a
/// single pixel.
fn lazy_fleet(n_clients: usize) -> Arc<FlData> {
    let spec = DataSpec {
        classes: 10,
        n_clients,
        per_client: 600,
        test_n: 16,
        server_n: 8,
        partition: Partition::Iid,
        size_skew: 0.25,
        lazy_shards: true,
    };
    Arc::new(synthetic(&spec, 99))
}

fn fake_cfg(client_threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist(Scheme::TopK { keep: 0.2 }, 3);
    cfg.model = "fake".into();
    cfg.fake_train = true;
    cfg.n_clients = 40;
    cfg.data.n_clients = 40;
    cfg.participation = 0.5;
    cfg.batch = 16;
    cfg.data.per_client = 64;
    cfg.data.test_n = 64;
    cfg.data.server_n = 16;
    // Non-IID shards + unequal shard sizes + a lossy policy + weighted
    // aggregation: the most order-sensitive configuration the pipeline
    // offers.
    cfg.data.partition = Partition::Dirichlet { alpha: 0.3 };
    cfg.data.size_skew = 0.25;
    cfg.client_threads = client_threads;
    cfg.scenario = ScenarioConfig {
        policy: RoundPolicy::FastestM { m: 12 },
        aggregator: AggregatorKind::SampleWeighted,
        devices: DevicePreset::Iot {
            sigma: 0.5,
            dropout_p: 0.1,
        },
        ..ScenarioConfig::default()
    };
    cfg
}

fn run(client_threads: usize) -> (Vec<f32>, Vec<RoundRecord>) {
    let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
    let mut sim = Simulation::new(&engine, fake_cfg(client_threads)).unwrap();
    assert_eq!(sim.client_threads(), client_threads);
    let report = sim.run().unwrap();
    (sim.global().to_vec(), report.rounds)
}

#[test]
fn results_are_bit_identical_across_pool_sizes() {
    let (g1, r1) = run(1);
    for client_threads in [4usize, 16] {
        let (g, r) = run(client_threads);
        assert_eq!(
            g1, g,
            "global model diverged at client_threads={client_threads}"
        );
        assert_eq!(r1.len(), r.len());
        for (a, b) in r1.iter().zip(&r) {
            // deterministic fields only: wall/compute times are measured
            // and legitimately vary between runs
            assert_eq!(a.round, b.round);
            assert_eq!(a.up_bytes, b.up_bytes);
            assert_eq!(a.down_bytes, b.down_bytes);
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.stragglers, b.stragglers);
            assert_eq!(a.recon_mse, b.recon_mse);
        }
    }
}

#[test]
fn pool_reports_every_submitted_item_exactly_once() {
    let fleet = lazy_fleet(200);
    let compressor: Arc<dyn Compressor> = Arc::new(Identity);
    let runner: Arc<dyn ClientRunner> =
        Arc::new(FakeTrainRunner::new(compressor, Arc::clone(&fleet)));
    let pool = ClientPool::new(runner, 7, 3).unwrap();
    let global = Arc::new(vec![0.5f32; 64]);
    let specs: Vec<WorkSpec> = (0..200)
        .map(|slot| WorkSpec {
            slot,
            client: slot,
            seed: 0xAB ^ ((slot as u64) << 1),
            codec: Scheme::Fedavg.codec_tag(), // the Identity entry of the single-codec bank
        })
        .collect();
    let round = RoundInputs {
        global,
        epochs: 1,
        batch: 16,
        lr: 0.05,
        encode_deltas: true,
    };
    let msgs = pool.run_clients(round, &specs).unwrap();
    assert_eq!(msgs.len(), 200);
    let mut slots: Vec<usize> = msgs.iter().map(|m| m.slot).collect();
    slots.sort_unstable();
    assert_eq!(slots, (0..200).collect::<Vec<_>>());
    // n_k flows through from the (skewed) shard sizes
    for msg in &msgs {
        assert_eq!(msg.n_samples, fleet.shard_rows(msg.slot));
    }
    // same seed => same payload, regardless of which thread ran it
    let by_slot = |msgs: &[ClientMsg], slot: usize| -> Vec<f32> {
        msgs.iter().find(|m| m.slot == slot).unwrap().exact.clone()
    };
    let first = by_slot(&msgs, 17);
    let pool2 = ClientPool::new(
        Arc::new(FakeTrainRunner::new(Arc::new(Identity), fleet)) as Arc<dyn ClientRunner>,
        1,
        1,
    )
    .unwrap();
    let round2 = RoundInputs {
        global: Arc::new(vec![0.5f32; 64]),
        epochs: 1,
        batch: 16,
        lr: 0.05,
        encode_deltas: true,
    };
    let msgs2 = pool2.run_clients(round2, &specs).unwrap();
    assert_eq!(first, by_slot(&msgs2, 17));
}

/// The acceptance-criterion twin of the client-stage test: the
/// reduction-tree aggregation fold must be bit-identical for any pool
/// size, because the tree shape and every node's summation order are
/// pure functions of the leaf order.
#[test]
fn reduction_tree_is_bit_identical_across_pool_sizes() {
    let d = 1003; // not a multiple of the fan-in
    let mut rng = Rng::new(4242);
    // deliberately unequal weights (sample-weighted regime)
    let leaves_src: Vec<(f64, Vec<f32>)> = (0..257)
        .map(|i| {
            (
                (50 + (i * 37) % 600) as f64,
                (0..d).map(|_| rng.normal() * 0.3).collect(),
            )
        })
        .collect();
    let fold = |threads: usize| -> Vec<f32> {
        let pool = WorkerPool::new(threads, threads).unwrap();
        let leaves: Vec<WeightedLeaf> = leaves_src
            .iter()
            .map(|(w, x)| WeightedLeaf::new(*w, x.clone()))
            .collect();
        let root = reduce_tree(&pool, leaves, TREE_FAN_IN).unwrap().unwrap();
        finish_tree(root).unwrap()
    };
    let reference = fold(1);
    for threads in [4usize, 16] {
        // exact f32 equality, not approximate: same tree, same bits
        assert_eq!(reference, fold(threads), "client_threads={threads}");
    }
    // empty leaf set folds to nothing, single leaf folds to itself
    let pool = WorkerPool::new(3, 3).unwrap();
    assert!(reduce_tree(&pool, Vec::new(), TREE_FAN_IN).unwrap().is_none());
    let one = reduce_tree(
        &pool,
        vec![WeightedLeaf::new(2.0, vec![4.0f32; 8])],
        TREE_FAN_IN,
    )
    .unwrap()
    .unwrap();
    assert_eq!(finish_tree(one).unwrap(), vec![4.0f32; 8]);
    // degenerate fan-in is a config error
    assert!(reduce_tree(&pool, Vec::new(), 1).is_err());
}

/// A runner that fails on one specific slot: the pool must drain the
/// batch and surface the error.
struct FailOnSlot(usize);

impl ClientRunner for FailOnSlot {
    fn run(
        &self,
        spec: &WorkSpec,
        _round: &RoundInputs,
        ctx: &mut WorkerCtx,
    ) -> Result<ClientMsg> {
        if spec.slot == self.0 {
            return Err(HcflError::Engine("injected client failure".into()));
        }
        let upd = Identity.compress(&[1.0, 2.0], 0)?;
        Ok(ClientMsg {
            slot: spec.slot,
            update: ctx.scratch.pack_update(&upd.payload)?,
            exact: vec![1.0, 2.0],
            n_samples: 1,
            train_s: 0.0,
        })
    }
}

#[test]
fn pool_propagates_client_failures() {
    let pool = ClientPool::new(Arc::new(FailOnSlot(3)), 4, 2).unwrap();
    let specs: Vec<WorkSpec> = (0..10)
        .map(|slot| WorkSpec {
            slot,
            client: slot,
            seed: slot as u64,
            codec: 0,
        })
        .collect();
    let round = RoundInputs {
        global: Arc::new(vec![0.0; 2]),
        epochs: 1,
        batch: 1,
        lr: 0.1,
        encode_deltas: false,
    };
    let err = pool.run_clients(round, &specs).unwrap_err();
    assert!(err.to_string().contains("injected client failure"));
    // the pool survives a failed round: the next batch still works
    let round = RoundInputs {
        global: Arc::new(vec![0.0; 2]),
        epochs: 1,
        batch: 1,
        lr: 0.1,
        encode_deltas: false,
    };
    let ok_specs: Vec<WorkSpec> = (10..20)
        .map(|slot| WorkSpec {
            slot,
            client: slot,
            seed: slot as u64,
            codec: 0,
        })
        .collect();
    assert_eq!(pool.run_clients(round, &ok_specs).unwrap().len(), 10);
}
