//! The very-large-scale acceptance pin: one full K=10 000 fake-train
//! round through the session pipeline — selection, the worker-pool
//! client stage, wire packing, the zero-copy arena decode and the
//! reduction tree — must produce a bit-identical global model and
//! identical deterministic round-record fields for any
//! `client_threads`.  This is the scale the SIMD + zero-copy hot path
//! exists for; `pool_determinism.rs` pins the same property at m=40
//! with stragglers and a deadline, this pins it at the paper's
//! "very large scale IoT" population.
//!
//! Engine-free (fake train on the synthetic manifest), so it always
//! runs in CI — including the `HCFL_FORCE_SCALAR=1` leg, which pins the
//! scalar tier to the same bits the vector tiers produce on the
//! default leg.

use hcfl::compression::Scheme;
use hcfl::data::Partition;
use hcfl::metrics::RoundRecord;
use hcfl::prelude::*;

fn k10_cfg(client_threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist(Scheme::TopK { keep: 0.1 }, 1);
    cfg.model = "fake".into();
    cfg.fake_train = true;
    cfg.n_clients = 10_000;
    cfg.data.n_clients = 10_000;
    cfg.participation = 1.0;
    cfg.batch = 16;
    cfg.data.per_client = 64;
    cfg.data.test_n = 16;
    cfg.data.server_n = 8;
    // a 10k fleet must stay lazy: the fake runner reads shard row
    // counts, never pixels
    cfg.data.lazy_shards = true;
    // order-sensitive configuration on purpose: unequal shards +
    // sample-weighted aggregation would expose any thread-dependent
    // fold or decode order
    cfg.data.partition = Partition::Dirichlet { alpha: 0.3 };
    cfg.data.size_skew = 0.25;
    cfg.client_threads = client_threads;
    cfg.engine_workers = 2;
    cfg.scenario.aggregator = AggregatorKind::SampleWeighted;
    cfg
}

fn run_one_round(client_threads: usize) -> (Vec<f32>, RoundRecord) {
    let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
    let mut sim = Simulation::new(&engine, k10_cfg(client_threads)).unwrap();
    let rec = sim.run_round(1).unwrap();
    assert_eq!(rec.selected, 10_000);
    (sim.global().to_vec(), rec)
}

#[test]
fn k10000_round_is_bit_identical_across_pool_sizes() {
    let (g1, r1) = run_one_round(1);
    assert!(g1.iter().all(|v| v.is_finite()));
    for client_threads in [4usize, 16] {
        let (g, r) = run_one_round(client_threads);
        assert_eq!(
            g1, g,
            "global model diverged at client_threads={client_threads}"
        );
        assert_eq!(r1.up_bytes, r.up_bytes);
        assert_eq!(r1.down_bytes, r.down_bytes);
        assert_eq!(r1.selected, r.selected);
        assert_eq!(r1.completed, r.completed);
        assert_eq!(r1.dropped, r.dropped);
        assert_eq!(r1.stragglers, r.stragglers);
        assert_eq!(r1.recon_mse, r.recon_mse);
    }
}
