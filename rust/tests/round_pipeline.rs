//! Cross-layer tests of the round-execution pipeline that need no PJRT
//! engine: payload coding, downlink accounting, the device → clock →
//! aggregation path, and the pre-refactor regression guarantee.

use std::sync::Arc;

use hcfl::compression::{Compressor, Identity, TopKCompressor};
use hcfl::coordinator::clock::{client_timing, resolve, RoundPolicy};
use hcfl::coordinator::pool::{reduce_tree, WorkerPool};
use hcfl::coordinator::session::{CarryOver, CarryPolicy, FlSession};
use hcfl::fl::{
    finish_tree, AggregatorKind, RunningAverage, Server, UpdateMeta, WeightedLeaf,
    TREE_FAN_IN,
};
use hcfl::network::{DeviceFleet, DevicePreset, LinkModel};
use hcfl::runtime::Manifest;
use hcfl::util::rng::Rng;

fn random_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

// ---- satellite: delta-encoding round-trip ------------------------------

#[test]
fn delta_roundtrip_is_exact_for_identity() {
    let mut rng = Rng::new(101);
    let d = 777;
    let g = random_vec(&mut rng, d, 0.5);
    let w = random_vec(&mut rng, d, 0.5);

    // encode_deltas=true: the wire carries Δ = w − g ...
    let delta = Identity.encode_payload(&w, &g, true);
    let upd = Identity.compress(&delta, 0).unwrap();
    let mut decoded = Identity.decompress(upd, d, 0).unwrap();
    // ... losslessly: Δ̂ == Δ bit for bit ...
    assert_eq!(decoded, delta);
    // ... and the server reconstructs w = g + Δ̂ exactly up to one f32
    // rounding step per weight (the subtract/re-add pair).
    Identity.decode_payload(&mut decoded, &g, true);
    let mse: f64 = decoded
        .iter()
        .zip(&w)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / d as f64;
    assert!(mse < 1e-12, "delta roundtrip mse {mse}");
    // One rounding step each for w−g and g+Δ̂: bounded by ε·(|w|+|g|).
    for ((a, b), gi) in decoded.iter().zip(&w).zip(&g) {
        assert!((a - b).abs() <= f32::EPSILON * (b.abs() + gi.abs()).max(1.0));
    }
}

#[test]
fn raw_payload_roundtrip_is_bitwise_identity() {
    let mut rng = Rng::new(102);
    let d = 256;
    let g = random_vec(&mut rng, d, 0.5);
    let w = random_vec(&mut rng, d, 0.5);

    // encode_deltas=false (Algorithm 1 literally): raw weights travel.
    let payload = Identity.encode_payload(&w, &g, false);
    assert_eq!(payload, w);
    let upd = Identity.compress(&payload, 0).unwrap();
    let mut decoded = Identity.decompress(upd, d, 0).unwrap();
    Identity.decode_payload(&mut decoded, &g, false);
    assert_eq!(decoded, w);
}

// ---- satellite: downlink accounting ------------------------------------

#[test]
fn compress_downlink_toggles_wire_size_but_never_the_broadcast() {
    // The broadcast lives behind the session now: begin_round performs
    // it and exposes the payload + accounted bytes.
    let model = Manifest::synthetic().model("fake").unwrap().clone();
    let open = |compress_downlink: bool| -> (Vec<f32>, usize, Vec<f32>) {
        let server = Server::new(&model, &mut Rng::new(103));
        let g = server.global.flat.clone();
        let mut fl = FlSession::new(
            server,
            Arc::new(TopKCompressor::new(0.1).unwrap()),
            AggregatorKind::UniformMean,
            CarryPolicy::Discard,
            true,
            compress_downlink,
        );
        let round = fl.begin_round(1, CarryOver::empty()).unwrap();
        ((**round.global()).clone(), round.down_bytes(), g)
    };
    let d = model.d;
    let (payload_plain, bytes_plain, g) = open(false);
    let (payload_coded, bytes_coded, g2) = open(true);
    assert_eq!(g, g2, "same seed, same server init");

    // accounting follows the toggle ...
    assert_eq!(bytes_plain, 4 * d);
    assert!(
        bytes_coded < 4 * d,
        "encoded broadcast {bytes_coded} not smaller than {}",
        4 * d
    );
    // ... but the payload clients receive is the exact global either way
    // (paper Fig. 3: the only decoder lives at the server).
    assert_eq!(payload_plain, g);
    assert_eq!(payload_coded, g);
}

// ---- acceptance: pre-refactor regression -------------------------------

#[test]
fn synchronous_uniform_homogeneous_matches_prerefactor_fold() {
    // The pre-refactor coordinator folded decoded updates through
    // RunningAverage while a homogeneous synchronous round delivered all
    // of them.  Two guarantees survive the tree-aggregation rewrite:
    // the streaming Aggregator stays bit-identical to RunningAverage
    // (the sequential reference), and the reduction tree — the fold
    // `run_round` actually executes now — computes the same uniform
    // mean up to f32 summation-order rounding on the identical
    // survivor set (everyone, in selection order — homogeneous
    // arrivals tie).
    let mut rng = Rng::new(104);
    let d = 512;
    let m = 10;
    let updates: Vec<Vec<f32>> = (0..m).map(|_| random_vec(&mut rng, d, 0.3)).collect();

    // device layer: homogeneous fleet
    let fleet = DeviceFleet::sample(m, &DevicePreset::Homogeneous, 42);
    let link = LinkModel::default();
    let timings: Vec<_> = (0..m)
        .map(|slot| {
            client_timing(
                &link,
                fleet.profile(slot),
                slot,
                slot,
                4 * d,
                4 * d,
                0.25,
                m,
                m,
                false,
            )
        })
        .collect();

    // clock layer: synchronous round keeps everyone, selection order
    let outcome = resolve(&RoundPolicy::Synchronous, &timings);
    assert_eq!(outcome.survivors, (0..m).collect::<Vec<_>>());
    assert_eq!(outcome.dropped, 0);
    assert_eq!(outcome.stragglers, 0);
    // homogeneous: makespan is every client's (equal) arrival
    assert!((outcome.makespan_s - timings[0].arrival_s()).abs() < 1e-15);

    // aggregation layer vs the pre-refactor server fold
    let mut pre = RunningAverage::new(d);
    let mut agg = AggregatorKind::UniformMean.build(d);
    for &i in &outcome.survivors {
        pre.push(&updates[i]).unwrap();
        agg.push(
            &updates[i],
            &UpdateMeta {
                client: i,
                n_samples: 128,
                arrival_s: timings[i].arrival_s(),
            },
        )
        .unwrap();
    }
    let reference = pre.finish().unwrap();
    assert_eq!(reference, agg.finish().unwrap());

    // The reduction tree run_round executes now: same survivors in the
    // same order, uniform unit weights, result equal to the streaming
    // mean up to the f32 rounding of the re-associated summation.
    let pool = WorkerPool::new(3, 3).unwrap();
    let leaves: Vec<WeightedLeaf> = outcome
        .survivors
        .iter()
        .map(|&i| WeightedLeaf::new(1.0, updates[i].clone()))
        .collect();
    let root = reduce_tree(&pool, leaves, TREE_FAN_IN).unwrap().unwrap();
    let tree = finish_tree(root).unwrap();
    for (j, (a, b)) in reference.iter().zip(&tree).enumerate() {
        assert!((a - b).abs() < 1e-5, "dim {j}: streaming {a} vs tree {b}");
    }
}

// ---- device -> clock -> policy integration -----------------------------

#[test]
fn straggler_fleet_is_cut_by_deadline_and_fastest_m() {
    let mut rng = Rng::new(105);
    let n = 40;
    let preset = DevicePreset::Stragglers {
        frac: 0.25,
        slowdown: 16.0,
    };
    let fleet = DeviceFleet::sample(n, &preset, 7);
    let n_slow = fleet.n_slow();
    assert!(n_slow > 0 && n_slow < n, "seed must give a mixed fleet");

    let link = LinkModel::default();
    let d = 4096;
    let timings: Vec<_> = (0..n)
        .map(|slot| {
            // exact per-client bytes: vary them to prove no mean-flooring
            let up = 4 * d + (rng.below(64) as usize);
            client_timing(&link, fleet.profile(slot), slot, slot, up, 4 * d, 0.5, n, n, false)
        })
        .collect();

    // a 16x straggler can never arrive within 2x the reference arrival
    let reference_arrival = timings
        .iter()
        .enumerate()
        .filter(|(i, _)| fleet.profile(*i).compute_mult == 1.0)
        .map(|(_, t)| t.arrival_s())
        .fold(0.0, f64::max);
    let deadline = RoundPolicy::Deadline {
        t_max_s: reference_arrival * 2.0,
    };
    let out = resolve(&deadline, &timings);
    assert_eq!(out.stragglers, n_slow);
    assert_eq!(out.survivors.len(), n - n_slow);
    assert_eq!(out.makespan_s, reference_arrival * 2.0);
    // every survivor is a reference device
    for &i in &out.survivors {
        assert_eq!(fleet.profile(timings[i].client).compute_mult, 1.0);
    }
    // the cut identities survive resolution, and they are exactly the
    // slow devices (in arrival order)
    assert_eq!(out.late.len(), n_slow);
    for &i in &out.late {
        assert!(fleet.profile(timings[i].client).compute_mult > 1.0);
    }
    for w in out.late.windows(2) {
        assert!(timings[w[0]].arrival_s() <= timings[w[1]].arrival_s());
    }

    // fastest-m with m = fast population: same survivor set
    let fastest = resolve(&RoundPolicy::FastestM { m: n - n_slow }, &timings);
    let mut a = out.survivors.clone();
    let mut b = fastest.survivors.clone();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b);
    // fastest-m ends when its last survivor arrives, before the deadline
    assert!(fastest.makespan_s <= out.makespan_s);
}

#[test]
fn uplink_time_scales_with_exact_bytes() {
    // The pre-refactor coordinator floored the *mean* upload size before
    // computing air time; the clock layer must use each client's exact
    // byte count instead.
    let link = LinkModel {
        uplink_bps: 8e6,
        downlink_bps: 8e6,
    };
    let fleet = DeviceFleet::sample(2, &DevicePreset::Homogeneous, 1);
    let a = client_timing(&link, fleet.profile(0), 0, 0, 1_000_000, 0, 0.0, 2, 2, false);
    let b = client_timing(&link, fleet.profile(1), 1, 1, 1_000_001, 0, 0.0, 2, 2, false);
    // 1 byte more at 4 Mbit/s per-client share = 2 microseconds more
    assert!(b.uplink_s > a.uplink_s);
    assert!((b.uplink_s - a.uplink_s - 2e-6).abs() < 1e-12);
}

#[test]
fn dropouts_shrink_the_survivor_set_not_the_round() {
    let fleet = DeviceFleet::sample(
        8,
        &DevicePreset::Iot {
            sigma: 0.0,
            dropout_p: 0.5,
        },
        3,
    );
    let link = LinkModel::default();
    let timings: Vec<_> = (0..8)
        .map(|slot| {
            client_timing(
                &link,
                fleet.profile(slot),
                slot,
                slot,
                1024,
                1024,
                0.1,
                8,
                5,
                slot >= 5, // three devices vanished this round
            )
        })
        .collect();
    let out = resolve(&RoundPolicy::Synchronous, &timings);
    assert_eq!(out.dropped, 3);
    assert_eq!(out.stragglers, 0);
    assert_eq!(out.survivors.len(), 5);
    assert!(out.survivors.iter().all(|&i| i < 5));
}
