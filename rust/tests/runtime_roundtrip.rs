//! Integration: HLO artifacts load, compile and execute through the PJRT
//! engine, and the numbers agree with rust-side reference math.

mod common;

use hcfl::prelude::*;
use hcfl::util::rng::Rng;

#[test]
fn ternary_matches_reference() {
    let Some(eng) = common::engine(1) else { return };
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..256).map(|_| rng.normal() * 0.1).collect();

    let out = eng
        .call("ternary_c256", vec![TensorValue::vec_f32(w.clone())])
        .unwrap();
    assert_eq!(out.len(), 2);
    let q = out[0].as_f32().unwrap();
    let alpha = out[1].scalar().unwrap();

    // Reference TWN math.
    let mean_abs: f32 = w.iter().map(|x| x.abs()).sum::<f32>() / w.len() as f32;
    let delta = 0.7 * mean_abs;
    let above: Vec<f32> = w.iter().filter(|x| x.abs() > delta).map(|x| x.abs()).collect();
    let alpha_ref = above.iter().sum::<f32>() / above.len().max(1) as f32;

    assert!((alpha - alpha_ref).abs() < 1e-5, "alpha {alpha} vs {alpha_ref}");
    for (qi, wi) in q.iter().zip(&w) {
        let expect = if wi.abs() > delta { wi.signum() } else { 0.0 };
        assert_eq!(*qi, expect, "w={wi}");
    }
}

#[test]
fn ae_encode_decode_shapes_and_bounds() {
    let Some(eng) = common::engine(1) else { return };
    let ae = eng.manifest().autoencoder(256, 8).unwrap().clone();
    let mut rng = Rng::new(2);
    // Untrained AE params: random small weights.
    let params: Vec<f32> = (0..ae.d).map(|_| rng.normal() * 0.05).collect();
    let w: Vec<f32> = (0..256).map(|_| rng.normal() * 0.1).collect();

    let out = eng
        .call(
            &ae.encode,
            vec![
                TensorValue::vec_f32(params.clone()),
                TensorValue::vec_f32(w.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 5); // code, lo, hi, mu, sd
    assert_eq!(out[0].shape(), &[32]); // 256 / 8
    let lo = out[1].scalar().unwrap();
    let hi = out[2].scalar().unwrap();
    let mu = out[3].scalar().unwrap();
    let sd = out[4].scalar().unwrap();
    let w_min = w.iter().cloned().fold(f32::INFINITY, f32::min);
    let w_max = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    assert!((lo - w_min).abs() < 1e-6);
    assert!((hi - w_max).abs() < 1e-6);
    assert!(sd > 0.0 && mu.abs() <= 1.0);
    // code is tanh-bounded
    for c in out[0].as_f32().unwrap() {
        assert!(c.abs() <= 1.0 + 1e-6);
    }

    let code = out[0].clone();
    let dec = eng
        .call(
            &ae.decode,
            vec![
                TensorValue::vec_f32(params),
                code,
                TensorValue::scalar_f32(lo),
                TensorValue::scalar_f32(hi),
                TensorValue::scalar_f32(mu),
                TensorValue::scalar_f32(sd),
            ],
        )
        .unwrap();
    assert_eq!(dec.len(), 1);
    assert_eq!(dec[0].shape(), &[256]);
    // Variance-preserving decode: reconstruction moments match the
    // transmitted side info in scaled space, i.e. the output is finite
    // and roughly centered inside the chunk's range.
    let vals = dec[0].as_f32().unwrap();
    assert!(vals.iter().all(|v| v.is_finite()));
    let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
    assert!(mean >= lo - (hi - lo) && mean <= hi + (hi - lo));
}

#[test]
fn spec_mismatch_is_rejected() {
    let Some(eng) = common::engine(1) else { return };
    // wrong shape
    let err = eng
        .call("ternary_c256", vec![TensorValue::vec_f32(vec![0.0; 5])])
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("spec mismatch"), "{msg}");
    // wrong arity
    assert!(eng.call("ternary_c256", vec![]).is_err());
    // unknown executable
    assert!(eng.call("nope", vec![]).is_err());
}

#[test]
fn multi_worker_round_robin() {
    let Some(eng) = common::engine(2) else { return };
    let w: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) / 128.0).collect();
    let a = eng
        .call("ternary_c256", vec![TensorValue::vec_f32(w.clone())])
        .unwrap();
    let b = eng
        .call("ternary_c256", vec![TensorValue::vec_f32(w)])
        .unwrap();
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    assert_eq!(a[1].scalar().unwrap(), b[1].scalar().unwrap());
}

#[test]
fn parallel_callers_share_engine() {
    let Some(eng) = common::engine(2) else { return };
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let eng = eng.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                let w: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
                let out = eng
                    .call("ternary_c256", vec![TensorValue::vec_f32(w)])
                    .unwrap();
                out[1].scalar().unwrap()
            })
        })
        .collect();
    for h in handles {
        assert!(h.join().unwrap() >= 0.0);
    }
}
