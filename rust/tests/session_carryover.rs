//! Engine-free tests of the event-driven `RoundSession` lifecycle and
//! its cross-round straggler carry-over (`coordinator/session.rs`):
//!
//! * carry-over results are bit-identical for any pool size — both
//!   driven directly through the session API with synthetic timings and
//!   through the full fake-train `Simulation`;
//! * `CarryDiscounted { max_age_rounds }` expires updates exactly;
//! * carry off reproduces the pre-refactor `run_round` output on a
//!   homogeneous synchronous fleet (regression pin: the old staged
//!   pipeline is reimplemented here from primitives and compared bit
//!   for bit);
//! * carried leaves enter the next round's tree first, in arrival
//!   order, with `base_weight * exp(-lambda * age)` weights.

use std::sync::Arc;

use hcfl::compression::{Compressor, Identity, Scheme, TopKCompressor, WireScratch};
use hcfl::config::{ExperimentConfig, ScenarioConfig};
use hcfl::coordinator::clock::{ClientTiming, RoundPolicy};
use hcfl::coordinator::pool::{
    reduce_tree, ClientMsg, ClientPool, ClientRunner, FakeTrainRunner, RoundInputs,
    WorkSpec, WorkerPool,
};
use hcfl::coordinator::session::{CarryOver, CarryPolicy, ClientUpdate, FlSession};
use hcfl::coordinator::{round_seed, Simulation};
use hcfl::data::synthetic;
use hcfl::fl::{
    finish_tree, select_clients, AggregatorKind, Server, WeightedLeaf, TREE_FAN_IN,
};
use hcfl::metrics::RoundRecord;
use hcfl::network::{DeviceFleet, DevicePreset, LinkModel};
use hcfl::runtime::{Engine, Manifest};
use hcfl::util::rng::Rng;

const D: usize = 802; // the synthetic manifest's "fake" model

fn mk_session(carry: CarryPolicy) -> FlSession {
    let model = Manifest::synthetic().model("fake").unwrap().clone();
    let server = Server::new(&model, &mut Rng::new(11));
    FlSession::new(
        server,
        Arc::new(Identity),
        AggregatorKind::UniformMean,
        carry,
        true,
        false,
    )
}

/// A synthetic arrival: seeded fake-trained params delta-encoded against
/// the broadcast, landing at exactly `arrival_s` on the round clock.
fn mk_update(client: usize, slot: usize, arrival_s: f64, global: &[f32], seed: u64) -> ClientUpdate {
    let mut rng = Rng::new(seed);
    let params: Vec<f32> = global.iter().map(|g| g + 0.1 * rng.normal()).collect();
    let delta = Identity.encode_payload(&params, global, true);
    let upd = Identity.compress(&delta, 0).unwrap();
    let payload = WireScratch::new().pack_update(&upd.payload).unwrap();
    ClientUpdate {
        payload,
        n_samples: 50 + client,
        timing: ClientTiming {
            client,
            order: slot,
            downlink_s: 0.0,
            compute_s: arrival_s,
            uplink_s: 0.0,
            dropped: false,
        },
        exact: params,
        extra_up_bytes: 0,
        train_s: 0.01,
        codec: Scheme::Fedavg.codec_tag(), // the session's Identity bank entry
    }
}

/// Drive `rounds` deadline rounds straight through the session API: 7
/// fast clients plus 3 stragglers whose uploads land after `t_max` and
/// carry into the next round.  Timings are synthetic, so everything —
/// survivor sets, carried counts, the folded bits — is deterministic.
fn run_session(
    threads: usize,
    carry: CarryPolicy,
    rounds: usize,
    t_max: f64,
) -> (Vec<f32>, Vec<RoundRecord>) {
    let mut fl = mk_session(carry);
    let pool = WorkerPool::new(threads, threads).unwrap();
    let mut carryover = CarryOver::empty();
    let mut recs = Vec::new();
    for t in 1..=rounds {
        let mut round = fl.begin_round(t, carryover).unwrap();
        let g = Arc::clone(round.global());
        for slot in 0..10usize {
            let arrival = if slot < 7 {
                0.2 + 0.01 * slot as f64
            } else {
                t_max + 0.5 + 0.3 * (slot - 7) as f64
            };
            let seed = 0xC0FFEE ^ ((t as u64) << 8) ^ slot as u64;
            round.submit(mk_update(100 + slot, slot, arrival, &g, seed));
        }
        let resolved = round.resolve(&RoundPolicy::Deadline { t_max_s: t_max });
        assert_eq!(resolved.outcome().late.len(), 3);
        assert_eq!(resolved.late_clients(), vec![107, 108, 109]);
        let (rec, co) = resolved.finalize(&pool).unwrap();
        carryover = co;
        recs.push(rec);
    }
    (fl.global().to_vec(), recs)
}

#[test]
fn session_carry_is_bit_identical_across_pool_sizes() {
    let carry = CarryPolicy::CarryDiscounted {
        lambda: 0.5,
        max_age_rounds: 2,
    };
    let (g1, r1) = run_session(1, carry.clone(), 3, 2.0);
    // round 1 generates the carry, rounds 2 and 3 fold it
    assert_eq!(r1[0].carried_in, 0);
    assert_eq!(r1[0].carried_out, 3);
    assert_eq!(r1[1].carried_in, 3);
    assert_eq!(r1[1].carried_out, 3);
    assert_eq!(r1[2].carried_in, 3);
    for threads in [4usize, 16] {
        let (g, r) = run_session(threads, carry.clone(), 3, 2.0);
        assert_eq!(g1, g, "global diverged at {threads} pool threads");
        for (a, b) in r1.iter().zip(&r) {
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.carried_in, b.carried_in);
            assert_eq!(a.carried_out, b.carried_out);
            assert_eq!(a.up_bytes, b.up_bytes);
            assert_eq!(a.recon_mse, b.recon_mse);
            assert_eq!(a.makespan_s, b.makespan_s);
        }
    }
    // carrying actually changes the model relative to discarding
    let (g_off, r_off) = run_session(1, CarryPolicy::Discard, 3, 2.0);
    assert_ne!(g1, g_off);
    assert!(r_off.iter().all(|r| r.carried_in == 0 && r.carried_out == 0));
}

#[test]
fn carried_leaves_fold_first_with_discounted_weights() {
    // Replay the session's aggregation by hand: round 1 folds the 7
    // fast arrivals; round 2 folds the 3 carried leaves FIRST (arrival
    // order, weight exp(-lambda * 1), decoded against round 1's
    // broadcast) and then the 7 fresh survivors at weight 1.
    let lambda = 0.5;
    let carry = CarryPolicy::CarryDiscounted {
        lambda,
        max_age_rounds: 2,
    };
    let t_max = 2.0;
    let (g2, _) = run_session(1, carry, 2, t_max);

    let pool = WorkerPool::new(3, 3).unwrap();
    let g0 = {
        let model = Manifest::synthetic().model("fake").unwrap().clone();
        Server::new(&model, &mut Rng::new(11)).global.flat
    };
    let decode = |slot: usize, t: u64, global: &[f32]| -> Vec<f32> {
        let seed = 0xC0FFEE ^ (t << 8) ^ slot as u64;
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = global.iter().map(|g| g + 0.1 * rng.normal()).collect();
        let mut dec = Identity.encode_payload(&params, global, true);
        Identity.decode_payload(&mut dec, global, true);
        dec
    };
    // round 1: uniform mean of the 7 fast arrivals
    let leaves: Vec<WeightedLeaf> = (0..7)
        .map(|slot| WeightedLeaf::new(1.0, decode(slot, 1, &g0)))
        .collect();
    let g1 = finish_tree(reduce_tree(&pool, leaves, TREE_FAN_IN).unwrap().unwrap()).unwrap();
    // round 2: carried leaves (slots 7..10 of round 1, decoded against
    // g0) first, then the fresh survivors (decoded against g1)
    let w_carried = (-lambda * 1.0).exp(); // base_weight 1.0, age 1
    let mut leaves: Vec<WeightedLeaf> = (7..10)
        .map(|slot| WeightedLeaf::new(w_carried, decode(slot, 1, &g0)))
        .collect();
    leaves.extend((0..7).map(|slot| WeightedLeaf::new(1.0, decode(slot, 2, &g1))));
    let expected =
        finish_tree(reduce_tree(&pool, leaves, TREE_FAN_IN).unwrap().unwrap()).unwrap();
    assert_eq!(expected, g2, "carry weight rule or leaf order drifted");
}

#[test]
fn max_age_expires_updates_exactly() {
    // One upload late by several deadlines: its rebased arrival loses
    // one makespan (= t_max, the round waits it out) per round, so it
    // can only fold in round 4 at age 3.  max_age_rounds = 3 folds it
    // there; max_age_rounds = 2 expires it at begin_round(4).
    let t_max = 1.0;
    let run = |max_age: usize| -> Vec<RoundRecord> {
        let mut fl = mk_session(CarryPolicy::CarryDiscounted {
            lambda: 0.1,
            max_age_rounds: max_age,
        });
        let pool = WorkerPool::new(2, 2).unwrap();
        let mut carryover = CarryOver::empty();
        let mut recs = Vec::new();
        for t in 1..=4usize {
            let mut round = fl.begin_round(t, carryover).unwrap();
            let g = Arc::clone(round.global());
            round.submit(mk_update(0, 0, 0.1, &g, 7 ^ (t as u64) << 3));
            if t == 1 {
                // arrives 3.2 deadlines after its own broadcast:
                // rebased 2.2 -> 1.2 -> 0.2, foldable in round 4
                round.submit(mk_update(1, 1, 3.2 * t_max, &g, 99));
            }
            let resolved = round.resolve(&RoundPolicy::Deadline { t_max_s: t_max });
            let (rec, co) = resolved.finalize(&pool).unwrap();
            // an in-flight carried upload keeps the deadline round open
            // the full t_max
            if rec.carried_out > 0 {
                assert_eq!(rec.makespan_s, t_max);
            }
            carryover = co;
            recs.push(rec);
        }
        recs
    };

    let kept = run(3);
    assert_eq!(
        kept.iter().map(|r| r.carried_out).collect::<Vec<_>>(),
        vec![1, 1, 1, 0]
    );
    assert_eq!(
        kept.iter().map(|r| r.carried_in).collect::<Vec<_>>(),
        vec![0, 0, 0, 1],
        "a 3-round-late upload must fold exactly in round 4"
    );
    assert!(kept.iter().all(|r| r.carried_expired == 0));

    let expired = run(2);
    assert_eq!(
        expired.iter().map(|r| r.carried_out).collect::<Vec<_>>(),
        vec![1, 1, 1, 0]
    );
    assert_eq!(
        expired.iter().map(|r| r.carried_in).collect::<Vec<_>>(),
        vec![0, 0, 0, 0],
        "age 3 > max_age_rounds 2 must expire unfolded"
    );
    assert_eq!(
        expired.iter().map(|r| r.carried_expired).collect::<Vec<_>>(),
        vec![0, 0, 0, 1],
        "the expiry must land exactly on entry to round 4"
    );
}

fn fake_cfg(scheme: Scheme, rounds: usize, client_threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist(scheme, rounds);
    cfg.model = "fake".into();
    cfg.fake_train = true;
    cfg.n_clients = 24;
    cfg.data.n_clients = 24;
    cfg.participation = 1.0;
    cfg.batch = 16;
    cfg.data.per_client = 64;
    cfg.data.test_n = 64;
    cfg.data.server_n = 16;
    cfg.client_threads = client_threads;
    cfg
}

/// The acceptance criterion end to end: a fake-train `Simulation` under
/// a deadline with 8x stragglers and carry on is bit-identical for any
/// `client_threads`.  The deadline and the fold boundary are placed
/// hundreds of milliseconds from any modelled arrival, so measured
/// compute noise (microseconds) cannot flip a survivor set.
#[test]
fn simulation_carry_is_bit_identical_across_pool_sizes() {
    let preset = DevicePreset::Stragglers {
        frac: 0.25,
        slowdown: 8.0,
    };
    // a seed whose 24-device fleet is mixed
    let seed = (42..64)
        .find(|&s| {
            let n = DeviceFleet::sample(24, &preset, s).n_slow();
            (2..=8).contains(&n)
        })
        .expect("some seed yields a mixed fleet");
    let n_slow = DeviceFleet::sample(24, &preset, seed).n_slow();

    // FedAvg wire size is content-independent: every upload is 4*d
    // bytes, so the modelled air times below are exact.
    let link = LinkModel::default();
    let up = link.uplink_time(4 * D, 24);
    let down = link.downlink_time(4 * D, 24);
    // fast arrival ~ down + up + eps; slow ~ down + 8*up + 8*eps: the
    // deadline sits ~4 uplink-times above fast, ~3 below slow, and the
    // carried rebased arrival (slow - t_max) refolds with ~150 ms margin.
    let t_max = down + 5.0 * up;

    let run = |threads: usize| -> (Vec<f32>, Vec<RoundRecord>) {
        let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
        let mut cfg = fake_cfg(Scheme::Fedavg, 4, threads);
        cfg.seed = seed;
        cfg.scenario = ScenarioConfig {
            policy: RoundPolicy::Deadline { t_max_s: t_max },
            devices: preset.clone(),
            carry: CarryPolicy::CarryDiscounted {
                lambda: 0.5,
                max_age_rounds: 2,
            },
            ..ScenarioConfig::default()
        };
        let mut sim = Simulation::new(&engine, cfg).unwrap();
        let report = sim.run().unwrap();
        (sim.global().to_vec(), report.rounds)
    };

    let (g1, r1) = run(1);
    // stragglers are cut every round and fold one round later
    assert_eq!(r1[0].stragglers, n_slow);
    assert_eq!(r1[0].carried_in, 0);
    assert_eq!(r1[0].carried_out, n_slow);
    for r in &r1[1..] {
        assert_eq!(r.stragglers, n_slow);
        assert_eq!(r.carried_in, n_slow, "round {}", r.round);
        assert_eq!(r.carried_out, n_slow);
        assert_eq!(r.completed, 24 - n_slow);
    }
    for threads in [4usize, 16] {
        let (g, r) = run(threads);
        assert_eq!(g1, g, "global diverged at client_threads={threads}");
        for (a, b) in r1.iter().zip(&r) {
            assert_eq!(a.up_bytes, b.up_bytes);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.carried_in, b.carried_in);
            assert_eq!(a.carried_out, b.carried_out);
            assert_eq!(a.recon_mse, b.recon_mse);
            assert_eq!(a.makespan_s, b.makespan_s);
        }
    }
}

/// Regression pin: with carry off, the session-driven `run_round` must
/// reproduce the pre-refactor staged pipeline bit for bit on a
/// homogeneous synchronous fleet.  The old pipeline — select, fake
/// train on the pool, uniform-weight leaves in selection order, the
/// fixed-fan-in tree — is reimplemented here from primitives.
#[test]
fn carry_off_matches_prerefactor_round_output() {
    let engine = Engine::with_manifest(Manifest::synthetic(), 2).unwrap();
    let cfg = fake_cfg(Scheme::TopK { keep: 0.2 }, 3, 3);
    let mut sim = Simulation::new(&engine, cfg.clone()).unwrap();
    let report = sim.run().unwrap();
    for r in &report.rounds {
        assert_eq!(r.completed, r.selected);
        assert_eq!(r.stragglers, 0);
        assert_eq!(r.carried_in, 0);
        assert_eq!(r.carried_out, 0);
    }

    // The pre-refactor reference, from primitives.
    let mut data_spec = cfg.data.clone();
    data_spec.n_clients = cfg.n_clients;
    let data = Arc::new(synthetic(&data_spec, cfg.seed));
    let model = engine.manifest().model("fake").unwrap().clone();
    let mut rng = Rng::new(cfg.seed);
    let server = Server::new(&model, &mut rng); // same init stream
    let mut global = server.global.flat.clone();
    let compressor: Arc<dyn Compressor> = Arc::new(TopKCompressor::new(0.2).unwrap());
    let runner: Arc<dyn ClientRunner> = Arc::new(FakeTrainRunner::new(
        Arc::clone(&compressor),
        Arc::clone(&data),
    ));
    let pool = ClientPool::new(runner, 5, 2).unwrap();
    for t in 1..=cfg.rounds {
        let selected = select_clients(cfg.n_clients, cfg.participation, &mut rng);
        let seed = round_seed(cfg.seed, t);
        let specs: Vec<WorkSpec> = selected
            .iter()
            .enumerate()
            .map(|(slot, &k)| WorkSpec {
                slot,
                client: k,
                seed: seed ^ ((k as u64) << 1),
                codec: cfg.scheme.codec_tag(),
            })
            .collect();
        let inputs = RoundInputs {
            global: Arc::new(global.clone()),
            epochs: cfg.local_epochs,
            batch: cfg.batch,
            lr: cfg.lr,
            encode_deltas: cfg.encode_deltas,
        };
        let mut msgs: Vec<Option<ClientMsg>> = Vec::new();
        msgs.resize_with(selected.len(), || None);
        for msg in pool.run_clients(inputs, &specs).unwrap() {
            let slot = msg.slot;
            msgs[slot] = Some(msg);
        }
        // homogeneous synchronous round: everyone survives, equal
        // arrivals tie on the selection slot — selection order.  The
        // reference decodes straight off the wire bytes through
        // `unpack_into`, pinning the zero-copy decode path against the
        // session output bit for bit.
        let mut scratch = WireScratch::new();
        let mut leaves = Vec::with_capacity(selected.len());
        for slot_msg in &mut msgs {
            let msg = slot_msg.take().unwrap();
            let mut dec = Vec::new();
            compressor
                .unpack_into(&msg.update.bytes, model.d, 0, &mut scratch, &mut dec)
                .unwrap();
            compressor.decode_payload(&mut dec, &global, cfg.encode_deltas);
            leaves.push(WeightedLeaf::new(1.0, dec));
        }
        let root = reduce_tree(pool.workers(), leaves, TREE_FAN_IN)
            .unwrap()
            .unwrap();
        global = finish_tree(root).unwrap();
    }
    assert_eq!(
        global,
        sim.global(),
        "carry-off session output drifted from the pre-refactor pipeline"
    );

    // and carry ON is a no-op when nothing is ever late
    let mut cfg_on = cfg;
    cfg_on.scenario.carry = CarryPolicy::CarryDiscounted {
        lambda: 0.5,
        max_age_rounds: 2,
    };
    let mut sim_on = Simulation::new(&engine, cfg_on).unwrap();
    sim_on.run().unwrap();
    assert_eq!(sim.global(), sim_on.global());
    assert_eq!(sim_on.carry_pending(), 0);
}
