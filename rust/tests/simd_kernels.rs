//! Property tests of the SIMD kernel layer (`compression/simd`): for
//! every kernel, the runtime-dispatched path must be byte-/bit-identical
//! to the portable scalar reference on randomized inputs covering every
//! remainder tail 1..=63 plus larger vector-dominated lengths.
//!
//! On a scalar-only host (or under `HCFL_FORCE_SCALAR=1`) the dispatched
//! path *is* the scalar path and the tests degenerate to self-identity —
//! still worth running, since CI's forced-scalar leg uses exactly that
//! to pin the reference tier.

use hcfl::compression::simd;
use hcfl::util::rng::Rng;

/// Every tail 1..=63 (covers all SSE2 16-lane and AVX2 32-lane remainder
/// classes), 0, plus lengths where the vector body dominates.
fn probe_lengths(rng: &mut Rng) -> Vec<usize> {
    let mut lens: Vec<usize> = (0..=63).collect();
    lens.extend([64, 100, 127, 128, 255, 256, 1000, 1024, 4096 + 17]);
    for _ in 0..8 {
        lens.push(1 + rng.below(20_000));
    }
    lens
}

fn random_symbols(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| [0i8, 1, -1][rng.below(3)]).collect()
}

fn random_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn pack_2bit_matches_scalar_on_all_tails() {
    let mut rng = Rng::new(0x51);
    for n in probe_lengths(&mut rng) {
        let q = random_symbols(&mut rng, n);
        let mut fast = vec![0xAAu8; 3]; // non-empty: both paths append
        let mut refr = vec![0xAAu8; 3];
        simd::pack_2bit(&q, &mut fast).unwrap();
        simd::scalar::pack_2bit(&q, &mut refr).unwrap();
        assert_eq!(fast, refr, "pack_2bit diverged at n={n} ({})", simd::level().label());
    }
}

#[test]
fn unpack_2bit_f32_matches_scalar_on_all_tails() {
    let mut rng = Rng::new(0x52);
    for n in probe_lengths(&mut rng) {
        let q = random_symbols(&mut rng, n);
        let mut packed = Vec::new();
        simd::scalar::pack_2bit(&q, &mut packed).unwrap();
        let alpha = 0.25 + rng.normal().abs();
        let mut fast = vec![0.0f32; n];
        let mut refr = vec![0.0f32; n];
        simd::unpack_2bit_f32(&packed, n, alpha, &mut fast).unwrap();
        simd::scalar::unpack_2bit_f32(&packed, n, alpha, &mut refr).unwrap();
        assert_eq!(bits(&fast), bits(&refr), "unpack_2bit_f32 diverged at n={n}");
    }
}

#[test]
fn f32_le_moves_match_scalar_on_all_tails() {
    let mut rng = Rng::new(0x53);
    for n in probe_lengths(&mut rng) {
        let v = random_f32(&mut rng, n, 3.0);
        let mut fast = Vec::new();
        let mut refr = Vec::new();
        simd::pack_f32_le(&v, &mut fast);
        simd::scalar::pack_f32_le(&v, &mut refr);
        assert_eq!(fast, refr, "pack_f32_le diverged at n={n}");
        let mut back_fast = vec![0.0f32; n];
        let mut back_ref = vec![0.0f32; n];
        simd::unpack_f32_le(&fast, &mut back_fast);
        simd::scalar::unpack_f32_le(&refr, &mut back_ref);
        assert_eq!(bits(&back_fast), bits(&back_ref), "unpack_f32_le diverged at n={n}");
        assert_eq!(bits(&back_fast), bits(&v));
    }
}

/// Canonical LEB128 encoder (what `wire::push_varint` emits), used to
/// build inputs the hardened decoder must accept.
fn push_varint(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

#[test]
fn decode_varints_matches_scalar_on_mixed_widths() {
    let mut rng = Rng::new(0x54);
    for n in probe_lengths(&mut rng) {
        // mix of single-byte values (the batched fast path) and wide
        // values that break each 8-run differently
        let vals: Vec<u32> = (0..n)
            .map(|i| {
                if rng.below(4) == 0 {
                    rng.below(u32::MAX as usize) as u32
                } else {
                    (i % 128) as u32
                }
            })
            .collect();
        let mut bytes = vec![0x7Fu8; 3]; // leading garbage skipped via pos
        for &v in &vals {
            push_varint(v, &mut bytes);
        }
        let mut fast = vec![0u32; n];
        let mut refr = vec![0u32; n];
        let mut pos_fast = 3usize;
        let mut pos_ref = 3usize;
        simd::decode_varints(&bytes, &mut pos_fast, &mut fast).unwrap();
        simd::scalar::decode_varints(&bytes, &mut pos_ref, &mut refr).unwrap();
        assert_eq!(fast, refr, "decode_varints diverged at n={n}");
        assert_eq!(fast, vals);
        assert_eq!(pos_fast, pos_ref, "cursor diverged at n={n}");
        assert_eq!(pos_fast, bytes.len());
    }
}

#[test]
fn scatter_f32_le_matches_scalar_on_all_tails() {
    let mut rng = Rng::new(0x57);
    for k in probe_lengths(&mut rng) {
        // a sparse Top-K shape: k kept values scattered over d slots,
        // strictly ascending indices as the wire layer guarantees
        let d = 4 * k + 7;
        let mut idx = Vec::with_capacity(k);
        let mut next = 0u32;
        for _ in 0..k {
            next += 1 + rng.below(4) as u32;
            idx.push(next.min(d as u32 - 1));
        }
        idx.dedup();
        let vals = random_f32(&mut rng, idx.len(), 2.0);
        let mut bytes = Vec::new();
        simd::pack_f32_le(&vals, &mut bytes);
        // extra trailing bytes must be ignored, exactly k values read
        bytes.extend_from_slice(&[0xEE; 5]);

        let mut fast = vec![0.125f32; d];
        let mut refr = vec![0.125f32; d];
        simd::scatter_f32_le(&bytes, &idx, &mut fast);
        simd::scalar::scatter_f32_le(&bytes, &idx, &mut refr);
        assert_eq!(bits(&fast), bits(&refr), "scatter_f32_le diverged at k={k}");
        for (i, v) in idx.iter().zip(&vals) {
            assert_eq!(fast[*i as usize].to_bits(), v.to_bits(), "k={k}");
        }
    }
}

#[test]
fn fold_kernels_match_scalar_on_all_tails() {
    let mut rng = Rng::new(0x55);
    for n in probe_lengths(&mut rng) {
        let x = random_f32(&mut rng, n, 1.5);
        let y = random_f32(&mut rng, n, 0.7);
        let w = 0.1 + rng.normal().abs() as f64 * 100.0;

        let mut fast = x.clone();
        let mut refr = x.clone();
        simd::add_assign(&mut fast, &y);
        simd::scalar::add_assign(&mut refr, &y);
        assert_eq!(bits(&fast), bits(&refr), "add_assign diverged at n={n}");

        let mut fast = x.clone();
        let mut refr = x.clone();
        simd::scale_f64(&mut fast, w);
        simd::scalar::scale_f64(&mut refr, w);
        assert_eq!(bits(&fast), bits(&refr), "scale_f64 diverged at n={n} w={w}");

        let mut fast = x.clone();
        let mut refr = x.clone();
        simd::div_f64(&mut fast, w);
        simd::scalar::div_f64(&mut refr, w);
        assert_eq!(bits(&fast), bits(&refr), "div_f64 diverged at n={n} w={w}");
    }
}

#[test]
fn invalid_symbols_rejected_at_every_position() {
    let mut rng = Rng::new(0x56);
    // an invalid symbol must be caught wherever it falls relative to the
    // vector block boundary — probe every lane of one 32-symbol block
    // plus a scalar tail
    for bad_at in (0..40).chain([63, 64, 100]) {
        let n = 101;
        let mut q = random_symbols(&mut rng, n);
        q[bad_at] = 2;
        let mut out = Vec::new();
        let err = simd::pack_2bit(&q, &mut out).unwrap_err();
        assert!(
            err.to_string().contains("is not in {-1, 0, 1}"),
            "bad_at={bad_at}: {err}"
        );
        // the 0b11 code on the unpack side, same positions
        let good = random_symbols(&mut rng, n);
        let mut packed = Vec::new();
        simd::scalar::pack_2bit(&good, &mut packed).unwrap();
        packed[bad_at / 4] |= 0b11 << (2 * (bad_at % 4));
        let mut dst = vec![0.0f32; n];
        assert!(
            simd::unpack_2bit_f32(&packed, n, 1.0, &mut dst).is_err(),
            "corrupt code at {bad_at} accepted"
        );
    }
}
