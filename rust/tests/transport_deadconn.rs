//! Liveness + accounting regressions for the transport layer
//! (DESIGN.md §8.6):
//!
//! 1. A connection that dies mid-round must leave the *timing* model
//!    untouched for the survivors: `transmitting` is the count of
//!    realized arrivals, not the pre-collection forecast.  The arm
//!    pins this with a `Deadline` whose `t_max_s` sits between the
//!    correct arrival time (uplink shared by the 4 realized uploads)
//!    and the inflated one a forecast of 8 would produce — counting
//!    the dead connection's uploads would halve every survivor's
//!    modelled rate and cut all of them.  The loopback records are
//!    checked field-by-field against an in-process replica of the
//!    server recipe suffering the same losses.
//! 2. A client that connects and never sends `Hello` is retired by the
//!    handshake timeout instead of wedging `accept_swarm` forever.
//! 3. A connection that accepts assignments and then goes silent is
//!    retired by the per-round deadline; its share becomes device
//!    losses, the round closes, and the next round reroutes.

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use hcfl::compression::wire::MsgType;
use hcfl::compression::{Compressor, Scheme, WireScratch, WireUpdate};
use hcfl::config::ExperimentConfig;
use hcfl::coordinator::clock::client_timing;
use hcfl::coordinator::pool::WorkerPool;
use hcfl::coordinator::session::ClientUpdate;
use hcfl::coordinator::{round_seed, CarryOver, FlSession};
use hcfl::data::{synthetic, FlData};
use hcfl::fl::{select_clients, Server};
use hcfl::metrics::RoundRecord;
use hcfl::network::{DeviceFleet, LinkModel};
use hcfl::prelude::*;
use hcfl::transport::{
    demo_config, engine_free_compressor, read_frame, run_swarm, write_frame, RoundOpenMsg,
    DEFAULT_MAX_FRAME,
};
use hcfl::util::rng::Rng;

/// The deterministic RoundRecord fields (timing fields are measured on
/// both paths and excluded by design — see `tests/transport_loopback.rs`).
fn assert_record_eq(a: &RoundRecord, b: &RoundRecord) {
    let t = a.round;
    assert_eq!(a.round, b.round);
    assert_eq!(a.up_bytes, b.up_bytes, "up_bytes diverged in round {t}");
    assert_eq!(a.down_bytes, b.down_bytes, "down_bytes diverged in round {t}");
    assert_eq!(a.selected, b.selected, "selected diverged in round {t}");
    assert_eq!(a.completed, b.completed, "completed diverged in round {t}");
    assert_eq!(a.dropped, b.dropped, "dropped diverged in round {t}");
    assert_eq!(a.stragglers, b.stragglers, "stragglers diverged in round {t}");
    assert_eq!(a.carried_in, b.carried_in, "carried_in diverged in round {t}");
    assert_eq!(a.carried_out, b.carried_out, "carried_out diverged in round {t}");
    assert_eq!(
        a.carried_expired, b.carried_expired,
        "carried_expired diverged in round {t}"
    );
    assert_eq!(a.recon_mse, b.recon_mse, "recon_mse diverged in round {t}");
}

/// An in-process replica of the `RoundServer` recipe that can lose an
/// arbitrary subset of each round's assignments, standing in for a
/// connection that died mid-round.  Everything else — selection,
/// dropout stream, fake-train math, codec, timing pump — is the shared
/// deterministic recipe, so its records are the ground truth a lossy
/// loopback round must reproduce.
struct LossyReplica {
    cfg: ExperimentConfig,
    session: FlSession,
    carry: CarryOver,
    fleet: DeviceFleet,
    pool: WorkerPool,
    rng: Rng,
    compressor: std::sync::Arc<dyn Compressor>,
    data: FlData,
}

impl LossyReplica {
    fn new(manifest: &Manifest, cfg: ExperimentConfig) -> LossyReplica {
        let model = manifest.model(&cfg.model).unwrap().clone();
        let mut rng = Rng::new(cfg.seed);
        let server = Server::new(&model, &mut rng);
        let fleet = DeviceFleet::sample(cfg.n_clients, &cfg.scenario.devices, cfg.seed);
        let compressor = engine_free_compressor(&cfg.scheme).unwrap();
        let session = FlSession::new(
            server,
            compressor.clone(),
            cfg.scenario.aggregator.clone(),
            cfg.scenario.carry.clone(),
            cfg.encode_deltas,
            cfg.compress_downlink,
        );
        let pool = WorkerPool::new(cfg.client_threads, cfg.engine_workers).unwrap();
        let data = synthetic(&cfg.data, cfg.seed);
        LossyReplica {
            cfg,
            session,
            carry: CarryOver::empty(),
            fleet,
            pool,
            rng,
            compressor,
            data,
        }
    }

    /// Run round `t`, losing every assignment whose index satisfies
    /// `lost` (the loopback analogue: assignment i rides connection
    /// `live[i % live.len()]`, so a dead connection loses a residue
    /// class).
    fn run_round(&mut self, t: usize, lost: impl Fn(usize) -> bool) -> RoundRecord {
        let selected = select_clients(self.cfg.n_clients, self.cfg.participation, &mut self.rng);
        let m = selected.len();
        self.session.set_scenario(
            self.cfg.scenario.aggregator.clone(),
            self.cfg.scenario.carry.clone(),
        );
        let carry = std::mem::take(&mut self.carry);
        let mut round = self.session.begin_round(t, carry).unwrap();

        let seed = round_seed(self.cfg.seed, t);
        let mut drop_rng = Rng::new(seed ^ 0x0D10_D0A7_5EED_0001);
        let dropped: Vec<bool> = selected
            .iter()
            .map(|&k| drop_rng.next_f64() < self.fleet.profile(k).dropout_p)
            .collect();
        let specs: Vec<(usize, usize, u64)> = selected
            .iter()
            .enumerate()
            .filter(|&(slot, _)| !dropped[slot])
            .map(|(slot, &k)| (slot, k, seed ^ ((k as u64) << 1)))
            .collect();

        // Fake-train + encode the assignments that "arrived" — the
        // exact swarm-worker computation, seeded identically.
        let global: Vec<f32> = round.global().as_ref().clone();
        let mut scratch = WireScratch::new();
        let mut results: Vec<Option<(Vec<u8>, usize, f64)>> = vec![None; m];
        for (i, &(slot, k, wseed)) in specs.iter().enumerate() {
            if lost(i) {
                continue;
            }
            let mut crng = Rng::new(wseed);
            let started = Instant::now();
            let scale = self.cfg.lr * (self.cfg.local_epochs.max(1) as f32).sqrt() * 0.1;
            let params: Vec<f32> = global.iter().map(|g| g + scale * crng.normal()).collect();
            let payload = self
                .compressor
                .encode_payload(&params, &global, self.cfg.encode_deltas);
            let update = self.compressor.compress(&payload, 0).unwrap();
            let wire = scratch.pack_update(&update.payload).unwrap();
            let train_s = started.elapsed().as_secs_f64();
            results[slot] = Some((wire.bytes, self.data.shard_rows(k), train_s));
        }

        // Timing pump: transmitting = realized arrivals, exactly the
        // rule the loopback server must follow when connections die.
        let measured: Vec<f64> = results
            .iter()
            .flatten()
            .map(|&(_, _, train_s)| train_s)
            .collect();
        let reference_compute_s = if measured.is_empty() {
            0.0
        } else {
            measured.iter().sum::<f64>() / measured.len() as f64
        };
        let transmitting = measured.len();
        let down_bytes = round.down_bytes();
        for (slot, &k) in selected.iter().enumerate() {
            let up = results[slot].as_ref().map(|(w, _, _)| w.len()).unwrap_or(0);
            let timing = client_timing(
                &self.cfg.link,
                self.fleet.profile(k),
                k,
                slot,
                up,
                down_bytes,
                reference_compute_s,
                m,
                transmitting,
                results[slot].is_none(),
            );
            match results[slot].take() {
                Some((wire, n_samples, train_s)) => round.submit(ClientUpdate {
                    payload: WireUpdate { bytes: wire },
                    n_samples,
                    timing,
                    exact: Vec::new(),
                    extra_up_bytes: 0,
                    train_s,
                    codec: self.cfg.scheme.codec_tag(),
                }),
                None => round.mark_dropped(timing),
            }
        }

        let resolved = round.resolve(&self.cfg.scenario.policy);
        let (rec, carry) = resolved.finalize(&self.pool).unwrap();
        self.carry = carry;
        rec
    }
}

/// The byte length of one Fedavg (identity-codec) update on the wire —
/// content-independent, so one probe encode prices every client.
fn fedavg_wire_len(cfg: &ExperimentConfig, d: usize) -> usize {
    let comp = engine_free_compressor(&cfg.scheme).unwrap();
    let zeros = vec![0.0f32; d];
    let payload = comp.encode_payload(&zeros, &zeros, cfg.encode_deltas);
    let update = comp.compress(&payload, 0).unwrap();
    WireScratch::new()
        .pack_update(&update.payload)
        .unwrap()
        .bytes
        .len()
}

/// Regression pin for the `transmitting` fix: a connection dying
/// mid-round must not inflate the survivors' modelled uplink share.
/// The deadline is placed halfway between the correct arrival (cell
/// shared by the 4 realized uploads) and the arrival a stale forecast
/// of 8 would model — under the old accounting every survivor misses
/// the deadline and the round collapses to zero completions.
#[test]
fn dead_connection_keeps_survivor_timing_honest() {
    let mut cfg = demo_config(Scheme::Fedavg, 8, 2, 42);
    let manifest = Manifest::synthetic();
    let d = RoundServer::new(&manifest, cfg.clone()).unwrap().global().len();
    let wire_len = fedavg_wire_len(&cfg, d);

    // Price the link so 4 transmitters put one update on the air in
    // exactly 1 s (and a stale forecast of 8 would model 2 s), then
    // split the difference with the deadline: the margin on either
    // side is ~0.5 s of modelled air time against microseconds of
    // measured-compute jitter.
    cfg.link = LinkModel {
        uplink_bps: (wire_len * 8 * 4) as f64,
        downlink_bps: cfg.link.downlink_bps,
    };
    let fleet = DeviceFleet::sample(cfg.n_clients, &cfg.scenario.devices, cfg.seed);
    let down_bytes = 4 * d; // compress_downlink is off in demo_config
    let arrive = |tx: usize| {
        client_timing(
            &cfg.link,
            fleet.profile(0),
            0,
            0,
            wire_len,
            down_bytes,
            0.0,
            8,
            tx,
            false,
        )
        .arrival_s()
    };
    cfg.scenario.policy = RoundPolicy::Deadline {
        t_max_s: 0.5 * (arrive(4) + arrive(8)),
    };

    // TCP path: the evil connection handshakes first (so it is conn 0,
    // owning assignment indices i % 2 == 0), then garbles mid-round 1.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut server = RoundServer::new(&manifest, cfg.clone()).unwrap();
    let mut evil = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut evil,
        MsgType::Hello,
        cfg.scheme.codec_tag(),
        0,
        0,
        0,
        &[],
    )
    .unwrap();
    let evil_thread = std::thread::spawn(move || {
        use std::io::Write;
        let open = read_frame(&mut evil, DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(open.header.msg_type, MsgType::RoundOpen);
        let assigned = RoundOpenMsg::decode(&open.payload).unwrap().assignments.len();
        evil.write_all(&[0xFF; 64]).unwrap(); // not a frame
        let _ = evil.flush();
        assigned
    });
    let swarm_cfg = cfg.clone();
    let swarm_addr = addr.clone();
    let honest = std::thread::spawn(move || run_swarm(&swarm_addr, &swarm_cfg, 1, 0.0).unwrap());
    let records = server.serve(&listener, 2, 2).unwrap();
    assert_eq!(evil_thread.join().unwrap(), 4);
    let stats = honest.join().unwrap();

    // Round 1: the honest half beats the honest deadline.  Under the
    // stale-forecast bug their modelled uplink takes 2x longer and all
    // four are cut as stragglers instead.
    assert_eq!(records[0].selected, 8);
    assert_eq!(records[0].dropped, 4, "dead connection's share is lost");
    assert_eq!(records[0].completed, 4, "survivors must beat the deadline");
    assert_eq!(records[0].stragglers, 0);
    // Round 2: all 8 reroute to the live connection; with 8 realized
    // transmitters the shared cell halves every rate and the same
    // deadline now cuts everyone — the fix must price round 2 at 8.
    assert_eq!(records[1].completed, 0);
    assert_eq!(records[1].stragglers, 8);
    assert_eq!(records[1].dropped, 0);
    assert_eq!(stats.updates_sent, 4 + 8);

    // Field-by-field against the in-process replica with the same
    // losses: round 1 loses conn 0's residue class, round 2 nothing.
    let mut replica = LossyReplica::new(&manifest, cfg.clone());
    let r1 = replica.run_round(1, |i| i % 2 == 0);
    let r2 = replica.run_round(2, |_| false);
    assert_record_eq(&r1, &records[0]);
    assert_record_eq(&r2, &records[1]);
}

/// A client that connects and never says `Hello` is retired by the
/// handshake timeout; the swarm queued behind it is served normally.
/// Before the timeout existed this wedged `accept_swarm` forever.
#[test]
fn stalled_pre_hello_client_cannot_wedge_the_server() {
    let cfg = demo_config(Scheme::Fedavg, 8, 1, 42);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut server = RoundServer::new(&Manifest::synthetic(), cfg.clone()).unwrap();
    server.set_handshake_timeout(Some(Duration::from_millis(250)));

    // Connects first (so it is accepted first) and stays silent.
    let stalled = TcpStream::connect(&addr).unwrap();
    let swarm_cfg = cfg.clone();
    let swarm_addr = addr.clone();
    let honest = std::thread::spawn(move || run_swarm(&swarm_addr, &swarm_cfg, 1, 0.0).unwrap());

    let records = server.serve(&listener, 2, 1).unwrap();
    let stats = honest.join().unwrap();
    drop(stalled);

    assert_eq!(records.len(), 1);
    assert_eq!(records[0].selected, 8);
    assert_eq!(records[0].completed, 8, "all work reroutes past the stall");
    assert_eq!(records[0].dropped, 0);
    assert_eq!(stats.updates_sent, 8);
}

/// A connection that takes assignments and then goes silent mid-round
/// is retired by the per-round deadline: its share becomes device
/// losses, the round closes with what arrived, and the next round
/// reroutes everything to the survivor.
#[test]
fn silent_mid_round_stall_is_cut_by_the_round_deadline() {
    let cfg = demo_config(Scheme::Fedavg, 8, 2, 42);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut server = RoundServer::new(&Manifest::synthetic(), cfg.clone()).unwrap();
    server.set_round_deadline(Some(Duration::from_millis(750)));

    // Handshakes first (conn 0), accepts round 1's assignments, then
    // never replies — the socket stays open, so only the deadline can
    // retire it.
    let mut mute = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut mute,
        MsgType::Hello,
        cfg.scheme.codec_tag(),
        0,
        0,
        0,
        &[],
    )
    .unwrap();
    let mute_thread = std::thread::spawn(move || {
        let open = read_frame(&mut mute, DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(open.header.msg_type, MsgType::RoundOpen);
        // Hold the connection open and silent until the server tears it
        // down at the deadline.
        while read_frame(&mut mute, DEFAULT_MAX_FRAME).is_ok() {}
    });
    let swarm_cfg = cfg.clone();
    let swarm_addr = addr.clone();
    let honest = std::thread::spawn(move || run_swarm(&swarm_addr, &swarm_cfg, 1, 0.0).unwrap());

    let records = server.serve(&listener, 2, 2).unwrap();
    mute_thread.join().unwrap();
    let stats = honest.join().unwrap();

    assert_eq!(records[0].selected, 8);
    assert_eq!(records[0].completed, 4, "the honest half arrived in time");
    assert_eq!(records[0].dropped, 4, "the mute half expired at the deadline");
    assert_eq!(records[1].completed, 8, "round 2 reroutes past the dead conn");
    assert_eq!(records[1].dropped, 0);
    assert_eq!(stats.updates_sent, 4 + 8);
}
