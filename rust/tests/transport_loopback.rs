//! Transport acceptance: a real TCP loopback session (RoundServer +
//! swarm workers) must be bit-identical to the in-process `Simulation`
//! driver — same global model bits, same deterministic `RoundRecord`
//! fields — because both sides derive everything from the shared config
//! seed and only seeds, slots and packed wire buffers cross the socket.
//!
//! Measured wall-clock fields (makespan, client/server/comm/wall time)
//! are excluded: they depend on host timing on both paths.  The
//! scenarios are chosen so every *decision* made from measured time has
//! a deterministic margin (see each arm's comment): survivor ordering
//! and carry decisions ride on modelled byte air-times (milliseconds)
//! while run-to-run measurement jitter is microseconds.

use hcfl::compression::Scheme;
use hcfl::data::Partition;
use hcfl::metrics::RoundRecord;
use hcfl::prelude::*;
use hcfl::transport::{demo_config, run_loopback, LoopbackRun};

/// Drive the classic in-process path for `cfg.rounds` rounds.
fn run_inprocess(cfg: &ExperimentConfig) -> (Vec<f32>, Vec<RoundRecord>) {
    let engine = Engine::with_manifest(Manifest::synthetic(), cfg.engine_workers).unwrap();
    let mut sim = Simulation::new(&engine, cfg.clone()).unwrap();
    let mut recs = Vec::with_capacity(cfg.rounds);
    for t in 1..=cfg.rounds {
        recs.push(sim.run_round(t).unwrap());
    }
    (sim.global().to_vec(), recs)
}

/// Drive the same config over real localhost sockets.
fn run_over_tcp(cfg: &ExperimentConfig, workers: usize) -> LoopbackRun {
    run_loopback(&Manifest::synthetic(), cfg, workers, 0.0).unwrap()
}

/// Every deterministic RoundRecord field must agree between the two
/// paths; timing fields are measured and excluded by design.
fn assert_records_match(inproc: &[RoundRecord], tcp: &[RoundRecord]) {
    assert_eq!(inproc.len(), tcp.len());
    for (a, b) in inproc.iter().zip(tcp) {
        let t = a.round;
        assert_eq!(a.round, b.round);
        assert_eq!(a.up_bytes, b.up_bytes, "up_bytes diverged in round {t}");
        assert_eq!(a.down_bytes, b.down_bytes, "down_bytes diverged in round {t}");
        assert_eq!(a.selected, b.selected, "selected diverged in round {t}");
        assert_eq!(a.completed, b.completed, "completed diverged in round {t}");
        assert_eq!(a.dropped, b.dropped, "dropped diverged in round {t}");
        assert_eq!(a.stragglers, b.stragglers, "stragglers diverged in round {t}");
        assert_eq!(a.carried_in, b.carried_in, "carried_in diverged in round {t}");
        assert_eq!(a.carried_out, b.carried_out, "carried_out diverged in round {t}");
        assert_eq!(
            a.carried_expired, b.carried_expired,
            "carried_expired diverged in round {t}"
        );
        assert_eq!(a.recon_mse, b.recon_mse, "recon_mse diverged in round {t}");
    }
}

/// FastestM + stragglers + carry-over across 4 rounds: the carried-leaf
/// path (weights, fold order, re-carry, expiry) must survive the wire.
/// m=16 of K=32 with 25% stragglers at 8x guarantees the cut boundary
/// falls inside the non-straggler group (its ordering is decided by
/// deterministic per-client wire bytes, not measured time), and cut
/// non-stragglers rebase to near-zero arrivals that fold next round —
/// so carried_in is structurally nonzero.
#[test]
fn loopback_carryover_session_is_bit_identical() {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.2 }, 32, 4, 42);
    cfg.data.size_skew = 0.25;
    cfg.scenario.policy = RoundPolicy::FastestM { m: 16 };
    cfg.scenario.devices = DevicePreset::Stragglers {
        frac: 0.25,
        slowdown: 8.0,
    };
    cfg.scenario.carry = CarryPolicy::CarryDiscounted {
        lambda: 0.5,
        max_age_rounds: 3,
    };
    cfg.scenario.aggregator = AggregatorKind::SampleWeighted;

    let (global, recs) = run_inprocess(&cfg);
    let tcp = run_over_tcp(&cfg, 3);

    assert_eq!(global, tcp.global, "global model bits diverged");
    assert_records_match(&recs, &tcp.records);
    let carried: usize = recs.iter().map(|r| r.carried_in).sum();
    assert!(carried > 0, "the carry arm never exercised carry-over");
    assert_eq!(tcp.swarm.rounds, 4);
    assert_eq!(
        tcp.swarm.updates_sent,
        recs.iter().map(|r| r.selected - r.dropped).sum::<usize>()
    );
}

/// Seeded per-round dropouts over the wire: dropped devices are never
/// assigned, the swarm replays nothing for them, and both paths account
/// the same losses.  sigma=0 keeps every rate multiplier at exactly 1,
/// so arrival order is decided by wire bytes + slot only and the arm is
/// immune to measured-time jitter even with real dropouts.
#[test]
fn loopback_dropouts_are_bit_identical() {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.1 }, 48, 2, 42);
    cfg.scenario.devices = DevicePreset::Iot {
        sigma: 0.0,
        dropout_p: 0.2,
    };

    let (global, recs) = run_inprocess(&cfg);
    let tcp = run_over_tcp(&cfg, 2);

    assert_eq!(global, tcp.global, "global model bits diverged");
    assert_records_match(&recs, &tcp.records);
    let dropped: usize = recs.iter().map(|r| r.dropped).sum();
    assert!(dropped > 0, "the dropout arm never dropped a device");
}

/// The exact-params sidecar is config-driven and accounted: with
/// `send_exact = true` the wire path ships the raw f32s next to every
/// compressed payload and bills them into `up_bytes` (4-byte count +
/// 4·d payload per arrival), while the in-process path measures the
/// codec wire alone — so the two paths differ by exactly the sidecar
/// bytes and agree on everything else, including a nonzero
/// reconstruction MSE computed from the very same sidecar.
/// Synchronous policy + homogeneous devices: every selected client
/// arrives and aggregation ignores arrival order, so the constant
/// per-update byte shift cannot change any decision.
#[test]
fn loopback_exact_sidecar_is_accounted_in_up_bytes() {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.2 }, 24, 2, 42);
    cfg.send_exact = true;

    let (global, recs) = run_inprocess(&cfg);
    let tcp = run_over_tcp(&cfg, 2);

    assert_eq!(global, tcp.global, "global model bits diverged");
    let d = tcp.global.len() as u64;
    assert_eq!(recs.len(), tcp.records.len());
    for (a, b) in recs.iter().zip(&tcp.records) {
        let t = a.round;
        assert_eq!(a.dropped, 0, "homogeneous arm must not drop");
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.stragglers, b.stragglers);
        assert_eq!(a.down_bytes, b.down_bytes);
        assert_eq!(a.recon_mse, b.recon_mse, "recon_mse diverged in round {t}");
        assert!(
            a.recon_mse > 0.0,
            "TopK keep<1 must reconstruct with loss, round {t}"
        );
        let arrivals = (a.selected - a.dropped) as u64;
        assert_eq!(
            b.up_bytes,
            a.up_bytes + arrivals * (4 + 4 * d),
            "round {t}: wire up_bytes must equal codec bytes plus the \
             accounted sidecar (4-byte count + 4·d per arrival)"
        );
    }
}

/// The control plane over the wire (DESIGN.md §11): a ThresholdByUplink
/// policy splits the heterogeneous IoT fleet between the TopK base
/// codec and the ternary reference codec, with FedAdam applied
/// server-side between fold and install — and the TCP path must still
/// land on the in-process global bits, for any connection/thread split
/// and with the edge-sharded fold on.  Policy decisions are pure
/// functions of (round seed, fleet, config), so both endpoints derive
/// the same per-slot codec without it ever crossing the wire as more
/// than a one-byte tag.
#[test]
fn loopback_mixed_codec_control_plane_is_bit_identical() {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.2 }, 32, 3, 42);
    cfg.scenario.devices = DevicePreset::Iot {
        sigma: 0.8,
        dropout_p: 0.0,
    };
    cfg.codec_policy = CodecPolicy::ThresholdByUplink {
        cutoff: 1.0,
        slow: Scheme::Ternary,
    };
    cfg.server_opt = ServerOptKind::DEFAULT_ADAM;

    let (global, recs) = run_inprocess(&cfg);

    // The policy must actually split the fleet: the same fleet under
    // the static single-codec plane ships a different byte total.
    let mut static_cfg = cfg.clone();
    static_cfg.codec_policy = CodecPolicy::Static;
    let (_, static_recs) = run_inprocess(&static_cfg);
    assert_ne!(
        recs.iter().map(|r| r.up_bytes).sum::<u64>(),
        static_recs.iter().map(|r| r.up_bytes).sum::<u64>(),
        "the uplink policy never moved a client off the base codec"
    );

    // Worker count, pool width and edge sharding are all declared
    // bit-transparent; the mixed-codec session must hold that over TCP.
    for (workers, threads, edge) in [(2usize, 4usize, 0usize), (3, 1, 4)] {
        let mut arm = cfg.clone();
        arm.client_threads = threads;
        arm.edge_shards = edge;
        let tcp = run_over_tcp(&arm, workers);
        assert_eq!(
            global, tcp.global,
            "global bits diverged (workers={workers}, threads={threads}, edge={edge})"
        );
        assert_records_match(&recs, &tcp.records);
    }
}

/// The issue's acceptance bar: one K=10 000 round over real sockets,
/// bit-identical to the in-process K=10k pin (`tests/round10k.rs`
/// configuration: non-IID Dirichlet shards, skewed sizes,
/// sample-weighted aggregation).
#[test]
fn loopback_k10000_round_is_bit_identical() {
    let mut cfg = demo_config(Scheme::TopK { keep: 0.1 }, 10_000, 1, 42);
    cfg.data.partition = Partition::Dirichlet { alpha: 0.3 };
    cfg.data.size_skew = 0.25;
    cfg.scenario.aggregator = AggregatorKind::SampleWeighted;

    let (global, recs) = run_inprocess(&cfg);
    let tcp = run_over_tcp(&cfg, 4);

    assert_eq!(recs[0].selected, 10_000);
    assert!(tcp.global.iter().all(|v| v.is_finite()));
    assert_eq!(global, tcp.global, "global model bits diverged at K=10k");
    assert_records_match(&recs, &tcp.records);
    assert_eq!(tcp.swarm.updates_sent, 10_000 - recs[0].dropped);
}
