//! Hardening pins for the transport boundary (DESIGN.md §8.6): every
//! malformed-frame class must surface as a typed error — never a panic,
//! never an oversized allocation — and a live server must retire the
//! offending connection while keeping the round open and completing it
//! with the honest connections.

use std::io::{Cursor, Write};
use std::net::{TcpListener, TcpStream};

use hcfl::compression::wire::{FrameHeader, MsgType, FLAG_EXACT_PARAMS, FRAME_HEADER_LEN};
use hcfl::compression::Scheme;
use hcfl::error::HcflError;
use hcfl::prelude::*;
use hcfl::transport::{
    demo_config, read_frame, write_frame, RoundOpenMsg, UpdateMsg, DEFAULT_MAX_FRAME,
};

fn packed_frame(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    write_frame(
        &mut buf,
        MsgType::Update,
        3,
        FLAG_EXACT_PARAMS,
        2,
        7,
        payload,
    )
    .unwrap();
    buf
}

#[test]
fn truncated_header_is_an_io_error() {
    let buf = packed_frame(b"abc");
    for cut in 0..FRAME_HEADER_LEN {
        let err = read_frame(&mut Cursor::new(&buf[..cut]), DEFAULT_MAX_FRAME).unwrap_err();
        assert!(matches!(err, HcflError::Io(_)), "cut={cut}: {err}");
    }
}

#[test]
fn bad_magic_and_bad_version_are_rejected() {
    let mut bad_magic = packed_frame(b"abc");
    bad_magic[0] ^= 0xFF;
    assert!(read_frame(&mut Cursor::new(&bad_magic), DEFAULT_MAX_FRAME).is_err());

    let mut bad_version = packed_frame(b"abc");
    bad_version[4] = 99;
    assert!(read_frame(&mut Cursor::new(&bad_version), DEFAULT_MAX_FRAME).is_err());

    let mut bad_type = packed_frame(b"abc");
    bad_type[5] = 0; // no MsgType is 0
    assert!(read_frame(&mut Cursor::new(&bad_type), DEFAULT_MAX_FRAME).is_err());
}

#[test]
fn checksum_mismatch_is_rejected() {
    let mut buf = packed_frame(b"checksummed payload");
    let last = buf.len() - 1;
    buf[last] ^= 0x01;
    let err = read_frame(&mut Cursor::new(&buf), DEFAULT_MAX_FRAME).unwrap_err();
    assert!(
        matches!(&err, HcflError::Config(msg) if msg.contains("checksum")),
        "{err}"
    );
}

#[test]
fn oversized_declared_length_is_rejected_before_reading() {
    // A forged header declaring a payload beyond the cap: rejected from
    // the header alone, so no payload bytes (and no allocation of the
    // declared size) are ever consumed.
    let header = FrameHeader {
        msg_type: MsgType::Update,
        codec: 0,
        flags: 0,
        round: 1,
        client: 0,
        len: u32::MAX,
        crc: 0,
    };
    let err = read_frame(&mut Cursor::new(header.pack().to_vec()), DEFAULT_MAX_FRAME).unwrap_err();
    assert!(
        matches!(&err, HcflError::Config(msg) if msg.contains("cap")),
        "{err}"
    );
}

#[test]
fn mid_frame_disconnect_is_an_io_error() {
    let buf = packed_frame(&[0xAB; 100]);
    // the peer vanished 40 payload bytes in
    let err =
        read_frame(&mut Cursor::new(&buf[..FRAME_HEADER_LEN + 40]), DEFAULT_MAX_FRAME).unwrap_err();
    assert!(matches!(err, HcflError::Io(_)), "{err}");
}

#[test]
fn update_payload_truncations_are_rejected() {
    let msg = UpdateMsg {
        slot: 1,
        client: 5,
        n_samples: 64,
        train_s: 0.25,
        wire: vec![9, 8, 7, 6],
        exact: vec![1.0, -1.0],
    };
    let good = msg.encode();
    assert_eq!(UpdateMsg::decode(&good, true).unwrap(), msg);
    for cut in 0..good.len() {
        assert!(UpdateMsg::decode(&good[..cut], true).is_err(), "cut={cut}");
    }
    let mut trailing = good;
    trailing.push(0);
    assert!(UpdateMsg::decode(&trailing, true).is_err());
}

/// A syntactically perfect Update whose envelope codec tag disagrees
/// with the slot's control-plane assignment (DESIGN.md §11): the server
/// must refuse to decode it with either codec and retire exactly that
/// connection, while the round stays open and completes with the honest
/// peer.
#[test]
fn forged_codec_tag_retires_only_the_offending_connection() {
    let cfg = demo_config(Scheme::Fedavg, 8, 2, 42);
    let manifest = Manifest::synthetic();
    let mut server = RoundServer::new(&manifest, cfg.clone()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let server_thread = std::thread::spawn(move || {
        let records = server.serve(&listener, 2, 2).unwrap();
        (records, server.into_global())
    });

    let swarm_cfg = cfg.clone();
    let swarm_addr = addr.clone();
    let honest = std::thread::spawn(move || run_swarm(&swarm_addr, &swarm_cfg, 1, 0.0).unwrap());

    // Forger: a correct Hello, then a well-formed Update for its own
    // assigned slot — but the envelope claims the ternary codec while
    // the static control plane assigned fedavg to every slot.
    let mut evil = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut evil,
        MsgType::Hello,
        cfg.scheme.codec_tag(),
        0,
        0,
        1,
        &[],
    )
    .unwrap();
    let open = read_frame(&mut evil, DEFAULT_MAX_FRAME).unwrap();
    assert_eq!(open.header.msg_type, MsgType::RoundOpen);
    let a = RoundOpenMsg::decode(&open.payload).unwrap().assignments[0];
    assert_eq!(a.codec, Scheme::Fedavg.codec_tag());
    let update = UpdateMsg {
        slot: a.slot,
        client: a.client,
        n_samples: 64,
        train_s: 0.01,
        wire: vec![0; 16],
        exact: Vec::new(),
    };
    write_frame(
        &mut evil,
        MsgType::Update,
        Scheme::Ternary.codec_tag(),
        0,
        1,
        a.client,
        &update.encode(),
    )
    .unwrap();
    let _ = evil.flush();
    // The server must close this connection, not the round: the next
    // read hits EOF/reset (a retired socket) instead of a round-2 open.
    assert!(read_frame(&mut evil, DEFAULT_MAX_FRAME).is_err());
    drop(evil);

    let (records, global) = server_thread.join().unwrap();
    let stats = honest.join().unwrap();

    // Round 1: the honest half aggregated, the forger's half lost.
    assert_eq!(records[0].selected, 8);
    assert_eq!(records[0].completed, 4);
    assert_eq!(records[0].dropped, 4);
    // Round 2: everything reroutes to the surviving connection.
    assert_eq!(records[1].completed, 8);
    assert_eq!(records[1].dropped, 0);
    assert!(global.iter().all(|v| v.is_finite()));
    assert_eq!(stats.rounds, 2);
    assert_eq!(stats.updates_sent, 4 + 8);
}

/// A server with one honest swarm connection and one misbehaving
/// connection: the garbage sender is retired mid-round, its share of
/// the round is accounted as device losses, the round completes, and
/// the next round reassigns everything to the surviving connection.
#[test]
fn server_survives_a_garbage_connection_and_keeps_rounds_open() {
    let cfg = demo_config(Scheme::Fedavg, 8, 2, 42);
    let manifest = Manifest::synthetic();
    let mut server = RoundServer::new(&manifest, cfg.clone()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let server_thread = std::thread::spawn(move || {
        let records = server.serve(&listener, 2, 2).unwrap();
        (records, server.into_global())
    });

    // Honest connection: a 1-worker swarm replaying the same config.
    let swarm_cfg = cfg.clone();
    let swarm_addr = addr.clone();
    let honest = std::thread::spawn(move || run_swarm(&swarm_addr, &swarm_cfg, 1, 0.0).unwrap());

    // Misbehaving connection: a correct Hello, then garbage mid-round.
    let mut evil = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut evil,
        MsgType::Hello,
        cfg.scheme.codec_tag(),
        0,
        0,
        1,
        &[],
    )
    .unwrap();
    let open = read_frame(&mut evil, DEFAULT_MAX_FRAME).unwrap();
    assert_eq!(open.header.msg_type, MsgType::RoundOpen);
    let assigned = RoundOpenMsg::decode(&open.payload).unwrap().assignments.len();
    assert_eq!(assigned, 4, "round-robin should hand each conn half of m=8");
    evil.write_all(&[0xFF; 64]).unwrap(); // not a frame
    let _ = evil.flush();
    drop(evil);

    let (records, global) = server_thread.join().unwrap();
    let stats = honest.join().unwrap();

    // Round 1: the honest half aggregated, the garbage half lost.
    assert_eq!(records[0].selected, 8);
    assert_eq!(records[0].completed, 4);
    assert_eq!(records[0].dropped, 4);
    // Round 2: the dead connection is gone; everything reroutes.
    assert_eq!(records[1].completed, 8);
    assert_eq!(records[1].dropped, 0);
    assert!(global.iter().all(|v| v.is_finite()));
    assert_eq!(stats.rounds, 2);
    assert_eq!(stats.updates_sent, 4 + 8);
}
